"""End-to-end observability smoke against a real server process.

Launches ``repro.launch.serve --serve --disagg --trace-out`` as a
subprocess, drives two concurrent ``POST /generate`` streams, scrapes
``GET /metrics`` (Prometheus content type, counters present and
monotonic) and ``GET /stats/v2``, then SIGINTs the server and validates
the exported Chrome trace: parseable trace-event JSON, the engine-step /
prefill-pool / kv-handoff lanes all present, spans monotonically nested
per lane, exactly one ``req.finish`` per request — and (the disagg
payoff, printed) measurable wall-clock overlap between prefill-chunk
compute on the prefill-pool lane and decode quanta on the engine lane.

CI runs this as the observability gate next to the unit tests:

    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
        PYTHONPATH=src python scripts/server_smoke.py
"""
from __future__ import annotations

import asyncio
import json
import os
import signal
import socket
import subprocess
import sys
import tempfile
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

EXPECT_LANES = {"engine-step_0", "kv-handoff"}
EXPECT_SPANS = {"engine.step", "decode.round", "handoff.ship", "req.finish"}


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


async def _request(port, method, path, body=b""):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(f"{method} {path} HTTP/1.1\r\nHost: s\r\n"
                 f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
    await writer.drain()
    data = await reader.read()
    writer.close()
    try:
        await writer.wait_closed()
    except (ConnectionResetError, BrokenPipeError):
        pass
    head, _, payload = data.partition(b"\r\n\r\n")
    lines = head.decode().split("\r\n")
    headers = {}
    for ln in lines[1:]:
        k, _, v = ln.partition(":")
        headers[k.strip().lower()] = v.strip()
    return lines[0], headers, payload


async def _generate(port, prompt, max_new, request_id):
    body = json.dumps({"prompt": prompt, "max_new": max_new,
                       "request_id": request_id}).encode()
    status, _, payload = await _request(port, "POST", "/generate", body)
    assert status.startswith("HTTP/1.1 200"), status
    events = [json.loads(c[len(b"data: "):])
              for c in payload.split(b"\n\n") if c.startswith(b"data: ")]
    assert events and events[-1]["finished"], events
    assert events[-1]["finish_reason"] in ("stop", "length"), events[-1]
    n = sum(len(e["new_token_ids"]) for e in events)
    assert n == max_new, (n, max_new)
    return events


def _counter_value(text: str, name: str) -> float:
    for line in text.splitlines():
        if line.startswith(name + " ") or line.startswith(name + "{"):
            return float(line.rsplit(" ", 1)[1])
    raise AssertionError(f"metric {name} not found in /metrics output")


async def drive(port: int) -> None:
    deadline = time.time() + 120
    while True:  # wait for the socket
        try:
            status, _, _ = await _request(port, "GET", "/stats")
            if status.startswith("HTTP/1.1 200"):
                break
        except OSError:
            pass
        if time.time() > deadline:
            raise TimeoutError("server never came up")
        await asyncio.sleep(0.25)

    status, headers, payload = await _request(port, "GET", "/metrics")
    assert status.startswith("HTTP/1.1 200"), status
    ctype = headers.get("content-type", "")
    assert ctype.startswith("text/plain") and "version=0.0.4" in ctype, ctype
    before = payload.decode()
    tok_before = _counter_value(before, "repro_decode_tokens_total")
    assert _counter_value(before, "repro_trace_enabled") == 1.0

    # two concurrent streams: a long chunked prefill + a decoder, so the
    # trace has chunk compute overlapping decode quanta
    await asyncio.gather(
        _generate(port, [2 + i % 251 for i in range(96)], 8, "smoke-long"),
        _generate(port, list(range(3, 11)), 24, "smoke-dec"),
    )

    status, _, payload = await _request(port, "GET", "/metrics")
    after = payload.decode()
    # 20 tokens streamed, but each request's FIRST token is sampled from
    # prefill logits — only the rest count as decode-round tokens
    tok_after = _counter_value(after, "repro_decode_tokens_total")
    assert tok_after >= tok_before + 18, (tok_before, tok_after)
    for needle in ("repro_ttft_seconds{quantile=", "repro_itl_seconds{",
                   "repro_roofline_residency_ratio{phase=",
                   "repro_handoff_segments_total",
                   "repro_frontend_accepted_total"):
        assert needle in after, f"missing {needle} in /metrics"

    status, _, payload = await _request(port, "GET", "/stats/v2")
    assert status.startswith("HTTP/1.1 200"), status
    v2 = json.loads(payload)
    assert v2["schema"] == "v2"
    assert v2["counters"]["repro_decode_tokens_total"] >= 18
    print("HTTP surface OK: /metrics (prometheus 0.0.4), /stats/v2, "
          f"{tok_after - tok_before:.0f} tokens decoded during the smoke")


def validate_trace(path: str) -> None:
    data = json.loads(Path(path).read_text())
    evs = data["traceEvents"]
    lane_name = {e["tid"]: e["args"]["name"] for e in evs
                 if e["ph"] == "M" and e["name"] == "thread_name"}
    lanes = set(lane_name.values())
    missing = EXPECT_LANES - lanes
    assert not missing, f"missing trace lanes {missing}; have {lanes}"

    spans = [e for e in evs if e["ph"] == "X"]
    names = {e["name"] for e in spans} | {
        e["name"] for e in evs if e["ph"] == "i"}
    assert EXPECT_SPANS <= names, f"missing spans {EXPECT_SPANS - names}"

    # same-lane spans must nest monotonically: sorted by start, each span
    # either starts after the previous ended or sits fully inside it.
    # Thread lanes only — "kv-handoff" is a resource lane fed by BOTH the
    # engine thread (monolithic swap ships) and the pool thread (eager
    # chunk ships), so concurrent transfers may legitimately overlap there.
    by_lane = {}
    for e in spans:
        if lane_name.get(e["tid"]) == "kv-handoff":
            continue
        by_lane.setdefault(e["tid"], []).append((e["ts"], e["ts"] + e["dur"]))
    for tid, ivs in by_lane.items():
        ivs.sort()
        stack = []
        for t0, t1 in ivs:
            while stack and stack[-1] <= t0 + 1e-3:
                stack.pop()
            assert not stack or t1 <= stack[-1] + 1e-3, \
                f"non-nested spans on lane {lane_name.get(tid, tid)}"
            stack.append(t1)

    finishes = [e["args"]["request_id"] for e in evs
                if e["ph"] == "i" and e["name"] == "req.finish"]
    assert len(finishes) == len(set(finishes)), \
        f"duplicate req.finish events: {finishes}"
    assert {"smoke-long", "smoke-dec"} <= set(finishes), finishes

    # the disagg payoff: prefill-chunk compute on the pool lane overlapping
    # decode quanta on the engine lane
    def lane_spans(lane_prefix, name):
        return [(e["ts"], e["ts"] + e["dur"]) for e in spans
                if e["name"] == name
                and lane_name.get(e["tid"], "").startswith(lane_prefix)]

    def total_overlap(a, b):
        return sum(max(0.0, min(a1, b1) - max(a0, b0))
                   for a0, a1 in a for b0, b1 in b)

    chunks = lane_spans("prefill-pool", "prefill.chunk.compute")
    steps = lane_spans("engine-step", "engine.step")
    rounds = lane_spans("engine-step", "decode.round")
    overlap = total_overlap(chunks, steps)
    assert chunks and steps, (len(chunks), len(steps))
    assert overlap > 0.0, \
        "no wall-clock overlap between prefill-pool compute and engine quanta"
    print(f"trace OK: {len(evs)} events, lanes {sorted(lanes)}, "
          f"{len(finishes)} finishes (all unique); prefill-pool compute "
          f"overlaps engine quanta {overlap / 1e3:.2f} ms "
          f"(decode rounds specifically: {total_overlap(chunks, rounds) / 1e3:.2f} ms)")


def main() -> int:
    port = _free_port()
    trace_path = os.path.join(tempfile.mkdtemp(prefix="obs-smoke-"),
                              "trace.json")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "smollm-135m",
         "--reduced", "--serve", "--disagg", "--port", str(port),
         "--slots", "2", "--max-len", "128", "--prompt-len", "96",
         "--prefill-chunk", "16", "--cache-layout", "paged",
         "--trace-out", trace_path],
        env=env, cwd=REPO)
    try:
        asyncio.run(drive(port))
    finally:
        proc.send_signal(signal.SIGINT)
        try:
            proc.wait(timeout=60)
        except subprocess.TimeoutExpired:
            proc.kill()
            proc.wait()
            raise
    assert proc.returncode == 0, f"server exited {proc.returncode}"
    validate_trace(trace_path)
    print("server smoke PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
