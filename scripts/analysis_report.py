#!/usr/bin/env python
"""Per-pass finding counts for `repro.analysis` — informational, exits 0.

The enforcing gate is ``python -m repro.analysis --all``; this script is the
human-facing summary (CI logs, local triage): per-pass totals, how many are
baselined vs active, the rule histogram, and the `program` pass's
static-cost-vs-roofline residual table.  ``--json`` emits the same data as
one machine-readable object (consumed by the CI step summary / artifact).

    PYTHONPATH=src python scripts/analysis_report.py [--root DIR] [--baseline FILE] [--json]
"""
import argparse
import json
import sys
from collections import Counter
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.analysis import (  # noqa: E402
    PASSES, default_baseline, default_root, run_passes)
from repro.analysis.common import load_baseline, split_baselined  # noqa: E402


def report_data(root: Path, baseline_path: Path) -> dict:
    """The full report as one JSON-serializable object."""
    from repro.analysis import progcheck

    fps, errors = load_baseline(baseline_path)
    results = run_passes(list(PASSES), root=root)
    passes = {}
    total_active = 0
    for name in PASSES:
        active, suppressed = split_baselined(results[name], fps)
        total_active += len(active)
        passes[name] = {
            "total": len(results[name]),
            "active": len(active),
            "baselined": len(suppressed),
            "rules": dict(sorted(Counter(
                f.rule for f in results[name]).items())),
            "findings": [f.render() for f in active],
        }
    return {
        "root": str(root),
        "baseline": str(baseline_path),
        "baseline_entries": len(fps),
        "baseline_errors": list(errors),
        "passes": passes,
        "cost_table": progcheck.cost_table(root),
        "total_active": total_active,
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", type=Path, default=None,
                    help="tree to analyze (default: src/repro)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="baseline file (default: analysis_baseline.txt)")
    ap.add_argument("--json", action="store_true",
                    help="emit one machine-readable JSON object instead of "
                         "the human-facing text")
    args = ap.parse_args()

    root = args.root or default_root()
    baseline_path = args.baseline or default_baseline()
    if args.json:
        print(json.dumps(report_data(root, baseline_path), indent=2))
        return 0
    fps, errors = load_baseline(baseline_path)
    results = run_passes(list(PASSES), root=root)

    print(f"repro.analysis report — root={root}")
    print(f"baseline: {baseline_path} "
          f"({len(fps)} entr{'y' if len(fps) == 1 else 'ies'})")
    total_active = 0
    for name in PASSES:
        found = results[name]
        active, suppressed = split_baselined(found, fps)
        total_active += len(active)
        print(f"\n[{name}] {len(found)} finding(s)"
              f" — {len(active)} active, {len(suppressed)} baselined")
        hist = Counter(f.rule for f in found)
        for rule, n in sorted(hist.items()):
            print(f"    {rule:<28} {n}")
        for f in active:
            print(f"    {f.render()}")
    from repro.analysis import progcheck

    rows = progcheck.cost_table(root)
    if rows:
        print("\n[program] static cost vs roofline "
              "(counted / bound, per audited program):")
        for r in rows:
            flag = "" if r["tol_lo"] <= r["ratio"] <= r["tol_hi"] \
                else "  <-- OUT OF BAND"
            print(f"    {r['layout']:<11} {r['kv_dtype']:<5} "
                  f"{r['program']:<34} {r['kind']:<16} "
                  f"ratio={r['ratio']:.3f} "
                  f"[{r['tol_lo']}, {r['tol_hi']}]{flag}")
    for e in errors:
        print(f"\nbaseline error: {e}")
    print(f"\ntotal active findings: {total_active}"
          + (" (gate would FAIL)" if total_active or errors else ""))
    return 0  # informational by contract; the gate is `-m repro.analysis`


if __name__ == "__main__":
    sys.exit(main())
