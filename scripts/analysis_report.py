#!/usr/bin/env python
"""Per-pass finding counts for `repro.analysis` — informational, exits 0.

The enforcing gate is ``python -m repro.analysis --all``; this script is the
human-facing summary (CI logs, local triage): per-pass totals, how many are
baselined vs active, and the rule histogram.

    PYTHONPATH=src python scripts/analysis_report.py [--root DIR] [--baseline FILE]
"""
import argparse
import sys
from collections import Counter
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.analysis import (  # noqa: E402
    PASSES, default_baseline, default_root, run_passes)
from repro.analysis.common import load_baseline, split_baselined  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", type=Path, default=None,
                    help="tree to analyze (default: src/repro)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="baseline file (default: analysis_baseline.txt)")
    args = ap.parse_args()

    root = args.root or default_root()
    baseline_path = args.baseline or default_baseline()
    fps, errors = load_baseline(baseline_path)
    results = run_passes(list(PASSES), root=root)

    print(f"repro.analysis report — root={root}")
    print(f"baseline: {baseline_path} "
          f"({len(fps)} entr{'y' if len(fps) == 1 else 'ies'})")
    total_active = 0
    for name in PASSES:
        found = results[name]
        active, suppressed = split_baselined(found, fps)
        total_active += len(active)
        print(f"\n[{name}] {len(found)} finding(s)"
              f" — {len(active)} active, {len(suppressed)} baselined")
        hist = Counter(f.rule for f in found)
        for rule, n in sorted(hist.items()):
            print(f"    {rule:<28} {n}")
        for f in active:
            print(f"    {f.render()}")
    for e in errors:
        print(f"\nbaseline error: {e}")
    print(f"\ntotal active findings: {total_active}"
          + (" (gate would FAIL)" if total_active or errors else ""))
    return 0  # informational by contract; the gate is `-m repro.analysis`


if __name__ == "__main__":
    sys.exit(main())
