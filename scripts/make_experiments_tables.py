"""Render the EXPERIMENTS.md §Dry-run and §Roofline tables from
results/dryrun/*.json (optimized) and results/dryrun_baseline/*.json.

    PYTHONPATH=src python scripts/make_experiments_tables.py > results/tables.md
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
CHIP_FLOPS, HBM_BW, ICI_BW = 197e12, 819e9, 200e9


def load(d):
    out = {}
    for p in sorted((REPO / d).glob("*.json")):
        r = json.loads(p.read_text())
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def row_terms(rec, key="roofline"):
    r = rec[key]
    return r["t_compute"], r["t_memory"], r["t_collective"]


def fmt(x):
    return f"{x:.3g}"


def main():
    opt = load("results/dryrun")
    base = load("results/dryrun_baseline")

    print("### §Dry-run — compile certification (all cells, both meshes)\n")
    print("| arch | shape | mesh | status | peak mem/dev (compiled) | HLO collective bytes/dev |")
    print("|---|---|---|---|---|---|")
    for key in sorted(opt):
        r = opt[key]
        if r.get("status") == "skipped":
            print(f"| {key[0]} | {key[1]} | {key[2]} | SKIP (full-attention @500k; DESIGN §4) | - | - |")
            continue
        pm = r.get("peak_memory_per_device") or 0
        coll = sum((r.get("collective_bytes") or {}).values())
        print(f"| {key[0]} | {key[1]} | {key[2]} | ok | {pm/2**30:.2f} GiB | {coll/1e9:.3f} GB |")

    print("\n### §Roofline — three terms per cell, single-pod (256 chips)\n")
    print("paper-faithful static-generic baseline vs PD-Swap optimized+kernel-substituted.")
    print("rf_mem = irreducible traffic (Pallas-kernel HBM bytes + one TP-sharded weight")
    print("pass) / counted HBM bytes — the roofline fraction that matters for these\n"
          "memory-dominated programs; rf_comp = model-FLOPs time / bound (MFU-style).\n")
    print("| arch | shape | base t_mem | opt t_comp | opt t_mem | opt t_coll | dominant | useful | rf_comp | rf_mem | speedup |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    from repro.configs import get_config

    for key in sorted(opt):
        if key[2] != "pod16x16":
            continue
        r = opt[key]
        if r.get("status") == "skipped":
            continue
        b = base.get(key)
        tb = max(row_terms(b)) if b and b.get("status") == "ok" else float("nan")
        tc, tm, tl = row_terms(r)
        t_bound = max(tc, tm, tl)
        rr = r["roofline"]
        t_ideal = rr["model_flops"] / (rr["chips"] * CHIP_FLOPS)
        rf = t_ideal / t_bound if t_bound else 0.0
        speed = tb / t_bound if t_bound and tb == tb else float("nan")
        # memory-roofline fraction (inference cells with kernel substitution)
        rf_mem = ""
        if r.get("kernel_substituted") and r["kind"] in ("prefill", "decode"):
            from repro.configs.base import SHAPES
            from repro.core.kernel_substitution import kernel_costs_for_cell

            cfg = get_config(key[0])
            kb = r["roofline"]["hbm_bytes/dev"]
            # irreducible = analytic kernel bytes + one pass over TP-sharded weights
            kc = kernel_costs_for_cell(cfg, SHAPES[key[1]], dp=16, tp=16)
            weights_once = cfg.active_param_count() * 2 / 16
            irreducible = kc.hbm_bytes + weights_once
            rf_mem = f"{min(irreducible / kb, 1.0):.2f}" if kb else ""
        print(f"| {key[0]} | {key[1]} | {fmt(tb)} | {fmt(tc)} | {fmt(tm)} | {fmt(tl)} "
              f"| {rr['dominant']} | {rr['useful_frac']:.2f} | {rf:.3f} | {rf_mem} | {speed:.1f}x |")

    # summary stats
    speeds, boundcnt = [], {}
    for key in sorted(opt):
        if key[2] != "pod16x16" or opt[key].get("status") == "skipped":
            continue
        b = base.get(key)
        if not b or b.get("status") != "ok":
            continue
        tb = max(row_terms(b))
        t_bound = max(row_terms(opt[key]))
        if t_bound:
            speeds.append(tb / t_bound)
        dom = opt[key]["roofline"]["dominant"]
        boundcnt[dom] = boundcnt.get(dom, 0) + 1
    if speeds:
        import statistics

        print(f"\nmedian speedup vs paper-faithful baseline: "
              f"{statistics.median(speeds):.1f}x (min {min(speeds):.1f}x, max {max(speeds):.1f}x, n={len(speeds)})")
        print(f"dominant-term census: {boundcnt}")


if __name__ == "__main__":
    main()
