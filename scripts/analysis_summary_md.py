#!/usr/bin/env python
"""Render an ``analysis_report.py --json`` report as GitHub-flavored
markdown for the CI step summary: the per-pass finding counts and the
`program` pass's static-cost-vs-roofline residual table.

    python scripts/analysis_summary_md.py analysis_report.json >> "$GITHUB_STEP_SUMMARY"
"""
import json
import sys


def render(data: dict) -> str:
    lines = ["## Static analysis", ""]
    lines += ["| pass | findings | active | baselined |",
              "|---|---|---|---|"]
    for name, p in data["passes"].items():
        lines.append(
            f"| `{name}` | {p['total']} | {p['active']} | {p['baselined']} |")
    active = [f for p in data["passes"].values() for f in p["findings"]]
    if active:
        lines += ["", "### Active findings", ""]
        lines += [f"- `{f}`" for f in active]
    rows = data.get("cost_table", [])
    if rows:
        lines += ["", "### Static cost vs roofline (`program` pass)", "",
                  "| layout | kv_dtype | program | metric | ratio | band |",
                  "|---|---|---|---|---|---|"]
        for r in rows:
            ok = r["tol_lo"] <= r["ratio"] <= r["tol_hi"]
            mark = "" if ok else " :warning:"
            lines.append(
                f"| {r['layout']} | {r['kv_dtype']} | `{r['program']}` "
                f"| {r['kind']} | {r['ratio']:.3f}{mark} "
                f"| [{r['tol_lo']}, {r['tol_hi']}] |")
    lines.append("")
    return "\n".join(lines)


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "analysis_report.json"
    with open(path) as fh:
        data = json.load(fh)
    print(render(data))
    return 0


if __name__ == "__main__":
    sys.exit(main())
