"""Analytic kernel-cost models and sharding-rule helpers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.base import SHAPES, applicable_shapes
from repro.core.kernel_substitution import kernel_costs_for_cell
from repro.kernels.costs import (
    decode_attention_cost,
    mlstm_chunk_cost,
    prefill_attention_cost,
    tlmm_cost,
)


def test_decode_cost_is_kv_stream_bound():
    """Decode attention reads K and V exactly once per KV-head group."""
    b, h, hkv, s, d = 8, 32, 32, 2048, 128
    c = decode_attention_cost(b, h, hkv, s, d)
    kv_bytes = b * hkv * s * d * 2 * 2
    assert kv_bytes <= c.hbm_bytes <= 1.05 * kv_bytes + 1e6


def test_decode_cost_gqa_shares_kv_stream():
    full = decode_attention_cost(4, 32, 32, 4096, 128)
    gqa = decode_attention_cost(4, 32, 8, 4096, 128)  # 4 q heads per kv head
    assert gqa.hbm_bytes < full.hbm_bytes / 3.5  # ~4x less KV traffic
    assert abs(gqa.flops - full.flops) / full.flops < 0.01  # same math


def test_decode_cost_window_caps_traffic():
    full = decode_attention_cost(4, 8, 8, 32768, 128)
    win = decode_attention_cost(4, 8, 8, 32768, 128, window=1024)
    assert win.hbm_bytes < full.hbm_bytes / 16


def test_prefill_cost_causal_half_of_full():
    causal = prefill_attention_cost(2, 8, 8, 4096, 128, causal=True)
    full = prefill_attention_cost(2, 8, 8, 4096, 128, causal=False)
    assert 0.4 < causal.flops / full.flops < 0.6


def test_prefill_cost_quadratic_in_seq():
    a = prefill_attention_cost(1, 8, 8, 4096, 128)
    b = prefill_attention_cost(1, 8, 8, 8192, 128)
    assert 3.5 < b.flops / a.flops < 4.5


def test_vmem_budgets_fit_v5e():
    from repro.common.hardware import TPU_V5E

    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for cell in applicable_shapes(cfg):
            c = kernel_costs_for_cell(cfg, cell, dp=16, tp=16)
            assert c.vmem_bytes < TPU_V5E.vmem_bytes, (arch, cell.name, c.vmem_bytes)


def test_kernel_cost_scales_down_with_mesh():
    cfg = get_config("deepseek-7b")
    cell = SHAPES["decode_32k"]
    small = kernel_costs_for_cell(cfg, cell, dp=16, tp=16)
    big = kernel_costs_for_cell(cfg, cell, dp=32, tp=16)  # multi-pod
    assert big.hbm_bytes < small.hbm_bytes


def test_mlstm_cost_linear_in_seq():
    a = mlstm_chunk_cost(2, 4, 8192, 512)
    b = mlstm_chunk_cost(2, 4, 16384, 512)
    assert 1.9 < b.flops / a.flops < 2.1  # sub-quadratic: linear in S


def test_tlmm_cost_quarter_byte_weights():
    c = tlmm_cost(128, 4096, 4096)
    w_bytes_min = 4096 * 4096 / 4
    assert c.hbm_bytes >= w_bytes_min
    assert c.flops == 2 * 128 * 4096 * 4096


# ------------------------------------------------------------- sharding ----


def test_sanitize_spec_drops_indivisible_axes():
    from repro.layers.sharding import sanitize_spec
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()  # 1 device: every axis size 1 -> all divisible
    spec = sanitize_spec(P("data", "model"), (7, 13), mesh)
    assert spec == P("data", "model")  # size-1 axes always divide


def test_param_pspec_rules_cover_all_archs():
    """Every arch's full param tree gets a spec without error, and TP'd
    dims are actually divisible after sanitation (the xlstm w_if case)."""
    import os

    from repro.launch.sharding_rules import eval_shape_params, params_shardings

    if jax.device_count() != 1:
        pytest.skip("host test")
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        params = eval_shape_params(cfg, dtype=jnp.bfloat16)
        sh = params_shardings(params, cfg, mesh, train=True)
        assert len(jax.tree.leaves(sh)) == len(jax.tree.leaves(params))
