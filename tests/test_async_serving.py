"""Async serving front-end: AsyncEngine bit-identity against the sync core,
backpressure, aborts (with pool accounting), weighted fair queueing, the
SLO-aware policy's deadline shedding, and HTTP graceful shutdown."""
import asyncio
import json
import socket

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.launch.serve import serve_http
from repro.models import get_model
from repro.serving import (
    AdmissionRejected,
    AsyncEngine,
    EngineCore,
    Request,
    SamplingParams,
)
from repro.serving.fair_queue import WeightedFairQueue
from repro.serving.slo import SLOAwareSwapPolicy, SLOConfig


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced_config("bitnet-730m", num_layers=3, d_model=128, vocab_size=512,
                         num_heads=4, num_kv_heads=2)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, params


def _requests(cfg, n=3, lo=5, hi=12, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [(f"r{i}",
             rng.integers(0, cfg.vocab_size, int(rng.integers(lo, hi + 1)))
             .astype(np.int32),
             max_new)
            for i in range(n)]


def _sync_tokens(cfg, params, reqs, **eng_kw):
    eng = EngineCore(cfg, params, **eng_kw)
    for rid, prompt, max_new in reqs:
        eng.submit(Request(rid, prompt.copy(), max_new=max_new))
    eng.run()
    return {rid: list(eng.finished[rid].out_tokens) for rid, _, _ in reqs}


def _async_tokens(cfg, params, reqs, *, max_queue=32, tenants=None, **eng_kw):
    async def go():
        core = EngineCore(cfg, params, **eng_kw)
        toks = {}
        async with AsyncEngine(core, max_queue=max_queue) as eng:
            streams = {}
            for i, (rid, prompt, max_new) in enumerate(reqs):
                kw = {}
                if tenants:
                    kw["tenant"], kw["weight"] = tenants[i % len(tenants)]
                streams[rid] = await eng.submit(
                    prompt.copy(), request_id=rid, max_new=max_new, **kw)
            for rid, stream in streams.items():
                got = []
                async for out in stream:
                    got.extend(out.new_token_ids)
                    if out.finished:
                        assert out.finish_reason in ("stop", "length")
                toks[rid] = got
        return toks

    return asyncio.run(go())


# ------------------------------------------------- async == sync identity --


@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("kv_dtype", ["fp", "int8", "int4"])
def test_async_matches_sync_greedy(tiny, layout, kv_dtype):
    """Greedy tokens through AsyncEngine are bit-identical to the sync
    EngineCore for every cache layout x KV dtype."""
    cfg, params = tiny
    kw = dict(n_slots=2, max_len=40, prompt_len=12, cache_layout=layout,
              kv_dtype=kv_dtype)
    if layout == "paged":
        kw.update(block_size=8, num_blocks=16)
    reqs = _requests(cfg)
    assert _async_tokens(cfg, params, reqs, **kw) == \
        _sync_tokens(cfg, params, reqs, **kw)


def test_async_matches_sync_chunked_prefill(tiny):
    cfg, params = tiny
    kw = dict(n_slots=2, max_len=48, prompt_len=24, cache_layout="paged",
              block_size=8, num_blocks=24, prefill_chunk=8)
    reqs = _requests(cfg, lo=12, hi=24, seed=1)
    assert _async_tokens(cfg, params, reqs, **kw) == \
        _sync_tokens(cfg, params, reqs, **kw)


def test_async_matches_sync_spec_decode(tiny):
    cfg, params = tiny
    kw = dict(n_slots=2, max_len=48, prompt_len=16, cache_layout="paged",
              block_size=8, num_blocks=24, spec_decode=2)
    # repetitive prompts so prompt-lookup drafting actually proposes
    base = np.arange(8, dtype=np.int32) % 5 + 3
    reqs = [(f"r{i}", np.tile(base, 2), 10) for i in range(3)]
    assert _async_tokens(cfg, params, reqs, **kw) == \
        _sync_tokens(cfg, params, reqs, **kw)


def test_two_tenants_complete_identically(tiny):
    """Weighted fair queueing reorders service, not tokens: a two-tenant
    run still matches the sync single-tenant reference per request."""
    cfg, params = tiny
    kw = dict(n_slots=2, max_len=40, prompt_len=12)
    reqs = _requests(cfg, n=4)
    toks = _async_tokens(cfg, params, reqs,
                         tenants=[("interactive", 2.0), ("batch", 1.0)], **kw)
    assert toks == _sync_tokens(cfg, params, reqs, **kw)


# ------------------------------------------------------------ backpressure --


def test_backpressure_rejects_when_queue_full(tiny):
    cfg, params = tiny

    async def go():
        core = EngineCore(cfg, params, n_slots=2, max_len=32, prompt_len=8)
        eng = AsyncEngine(core, max_queue=2)  # NOT started: nothing drains
        prompt = np.arange(6, dtype=np.int32)
        await eng.submit(prompt, request_id="a", max_new=2)
        await eng.submit(prompt, request_id="b", max_new=2)
        with pytest.raises(AdmissionRejected) as exc:
            await eng.submit(prompt, request_id="c", max_new=2)
        assert exc.value.reason.startswith("queue_full")
        assert eng.rejected == 1 and eng.reject_reasons == {"queue_full": 1}
        with pytest.raises(AdmissionRejected):  # duplicate id
            await eng.submit(prompt, request_id="a", max_new=2)
        await eng.shutdown()

    asyncio.run(go())


def test_impossible_request_rejected_at_submit(tiny):
    cfg, params = tiny

    async def go():
        core = EngineCore(cfg, params, n_slots=2, max_len=16, prompt_len=8)
        async with AsyncEngine(core) as eng:
            with pytest.raises(AdmissionRejected) as exc:
                await eng.submit(np.arange(64, dtype=np.int32),
                                 request_id="big", max_new=4)
            assert exc.value.reason.startswith("invalid")

    asyncio.run(go())


# ----------------------------------------------------------------- aborts --


def _paged_engine(cfg, params, **over):
    kw = dict(n_slots=2, max_len=48, prompt_len=24, cache_layout="paged",
              block_size=8, num_blocks=24)
    kw.update(over)
    return EngineCore(cfg, params, **kw)


def test_abort_mid_prefill_chunk(tiny):
    """Abort between two chunks of a chunked prefill: the slot and every
    exclusively-held page come back, and the engine keeps serving."""
    cfg, params = tiny
    eng = _paged_engine(cfg, params, prefill_chunk=8)
    free0 = eng.runner.paged.pool.num_free
    eng.submit(Request("long", np.arange(24, dtype=np.int32) % 64, max_new=4))
    eng.step()  # runs exactly one chunk: the prefill is now mid-flight
    assert eng._prefilling, "request should be mid-chunked-prefill"
    out = eng.abort("long")
    assert out is not None and out.finished and out.finish_reason == "abort"
    assert not eng._prefilling
    assert eng.stats.aborts == 1
    assert eng.runner.paged.pool.num_free == free0
    # engine still serves after the abort
    eng.submit(Request("after", np.arange(10, dtype=np.int32), max_new=3))
    eng.run()
    assert eng.finished["after"].finish_reason in ("stop", "length")


def test_abort_mid_decode_and_queued(tiny):
    cfg, params = tiny
    eng = _paged_engine(cfg, params, n_slots=1)
    free0 = eng.runner.paged.pool.num_free
    eng.submit(Request("live", np.arange(9, dtype=np.int32), max_new=16))
    eng.submit(Request("waiting", np.arange(9, dtype=np.int32), max_new=16))
    while not eng.finished.get("live") and not eng.scheduler.inflight:
        eng.step()
    out_q = eng.abort("waiting")  # still queued (single slot is occupied)
    assert out_q is not None and out_q.finish_reason == "abort"
    out_d = eng.abort("live")  # decoding right now
    assert out_d is not None and out_d.finish_reason == "abort"
    assert not eng.scheduler.inflight and not eng.has_unfinished()
    assert eng.stats.aborts == 2
    assert eng.runner.paged.pool.num_free == free0
    assert eng.abort("live") is None  # already finished: harmless no-op


def test_abort_mid_spec_verify(tiny):
    cfg, params = tiny
    eng = _paged_engine(cfg, params, spec_decode=2)
    free0 = eng.runner.paged.pool.num_free
    base = np.arange(8, dtype=np.int32) % 5 + 3
    eng.submit(Request("spec", np.tile(base, 2), max_new=24))
    eng.submit(Request("other", np.arange(10, dtype=np.int32), max_new=6))
    # advance until the spec stream has produced tokens through at least one
    # verify round, then abort it between quanta
    while eng.stats.verify_rounds < 1 and eng.has_unfinished():
        eng.step()
    out = eng.abort("spec")
    assert out is not None and out.finish_reason == "abort"
    eng.run()
    assert eng.finished["other"].finish_reason in ("stop", "length")
    assert eng.runner.paged.pool.num_free == free0


def test_async_stream_abort(tiny):
    cfg, params = tiny

    async def go():
        core = EngineCore(cfg, params, n_slots=2, max_len=64, prompt_len=8)
        async with AsyncEngine(core) as eng:
            stream = await eng.submit(np.arange(8, dtype=np.int32),
                                      request_id="x", max_new=48)
            outs = []
            async for out in stream:
                outs.append(out)
                if len(outs) == 1:
                    await stream.abort()
            assert outs[-1].finished and outs[-1].finish_reason == "abort"
            assert core.stats.aborts == 1
        return outs

    outs = asyncio.run(go())
    # aborted well before the 48-token budget
    assert sum(len(o.new_token_ids) for o in outs) < 48


# --------------------------------------------------- weighted fair queueing --


class _Req:
    def __init__(self, rid, tenant="default", weight=1.0):
        self.request_id, self.tenant, self.weight = rid, tenant, weight


def test_wfq_single_tenant_is_fifo():
    q = WeightedFairQueue()
    for i in range(5):
        q.append(_Req(f"r{i}"))
    assert [q.popleft().request_id for _ in range(5)] == [f"r{i}" for i in range(5)]
    assert len(q) == 0 and not q


def test_wfq_drr_serves_proportional_to_weight():
    q = WeightedFairQueue()
    for i in range(6):
        q.append(_Req(f"a{i}", tenant="A", weight=2.0))
        q.append(_Req(f"b{i}", tenant="B", weight=1.0))
    order = [q.popleft().request_id for _ in range(9)]
    served_a = sum(1 for rid in order if rid.startswith("a"))
    assert served_a == 6 and len(order) - served_a == 3  # 2:1 service ratio
    # remaining B requests drain in FIFO order once A is empty
    rest = [q.popleft().request_id for _ in range(len(q))]
    assert rest == [f"b{i}" for i in range(3, 6)]


def test_wfq_head_requeue_beats_fair_share():
    q = WeightedFairQueue()
    q.append(_Req("a0", tenant="A", weight=2.0))
    q.append(_Req("b0", tenant="B", weight=1.0))
    q.appendleft(_Req("retry", tenant="B", weight=1.0))
    assert q[0].request_id == "retry"
    assert q.popleft().request_id == "retry"


def test_wfq_remove_by_id():
    q = WeightedFairQueue()
    for i in range(3):
        q.append(_Req(f"r{i}"))
    assert q.remove("r1").request_id == "r1"
    assert q.remove("nope") is None
    assert [r.request_id for r in q] == ["r0", "r2"]


# ------------------------------------------------------------ SLO shedding --


def test_should_shed_line():
    pol = SLOAwareSwapPolicy(SLOConfig(ttft_target_s=0.2, itl_target_s=0.05))
    # no observations: shed exactly at the bare deadline
    assert not pol.should_shed(0.19)
    assert pol.should_shed(0.2)
    # the clamp: even a huge serve estimate never sheds before half of it
    assert not pol.should_shed(0.09)


def test_slo_policy_sheds_doomed_head(tiny):
    cfg, params = tiny
    pol = SLOAwareSwapPolicy(SLOConfig(ttft_target_s=0.05, itl_target_s=0.05))
    eng = EngineCore(cfg, params, n_slots=2, max_len=32, prompt_len=8,
                     swap_policy=pol)
    ok = Request("ok", np.arange(6, dtype=np.int32), max_new=2)
    doomed = Request("doomed", np.arange(6, dtype=np.int32), max_new=2)
    eng.submit(ok)
    eng.run()  # "ok" is served immediately: comfortably inside its deadline
    eng.submit(doomed)
    doomed.arrival_time_s -= 1.0  # backdate: already 1s past the deadline
    outs = eng.step()
    assert eng.finished["doomed"].finish_reason == "shed"
    assert any(o.request_id == "doomed" and o.finish_reason == "shed"
               for o in outs)
    assert eng.stats.sheds == 1
    assert eng.finished["ok"].finish_reason in ("stop", "length")


# ------------------------------------------------------- graceful shutdown --


def _free_port():
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


async def _request(port, method, path, body=b""):
    """One full HTTP exchange on a fresh connection (server closes it)."""
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
                 f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
    await writer.drain()
    data = await reader.read()
    writer.close()
    try:
        await writer.wait_closed()
    except (ConnectionResetError, BrokenPipeError):
        pass
    head, _, payload = data.partition(b"\r\n\r\n")
    return head.split(b"\r\n", 1)[0].decode(), payload


async def _open_stream(port, max_new):
    """Start a generate stream and block until its first SSE delta, so the
    caller knows the request is live inside the engine."""
    body = json.dumps({"prompt": list(range(3, 9)), "max_new": max_new}).encode()
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(f"POST /generate HTTP/1.1\r\nHost: t\r\n"
                 f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
    await writer.drain()
    while True:  # skip the response headers; keep from the first delta on
        line = await asyncio.wait_for(reader.readline(), 30)
        if line.startswith(b"data: "):
            return reader, writer, line


def _sse_events(raw):
    return [json.loads(chunk[len(b"data: "):])
            for chunk in raw.split(b"\n\n") if chunk.startswith(b"data: ")]


def test_graceful_shutdown_drains_inflight_and_rejects_new(tiny):
    """stop -> draining: new generates answer 503, /stats stays up, and the
    in-flight stream runs to natural completion inside the grace window."""
    cfg, params = tiny

    async def go():
        core = EngineCore(cfg, params, n_slots=2, max_len=256, prompt_len=8)
        ready, stop = asyncio.Event(), asyncio.Event()
        port = _free_port()
        task = asyncio.create_task(serve_http(
            core, SamplingParams(), "127.0.0.1", port,
            ready=ready, stop=stop, grace_s=60.0))
        await asyncio.wait_for(ready.wait(), 30)
        reader, writer, head = await _open_stream(port, max_new=200)
        stop.set()
        await asyncio.sleep(0.05)  # let the server flip into draining
        status, payload = await _request(port, "POST", "/generate",
                                         json.dumps({"prompt": [1, 2]}).encode())
        assert status.startswith("HTTP/1.1 503"), status
        assert b"draining" in payload
        status, payload = await _request(port, "GET", "/stats")
        assert status.startswith("HTTP/1.1 200"), status
        assert json.loads(payload)["frontend"]["open_streams"] >= 1
        events = _sse_events(head + await asyncio.wait_for(reader.read(), 60))
        assert events[-1]["finished"]
        assert events[-1]["finish_reason"] == "length"
        assert sum(len(e["new_token_ids"]) for e in events) == 200
        writer.close()
        assert await asyncio.wait_for(task, 60) == 0

    asyncio.run(go())


def test_graceful_shutdown_aborts_at_grace_deadline(tiny):
    """grace exhausted: the engine shutdown cuts the in-flight stream with a
    terminal ``finish_reason="abort"`` delta instead of hanging the reader."""
    cfg, params = tiny

    async def go():
        core = EngineCore(cfg, params, n_slots=2, max_len=256, prompt_len=8)
        ready, stop = asyncio.Event(), asyncio.Event()
        port = _free_port()
        task = asyncio.create_task(serve_http(
            core, SamplingParams(), "127.0.0.1", port,
            ready=ready, stop=stop, grace_s=0.0))
        await asyncio.wait_for(ready.wait(), 30)
        reader, writer, head = await _open_stream(port, max_new=240)
        stop.set()
        events = _sse_events(head + await asyncio.wait_for(reader.read(), 60))
        assert events[-1]["finished"]
        assert events[-1]["finish_reason"] == "abort"
        assert sum(len(e["new_token_ids"]) for e in events) < 240
        writer.close()
        assert await asyncio.wait_for(task, 60) == 0

    asyncio.run(go())


def test_static_policies_never_shed(tiny):
    cfg, params = tiny
    for policy in ("drain", "swap-aware"):
        eng = EngineCore(cfg, params, n_slots=2, max_len=32, prompt_len=8,
                         swap_policy=policy)
        req = Request("r", np.arange(6, dtype=np.int32), max_new=2)
        eng.submit(req)
        req.arrival_time_s -= 100.0  # ancient — static policies still serve
        eng.run()
        assert eng.finished["r"].finish_reason in ("stop", "length")
        assert eng.stats.sheds == 0
