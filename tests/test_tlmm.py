"""TLMM kernel: shape/dtype sweeps vs the jnp oracle + the paper's LUT
algorithm, and hypothesis property tests on the packing format."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.tlmm.kernel import tlmm_pallas
from repro.kernels.tlmm.ops import tlmm_matmul
from repro.kernels.tlmm.ref import tlmm_lut_reference, tlmm_reference
from repro.quant.act_quant import quantize_activations_int8
from repro.quant.ternary import (
    pack_ternary,
    quantize_and_pack,
    ternary_quantize,
    unpack_ternary,
)


def _mk(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    return x, quantize_and_pack(w)


@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [
        (8, 64, 128, 8, 128, 64),
        (16, 256, 128, 8, 128, 64),
        (32, 512, 256, 16, 128, 128),
        (128, 1024, 512, 128, 256, 512),
        (8, 128, 384, 8, 128, 32),  # bn not dividing n exercises ops fallback
    ],
)
def test_kernel_matches_reference_shapes(m, k, n, bm, bn, bk):
    x, tw = _mk(m, k, n, seed=m + k + n)
    x_q, s = quantize_activations_int8(x)
    scale = s * tw.scale
    ref = tlmm_reference(x_q, tw.packed, scale, out_dtype=jnp.float32)
    if n % bn == 0 and k % bk == 0 and m % bm == 0:
        out = tlmm_pallas(x_q, tw.packed, scale, bm=bm, bn=bn, bk=bk, out_dtype=jnp.float32, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6, atol=1e-6)
    out2 = tlmm_matmul(x, tw, use_kernel=True, interpret=True, out_dtype=jnp.float32,
                       block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_dtype_sweep(out_dtype):
    x, tw = _mk(16, 256, 128)
    ref = tlmm_matmul(x, tw, use_kernel=False, out_dtype=out_dtype)
    out = tlmm_matmul(x, tw, use_kernel=True, interpret=True, out_dtype=out_dtype)
    np.testing.assert_allclose(
        np.asarray(ref, np.float32), np.asarray(out, np.float32), rtol=2e-2, atol=2e-2
    )


def test_lut_algorithm_bit_exact():
    """The paper's index->lookup->accumulate == direct int matmul, exactly."""
    x, tw = _mk(4, 64, 32, seed=7)
    x_q, s = quantize_activations_int8(x)
    scale = s * tw.scale
    a = tlmm_reference(x_q, tw.packed, scale, out_dtype=jnp.float32)
    b = tlmm_lut_reference(x_q, tw.packed, scale, out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(1, 64), st.integers(1, 16), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_pack_unpack_roundtrip(kq, n, seed):
    rng = np.random.default_rng(seed)
    w_q = jnp.asarray(rng.integers(-1, 2, size=(kq * 4, n)), jnp.int8)
    assert (unpack_ternary(pack_ternary(w_q)) == w_q).all()


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_absmean_quantizer_properties(seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(32, 16)) * rng.uniform(0.1, 10), jnp.float32)
    w_q, beta = ternary_quantize(w)
    assert set(np.unique(np.asarray(w_q))) <= {-1, 0, 1}
    assert float(beta) > 0
    # dequantized error is bounded by the quantization step
    err = np.abs(np.asarray(w) - np.asarray(w_q, np.float32) * float(beta))
    assert err.max() <= max(float(beta) * 1.5, float(np.abs(np.asarray(w)).max() - float(beta)))


def test_memory_footprint_is_quarter_byte():
    _, tw = _mk(8, 1024, 256)
    assert tw.packed.size == 1024 * 256 // 4
    assert tw.packed.dtype == jnp.uint8
