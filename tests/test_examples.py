"""Smoke tests for the runnable examples (subprocess, minimal args)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run_example(name: str, *args: str, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, str(REPO / "examples" / name), *args],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_dse_explore():
    out = _run_example("dse_explore.py", "--arch", "deepseek-7b")
    assert "logic swapping wins" in out


def test_serve_pdswap():
    out = _run_example("serve_pdswap.py", "--requests", "3", "--max-new", "4")
    assert "greedy outputs identical across engines: True" in out


def test_train_cli_short():
    from repro.launch import train as train_cli

    rc = train_cli.main([
        "--arch", "smollm-135m", "--reduced", "--steps", "4",
        "--batch", "2", "--seq", "32", "--log-every", "2",
    ])
    assert rc == 0
