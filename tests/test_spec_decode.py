"""Speculative decoding (prompt-lookup drafting + batched verify) and the
finish-semantics fixes that ride along: greedy bit-identity vs the
non-speculative engine across {contiguous, paged} x {fp, int8, int4},
preempt/replay mid-speculation, draft clamping at the cache headroom
(the parked-write-row invariant), multi-token stop/budget truncation, and
the resume-at-budget terminal output."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.sampling import accept_length
from repro.models import get_model
from repro.serving import EngineCore, Request, SamplingParams
from repro.serving.core import ModelRunner
from repro.serving.outputs import OutputProcessor
from repro.serving.spec_decode import find_draft


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced_config("bitnet-730m", num_layers=3, d_model=128, vocab_size=512,
                         num_heads=4, num_kv_heads=2)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, api, params


def _prompts(cfg, seed=3):
    """Mixed workload: one self-repetitive prompt (the drafter's regime)
    plus random ones (the adversarial pole)."""
    rng = np.random.default_rng(seed)
    pat = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    return [np.tile(pat, 4),
            rng.integers(0, cfg.vocab_size, 14).astype(np.int32),
            rng.integers(0, cfg.vocab_size, 9).astype(np.int32)]


def _serve(cfg, params, prompts, *, layout, spec=None, mode="static",
           max_new=12, max_len=64, sp=None, **kw):
    eng = EngineCore(cfg, params, n_slots=3, max_len=max_len, prompt_len=12,
                     mode=mode, cache_layout=layout, block_size=8,
                     spec_decode=spec, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(f"r{i}", p.copy(), max_new=max_new,
                           params=sp or SamplingParams()))
    stats = eng.run()
    return eng, stats, {k: v.out_tokens for k, v in eng.finished.items()}


# ----------------------------------------------------------- the drafter --


def test_find_draft_matches_most_recent_ngram():
    ctx = np.array([5, 1, 2, 3, 9, 1, 2, 3], np.int32)
    # trailing 3-gram [1,2,3] matched at position 1; continuation follows it
    np.testing.assert_array_equal(find_draft(ctx, 1, 3), [9])
    np.testing.assert_array_equal(find_draft(ctx, 4, 3), [9, 1, 2, 3])
    # among full-continuation matches the most recent wins
    ctx2 = np.array([1, 2, 3, 7, 8, 1, 2, 3, 9, 1, 2, 3], np.int32)
    np.testing.assert_array_equal(find_draft(ctx2, 2, 3), [9, 1])
    # a match whose continuation would be empty is never selected — the
    # earlier occurrence (with real continuation tokens) is
    ctx3 = np.array([1, 2, 3, 7, 8, 1, 2, 3], np.int32)
    np.testing.assert_array_equal(find_draft(ctx3, 2, 3), [7, 8])


def test_find_draft_falls_back_to_shorter_ngrams_and_empty():
    ctx = np.array([4, 4, 4, 4], np.int32)  # period-1: only size-1+ matches
    got = find_draft(ctx, 3, 3)
    assert len(got) >= 1 and all(t == 4 for t in got)
    # no earlier occurrence of anything -> no draft
    assert len(find_draft(np.array([1, 2, 3, 4], np.int32), 3, 3)) == 0
    assert len(find_draft(np.array([7], np.int32), 3, 3)) == 0
    assert len(find_draft(np.array([1, 2, 1, 2], np.int32), 0, 3)) == 0


def test_accept_length_rule():
    assert accept_length([1, 2, 3], [1, 2, 3]) == 3
    assert accept_length([1, 2, 3], [1, 9, 3]) == 1
    assert accept_length([1, 2], [9, 2]) == 0
    assert accept_length([], []) == 0


# ------------------------------------------- multi-token finish semantics --


class _Req:
    def __init__(self, max_new, stop=(), out=None):
        self.request_id = "t"
        self.max_new = max_new
        self.params = SamplingParams(stop_tokens=stop)
        self.out_tokens = list(out or [])
        self.first_token_t = 0.0
        self.done_t = 0.0
        self.finish_reason = None


def test_process_tokens_truncates_at_first_stop():
    """Satellite: an accepted speculative block must never leak tokens past
    a stop token — everything after the FIRST stop is dropped."""
    req = _Req(max_new=10, stop=(7,))
    out = OutputProcessor().process_tokens(req, [3, 7, 5, 6])
    assert out.new_token_ids == [3, 7]
    assert req.out_tokens == [3, 7]
    assert out.finished and out.finish_reason == "stop"


def test_process_tokens_caps_at_budget_headroom():
    req = _Req(max_new=4, out=[1, 2])
    out = OutputProcessor().process_tokens(req, [3, 4, 5, 6])
    assert out.new_token_ids == [3, 4]  # headroom was 2
    assert out.finished and out.finish_reason == "length"
    assert len(req.out_tokens) == 4


def test_process_tokens_stop_wins_on_budget_boundary():
    """A stop token landing exactly on the budget edge reports "stop" —
    the same precedence the single-token path always had."""
    req = _Req(max_new=2, stop=(9,), out=[1])
    out = OutputProcessor().process_tokens(req, [9, 5])
    assert out.new_token_ids == [9]
    assert out.finish_reason == "stop"


def test_process_token_delegates_unchanged():
    req = _Req(max_new=2)
    out = OutputProcessor().process_token(req, 5)
    assert out.new_token_ids == [5] and not out.finished
    assert req.first_token_t > 0.0
    out = OutputProcessor().process_token(req, 6)
    assert out.finished and out.finish_reason == "length"


def test_engine_stop_mid_accepted_block_truncates(tiny):
    """Satellite, engine-level: a stop token landing INSIDE an accepted
    speculative block ends the stream at the stop — no leaked tokens past
    it — and matches the non-speculative stream exactly."""
    cfg, api, params = tiny
    rng = np.random.default_rng(3)
    pat = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    prompt = np.tile(pat, 4)
    # probe the greedy stream for a token first generated at index >= 2, so
    # the stop can only be reached inside a multi-token accepted block
    _, _, probe = _serve(cfg, params, [prompt], layout="contiguous", max_new=12)
    stream = probe["r0"]
    stop_tok = next(t for i, t in enumerate(stream) if i >= 2 and t not in stream[:i])
    sp = SamplingParams(stop_tokens=(int(stop_tok),))
    _, _, ref = _serve(cfg, params, [prompt], layout="contiguous",
                       max_new=12, sp=sp)
    _, stats, got = _serve(cfg, params, [prompt], layout="contiguous",
                           max_new=12, sp=sp, spec=4)
    assert got == ref
    assert got["r0"][-1] == stop_tok and stop_tok not in got["r0"][:-1]
    assert stats.accepted_tokens > 0  # the block path was really exercised


# ------------------------------------------ resume-at-budget terminal out --


def _resume_at_budget(tiny, out_tokens, stop=()):
    cfg, api, params = tiny
    eng = EngineCore(cfg, params, n_slots=2, max_len=64, prompt_len=12,
                     mode="static", cache_layout="paged", block_size=8)
    rng = np.random.default_rng(0)
    req = Request("resume", rng.integers(0, cfg.vocab_size, 10).astype(np.int32),
                  max_new=len(out_tokens),
                  params=SamplingParams(stop_tokens=stop))
    req.out_tokens = list(out_tokens)
    req.preempted = True  # external replay / checkpoint-restore path
    eng.submit(req)
    outs = []
    for _ in range(20):
        outs.extend(eng.step())
        if "resume" in eng.finished:
            break
    return eng, outs


def test_resume_exactly_at_budget_emits_terminal_output(tiny):
    """Satellite regression: a replayed request resuming EXACTLY at its
    max_new budget used to finish silently — finish_reason None, no
    terminal RequestOutput, the stream just went dark."""
    eng, outs = _resume_at_budget(tiny, [5, 6, 7])
    assert "resume" in eng.finished
    req = eng.finished["resume"]
    assert req.finish_reason == "length"
    term = [o for o in outs if o.request_id == "resume" and o.finished]
    assert len(term) == 1
    assert term[0].new_token_ids == []  # zero-delta: tokens streamed pre-eviction
    assert term[0].finish_reason == "length"
    assert not eng.runner.slots.active_slots()  # slot released


def test_resume_at_budget_stop_token_reports_stop(tiny):
    eng, outs = _resume_at_budget(tiny, [5, 6, 9], stop=(9,))
    assert eng.finished["resume"].finish_reason == "stop"
    term = [o for o in outs if o.request_id == "resume" and o.finished]
    assert term and term[0].finish_reason == "stop"


# ------------------------------------------------- greedy bit-identity ----


@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("kv_dtype", ["fp", "int8", "int4"])
def test_spec_greedy_bit_identical_to_plain_decode(tiny, layout, kv_dtype):
    """THE speculative contract: with greedy sampling, spec-on streams are
    bit-identical to the non-speculative engine — every emitted token is
    the token sequential decode would have produced — for every layout x
    kv_dtype, while the repetitive prompt actually exercises acceptance."""
    cfg, api, params = tiny
    prompts = _prompts(cfg)
    _, _, ref = _serve(cfg, params, prompts, layout=layout, kv_dtype=kv_dtype)
    _, stats, got = _serve(cfg, params, prompts, layout=layout,
                           kv_dtype=kv_dtype, spec=4)
    assert got == ref
    assert stats.verify_rounds > 0 and stats.draft_tokens > 0
    assert stats.accepted_tokens > 0  # the repetitive prompt drafts land
    assert stats.decode_rounds < 3 * 12  # strictly fewer rounds than 1/token


def test_spec_pdswap_mode_bit_identical(tiny):
    cfg, api, params = tiny
    prompts = _prompts(cfg)
    _, _, ref = _serve(cfg, params, prompts, layout="contiguous", mode="pdswap")
    _, stats, got = _serve(cfg, params, prompts, layout="contiguous",
                           mode="pdswap", spec=4)
    assert got == ref and stats.accepted_tokens > 0


def test_spec_sampled_streams_match_sequential(tiny):
    """Sampled targets reuse the sequential fold_in(seed, index) key
    stream, so spec-on sampling reproduces spec-off sampling exactly."""
    cfg, api, params = tiny
    prompts = _prompts(cfg, seed=5)
    sp = SamplingParams(temperature=0.9, top_k=20, top_p=0.9, seed=11)
    _, _, ref = _serve(cfg, params, prompts, layout="contiguous", sp=sp)
    _, _, got = _serve(cfg, params, prompts, layout="contiguous", sp=sp, spec=4)
    assert got == ref


def test_spec_acceptance_exceeds_one_token_per_round(tiny):
    """The headline claim (pinned as a count, not wall clock): on a
    repetitive-suffix workload the engine accepts MORE than one draft
    token per SLOT per decode round.  Normalized by slot_rounds — a
    concurrent batch already emits batch-many tokens per round without
    speculation, so per-round totals could masquerade as amortization;
    per-slot cannot (the non-speculative baseline is exactly 1.0)."""
    cfg, api, params = tiny
    rng = np.random.default_rng(3)
    pat = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
    prompts = [np.tile(pat, 4)[:26].copy() for _ in range(2)]
    _, stats, _ = _serve(cfg, params, prompts, layout="paged", spec=4,
                         max_new=16, max_len=96)
    assert stats.verify_rounds > 0 and stats.slot_rounds > 0
    assert stats.accepted_tokens / stats.slot_rounds > 1.0
    assert stats.tokens_per_round() > 2.0  # per slot: >2x plain decode
    # sanity of the normalizer itself: a non-speculative run sits at 1.0
    _, base, _ = _serve(cfg, params, prompts, layout="paged",
                        max_new=16, max_len=96)
    assert base.tokens_per_round() == 1.0


# ------------------------------------------------ preemption + rollback ----


@pytest.mark.parametrize("kv_dtype", ["fp", "int4"])
def test_spec_preemption_replay_mid_speculation(tiny, kv_dtype):
    """A pool too small for the offered load forces eviction mid-stream
    (mid-speculation included); the replayed restart re-derives the same
    drafts from the same history and continues bit-identically to the
    never-preempted non-speculative reference."""
    cfg, api, params = tiny
    rng = np.random.default_rng(4)
    pat = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
    prompts = [np.tile(pat, 2)] + [
        rng.integers(0, cfg.vocab_size, 14).astype(np.int32) for _ in range(3)]
    _, _, ref = _serve(cfg, params, prompts, layout="contiguous",
                       max_new=10, kv_dtype=kv_dtype)
    eng, stats, got = _serve(cfg, params, prompts, layout="paged",
                             max_new=10, kv_dtype=kv_dtype, spec=4,
                             num_blocks=7)
    assert stats.preemptions > 0 and stats.replayed_tokens > 0
    assert got == ref
    # rollback accounting: after the run every page is back home
    pool = eng.runner.paged.pool
    assert pool.num_live == 0
    assert len(pool.free_list) + len(pool.evictable) == pool.num_blocks


def test_truncate_slot_releases_overshoot_pages(tiny):
    """Unit: speculative rollback drops exactly the trailing pages past the
    accepted length and keeps the pool invariant intact."""
    cfg, api, params = tiny
    runner = ModelRunner(cfg, params, n_slots=2, max_len=64, prompt_len=12,
                         mode="static", cache_layout="paged", block_size=8)
    paged = runner.paged
    slot = runner.slots.assign("t", 10, 20)
    match = paged.allocate_prompt(slot, np.arange(10, dtype=np.int32))
    assert len(paged.tables[slot]) == 2  # 10 tokens @ bs=8
    for pos in range(10, 10 + 7):  # grow a verify span of 7 rows
        paged.ensure_append_page(slot, pos)
    assert len(paged.tables[slot]) == 3  # positions [0, 17) -> 3 pages
    released = paged.truncate_slot(slot, 12)  # accept 2 rows, reject 5
    assert released == 1 and len(paged.tables[slot]) == 2
    pool = paged.pool
    assert pool.num_live == 2
    assert len(pool.free_list) + len(pool.evictable) + pool.num_live == pool.num_blocks
    assert paged.truncate_slot(slot, 12) == 0  # idempotent


# ----------------------------------------------- headroom clamp (parking) --


def test_spec_draft_clamped_at_cache_headroom(tiny):
    """Satellite: the contiguous parked-write trick relies on live KV never
    occupying row max_len - 1.  With prompt + max_new == max_len the final
    rounds leave less headroom than the draft depth — the clamp must keep
    every live verify row <= max_len - 2 (the engine asserts it per round)
    while the stream stays bit-identical."""
    cfg, api, params = tiny
    rng = np.random.default_rng(3)
    pat = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    prompts = [np.tile(pat, 4)]  # 20 tokens; 20 + 12 == max_len == 32
    _, _, ref = _serve(cfg, params, prompts, layout="contiguous",
                       max_new=12, max_len=32)
    _, stats, got = _serve(cfg, params, prompts, layout="contiguous",
                           max_new=12, max_len=32, spec=8)
    assert got == ref and stats.accepted_tokens > 0


def test_spec_unclamped_draft_trips_the_parking_assertion(tiny):
    """Regression guard for the clamp itself: an (artificially) unclamped
    draft that would write live KV at row max_len - 1 must be caught by
    the verify round's assertion, not silently corrupt the parked row."""
    cfg, api, params = tiny
    eng = EngineCore(cfg, params, n_slots=1, max_len=32, prompt_len=12,
                     mode="static", cache_layout="contiguous", spec_decode=16)
    rng = np.random.default_rng(0)
    eng.submit(Request("r0", rng.integers(0, cfg.vocab_size, 20).astype(np.int32),
                       max_new=12))
    k = 11  # slot length starts at 20: rows reach 20 + 11 = 31 == max_len - 1
    eng.runner.draft_for = lambda req, slot: np.zeros((k,), np.int32)
    with pytest.raises(AssertionError):
        eng.run(max_rounds=4)


# -------------------------------------------------------- streaming API ----


def test_generate_streams_multi_token_deltas(tiny):
    cfg, api, params = tiny
    eng = EngineCore(cfg, params, n_slots=2, max_len=64, prompt_len=12,
                     mode="static", cache_layout="paged", block_size=8,
                     spec_decode=4)
    rng = np.random.default_rng(3)
    pat = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    deltas = []
    for out in eng.generate(np.tile(pat, 4), max_new=12, request_id="g"):
        deltas.append(list(out.new_token_ids))
        last = out
    toks = [t for d in deltas for t in d]
    assert last.finished and len(toks) == 12
    assert toks == eng.finished["g"].out_tokens
    assert max(len(d) for d in deltas) > 1  # speculation produced real blocks
