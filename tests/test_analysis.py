"""Tests for the in-repo static analysis (`repro.analysis`).

Three layers:

1. fixture trees — one deliberately-violating snippet per rule, asserting
   the pass reports exactly that rule at that site (and that the pragma /
   baseline escape hatches behave);
2. the clean-tree gate — all three passes over the real ``src/repro`` with
   the checked-in baseline must report zero active findings (the same
   invariant CI enforces via ``python -m repro.analysis --all``);
3. regression tests for the concurrency fixes the lock pass drove
   (handoff counter atomicity, AsyncEngine loop-owned mirrors, prefill
   pool thread deprioritization hardening).
"""
import asyncio
import os
import textwrap
import threading
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import default_baseline, default_root, run_passes
from repro.analysis.common import (
    Finding, load_baseline, parse_pragmas, split_baselined)
from repro.analysis import determinism, locklint

REPO_SRC = Path(__file__).resolve().parents[1] / "src" / "repro"


def _tree(tmp_path: Path, files: dict) -> Path:
    root = tmp_path / "fixture"
    for rel, text in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return root


def _rules(findings):
    return sorted(f.rule for f in findings)


# ---------------------------------------------------------------- lock pass --

LOCK_FIXTURE = """\
    import threading

    class Chan:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0  # guarded-by: self._lock
            self.items = []  # owned-by: worker

        def bad_unguarded(self):
            self.count += 1

        def good_guarded(self):
            with self._lock:
                self.count += 1

        def good_thread(self):  # thread: worker
            self.items.append(1)

        def good_nested(self):  # thread: worker
            def inner():
                self.items.append(2)
            return inner

        def bad_thread(self):
            self.items.append(3)
"""


def test_lock_unguarded_and_wrong_thread(tmp_path):
    root = _tree(tmp_path, {"mod.py": LOCK_FIXTURE})
    found = locklint.run(root)
    assert _rules(found) == ["lock:thread", "lock:unguarded"]
    by_rule = {f.rule: f for f in found}
    assert "bad_unguarded" in by_rule["lock:unguarded"].message
    assert "bad_thread" in by_rule["lock:thread"].message
    # findings carry a usable location
    assert by_rule["lock:unguarded"].path == "mod.py"
    assert by_rule["lock:unguarded"].line > 0


def test_lock_init_exempt_and_annotation_collection(tmp_path):
    root = _tree(tmp_path, {"mod.py": """\
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.n = 0  # guarded-by: self._lock
                self.n = 1  # __init__ writes are exempt: not shared yet
    """})
    assert locklint.run(root) == []


def test_lock_pragma_waives_line_and_def(tmp_path):
    root = _tree(tmp_path, {"mod.py": """\
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.n = 0  # guarded-by: self._lock

            def waived_line(self):
                return self.n  # analysis: allow(lock:unguarded) — torn read tolerated

            def waived_def(self):  # analysis: allow(lock:unguarded) — whole body audited
                self.n += 1
                return self.n

            def still_bad(self):
                return self.n
    """})
    found = locklint.run(root)
    assert _rules(found) == ["lock:unguarded"]
    assert "still_bad" in found[0].message


def test_lock_cross_object_bind(tmp_path):
    root = _tree(tmp_path, {
        "pool.py": """\
            class Pool:
                def __init__(self):
                    self.state = None  # owned-by: pool-thread
        """,
        "user.py": """\
            # analysis: bind(pool=Pool)

            def misuse(pool):
                pool.state = 3

            def fine(pool):  # thread: pool-thread
                pool.state = 4
        """,
    })
    found = locklint.run(root)
    assert _rules(found) == ["lock:thread"]
    assert found[0].path == "user.py"
    assert "Pool.state" in found[0].message


def test_lock_shared_global_rebind(tmp_path):
    root = _tree(tmp_path, {
        "sing.py": """\
            class T:
                pass

            # analysis: shared-global(TRACER)
            TRACER = T()
        """,
        "evil.py": """\
            from fixture import sing

            def swap():
                sing.TRACER = None
        """,
    })
    found = locklint.run(root)
    assert _rules(found) == ["lock:global-rebind"]
    assert found[0].path == "evil.py"


def test_pragma_without_reason_is_a_finding(tmp_path):
    root = _tree(tmp_path, {"mod.py": """\
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.n = 0  # guarded-by: self._lock

            def f(self):
                return self.n  # analysis: allow(lock:unguarded)
    """})
    found = locklint.run(root)
    # the reasonless pragma is itself flagged AND does not waive the rule
    assert _rules(found) == ["analysis:pragma-no-reason", "lock:unguarded"]


def test_comment_block_pragma_covers_following_def():
    waivers_line, waivers_def, findings = parse_pragmas(textwrap.dedent("""\
        # analysis: allow(lock:unguarded) — two-line justification that
        # wraps onto a continuation comment line
        def target(self):
            return self.n
    """), "mod.py")
    assert findings == []
    assert waivers_def == {3: {"lock:unguarded"}}


# -------------------------------------------------------- determinism pass --

def test_det_wallclock_flagged_and_pragma_waived(tmp_path):
    root = _tree(tmp_path, {"sched.py": """\
        import time

        def decide(queue):
            return time.time() < queue[0].deadline

        def metered(stats):
            stats.t = time.perf_counter()  # analysis: allow(det:wallclock) — stats only
    """})
    found = determinism.run(root)
    assert _rules(found) == ["det:wallclock"]
    assert "decide" in found[0].message


def test_det_bare_set_iteration(tmp_path):
    root = _tree(tmp_path, {"sched.py": """\
        def order(slots):
            live = {s for s in slots if s.busy}
            out = []
            for s in live:
                out.append(s)
            return out

        def fine(slots):
            live = {s for s in slots if s.busy}
            return [s for s in sorted(live)]
    """})
    found = determinism.run(root)
    assert _rules(found) == ["det:bare-set-iter"]
    assert "order" in found[0].message


def test_det_unkeyed_prng(tmp_path):
    root = _tree(tmp_path, {"samp.py": """\
        import jax

        def bad(logits, seed):
            return jax.random.categorical(jax.random.PRNGKey(seed), logits)

        def good(logits, key, step):
            k = jax.random.fold_in(key, step)
            return jax.random.categorical(k, logits)

        def also_good(logits, key):
            return jax.random.categorical(jax.random.split(key)[0], logits)
    """})
    found = determinism.run(root)
    assert _rules(found) == ["det:unkeyed-prng"]
    assert "bad" in found[0].message


# ------------------------------------------------------------- kernel pass --

def _bad_kernel_ops():
    """Deliberately-broken fake ops exercised through check_op: the checker
    must catch each invariant violation with no real kernel executing."""
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def oob_index_map(x):  # index map walks past the operand
        n = x.shape[0]
        return pl.pallas_call(
            lambda x_ref, o_ref: None,
            grid=(n // 8,),
            in_specs=[pl.BlockSpec((8,), lambda i: (i + 1,))],
            out_specs=pl.BlockSpec((8,), lambda i: (i,)),
            out_shape=jnp.zeros((n,), jnp.float32),
        )(x)

    def bad_divisibility(x):  # block does not divide the (unpadded) dim
        n = x.shape[0]
        return pl.pallas_call(
            lambda x_ref, o_ref: None,
            grid=(1,),
            in_specs=[pl.BlockSpec((7,), lambda i: (0,))],
            out_specs=pl.BlockSpec((n,), lambda i: (0,)),
            out_shape=jnp.zeros((n,), jnp.float32),
        )(x)

    def fp_materializing_quant(k_q, k_scale):  # dequantizes the WHOLE cache
        deq = k_q.astype(jnp.float32) * k_scale[..., None]
        return pl.pallas_call(
            lambda k_ref, o_ref: None,
            grid=(1,),
            in_specs=[pl.BlockSpec(deq.shape, lambda i: (0,) * deq.ndim)],
            out_specs=pl.BlockSpec((1,), lambda i: (0,)),
            out_shape=jnp.zeros((1,), jnp.float32),
        )(deq)

    def clean(x):
        n = x.shape[0]
        return pl.pallas_call(
            lambda x_ref, o_ref: None,
            grid=(n // 8,),
            in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
            out_specs=pl.BlockSpec((8,), lambda i: (i,)),
            out_shape=jnp.zeros((n,), jnp.float32),
        )(x)

    return oob_index_map, bad_divisibility, fp_materializing_quant, clean


def test_kernel_checker_catches_oob_index_map():
    import jax.numpy as jnp
    from repro.analysis.kernel_check import KernelCase, check_op

    oob, _, _, _ = _bad_kernel_ops()
    found = check_op(oob, [KernelCase("oob", (jnp.zeros(64, jnp.float32),), {})])
    assert "kernel:index-oob" in _rules(found)


def test_kernel_checker_catches_block_divisibility():
    import jax.numpy as jnp
    from repro.analysis.kernel_check import KernelCase, check_op

    _, baddiv, _, _ = _bad_kernel_ops()
    found = check_op(
        baddiv, [KernelCase("div", (jnp.zeros(64, jnp.float32),), {})])
    assert "kernel:block-divisibility" in _rules(found)


def test_kernel_checker_catches_fp_cache_materialization():
    import jax.numpy as jnp
    from repro.analysis.kernel_check import KernelCase, check_op

    _, _, fpmat, _ = _bad_kernel_ops()
    k_q = jnp.zeros((4, 2, 128, 16), jnp.int8)
    k_scale = jnp.ones((4, 2, 128), jnp.float32)
    found = check_op(fpmat, [KernelCase(
        "quant", (k_q, k_scale), {}, fp_elems=int(np.prod(k_q.shape)))])
    assert "kernel:fp-cache-alloc" in _rules(found)


def test_kernel_checker_clean_op_passes():
    import jax.numpy as jnp
    from repro.analysis.kernel_check import KernelCase, check_op

    _, _, _, clean = _bad_kernel_ops()
    found = check_op(
        clean,
        [KernelCase("ok", (jnp.zeros(64, jnp.float32),), {}, fp_elems=10**9)])
    assert found == []


# ---------------------------------------------------------------- baseline --

def test_baseline_format_and_suppression(tmp_path):
    f = Finding("lock", "lock:unguarded", "mod.py", 10, "msg")
    bl = tmp_path / "baseline.txt"
    bl.write_text(
        "# comment\n"
        f"{f.fingerprint} lock:unguarded mod.py — tracked debt, see #42\n")
    fps, errors = load_baseline(bl)
    assert errors == [] and fps == {f.fingerprint}
    active, suppressed = split_baselined([f], fps)
    assert active == [] and suppressed == [f]


def test_baseline_rejects_missing_reason_and_bad_fingerprint(tmp_path):
    bl = tmp_path / "baseline.txt"
    bl.write_text(
        "deadbeefcafe lock:unguarded mod.py\n"  # no reason
        "nothex lock:unguarded mod.py — why\n")  # malformed fingerprint
    fps, errors = load_baseline(bl)
    assert fps == set()
    assert len(errors) == 2
    assert "no reason" in errors[0]
    assert "malformed" in errors[1]


def test_fingerprint_is_line_number_independent():
    a = Finding("lock", "lock:unguarded", "mod.py", 10, "msg")
    b = Finding("lock", "lock:unguarded", "mod.py", 99, "msg")
    assert a.fingerprint == b.fingerprint
    assert a.fingerprint != Finding(
        "lock", "lock:unguarded", "mod.py", 10, "other").fingerprint


# -------------------------------------------------------------- clean tree --

def test_real_tree_has_no_unbaselined_findings():
    """The CI gate, as a test: every pass over the real src/repro must be
    clean modulo the checked-in baseline."""
    results = run_passes(["lock", "kernel", "determinism"],
                         root=default_root())
    fps, errors = load_baseline(default_baseline())
    assert errors == []
    offenders = []
    for name, found in results.items():
        active, _ = split_baselined(found, fps)
        offenders += [f"[{name}] {f.render()}" for f in active]
    assert offenders == [], "\n".join(offenders)


def test_default_root_is_the_source_tree():
    assert default_root() == REPO_SRC


# --------------------------------------------- satellite: handoff counters --

def test_handoff_ship_counters_exact_under_contention():
    """ship() meters from the engine thread AND the pool thread; the lock
    the lint demanded must make the counters exact, not approximate."""
    from repro.serving.disagg.handoff import KVHandoffChannel

    chan = KVHandoffChannel()  # no mesh: passthrough, still metered
    payload = np.zeros(32, np.float32)
    per_thread, threads = 300, 4

    def hammer(eager):
        for _ in range(per_thread):
            chan.ship(payload, eager=eager)

    ts = [threading.Thread(target=hammer, args=(i % 2 == 1,))
          for i in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    total = per_thread * threads
    assert chan.segments == total
    assert chan.eager_segments == total // 2
    assert chan.bytes_shipped == total * payload.nbytes
    snap = chan.snapshot()
    assert snap["segments"] == total
    assert snap["pending"] == 0


# ------------------------------------- satellite: _deprioritize hardening --

def test_deprioritize_survives_permission_error(monkeypatch):
    from repro.serving.disagg import prefill_pool as pp

    def deny(*a, **k):
        raise PermissionError("RLIMIT_NICE")

    monkeypatch.setattr(os, "sched_setscheduler", deny, raising=False)
    monkeypatch.setattr(os, "setpriority", deny, raising=False)
    pp._deprioritize()  # must not raise


def test_deprioritize_survives_missing_apis(monkeypatch):
    from repro.serving.disagg import prefill_pool as pp

    monkeypatch.delattr(os, "sched_setscheduler", raising=False)
    monkeypatch.delattr(threading, "get_native_id", raising=False)
    pp._deprioritize()  # must not raise


def test_deprioritize_as_initializer_does_not_poison_executor(monkeypatch):
    """The failure mode the guard exists for: a raising initializer breaks
    the executor and every later submit dies with BrokenThreadPool."""
    from repro.serving.disagg import prefill_pool as pp

    def deny(*a, **k):
        raise PermissionError("denied")

    monkeypatch.setattr(os, "sched_setscheduler", deny, raising=False)
    monkeypatch.setattr(os, "setpriority", deny, raising=False)
    ex = ThreadPoolExecutor(max_workers=1, initializer=pp._deprioritize)
    try:
        assert ex.submit(lambda: 41 + 1).result(timeout=30) == 42
    finally:
        ex.shutdown(wait=True)


# ------------------------------- satellite: AsyncEngine loop-owned mirrors --

class _StubRunner:
    max_len = 128
    cache_layout = "contiguous"


class _StubScheduler:
    def __init__(self):
        self.queue = []

    def validate(self, req):
        pass


class _StubCore:
    """Just enough EngineCore surface for AsyncEngine admission paths."""

    def __init__(self):
        self.scheduler = _StubScheduler()
        self.runner = _StubRunner()


def _stub_engine(max_queue=4):
    from repro.serving.async_engine import AsyncEngine

    return AsyncEngine(_StubCore(), max_queue=max_queue)


def test_duplicate_id_rejected_even_after_stream_closed():
    """_ids (the loop-owned ever-admitted set) must keep rejecting a reused
    id after the stream is gone — the old code read core.finished, which
    the lint now forbids mid-step."""
    from repro.serving.async_engine import AdmissionRejected

    async def go():
        eng = _stub_engine()
        await eng.submit([1, 2, 3], request_id="r1", max_new=4)
        # simulate the stream finishing: _route deletes the stream entry,
        # but the id stays admitted forever
        del eng._streams["r1"]
        eng._pending.clear()
        with pytest.raises(AdmissionRejected) as exc:
            await eng.submit([1, 2, 3], request_id="r1", max_new=4)
        assert exc.value.reason.startswith("duplicate_id")
        assert eng.reject_reasons == {"duplicate_id": 1}

    asyncio.run(go())


def test_backlog_uses_between_quanta_snapshot_not_live_core():
    """Backpressure must consult _core_backlog (the mirror refreshed
    between quanta), never len(core.scheduler.queue) live."""
    from repro.serving.async_engine import AdmissionRejected

    async def go():
        eng = _stub_engine(max_queue=4)
        # live core queue says "full" but the snapshot says empty: admission
        # must trust the snapshot (the live read would race a quantum)
        eng.core.scheduler.queue = [object()] * 10
        await eng.submit([1], request_id="a", max_new=1)  # not rejected
        # snapshot says full -> rejected, even though we just emptied core
        eng.core.scheduler.queue = []
        eng._pending.clear()
        eng._core_backlog = eng.max_queue
        with pytest.raises(AdmissionRejected) as exc:
            await eng.submit([1], request_id="b", max_new=1)
        assert exc.value.reason.startswith("queue_full")

    asyncio.run(go())


def test_drain_control_refreshes_backlog_mirror():
    """_drain_control is the one place admission state touches the core:
    it must leave _core_backlog equal to the scheduler queue length."""

    async def go():
        eng = _stub_engine()
        submitted = []
        eng.core.submit = lambda req: (
            submitted.append(req), eng.core.scheduler.queue.append(req))
        await eng.submit([1], request_id="a", max_new=1)
        await eng.submit([2], request_id="b", max_new=1)
        eng._drain_control()
        assert [r.request_id for r in submitted] == ["a", "b"]
        assert eng._core_backlog == 2
        assert eng._backlog() == 2  # pending drained, mirror fresh

    asyncio.run(go())
