"""Tests for the in-repo static analysis (`repro.analysis`).

Three layers:

1. fixture trees / fixture programs — one deliberately-violating snippet
   per rule, asserting the pass reports exactly that rule at that site
   (and that the pragma / baseline escape hatches behave);
2. the clean-tree gate — all four passes over the real ``src/repro`` with
   the checked-in baseline must report zero active findings (the same
   invariant CI enforces via ``python -m repro.analysis --all``);
3. regression tests for the concurrency fixes the lock pass drove
   (handoff counter atomicity, AsyncEngine loop-owned mirrors, prefill
   pool thread deprioritization hardening).
"""
import asyncio
import os
import textwrap
import threading
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import default_baseline, default_root, run_passes
from repro.analysis.common import (
    Finding, load_baseline, parse_pragmas, split_baselined)
from repro.analysis import determinism, locklint

REPO_SRC = Path(__file__).resolve().parents[1] / "src" / "repro"


def _tree(tmp_path: Path, files: dict) -> Path:
    root = tmp_path / "fixture"
    for rel, text in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return root


def _rules(findings):
    return sorted(f.rule for f in findings)


# ---------------------------------------------------------------- lock pass --

LOCK_FIXTURE = """\
    import threading

    class Chan:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0  # guarded-by: self._lock
            self.items = []  # owned-by: worker

        def bad_unguarded(self):
            self.count += 1

        def good_guarded(self):
            with self._lock:
                self.count += 1

        def good_thread(self):  # thread: worker
            self.items.append(1)

        def good_nested(self):  # thread: worker
            def inner():
                self.items.append(2)
            return inner

        def bad_thread(self):
            self.items.append(3)
"""


def test_lock_unguarded_and_wrong_thread(tmp_path):
    root = _tree(tmp_path, {"mod.py": LOCK_FIXTURE})
    found = locklint.run(root)
    assert _rules(found) == ["lock:thread", "lock:unguarded"]
    by_rule = {f.rule: f for f in found}
    assert "bad_unguarded" in by_rule["lock:unguarded"].message
    assert "bad_thread" in by_rule["lock:thread"].message
    # findings carry a usable location
    assert by_rule["lock:unguarded"].path == "mod.py"
    assert by_rule["lock:unguarded"].line > 0


def test_lock_init_exempt_and_annotation_collection(tmp_path):
    root = _tree(tmp_path, {"mod.py": """\
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.n = 0  # guarded-by: self._lock
                self.n = 1  # __init__ writes are exempt: not shared yet
    """})
    assert locklint.run(root) == []


def test_lock_pragma_waives_line_and_def(tmp_path):
    root = _tree(tmp_path, {"mod.py": """\
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.n = 0  # guarded-by: self._lock

            def waived_line(self):
                return self.n  # analysis: allow(lock:unguarded) — torn read tolerated

            def waived_def(self):  # analysis: allow(lock:unguarded) — whole body audited
                self.n += 1
                return self.n

            def still_bad(self):
                return self.n
    """})
    found = locklint.run(root)
    assert _rules(found) == ["lock:unguarded"]
    assert "still_bad" in found[0].message


def test_lock_cross_object_bind(tmp_path):
    root = _tree(tmp_path, {
        "pool.py": """\
            class Pool:
                def __init__(self):
                    self.state = None  # owned-by: pool-thread
        """,
        "user.py": """\
            # analysis: bind(pool=Pool)

            def misuse(pool):
                pool.state = 3

            def fine(pool):  # thread: pool-thread
                pool.state = 4
        """,
    })
    found = locklint.run(root)
    assert _rules(found) == ["lock:thread"]
    assert found[0].path == "user.py"
    assert "Pool.state" in found[0].message


def test_lock_shared_global_rebind(tmp_path):
    root = _tree(tmp_path, {
        "sing.py": """\
            class T:
                pass

            # analysis: shared-global(TRACER)
            TRACER = T()
        """,
        "evil.py": """\
            from fixture import sing

            def swap():
                sing.TRACER = None
        """,
    })
    found = locklint.run(root)
    assert _rules(found) == ["lock:global-rebind"]
    assert found[0].path == "evil.py"


def test_pragma_without_reason_is_a_finding(tmp_path):
    root = _tree(tmp_path, {"mod.py": """\
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.n = 0  # guarded-by: self._lock

            def f(self):
                return self.n  # analysis: allow(lock:unguarded)
    """})
    found = locklint.run(root)
    # the reasonless pragma is itself flagged AND does not waive the rule
    assert _rules(found) == ["analysis:pragma-no-reason", "lock:unguarded"]


def test_comment_block_pragma_covers_following_def():
    waivers_line, waivers_def, findings = parse_pragmas(textwrap.dedent("""\
        # analysis: allow(lock:unguarded) — two-line justification that
        # wraps onto a continuation comment line
        def target(self):
            return self.n
    """), "mod.py")
    assert findings == []
    assert waivers_def == {3: {"lock:unguarded"}}


# -------------------------------------------------------- determinism pass --

def test_det_wallclock_flagged_and_pragma_waived(tmp_path):
    root = _tree(tmp_path, {"sched.py": """\
        import time

        def decide(queue):
            return time.time() < queue[0].deadline

        def metered(stats):
            stats.t = time.perf_counter()  # analysis: allow(det:wallclock) — stats only
    """})
    found = determinism.run(root)
    assert _rules(found) == ["det:wallclock"]
    assert "decide" in found[0].message


def test_det_bare_set_iteration(tmp_path):
    root = _tree(tmp_path, {"sched.py": """\
        def order(slots):
            live = {s for s in slots if s.busy}
            out = []
            for s in live:
                out.append(s)
            return out

        def fine(slots):
            live = {s for s in slots if s.busy}
            return [s for s in sorted(live)]
    """})
    found = determinism.run(root)
    assert _rules(found) == ["det:bare-set-iter"]
    assert "order" in found[0].message


def test_det_unkeyed_prng(tmp_path):
    root = _tree(tmp_path, {"samp.py": """\
        import jax

        def bad(logits, seed):
            return jax.random.categorical(jax.random.PRNGKey(seed), logits)

        def good(logits, key, step):
            k = jax.random.fold_in(key, step)
            return jax.random.categorical(k, logits)

        def also_good(logits, key):
            return jax.random.categorical(jax.random.split(key)[0], logits)
    """})
    found = determinism.run(root)
    assert _rules(found) == ["det:unkeyed-prng"]
    assert "bad" in found[0].message


# ------------------------------------------------------------- kernel pass --

def _bad_kernel_ops():
    """Deliberately-broken fake ops exercised through check_op: the checker
    must catch each invariant violation with no real kernel executing."""
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def oob_index_map(x):  # index map walks past the operand
        n = x.shape[0]
        return pl.pallas_call(
            lambda x_ref, o_ref: None,
            grid=(n // 8,),
            in_specs=[pl.BlockSpec((8,), lambda i: (i + 1,))],
            out_specs=pl.BlockSpec((8,), lambda i: (i,)),
            out_shape=jnp.zeros((n,), jnp.float32),
        )(x)

    def bad_divisibility(x):  # block does not divide the (unpadded) dim
        n = x.shape[0]
        return pl.pallas_call(
            lambda x_ref, o_ref: None,
            grid=(1,),
            in_specs=[pl.BlockSpec((7,), lambda i: (0,))],
            out_specs=pl.BlockSpec((n,), lambda i: (0,)),
            out_shape=jnp.zeros((n,), jnp.float32),
        )(x)

    def fp_materializing_quant(k_q, k_scale):  # dequantizes the WHOLE cache
        deq = k_q.astype(jnp.float32) * k_scale[..., None]
        return pl.pallas_call(
            lambda k_ref, o_ref: None,
            grid=(1,),
            in_specs=[pl.BlockSpec(deq.shape, lambda i: (0,) * deq.ndim)],
            out_specs=pl.BlockSpec((1,), lambda i: (0,)),
            out_shape=jnp.zeros((1,), jnp.float32),
        )(deq)

    def clean(x):
        n = x.shape[0]
        return pl.pallas_call(
            lambda x_ref, o_ref: None,
            grid=(n // 8,),
            in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
            out_specs=pl.BlockSpec((8,), lambda i: (i,)),
            out_shape=jnp.zeros((n,), jnp.float32),
        )(x)

    return oob_index_map, bad_divisibility, fp_materializing_quant, clean


def test_kernel_checker_catches_oob_index_map():
    import jax.numpy as jnp
    from repro.analysis.kernel_check import KernelCase, check_op

    oob, _, _, _ = _bad_kernel_ops()
    found = check_op(oob, [KernelCase("oob", (jnp.zeros(64, jnp.float32),), {})])
    assert "kernel:index-oob" in _rules(found)


def test_kernel_checker_catches_block_divisibility():
    import jax.numpy as jnp
    from repro.analysis.kernel_check import KernelCase, check_op

    _, baddiv, _, _ = _bad_kernel_ops()
    found = check_op(
        baddiv, [KernelCase("div", (jnp.zeros(64, jnp.float32),), {})])
    assert "kernel:block-divisibility" in _rules(found)


def test_kernel_checker_catches_fp_cache_materialization():
    import jax.numpy as jnp
    from repro.analysis.kernel_check import KernelCase, check_op

    _, _, fpmat, _ = _bad_kernel_ops()
    k_q = jnp.zeros((4, 2, 128, 16), jnp.int8)
    k_scale = jnp.ones((4, 2, 128), jnp.float32)
    found = check_op(fpmat, [KernelCase(
        "quant", (k_q, k_scale), {}, fp_elems=int(np.prod(k_q.shape)))])
    assert "kernel:fp-cache-alloc" in _rules(found)


def test_kernel_checker_clean_op_passes():
    import jax.numpy as jnp
    from repro.analysis.kernel_check import KernelCase, check_op

    _, _, _, clean = _bad_kernel_ops()
    found = check_op(
        clean,
        [KernelCase("ok", (jnp.zeros(64, jnp.float32),), {}, fp_elems=10**9)])
    assert found == []


# ---------------------------------------------------------------- baseline --

def test_baseline_format_and_suppression(tmp_path):
    f = Finding("lock", "lock:unguarded", "mod.py", 10, "msg")
    bl = tmp_path / "baseline.txt"
    bl.write_text(
        "# comment\n"
        f"{f.fingerprint} lock:unguarded mod.py — tracked debt, see #42\n")
    fps, errors = load_baseline(bl)
    assert errors == [] and fps == {f.fingerprint}
    active, suppressed = split_baselined([f], fps)
    assert active == [] and suppressed == [f]


def test_baseline_rejects_missing_reason_and_bad_fingerprint(tmp_path):
    bl = tmp_path / "baseline.txt"
    bl.write_text(
        "deadbeefcafe lock:unguarded mod.py\n"  # no reason
        "nothex lock:unguarded mod.py — why\n")  # malformed fingerprint
    fps, errors = load_baseline(bl)
    assert fps == set()
    assert len(errors) == 2
    assert "no reason" in errors[0]
    assert "malformed" in errors[1]


def test_fingerprint_is_line_number_independent():
    a = Finding("lock", "lock:unguarded", "mod.py", 10, "msg")
    b = Finding("lock", "lock:unguarded", "mod.py", 99, "msg")
    assert a.fingerprint == b.fingerprint
    assert a.fingerprint != Finding(
        "lock", "lock:unguarded", "mod.py", 10, "other").fingerprint


def test_fingerprint_folds_in_scope():
    """Identical messages in DIFFERENT functions must not collide — the
    scope (enclosing def) is part of the fingerprint."""
    a = Finding("lock", "lock:unguarded", "mod.py", 10, "msg", scope="A.f")
    b = Finding("lock", "lock:unguarded", "mod.py", 99, "msg", scope="B.g")
    assert a.fingerprint != b.fingerprint
    # both collapse to the same pre-scope (legacy) fingerprint
    assert a.legacy_fingerprint == b.legacy_fingerprint
    assert a.scope in a.render()


def test_legacy_fingerprint_still_suppresses_with_rewrite_hint():
    """A baseline written before scopes existed keeps suppressing, and the
    CLI surfaces a rewrite hint naming the new fingerprint."""
    from repro.analysis.common import legacy_hints

    f = Finding("det", "det:wallclock", "core.py", 5, "msg", scope="C.step")
    baseline = {f.legacy_fingerprint}
    active, suppressed = split_baselined([f], baseline)
    assert active == [] and suppressed == [f]
    hints = legacy_hints([f], baseline)
    assert len(hints) == 1
    assert f.fingerprint in hints[0] and f.legacy_fingerprint in hints[0]
    # an entry already using the scoped fingerprint needs no hint
    assert legacy_hints([f], {f.fingerprint}) == []


# ------------------------------------------------------------ program pass --

def _collect():
    got = []
    return got, lambda rule, msg: got.append(rule)


def test_progcheck_dtype_flow_catches_f64():
    import jax
    import jax.numpy as jnp
    from repro.analysis import progcheck

    with jax.experimental.enable_x64():
        closed = jax.make_jaxpr(lambda x: x.astype(jnp.float64) * 2.0)(
            jax.ShapeDtypeStruct((8,), jnp.float32))
    got, emit = _collect()
    progcheck.check_dtype_flow(closed, quantized=False,
                               fp_threshold_elems=10**9, emit=emit)
    assert "prog:f64" in got


def test_progcheck_catches_injected_fp_cache_dequant():
    """A quantized program that dequantizes the WHOLE KV cache into one
    f32 buffer (the jnp-fallback failure mode) must be flagged; a program
    under the threshold must not."""
    import jax
    import jax.numpy as jnp
    from repro.analysis import progcheck

    k_q = jax.ShapeDtypeStruct((4, 2, 128, 16), jnp.int8)
    scale = jax.ShapeDtypeStruct((4, 2, 128), jnp.float32)
    closed = jax.make_jaxpr(
        lambda k, s: (k.astype(jnp.float32) * s[..., None]).sum())(k_q, scale)
    cache_elems = int(np.prod(k_q.shape))
    got, emit = _collect()
    progcheck.check_dtype_flow(closed, quantized=True,
                               fp_threshold_elems=cache_elems, emit=emit)
    assert got == ["prog:fp-cache-alloc"]
    # same program, fp cache: dequant-sized f32 buffers are legitimate
    got, emit = _collect()
    progcheck.check_dtype_flow(closed, quantized=False,
                               fp_threshold_elems=cache_elems, emit=emit)
    assert got == []
    # per-layer-view-sized intermediates stay under the threshold
    got, emit = _collect()
    progcheck.check_dtype_flow(closed, quantized=True,
                               fp_threshold_elems=2 * cache_elems, emit=emit)
    assert got == []


def test_progcheck_catches_dropped_cache_donation():
    """A cache-sized buffer threaded through a step program without
    donation doubles the KV footprint — the audit must flag exactly the
    undonated variant."""
    import jax
    import jax.numpy as jnp
    from repro.analysis import progcheck

    tok = jax.ShapeDtypeStruct((), jnp.int32)
    cache = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def step(t, c):
        c = c.at[0, 0].set(t.astype(jnp.float32))
        return c[0, 0], c

    closed = jax.make_jaxpr(step)(tok, cache)
    inputs = (tok, cache)
    got, emit = _collect()
    progcheck.check_donation(closed, inputs, donate_argnums=(),
                             threshold_bytes=cache.size * 4, emit=emit)
    assert got == ["prog:cache-not-donated"]
    got, emit = _collect()
    progcheck.check_donation(closed, inputs, donate_argnums=(1,),
                             threshold_bytes=cache.size * 4, emit=emit)
    assert got == []
    # small threaded values (sampler seeds and friends) never trigger
    got, emit = _collect()
    progcheck.check_donation(closed, inputs, donate_argnums=(),
                             threshold_bytes=10**9, emit=emit)
    assert got == []


def test_progcheck_catches_cost_drift():
    from repro.analysis import progcheck

    def row(ratio):
        return dict(layout="contiguous", kv_dtype="int8", program="decode:x",
                    kind="kv_stream_bytes", counted=ratio * 100.0,
                    bound=100.0, ratio=ratio, tol_lo=0.87, tol_hi=1.15)

    got, emit = _collect()
    progcheck.cost_findings([row(1.0), row(1.14)], lambda r: emit)
    assert got == []
    got, emit = _collect()
    progcheck.cost_findings([row(2.0), row(0.4)], lambda r: emit)
    assert got == ["prog:cost-drift", "prog:cost-drift"]


class _StubEngine:
    def __init__(self):
        self.programs = {}


class _StubProgram:
    def __init__(self):
        self.abstract_inputs = ((),)


class _BucketStub:
    """Minimal ModelRunner bucket surface: quantum-aligned, covering, and
    closed over the built grid."""
    cache_layout = "contiguous"
    prompt_len = 8
    max_len = 32
    prefill_chunk = None

    def __init__(self):
        self.engine = _StubEngine()

    def bucket(self, n):
        b = -(-n // 8) * 8
        return min(b, self.max_len)

    def reachable_buckets(self):
        return sorted({self.bucket(n) for n in range(1, self.max_len + 1)})

    def progs(self, b):
        self.engine.programs.setdefault(f"prefill:{b}", _StubProgram())
        return {}

    def program_signatures(self):
        return dict(self.engine.programs)


def test_progcheck_bucket_coverage_clean_stub():
    from repro.analysis import progcheck

    runner = _BucketStub()
    for b in runner.reachable_buckets():
        runner.progs(b)  # the "built grid"
    got, emit = _collect()
    progcheck.check_bucket_coverage(runner, emit)
    assert got == []


def test_progcheck_catches_bucket_shape_leak():
    """bucket(n) = n (per-prompt shapes) blows the O(log) cardinality
    promise — the production recompile-storm failure mode."""
    from repro.analysis import progcheck

    class Leaky(_BucketStub):
        def bucket(self, n):
            return n

    got, emit = _collect()
    progcheck.check_bucket_coverage(Leaky(), emit)
    assert "prog:shape-leak" in got


def test_progcheck_catches_grid_closure_leak():
    """A program registered only when dispatch asks for it (not by
    build_serving_grid) is a per-request recompile — the closure check
    must see the registry grow."""
    from repro.analysis import progcheck

    runner = _BucketStub()  # grid NOT built: every progs() call registers
    got, emit = _collect()
    progcheck.check_bucket_coverage(runner, emit)
    assert "prog:shape-leak" in got


def test_progcheck_catches_noncovering_bucket():
    from repro.analysis import progcheck

    class Truncating(_BucketStub):
        def bucket(self, n):
            return 8  # every prompt padded DOWN to 8: truncation

    got, emit = _collect()
    progcheck.check_bucket_coverage(Truncating(), emit)
    assert "prog:shape-leak" in got


def _fake_ops_module(tmp_path, name, ns):
    import types

    mod = types.ModuleType(name)
    src = tmp_path / f"{name}.py"
    src.write_text("# fixture ops module\n")
    mod.__file__ = str(src)
    for k, v in ns.items():
        setattr(mod, k, v)
    return mod


def test_progcheck_flags_missing_and_malformed_op_annotations(tmp_path):
    from repro.analysis import progcheck

    got, emit = _collect()
    emit_at = lambda path, line, scope="": emit  # noqa: E731
    bare = _fake_ops_module(tmp_path, "bare_ops", {})
    progcheck.check_op_contracts(emit_at, modules=[bare])
    assert got == ["prog:op-annotation"]

    def my_op(q, k, v):
        return q

    got, emit = _collect()
    emit_at = lambda path, line, scope="": emit  # noqa: E731
    bad = _fake_ops_module(tmp_path, "bad_ops", {
        "my_op": my_op,
        "CACHE_OPERANDS": {
            "my_op": {"args": ("k", "nope"), "writes": False},  # unknown arg
            "ghost": {"args": ("k",), "writes": False},  # missing callable
            "my_op2": None,
        },
        "my_op2": my_op,
    })
    bad.CACHE_OPERANDS["my_op2"] = {"args": ("k",), "writes": True}
    progcheck.check_op_contracts(emit_at, modules=[bad])
    assert sorted(got) == ["prog:op-annotation"] * 3


def test_progcheck_catches_cache_passthrough_alias(tmp_path):
    """A declared read-only entry returning its cache operand unchanged is
    an aliasing violation; a computing entry is not."""
    import jax
    import jax.numpy as jnp
    from repro.analysis import progcheck

    s = jax.ShapeDtypeStruct

    def passthrough(q, k):
        return q + 1.0, k  # hands the cache buffer back out

    def computes(q, k):
        return (q[:, None, :] * k).sum(1)

    probe = ((s((4, 8), jnp.float32), s((4, 8), jnp.float32)), {})
    mod = _fake_ops_module(tmp_path, "alias_ops", {
        "passthrough": passthrough,
        "computes": computes,
        "CACHE_OPERANDS": {
            "passthrough": {"args": ("k",), "writes": False},
            "computes": {"args": ("k",), "writes": False},
        },
        "_ANALYSIS_PROBES": {"passthrough": probe, "computes": probe},
    })
    got, emit = _collect()
    progcheck.check_op_contracts(
        lambda path, line, scope="": emit, modules=[mod])
    assert got == ["prog:op-alias"]


def test_program_pass_foreign_root_reports_clean(tmp_path):
    """The program pass audits the imported package; fixture trees have no
    programs to trace and must come back clean (not crash)."""
    root = _tree(tmp_path, {"mod.py": "x = 1\n"})
    assert run_passes(["program"], root=root)["program"] == []


def test_cli_rejects_unknown_pass_listing_valid_names(capsys):
    from repro.analysis.__main__ import main

    with pytest.raises(SystemExit) as exc:
        main(["--pass", "bogus"])
    assert exc.value.code == 2
    err = capsys.readouterr().err
    for name in ("lock", "kernel", "determinism", "program"):
        assert name in err


# -------------------------------------------------------------- clean tree --

def test_real_tree_has_no_unbaselined_findings():
    """The CI gate, as a test: every pass over the real src/repro must be
    clean modulo the checked-in baseline."""
    results = run_passes(["lock", "kernel", "determinism", "program"],
                         root=default_root())
    fps, errors = load_baseline(default_baseline())
    assert errors == []
    offenders = []
    for name, found in results.items():
        active, _ = split_baselined(found, fps)
        offenders += [f"[{name}] {f.render()}" for f in active]
    assert offenders == [], "\n".join(offenders)


def test_default_root_is_the_source_tree():
    assert default_root() == REPO_SRC


# --------------------------------------------- satellite: handoff counters --

def test_handoff_ship_counters_exact_under_contention():
    """ship() meters from the engine thread AND the pool thread; the lock
    the lint demanded must make the counters exact, not approximate."""
    from repro.serving.disagg.handoff import KVHandoffChannel

    chan = KVHandoffChannel()  # no mesh: passthrough, still metered
    payload = np.zeros(32, np.float32)
    per_thread, threads = 300, 4

    def hammer(eager):
        for _ in range(per_thread):
            chan.ship(payload, eager=eager)

    ts = [threading.Thread(target=hammer, args=(i % 2 == 1,))
          for i in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    total = per_thread * threads
    assert chan.segments == total
    assert chan.eager_segments == total // 2
    assert chan.bytes_shipped == total * payload.nbytes
    snap = chan.snapshot()
    assert snap["segments"] == total
    assert snap["pending"] == 0


# ------------------------------------- satellite: _deprioritize hardening --

def test_deprioritize_survives_permission_error(monkeypatch):
    from repro.serving.disagg import prefill_pool as pp

    def deny(*a, **k):
        raise PermissionError("RLIMIT_NICE")

    monkeypatch.setattr(os, "sched_setscheduler", deny, raising=False)
    monkeypatch.setattr(os, "setpriority", deny, raising=False)
    pp._deprioritize()  # must not raise


def test_deprioritize_survives_missing_apis(monkeypatch):
    from repro.serving.disagg import prefill_pool as pp

    monkeypatch.delattr(os, "sched_setscheduler", raising=False)
    monkeypatch.delattr(threading, "get_native_id", raising=False)
    pp._deprioritize()  # must not raise


def test_deprioritize_as_initializer_does_not_poison_executor(monkeypatch):
    """The failure mode the guard exists for: a raising initializer breaks
    the executor and every later submit dies with BrokenThreadPool."""
    from repro.serving.disagg import prefill_pool as pp

    def deny(*a, **k):
        raise PermissionError("denied")

    monkeypatch.setattr(os, "sched_setscheduler", deny, raising=False)
    monkeypatch.setattr(os, "setpriority", deny, raising=False)
    ex = ThreadPoolExecutor(max_workers=1, initializer=pp._deprioritize)
    try:
        assert ex.submit(lambda: 41 + 1).result(timeout=30) == 42
    finally:
        ex.shutdown(wait=True)


# ------------------------------- satellite: AsyncEngine loop-owned mirrors --

class _StubRunner:
    max_len = 128
    cache_layout = "contiguous"


class _StubScheduler:
    def __init__(self):
        self.queue = []

    def validate(self, req):
        pass


class _StubCore:
    """Just enough EngineCore surface for AsyncEngine admission paths."""

    def __init__(self):
        self.scheduler = _StubScheduler()
        self.runner = _StubRunner()


def _stub_engine(max_queue=4):
    from repro.serving.async_engine import AsyncEngine

    return AsyncEngine(_StubCore(), max_queue=max_queue)


def test_duplicate_id_rejected_even_after_stream_closed():
    """_ids (the loop-owned ever-admitted set) must keep rejecting a reused
    id after the stream is gone — the old code read core.finished, which
    the lint now forbids mid-step."""
    from repro.serving.async_engine import AdmissionRejected

    async def go():
        eng = _stub_engine()
        await eng.submit([1, 2, 3], request_id="r1", max_new=4)
        # simulate the stream finishing: _route deletes the stream entry,
        # but the id stays admitted forever
        del eng._streams["r1"]
        eng._pending.clear()
        with pytest.raises(AdmissionRejected) as exc:
            await eng.submit([1, 2, 3], request_id="r1", max_new=4)
        assert exc.value.reason.startswith("duplicate_id")
        assert eng.reject_reasons == {"duplicate_id": 1}

    asyncio.run(go())


def test_backlog_uses_between_quanta_snapshot_not_live_core():
    """Backpressure must consult _core_backlog (the mirror refreshed
    between quanta), never len(core.scheduler.queue) live."""
    from repro.serving.async_engine import AdmissionRejected

    async def go():
        eng = _stub_engine(max_queue=4)
        # live core queue says "full" but the snapshot says empty: admission
        # must trust the snapshot (the live read would race a quantum)
        eng.core.scheduler.queue = [object()] * 10
        await eng.submit([1], request_id="a", max_new=1)  # not rejected
        # snapshot says full -> rejected, even though we just emptied core
        eng.core.scheduler.queue = []
        eng._pending.clear()
        eng._core_backlog = eng.max_queue
        with pytest.raises(AdmissionRejected) as exc:
            await eng.submit([1], request_id="b", max_new=1)
        assert exc.value.reason.startswith("queue_full")

    asyncio.run(go())


def test_drain_control_refreshes_backlog_mirror():
    """_drain_control is the one place admission state touches the core:
    it must leave _core_backlog equal to the scheduler queue length."""

    async def go():
        eng = _stub_engine()
        submitted = []
        eng.core.submit = lambda req: (
            submitted.append(req), eng.core.scheduler.queue.append(req))
        await eng.submit([1], request_id="a", max_new=1)
        await eng.submit([2], request_id="b", max_new=1)
        eng._drain_control()
        assert [r.request_id for r in submitted] == ["a", "b"]
        assert eng._core_backlog == 2
        assert eng._backlog() == 2  # pending drained, mirror fresh

    asyncio.run(go())
