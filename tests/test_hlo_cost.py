"""Validate the trip-count-aware HLO cost analyzer against known programs.

These are the experiments referenced from core/hlo_cost.py: XLA's own
``cost_analysis()`` counts while-loop bodies once, so scan-over-layers
programs under-report by ~num_layers x; ``total_costs`` folds trip counts
and must be exact on programs whose FLOPs we can write down.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core.hlo_cost import total_costs


def _hlo(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_plain_matmul_exact():
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    costs = total_costs(_hlo(lambda a, b: a @ b, a, b))
    assert costs["flops"] == 2 * 256 * 512 * 128


def test_scan_folds_trip_count():
    def f(x, ws):
        def body(c, w):
            return c @ w, ()
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    costs = total_costs(_hlo(f, x, ws))
    assert costs["flops"] == 10 * 2 * 256**3


def test_nested_scans_multiply():
    def f(x, ws):
        def outer(c, wrow):
            def inner(ci, w):
                return ci @ w, ()
            c, _ = jax.lax.scan(inner, c, wrow)
            return c, ()
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 3, 128, 128), jnp.float32)
    costs = total_costs(_hlo(f, x, ws))
    assert costs["flops"] == 4 * 3 * 2 * 128**3


def test_xla_cost_analysis_undercounts_loops():
    """The reason this module exists."""
    def f(x, ws):
        def body(c, w):
            return c @ w, ()
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((20, 128, 128), jnp.float32)
    compiled = jax.jit(f).lower(x, ws).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    xla_flops = float(ca.get("flops", 0.0))
    ours = total_costs(compiled.as_text())["flops"]
    assert ours == 20 * 2 * 128**3
    # XLA reports the body once (or at most a couple of unrolled copies)
    assert xla_flops < ours / 5


def test_bf16_dot_upcast_projected_out():
    """XLA:CPU rewrites bf16 dots as convert+f32 dot; the analyzer must not
    charge the TPU roofline for the materialized f32 copies."""
    a = jax.ShapeDtypeStruct((512, 512), jnp.bfloat16)
    b = jax.ShapeDtypeStruct((512, 512), jnp.bfloat16)
    costs = total_costs(_hlo(lambda a, b: a @ b, a, b))
    ideal = (3 * 512 * 512) * 2  # a, b read + out written, bf16
    assert costs["bytes"] <= 2.0 * ideal, costs["bytes"]


def test_dus_counts_update_not_buffer():
    def f(buf, upd):
        return jax.lax.dynamic_update_slice(buf, upd, (0, 0))

    buf = jax.ShapeDtypeStruct((4096, 4096), jnp.float32)  # 64 MiB
    upd = jax.ShapeDtypeStruct((8, 4096), jnp.float32)  # 128 KiB
    # donate the buffer: without donation XLA inserts a REAL defensive copy
    # of the whole buffer (and the analyzer correctly charges it)
    hlo = jax.jit(f, donate_argnums=(0,)).lower(buf, upd).compile().as_text()
    costs = total_costs(hlo)
    assert costs["bytes"] < 4096 * 4096 * 4 / 4, "in-place DUS must not charge the buffer"


def test_collective_bytes_from_sharded_program():
    """Collective-byte parsing on a real SPMD program (subprocess: the main
    test process must keep seeing one device)."""
    import os
    import subprocess
    import sys
    import textwrap
    from pathlib import Path

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent / "src")
    script = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.hlo_cost import total_costs

        mesh = jax.make_mesh((4,), ("x",))
        sh_in = NamedSharding(mesh, P(None, "x"))
        sh_rep = NamedSharding(mesh, P())

        def f(a, b):  # contraction over the sharded dim -> all-reduce
            return a @ b

        a = jax.ShapeDtypeStruct((128, 512), jnp.float32)
        b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
        hlo = jax.jit(f, in_shardings=(sh_in, NamedSharding(mesh, P("x", None))),
                      out_shardings=sh_rep).lower(a, b).compile().as_text()
        c = total_costs(hlo)
        expect = 128 * 128 * 4  # all-reduce of the (128,128) f32 partial
        assert c.get("coll_all-reduce", 0) >= expect, c
        assert c.get("coll_all-reduce", 0) <= 4 * expect, c
        print("collective bytes ok:", c.get("coll_all-reduce"))
    """)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=300, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
