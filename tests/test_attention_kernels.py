"""Prefill/decode attention kernels: shape/dtype/schedule sweeps vs oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.prefill_attention.ops import prefill_attention
from repro.kernels.prefill_attention.ref import prefill_attention_reference


def _qkv(b, h, hkv, s, d, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("schedule", ["reverse", "forward"])
@pytest.mark.parametrize(
    "b,h,hkv,s,d,blk",
    [
        (1, 2, 2, 128, 64, 64),
        (2, 4, 2, 256, 64, 64),
        (2, 8, 2, 128, 128, 128),  # single kv block
        (1, 3, 1, 192, 32, 64),  # odd head count, GQA g=3
    ],
)
def test_prefill_kernel_sweep(schedule, b, h, hkv, s, d, blk):
    q, k, v = _qkv(b, h, hkv, s, d, seed=s + h)
    ref = prefill_attention_reference(q, k, v)
    out = prefill_attention(q, k, v, blk=blk, schedule=schedule, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_prefill_bf16():
    q, k, v = _qkv(1, 2, 2, 128, 64, dtype=jnp.bfloat16)
    ref = prefill_attention_reference(q, k, v)
    out = prefill_attention(q, k, v, blk=64, use_kernel=True, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=3e-2, atol=3e-2
    )


def test_prefill_reverse_equals_forward():
    """The paper's reverse schedule is a pure reordering — identical output."""
    q, k, v = _qkv(2, 4, 4, 256, 64, seed=3)
    a = prefill_attention(q, k, v, blk=64, schedule="reverse", use_kernel=True, interpret=True)
    b = prefill_attention(q, k, v, blk=64, schedule="forward", use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "b,h,hkv,s,d,bk",
    [
        (2, 4, 2, 256, 64, 64),
        (1, 8, 1, 512, 64, 128),  # MQA
        (3, 6, 2, 128, 32, 32),
        (2, 2, 2, 64, 128, 64),  # MHA single block
    ],
)
def test_decode_kernel_sweep(b, h, hkv, s, d, bk):
    rng = np.random.default_rng(b * s + d)
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    lengths = jnp.asarray(rng.integers(1, s + 1, size=(b,)), jnp.int32)
    ref = decode_attention(q, k, v, lengths, use_kernel=False)
    out = decode_attention(q, k, v, lengths, bk=bk, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@given(
    st.integers(1, 3),  # batch
    st.integers(1, 4),  # kv heads
    st.integers(1, 4),  # group size
    st.sampled_from([64, 128, 192]),  # cache len
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_decode_window_property(b, hkv, g, s, seed):
    """Sliding-window decode == full decode over the truncated cache."""
    rng = np.random.default_rng(seed)
    h = hkv * g
    d = 32
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    length = int(rng.integers(1, s + 1))
    window = int(rng.integers(1, length + 1))
    lengths = jnp.full((b,), length, jnp.int32)
    starts = jnp.full((b,), length - window, jnp.int32)
    out = decode_attention(q, k, v, lengths, starts, bk=32, use_kernel=True, interpret=True)
    # oracle: zero-out everything outside the window by slicing
    ref = decode_attention(
        q, k[:, :, length - window : length], v[:, :, length - window : length],
        jnp.full((b,), window, jnp.int32), use_kernel=False,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_decode_stats_merge_matches_appended_cache(use_kernel):
    """attend(cache) + online-softmax merge of a fresh token ==
    attend(cache with the token appended) — the [§Perf D2] decode identity
    (attend-then-merge replaces update-then-attend)."""
    import math

    from repro.layers.attention import _merge_new_token

    rng = np.random.default_rng(7)
    b, hkv, g, s, d = 2, 2, 3, 64, 32
    h = hkv * g
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    lengths = jnp.asarray([13, 40], jnp.int32)
    k_new = jnp.asarray(rng.normal(size=(b, hkv, 1, d)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(b, hkv, 1, d)), jnp.float32)
    sm = 1.0 / math.sqrt(d)

    out_c, l_c, m_c = decode_attention(
        q, k, v, lengths, use_kernel=use_kernel, interpret=True, bk=32, return_stats=True
    )
    merged = _merge_new_token(out_c, l_c, m_c, q, k_new, v_new, sm)

    # reference: physically append the token at position `length`
    def append(buf, new):
        return jnp.stack([
            jax.lax.dynamic_update_slice(buf[i], new[i], (0, int(lengths[i]), 0))
            for i in range(b)
        ])

    k2, v2 = append(k, k_new), append(v, v_new)
    ref = decode_attention(q, k2, v2, lengths + 1, use_kernel=False)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(ref), rtol=3e-5, atol=3e-5)


def test_decode_stats_empty_cache_merge_is_new_token_only():
    """lengths=0: merge must return attention over just the fresh token
    (softmax of one logit = that token's V)."""
    import math

    from repro.layers.attention import _merge_new_token

    rng = np.random.default_rng(8)
    b, hkv, g, s, d = 1, 1, 2, 32, 16
    h = hkv * g
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    k_new = jnp.asarray(rng.normal(size=(b, hkv, 1, d)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(b, hkv, 1, d)), jnp.float32)
    lengths = jnp.zeros((b,), jnp.int32)
    out_c, l_c, m_c = decode_attention(q, k, v, lengths, return_stats=True)
    merged = _merge_new_token(out_c, l_c, m_c, q, k_new, v_new, 1.0 / math.sqrt(d))
    expect = jnp.broadcast_to(v_new[:, :, 0, :][:, :, None, :], (b, hkv, g, d)).reshape(b, h, d)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(expect), atol=1e-5)
