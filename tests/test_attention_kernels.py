"""Prefill/decode attention kernels: shape/dtype/schedule sweeps vs oracles,
plus the quantized-KV subsystem (int4 pack/unpack properties and the
fused-dequant kernel parity)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the parametrized sweeps still run
    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(reason="property tests need hypothesis")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class _NullStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _NullStrategies()

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.prefill_attention.ops import prefill_attention
from repro.kernels.prefill_attention.ref import prefill_attention_reference
from repro.quant.kv_quant import QMAX, dequantize_kv, pack_int4, quantize_kv, unpack_int4


def _qkv(b, h, hkv, s, d, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("schedule", ["reverse", "forward"])
@pytest.mark.parametrize(
    "b,h,hkv,s,d,blk",
    [
        (1, 2, 2, 128, 64, 64),
        (2, 4, 2, 256, 64, 64),
        (2, 8, 2, 128, 128, 128),  # single kv block
        (1, 3, 1, 192, 32, 64),  # odd head count, GQA g=3
    ],
)
def test_prefill_kernel_sweep(schedule, b, h, hkv, s, d, blk):
    q, k, v = _qkv(b, h, hkv, s, d, seed=s + h)
    ref = prefill_attention_reference(q, k, v)
    out = prefill_attention(q, k, v, blk=blk, schedule=schedule, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_prefill_bf16():
    q, k, v = _qkv(1, 2, 2, 128, 64, dtype=jnp.bfloat16)
    ref = prefill_attention_reference(q, k, v)
    out = prefill_attention(q, k, v, blk=64, use_kernel=True, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=3e-2, atol=3e-2
    )


def test_prefill_reverse_equals_forward():
    """The paper's reverse schedule is a pure reordering — identical output."""
    q, k, v = _qkv(2, 4, 4, 256, 64, seed=3)
    a = prefill_attention(q, k, v, blk=64, schedule="reverse", use_kernel=True, interpret=True)
    b = prefill_attention(q, k, v, blk=64, schedule="forward", use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "b,h,hkv,s,d,bk",
    [
        (2, 4, 2, 256, 64, 64),
        (1, 8, 1, 512, 64, 128),  # MQA
        (3, 6, 2, 128, 32, 32),
        (2, 2, 2, 64, 128, 64),  # MHA single block
    ],
)
def test_decode_kernel_sweep(b, h, hkv, s, d, bk):
    rng = np.random.default_rng(b * s + d)
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    lengths = jnp.asarray(rng.integers(1, s + 1, size=(b,)), jnp.int32)
    ref = decode_attention(q, k, v, lengths, use_kernel=False)
    out = decode_attention(q, k, v, lengths, bk=bk, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@given(
    st.integers(1, 3),  # batch
    st.integers(1, 4),  # kv heads
    st.integers(1, 4),  # group size
    st.sampled_from([64, 128, 192]),  # cache len
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_decode_window_property(b, hkv, g, s, seed):
    """Sliding-window decode == full decode over the truncated cache."""
    rng = np.random.default_rng(seed)
    h = hkv * g
    d = 32
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    length = int(rng.integers(1, s + 1))
    window = int(rng.integers(1, length + 1))
    lengths = jnp.full((b,), length, jnp.int32)
    starts = jnp.full((b,), length - window, jnp.int32)
    out = decode_attention(q, k, v, lengths, starts, bk=32, use_kernel=True, interpret=True)
    # oracle: zero-out everything outside the window by slicing
    ref = decode_attention(
        q, k[:, :, length - window : length], v[:, :, length - window : length],
        jnp.full((b,), window, jnp.int32), use_kernel=False,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_decode_stats_merge_matches_appended_cache(use_kernel):
    """attend(cache) + online-softmax merge of a fresh token ==
    attend(cache with the token appended) — the [§Perf D2] decode identity
    (attend-then-merge replaces update-then-attend)."""
    import math

    from repro.layers.attention import _merge_new_token

    rng = np.random.default_rng(7)
    b, hkv, g, s, d = 2, 2, 3, 64, 32
    h = hkv * g
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    lengths = jnp.asarray([13, 40], jnp.int32)
    k_new = jnp.asarray(rng.normal(size=(b, hkv, 1, d)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(b, hkv, 1, d)), jnp.float32)
    sm = 1.0 / math.sqrt(d)

    out_c, l_c, m_c = decode_attention(
        q, k, v, lengths, use_kernel=use_kernel, interpret=True, bk=32, return_stats=True
    )
    merged = _merge_new_token(out_c, l_c, m_c, q, k_new, v_new, sm)

    # reference: physically append the token at position `length`
    def append(buf, new):
        return jnp.stack([
            jax.lax.dynamic_update_slice(buf[i], new[i], (0, int(lengths[i]), 0))
            for i in range(b)
        ])

    k2, v2 = append(k, k_new), append(v, v_new)
    ref = decode_attention(q, k2, v2, lengths + 1, use_kernel=False)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(ref), rtol=3e-5, atol=3e-5)


# ------------------------------------------------ KV quantization (kv_dtype) --


@given(
    st.integers(1, 4),  # leading rows
    st.sampled_from([2, 8, 32, 64]),  # head_dim (even — nibble pairs)
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_int4_pack_unpack_roundtrip(rows, d, seed):
    """Nibble packing is lossless over the full int4 range [-8, 7]."""
    rng = np.random.default_rng(seed)
    q = rng.integers(-8, 8, size=(rows, 3, d)).astype(np.int8)
    packed = pack_int4(jnp.asarray(q))
    assert packed.shape == (rows, 3, d // 2) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(unpack_int4(packed)), q)


@given(
    st.sampled_from(["int8", "int4"]),
    st.integers(1, 3),  # rows
    st.sampled_from([4, 16, 64]),  # head_dim
    st.integers(0, 2**31 - 1),
    st.floats(1e-2, 1e2),  # magnitude sweep: scales must track dynamic range
)
@settings(max_examples=30, deadline=None)
def test_kv_quant_error_bound_and_idempotent_requantization(kv_dtype, rows, d, seed, mag):
    """Symmetric per-row absmax quantization: reconstruction error is within
    half a quantization step, and requantizing the dequantized values is a
    payload FIXED POINT — the property bit-identical preemption replay
    rests on (same values -> same page bytes, every time)."""
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(rows, 2, 5, d)) * mag).astype(np.float32)
    payload, scale = quantize_kv(jnp.asarray(x), kv_dtype)
    assert scale.shape == x.shape[:-1]
    xh = np.asarray(dequantize_kv(payload, scale, kv_dtype))
    step = np.abs(x).max(axis=-1, keepdims=True) / QMAX[kv_dtype]
    assert np.all(np.abs(xh - x) <= step / 2 + 1e-4 * mag)
    # fixed point: quantize(dequantize(quantize(x))) == quantize(x) bit-for-bit
    p2, s2 = quantize_kv(jnp.asarray(xh), kv_dtype)
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(payload))
    np.testing.assert_allclose(np.asarray(s2), np.asarray(scale), rtol=2e-6)


def test_kv_quant_zero_rows_are_safe():
    """All-zero rows must not divide by zero and must reconstruct as zero."""
    x = jnp.zeros((2, 3, 8), jnp.float32)
    for kv_dtype in ("int8", "int4"):
        payload, scale = quantize_kv(x, kv_dtype)
        assert np.all(np.asarray(scale) == 1.0)
        np.testing.assert_array_equal(np.asarray(dequantize_kv(payload, scale, kv_dtype)), 0.0)


@pytest.mark.parametrize("kv_dtype", ["int8", "int4"])
@pytest.mark.parametrize(
    "b,h,hkv,s,d,bk",
    [
        (2, 4, 2, 37, 32, 16),  # partial final block, GQA
        (1, 8, 1, 130, 64, 64),  # MQA, partial final block
        (3, 6, 2, 64, 32, 32),  # exact blocks
    ],
)
def test_decode_quant_kernel_matches_dequant_reference(kv_dtype, b, h, hkv, s, d, bk):
    """Fused-dequant contiguous decode kernel == dequantize-then-attend
    oracle, through the op-level dispatch (randomized ragged lengths)."""
    rng = np.random.default_rng(b * s + d)
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    kq, ks = quantize_kv(k, kv_dtype)
    vq, vs = quantize_kv(v, kv_dtype)
    lengths = jnp.asarray(rng.integers(1, s + 1, size=(b,)), jnp.int32)
    ref = decode_attention(q, kq, vq, lengths, k_scales=ks, v_scales=vs,
                           kv_dtype=kv_dtype, use_kernel=False)
    out = decode_attention(q, kq, vq, lengths, k_scales=ks, v_scales=vs,
                           kv_dtype=kv_dtype, bk=bk, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("kv_dtype", ["int8", "int4"])
def test_decode_quant_tracks_fp_within_quant_error(kv_dtype):
    """The quantized decode output stays close to the fp output — the
    accuracy/bandwidth trade-off is bounded by the quantization step."""
    rng = np.random.default_rng(9)
    b, h, hkv, s, d = 2, 4, 2, 48, 32
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    lengths = jnp.asarray([s, 17], jnp.int32)
    fp = decode_attention(q, k, v, lengths, use_kernel=False)
    kq, ks = quantize_kv(k, kv_dtype)
    vq, vs = quantize_kv(v, kv_dtype)
    qd = decode_attention(q, kq, vq, lengths, k_scales=ks, v_scales=vs,
                          kv_dtype=kv_dtype, use_kernel=False)
    tol = {"int8": 0.05, "int4": 0.6}[kv_dtype]  # ~attention of one quant step
    assert float(jnp.max(jnp.abs(qd - fp))) < tol


@pytest.mark.parametrize("kv_dtype", ["int8", "int4"])
@pytest.mark.parametrize(
    "b,hkv,g,d,bs,n_pages_seq",
    [
        (2, 2, 2, 32, 8, 3),
        (1, 1, 4, 64, 16, 2),  # MHA-as-GQA grouping
        (3, 2, 1, 32, 4, 4),  # g=1
    ],
)
def test_paged_quant_kernel_matches_reference_at_ragged_lengths(
    kv_dtype, b, hkv, g, d, bs, n_pages_seq
):
    """Fused-dequant paged decode kernel == dequantize-the-pool oracle on
    randomized shuffled block tables and ragged lengths (partial pages)."""
    from repro.kernels.paged_attention.kernel import paged_decode_attention_quant_pallas
    from repro.kernels.paged_attention.ref import paged_decode_attention_quant_reference

    rng = np.random.default_rng(d + bs)
    n_blocks = b * n_pages_seq + 2
    q = jnp.asarray(rng.normal(size=(b, hkv, g, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_blocks, hkv, bs, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_blocks, hkv, bs, d)), jnp.float32)
    kq, ks = quantize_kv(kp, kv_dtype)
    vq, vs = quantize_kv(vp, kv_dtype)
    perm = rng.permutation(n_blocks)[: b * n_pages_seq].reshape(b, n_pages_seq)
    tables = jnp.asarray(perm, jnp.int32)
    lengths = jnp.asarray(rng.integers(1, n_pages_seq * bs + 1, size=b), jnp.int32)
    ref = paged_decode_attention_quant_reference(
        q, kq, ks, vq, vs, tables, lengths, kv_dtype=kv_dtype)
    out, _, _ = paged_decode_attention_quant_pallas(
        q, kq, ks, vq, vs, tables, lengths, kv_dtype=kv_dtype, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_decode_stats_empty_cache_merge_is_new_token_only():
    """lengths=0: merge must return attention over just the fresh token
    (softmax of one logit = that token's V)."""
    import math

    from repro.layers.attention import _merge_new_token

    rng = np.random.default_rng(8)
    b, hkv, g, s, d = 1, 1, 2, 32, 16
    h = hkv * g
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    k_new = jnp.asarray(rng.normal(size=(b, hkv, 1, d)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(b, hkv, 1, d)), jnp.float32)
    lengths = jnp.zeros((b,), jnp.int32)
    out_c, l_c, m_c = decode_attention(q, k, v, lengths, return_stats=True)
    merged = _merge_new_token(out_c, l_c, m_c, q, k_new, v_new, 1.0 / math.sqrt(d))
    expect = jnp.broadcast_to(v_new[:, :, 0, :][:, :, None, :], (b, hkv, g, d)).reshape(b, h, d)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(expect), atol=1e-5)
