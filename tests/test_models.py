"""Per-architecture smoke tests: REDUCED same-family configs, one forward /
train step on CPU, asserting output shapes and no NaNs (full configs are
exercised only via the dry-run)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced_config
from repro.configs.base import QuantConfig
from repro.models import get_model


def _batch(cfg, key, b=2, s=16):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {
        "tokens": tokens,
        "targets": jnp.roll(tokens, -1, axis=1),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + ["bitnet-730m"])
def test_smoke_forward_and_train_step(arch):
    cfg = reduced_config(arch)
    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(cfg, key, dtype=jnp.float32)
    batch = _batch(cfg, key)

    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda pp: api.loss_fn(pp, b, cfg), has_aux=True
        )(p)
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)))
        return loss, gnorm

    loss, gnorm = jax.jit(step)(params, batch)
    assert np.isfinite(float(loss)), arch
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, arch
    # loss should start near ln(V) for random init
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.0, (arch, float(loss))


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen2.5-14b", "granite-moe-3b-a800m",
                                  "xlstm-1.3b", "hymba-1.5b", "whisper-large-v3"])
def test_smoke_prefill_then_decode(arch):
    """Prefill + N decode steps must equal a single teacher-forced forward."""
    cfg = reduced_config(arch)
    api = get_model(cfg)
    key = jax.random.PRNGKey(1)
    params = api.init(cfg, key, dtype=jnp.float32)
    b, s = 2, 12
    batch = _batch(cfg, key, b=b, s=s)
    tokens = batch["tokens"]

    kw = {"frames": batch["frames"]} if cfg.family == "encdec" else {}
    logits_last, cache = api.forward_prefill(params, tokens, cfg, **kw)
    assert logits_last.shape[0] == b
    assert np.isfinite(np.asarray(logits_last, np.float32)).all(), arch

    if cfg.family == "xlstm":
        state = cache
        lg = None
        lengths = jnp.full((b,), s, jnp.int32)
        for t in range(3):
            tok = jnp.argmax(logits_last if lg is None else lg, -1).astype(jnp.int32)
            lg, state = api.decode_step(params, tok, state, lengths + t, cfg)
        assert np.isfinite(np.asarray(lg, np.float32)).all()
        return

    # attention families: relayout prefill KV into a bigger decode buffer —
    # 5D KV leaves come out of prefill layer-major (L,B,...) and the decode
    # cache is batch-leading (B,L,...); recurrent/conv states keep (L,B,...)
    max_len = 32

    def _insert(buf, src):
        if src.ndim == 5:
            src = jnp.moveaxis(src, 0, 1)
        if buf.ndim == src.ndim and buf.shape[:-2] == src.shape[:-2]:
            return buf.at[..., : src.shape[-2], :].set(src)
        return src

    cache_buf = api.init_cache(cfg, b, max_len, dtype=jnp.float32)
    cache_buf = jax.tree.map(_insert, cache_buf, cache)
    lengths = jnp.full((b,), s, jnp.int32)
    lg = logits_last
    for t in range(3):
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        lg, cache_buf = api.decode_step(params, tok, cache_buf, lengths + t, cfg)
    assert np.isfinite(np.asarray(lg, np.float32)).all(), arch


def test_ternary_mode_trains():
    cfg = dataclasses.replace(reduced_config("smollm-135m"), quant=QuantConfig(mode="ternary"))
    api = get_model(cfg)
    key = jax.random.PRNGKey(2)
    params = api.init(cfg, key, dtype=jnp.float32)
    batch = _batch(cfg, key)
    (loss, _), grads = jax.jit(
        lambda p, b: jax.value_and_grad(lambda pp: api.loss_fn(pp, b, cfg), has_aux=True)(p)
    )(params, batch)
    assert np.isfinite(float(loss))
    # STE must pass gradients through the quantizer to the latent weights
    gw = grads["layers"]["mlp"]["w_gate"]["w"]
    assert float(jnp.max(jnp.abs(gw))) > 0


def test_param_counts_roughly_match_analytic():
    from repro.common.tree import tree_param_count

    for arch in ["smollm-135m", "deepseek-7b", "granite-moe-3b-a800m"]:
        cfg = reduced_config(arch)
        api = get_model(cfg)
        params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        actual = tree_param_count(params)
        analytic = cfg.param_count()
        # padded vocab + norm params make small diffs; require within 20 %
        assert abs(actual - analytic) / analytic < 0.2, (arch, actual, analytic)


@pytest.mark.parametrize("arch", ["smollm-135m"])
def test_use_pallas_end_to_end(arch):
    """Tiny model with every Pallas kernel live (interpret mode)."""
    cfg = dataclasses.replace(
        reduced_config(arch), use_pallas=True, quant=QuantConfig(mode="ternary")
    )
    api = get_model(cfg)
    key = jax.random.PRNGKey(3)
    params = api.init(cfg, key, dtype=jnp.float32)
    b, s = 1, 64
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    logits, cache = api.forward_prefill(params, tokens, cfg)
    cfg_ref = dataclasses.replace(cfg, use_pallas=False)
    logits_ref, _ = api.forward_prefill(params, tokens, cfg_ref)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(logits_ref, np.float32), rtol=2e-2, atol=2e-1
    )
