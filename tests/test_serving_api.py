"""Step-driven serving API: EngineCore.step(), SamplingParams + the on-device
sampler, streaming outputs, SwapPolicy, and PR-1 run() compatibility."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.swap import SwapAggregates, SwapTiming
from repro.models import get_model
from repro.serving import (
    DrainPolicy,
    EngineCore,
    EngineStats,
    Request,
    SamplingParams,
    SchedulerView,
    ServingEngine,
    SwapCostAwarePolicy,
    make_policy,
)
from repro.serving.outputs import OutputProcessor
from repro.serving.sampling import filter_logits, sample_tokens


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced_config("bitnet-730m", num_layers=3, d_model=128, vocab_size=512,
                         num_heads=4, num_kv_heads=2)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, api, params


# ---------------------------------------------------------------- sampler --


def _nucleus_support(logits, temp, top_k, top_p):
    """NumPy reference for the sampling support of one logit row."""
    scaled = np.asarray(logits, np.float64) / max(temp, 1e-6)
    order = np.argsort(-scaled, kind="stable")
    desc = scaled[order]
    v = len(desc)
    k_eff = min(top_k, v) if top_k > 0 else v
    probs = np.exp(desc - desc.max())
    probs /= probs.sum()
    mass_before = np.cumsum(probs) - probs
    n_keep = max(int((mass_before < top_p).sum()), 1)
    cut = max(desc[k_eff - 1], desc[n_keep - 1])
    return set(np.nonzero(scaled >= cut)[0].tolist())


def test_sampler_seeded_determinism():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 128)) * 3, jnp.float32)
    seeds = jnp.asarray([7, 7, 9, 9], jnp.int32)
    steps = jnp.asarray([0, 1, 0, 1], jnp.int32)
    temps = jnp.full((4,), 1.0, jnp.float32)
    ks = jnp.zeros((4,), jnp.int32)
    ps = jnp.ones((4,), jnp.float32)
    a = np.asarray(sample_tokens(logits, seeds, steps, temps, ks, ps))
    b = np.asarray(sample_tokens(logits, seeds, steps, temps, ks, ps))
    np.testing.assert_array_equal(a, b)  # same (seed, step) -> same token
    # a different seed (or a different step index) draws a different stream
    c = np.asarray(sample_tokens(logits, seeds + 1, steps, temps, ks, ps))
    assert not np.array_equal(a, c)


@pytest.mark.parametrize("temp,top_k,top_p", [
    (1.0, 8, 1.0),    # pure top-k
    (1.0, 0, 0.7),    # pure nucleus
    (0.7, 16, 0.85),  # composed, with temperature
    (2.5, 3, 0.5),    # aggressive truncation
])
def test_sampler_support_invariants(temp, top_k, top_p):
    """Mass outside the top-k ∩ nucleus support must be exactly zero, and
    every drawn token must come from the support."""
    rng = np.random.default_rng(1)
    logits = rng.normal(size=(2, 64)).astype(np.float32) * 2
    temps = jnp.full((2,), temp, jnp.float32)
    ks = jnp.full((2,), top_k, jnp.int32)
    ps = jnp.full((2,), top_p, jnp.float32)
    masked = np.asarray(filter_logits(jnp.asarray(logits), temps, ks, ps))
    for row in range(2):
        support = _nucleus_support(logits[row], temp, top_k, top_p)
        probs = jax.nn.softmax(jnp.asarray(masked[row]))
        outside = [i for i in range(64) if i not in support]
        assert float(jnp.asarray(probs)[jnp.asarray(outside)].sum()) == 0.0
        assert np.isfinite(masked[row][list(support)]).all()
        if top_k > 0:
            assert len(support) <= top_k
    # 64 draws across step indices: every token lands in the support
    for step in range(32):
        toks = np.asarray(sample_tokens(
            jnp.asarray(logits), jnp.asarray([3, 5], jnp.int32),
            jnp.full((2,), step, jnp.int32), temps, ks, ps))
        for row in range(2):
            assert toks[row] in _nucleus_support(logits[row], temp, top_k, top_p)


def test_sampler_temperature_greedy_limit():
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.normal(size=(3, 256)), jnp.float32)
    ref = np.asarray(jnp.argmax(logits, axis=-1))
    seeds = jnp.asarray([1, 2, 3], jnp.int32)
    steps = jnp.zeros((3,), jnp.int32)
    ks = jnp.zeros((3,), jnp.int32)
    ps = jnp.ones((3,), jnp.float32)
    # temp == 0: the greedy path, exactly argmax
    zero = sample_tokens(logits, seeds, steps, jnp.zeros((3,), jnp.float32), ks, ps)
    np.testing.assert_array_equal(np.asarray(zero), ref)
    # temp -> 0+: the sampled path concentrates all mass on the argmax
    for step in range(16):
        cold = sample_tokens(logits, seeds, jnp.full((3,), step, jnp.int32),
                             jnp.full((3,), 1e-3, jnp.float32), ks, ps)
        np.testing.assert_array_equal(np.asarray(cold), ref)


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError, match="max_tokens"):
        SamplingParams(max_tokens=0)
    assert SamplingParams().greedy and not SamplingParams(temperature=0.5).greedy
    assert SamplingParams(seed=-3).seed32 >= 0


# ------------------------------------------------------- step() vs run() --


def _submit_all(eng, prompts, max_new=6, params=None):
    for i, p in enumerate(prompts):
        eng.submit(Request(f"r{i}", p.copy(), max_new=max_new,
                           params=params or SamplingParams()))


# Golden greedy outputs for the workload below (tiny fixture, rng seed 11,
# n_slots=3, max_len=48, prompt_len=12, max_new=6), captured from the
# drain-scheduled greedy engine on CPU float32 / jax 0.4.37 — the PR-1
# behavior.  Pins run()/step() semantics against silent drift: a refactor
# that changes scheduling order, bucketing, or the greedy path must not
# alter these tokens.
_GOLDEN_GREEDY = {
    "r0": [335, 335, 335, 335, 335, 335],
    "r1": [224, 429, 429, 429, 429, 429],
    "r2": [478, 478, 478, 478, 478, 478],
    "r3": [386, 118, 118, 118, 118, 118],
}


@pytest.mark.parametrize("mode", ["pdswap", "static"])
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_step_drives_both_modes_and_layouts(tiny, mode, layout):
    """An explicit step() loop must finish every request in every
    mode x layout combination, matching the compat run() token-for-token
    (greedy + DrainPolicy == the PR-1 engine)."""
    cfg, api, params = tiny
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32) for _ in range(4)]

    ref_eng = ServingEngine(cfg, params, n_slots=3, max_len=48, prompt_len=12,
                            mode=mode, cache_layout=layout, block_size=8)
    _submit_all(ref_eng, prompts)
    ref_stats = ref_eng.run()
    ref = {k: v.out_tokens for k, v in ref_eng.finished.items()}
    # every mode x layout must reproduce the recorded PR-1 greedy tokens
    # (the PR-1 suite pinned cross-mode/layout equality; the literal values
    # anchor the whole equivalence class against drift)
    assert ref == _GOLDEN_GREEDY

    eng = EngineCore(cfg, params, n_slots=3, max_len=48, prompt_len=12,
                     mode=mode, cache_layout=layout, block_size=8,
                     swap_policy=DrainPolicy())
    _submit_all(eng, prompts)
    streamed = {f"r{i}": [] for i in range(4)}
    steps = 0
    while eng.has_unfinished():
        steps += 1
        assert steps < 100
        for out in eng.step():
            streamed[out.request_id].extend(out.new_token_ids)
    assert {k: v.out_tokens for k, v in eng.finished.items()} == ref
    assert streamed == ref  # the deltas reassemble the full outputs
    assert eng.stats.decode_tokens == ref_stats.decode_tokens
    assert eng.stats.swaps == ref_stats.swaps
    assert all(r.finish_reason == "length" for r in eng.finished.values())


def test_streaming_generate_deltas(tiny):
    cfg, api, params = tiny
    eng = EngineCore(cfg, params, n_slots=2, max_len=48, prompt_len=12)
    got = []
    for out in eng.generate(np.arange(10, dtype=np.int32), max_new=7):
        assert out.new_token_ids  # every yield carries a delta
        got.extend(out.new_token_ids)
        # token_ids is a live view: never behind the deltas, may run ahead
        # within one step() quantum (prefill token + decode token together)
        assert out.token_ids[: len(got)] == got
    req = eng.finished[out.request_id]
    assert out.finished and out.finish_reason == "length"
    assert got == req.out_tokens and len(got) == 7
    assert req.first_token_t > 0.0 and req.done_t >= req.first_token_t


def test_stop_token_finishes_early(tiny):
    cfg, api, params = tiny
    prompt = np.arange(8, dtype=np.int32)
    eng = EngineCore(cfg, params, n_slots=1, max_len=48, prompt_len=12)
    eng.submit(Request("g", prompt.copy(), max_new=8))
    eng.run()
    full = eng.finished["g"].out_tokens
    stop = full[3]
    first_hit = full.index(stop)

    eng2 = EngineCore(cfg, params, n_slots=1, max_len=48, prompt_len=12)
    eng2.submit(Request("g", prompt.copy(), max_new=8,
                        params=SamplingParams(stop_tokens=(stop,))))
    eng2.run()
    req = eng2.finished["g"]
    assert req.finish_reason == "stop"
    assert req.out_tokens == full[: first_hit + 1]  # stop token kept, then cut


def test_max_tokens_overrides_max_new(tiny):
    cfg, api, params = tiny
    eng = EngineCore(cfg, params, n_slots=1, max_len=48, prompt_len=12)
    eng.submit(Request("m", np.arange(8, dtype=np.int32), max_new=12,
                       params=SamplingParams(max_tokens=3)))
    eng.run()
    assert len(eng.finished["m"].out_tokens) == 3
    assert eng.finished["m"].finish_reason == "length"


def test_engine_sampling_seeded_determinism(tiny):
    cfg, api, params = tiny
    prompt = np.arange(10, dtype=np.int32)

    def gen(seed):
        eng = EngineCore(cfg, params, n_slots=2, max_len=48, prompt_len=12)
        eng.submit(Request("s", prompt.copy(), max_new=8,
                           params=SamplingParams(temperature=0.8, top_k=64,
                                                 top_p=0.95, seed=seed)))
        eng.run()
        return eng.finished["s"].out_tokens

    assert gen(123) == gen(123)  # bitwise-repeatable
    assert gen(123) != gen(124)  # and actually stochastic across seeds


def test_sampled_preemption_replay_token_parity(tiny):
    """THE sampling-correctness property: a preempted+replayed request under
    temperature/top-k/top-p sampling continues bit-identically to a run that
    was never preempted (stateless fold_in(seed, token_index) keys +
    teacher-forced replay)."""
    cfg, api, params = tiny
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, 14).astype(np.int32) for _ in range(4)]
    sps = [SamplingParams(temperature=0.8, top_k=64, top_p=0.95, seed=100 + i)
           for i in range(4)]

    def serve(layout, **kw):
        eng = EngineCore(cfg, params, n_slots=3, max_len=64, prompt_len=12,
                         mode="static", cache_layout=layout, block_size=8, **kw)
        for i, p in enumerate(prompts):
            eng.submit(Request(f"r{i}", p.copy(), max_new=10, priority=i,
                               params=sps[i]))
        stats = eng.run()
        return eng, stats, {k: v.out_tokens for k, v in eng.finished.items()}

    _, _, ref = serve("contiguous")  # ample capacity: never preempts
    eng, stats, got = serve("paged", num_blocks=7)  # starved pool: must evict
    assert stats.preemptions > 0 and stats.replayed_tokens > 0
    assert got == ref
    # satellite: resumed requests must report a real TTFT, not 0.0
    assert all(r.first_token_t > 0.0 for r in eng.finished.values())


def test_int8_kv_greedy_matches_fp_token_for_token(tiny):
    """Golden accuracy check for the quantized KV cache: at short contexts
    the int8 cache's greedy decode is token-identical to fp on this tiny
    model — the per-token absmax error (<0.5%) never flips an argmax.
    (Pinned workload: drift here means the quantization math changed.)"""
    cfg, api, params = tiny
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32) for _ in range(3)]

    def serve(kv_dtype):
        eng = EngineCore(cfg, params, n_slots=3, max_len=64, prompt_len=12,
                         mode="static", cache_layout="paged", block_size=8,
                         kv_dtype=kv_dtype)
        for i, p in enumerate(prompts):
            eng.submit(Request(f"r{i}", p.copy(), max_new=6))
        eng.run()
        assert len(eng.finished) == 3
        return {k: v.out_tokens for k, v in eng.finished.items()}

    assert serve("int8") == serve("fp")


# ----------------------------------------------------------- SwapPolicy --


def _view(queue_depth, active=2, free=1, swap_cost=0.04, decode_cost=0.01):
    return SchedulerView(queue_depth=queue_depth, free_slots=free,
                         active_slots=active, swap_cost=swap_cost,
                         decode_round_cost=decode_cost)


def test_swap_cost_aware_policy_defers_shallow_queues():
    pol = SwapCostAwarePolicy(max_defer_rounds=100)
    # one swap costs 4 decode rounds -> threshold 4
    assert pol.threshold(_view(1)) == 4
    assert not pol.should_prefill(_view(1))
    assert not pol.should_prefill(_view(3))
    assert pol.should_prefill(_view(4))  # deep enough to amortize the flip
    assert pol.should_prefill(_view(1, active=0))  # idle fabric: flip is free
    # no measured history yet -> drain-like warmup
    assert pol.should_prefill(_view(1, swap_cost=0.0, decode_cost=0.0))
    # roofline/modeled override stands in for measured host timings
    pol45 = SwapCostAwarePolicy(swap_cost_override=0.045, max_defer_rounds=100)
    assert pol45.threshold(_view(1, decode_cost=0.005)) == 9


def test_swap_cost_aware_policy_defer_cap_guarantees_admission():
    pol = SwapCostAwarePolicy(max_defer_rounds=3)
    assert [pol.should_prefill(_view(1)) for _ in range(4)] == [False] * 3 + [True]
    pol.reset()
    assert not pol.should_prefill(_view(1))  # counter restarts after reset


def test_make_policy_registry():
    assert isinstance(make_policy("drain"), DrainPolicy)
    p = make_policy("swap-aware", min_queue=5)
    assert isinstance(p, SwapCostAwarePolicy) and p.threshold(_view(1)) == 5
    with pytest.raises(ValueError, match="unknown swap policy"):
        make_policy("nope")


def test_swap_aware_engine_batches_bursts_same_tokens(tiny):
    """Step-driven arrivals: the cost-aware policy must enter fewer prefill
    phases than drain (it batches admissions) while leaving every request's
    tokens unchanged (slot trajectories are independent)."""
    cfg, api, params = tiny
    rng = np.random.default_rng(12)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32) for _ in range(6)]

    def drive(policy):
        eng = EngineCore(cfg, params, n_slots=6, max_len=48, prompt_len=12,
                         swap_policy=policy)
        eng.submit(Request("r0", prompts[0].copy(), max_new=10))
        # single-request arrivals mid-decode: drain flips the fabric for each
        # one; the cost-aware policy waits for the queue to deepen
        arrivals = {2: [1], 3: [2], 5: [3], 6: [4], 8: [5]}
        step = 0
        while eng.has_unfinished() or arrivals:
            step += 1
            assert step < 200
            for i in arrivals.pop(step, []):
                eng.submit(Request(f"r{i}", prompts[i].copy(), max_new=10))
            eng.step()
        return eng

    drain = drive(DrainPolicy())
    aware = drive(SwapCostAwarePolicy(min_queue=2, max_defer_rounds=6))
    assert len(drain.finished) == len(aware.finished) == 6
    assert {k: v.out_tokens for k, v in drain.finished.items()} == \
           {k: v.out_tokens for k, v in aware.finished.items()}
    assert aware.stats.swaps == drain.stats.swaps == 6  # one swap per request
    assert aware.stats.prefill_bursts < drain.stats.prefill_bursts


# ------------------------------------------------- stats & output plumbing --


def test_swap_timings_window_bounded_with_running_aggregates():
    stats = EngineStats()
    for i in range(200):
        stats.record_swap(SwapTiming(t_body=0.010, t_tail=0.005,
                                     t_total_overlapped=0.017))
    assert stats.swaps == 200
    assert len(stats.swap_timings) == stats.swap_timings.maxlen == 64
    assert stats.swap_agg.count == 200  # aggregates survive the window
    # exposed cost = overlapped_total - body - tail = 2ms per swap
    assert stats.swap_agg.mean_cost == pytest.approx(0.002)
    ser = SwapTiming(t_relayout=0.03, t_total_serialized=0.05)
    assert SwapAggregates.exposed_cost(ser) == pytest.approx(0.03)


def test_output_processor_stamps_ttft_once():
    proc = OutputProcessor()
    req = Request("x", np.zeros(4, np.int32), max_new=3)
    out = proc.process_token(req, 5)
    assert req.first_token_t > 0.0 and not out.finished
    t = req.first_token_t
    proc.process_token(req, 6)
    assert req.first_token_t == t  # never overwritten
    out = proc.process_token(req, 7)
    assert out.finished and out.finish_reason == "length" and req.done_t > 0.0
    # the PR-1 TTFT bug shape: a restart arriving with tokens but no stamp
    restart = Request("y", np.zeros(4, np.int32), max_new=8, out_tokens=[1, 2],
                      preempted=True)
    proc.process_token(restart, 3)
    assert restart.first_token_t > 0.0
