"""Disaggregated prefill/decode serving: DisaggEngine bit-identity against
the colocated EngineCore across layouts x KV dtypes (chunked prefill and
preemption included), handoff-channel accounting (eager shipping, deferred
installs, discard on release), pool-split mesh helpers, and — in
subprocesses with forced multi-device hosts — KV pytree transfer onto the
decode pool's sharding and end-to-end identity on a real two-pool mesh.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.disagg import DisaggCostModel, split_pod_meshes
from repro.models import get_model
from repro.serving import DisaggEngine, EngineCore, Request
from repro.serving.disagg import make_disagg_meshes

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced_config("bitnet-730m", num_layers=3, d_model=128, vocab_size=512,
                         num_heads=4, num_kv_heads=2)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, params


def _prompts(cfg, n=3, lo=5, hi=12, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, int(rng.integers(lo, hi + 1)))
            .astype(np.int32) for _ in range(n)]


def _serve(cls, cfg, params, prompts, max_new=6, **kw):
    eng = cls(cfg, params, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(f"r{i}", p.copy(), max_new=max_new))
    eng.run()
    toks = {rid: list(r.out_tokens) for rid, r in eng.finished.items()}
    assert all(toks.values())
    return eng, toks


# ----------------------------------------------- disagg == colocated tokens --


@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("kv_dtype", ["fp", "int8", "int4"])
def test_disagg_matches_colocated_greedy(tiny, layout, kv_dtype):
    """Monolithic prefill: DisaggEngine's two-pool pipeline (prefill-side
    compute + relayout, handoff, decode-side install) reproduces the single
    engine token-for-token for every layout x KV dtype."""
    cfg, params = tiny
    kw = dict(n_slots=2, max_len=40, prompt_len=12, cache_layout=layout,
              kv_dtype=kv_dtype)
    if layout == "paged":
        kw.update(block_size=8, num_blocks=16)
    prompts = _prompts(cfg)
    _, ref = _serve(EngineCore, cfg, params, prompts, **kw)
    eng, got = _serve(DisaggEngine, cfg, params, prompts, **kw)
    assert got == ref
    ho = eng.snapshot()["disagg"]["handoff"]
    assert ho["segments"] == len(prompts) and ho["pending"] == 0
    assert ho["bytes_shipped"] > 0


@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_disagg_matches_colocated_chunked_prefill(tiny, layout):
    """Chunked prefill: chunks ship eagerly, installs are deferred until the
    final chunk — and the tokens still match the colocated engine exactly."""
    cfg, params = tiny
    kw = dict(n_slots=2, max_len=48, prompt_len=24, cache_layout=layout,
              prefill_chunk=8, kv_dtype="int8")
    if layout == "paged":
        kw.update(block_size=8, num_blocks=24)
    prompts = _prompts(cfg, lo=12, hi=24, seed=1)
    _, ref = _serve(EngineCore, cfg, params, prompts, **kw)
    eng, got = _serve(DisaggEngine, cfg, params, prompts, **kw)
    assert got == ref
    ho = eng.snapshot()["disagg"]["handoff"]
    # every prompt here spans >1 chunk: the non-final ones shipped eagerly
    assert ho["eager_segments"] > 0
    assert ho["installs"] == ho["segments"]
    assert ho["pending"] == 0


def test_disagg_matches_colocated_static_mode(tiny):
    cfg, params = tiny
    kw = dict(n_slots=2, max_len=40, prompt_len=12, mode="static")
    prompts = _prompts(cfg, seed=2)
    _, ref = _serve(EngineCore, cfg, params, prompts, **kw)
    _, got = _serve(DisaggEngine, cfg, params, prompts, **kw)
    assert got == ref


def test_disagg_preemption_matches_colocated(tiny):
    """An undersized paged pool preempts identically in both engines (same
    scheduler, same step loop), and the replayed restarts — re-prefilled on
    the PREFILL pool — still land bit-identical tokens."""
    cfg, params = tiny
    kw = dict(n_slots=3, max_len=48, prompt_len=16, cache_layout="paged",
              block_size=8, num_blocks=7, mode="static")
    prompts = [p for p in _prompts(cfg, n=4, lo=14, hi=14, seed=4)]
    ref_eng, ref = _serve(EngineCore, cfg, params, prompts, max_new=10, **kw)
    eng, got = _serve(DisaggEngine, cfg, params, prompts, max_new=10, **kw)
    assert ref_eng.stats.preemptions > 0
    assert eng.stats.preemptions == ref_eng.stats.preemptions
    assert got == ref


# ----------------------------------------------------- handoff bookkeeping --


def test_abort_mid_chunked_prefill_discards_pending_installs(tiny):
    """Aborting between chunks releases the slot AND drops its queued
    installs — a late install would scribble on the pages' next owner."""
    cfg, params = tiny
    eng = DisaggEngine(cfg, params, n_slots=2, max_len=48, prompt_len=24,
                       cache_layout="paged", block_size=8, num_blocks=24,
                       prefill_chunk=8)
    free0 = eng.runner.paged.pool.num_free
    eng.submit(Request("long", np.arange(24, dtype=np.int32) % 64, max_new=4))
    eng.step()  # exactly one chunk: one install is now deferred
    assert eng._prefilling
    assert eng.handoff.pending == 1
    out = eng.abort("long")
    assert out is not None and out.finish_reason == "abort"
    assert eng.handoff.pending == 0
    assert eng.snapshot()["disagg"]["handoff"]["discarded"] == 1
    assert eng.runner.paged.pool.num_free == free0
    # the engine (and its channel) keep serving after the discard
    eng.submit(Request("after", np.arange(20, dtype=np.int32), max_new=3))
    eng.run()
    assert eng.finished["after"].finish_reason in ("stop", "length")
    assert eng.snapshot()["disagg"]["handoff"]["pending"] == 0


def test_tenant_stats_in_snapshot(tiny):
    """Satellite: per-tenant WFQ lane depths + queue-wait aggregates surface
    in EngineCore.snapshot() (and therefore in GET /stats)."""
    cfg, params = tiny
    eng = EngineCore(cfg, params, n_slots=1, max_len=40, prompt_len=8)
    for i in range(2):
        eng.submit(Request(f"a{i}", np.arange(6, dtype=np.int32), max_new=2,
                           tenant="A"))
    eng.submit(Request("b0", np.arange(6, dtype=np.int32), max_new=2,
                       tenant="B", weight=2.0))
    snap = eng.snapshot()
    assert {t: v["queued"] for t, v in snap["tenants"].items()} == \
        {"A": 2, "B": 1}
    eng.run()
    snap = eng.snapshot()
    assert snap["tenants"]["A"]["queued"] == 0
    assert snap["tenants"]["A"]["queue_wait_s"]["count"] == 2
    assert snap["tenants"]["B"]["queue_wait_s"]["count"] == 1


def test_cost_model_kv_bytes_tracks_kv_dtype():
    """Satellite: DisaggCostModel's KV traffic estimate follows the wire
    format — int8 pages (payload + fp32 scales) are far lighter than fp16,
    int4 lighter still, instead of the old hardcoded 2-byte assumption."""
    cfg = reduced_config("bitnet-730m")
    sizes = {dt: DisaggCostModel(cfg, chips_per_pod=2, kv_dtype=dt).kv_bytes(4, 128)
             for dt in ("fp", "int8", "int4")}
    assert sizes["fp"] > sizes["int8"] > sizes["int4"] > 0
    assert sizes["fp"] == pytest.approx(2 * 4 * 128 * cfg.num_layers
                                        * cfg.num_kv_heads * cfg.head_dim * 2)


# ----------------------------------------------------------- mesh helpers --


def test_split_pod_meshes_requires_pod_axis():
    from jax.sharding import Mesh

    devs = np.array(jax.devices()[:1]).reshape(1)
    with pytest.raises(AssertionError):
        split_pod_meshes(Mesh(devs, ("model",)))


def test_make_disagg_meshes_explains_device_shortfall():
    with pytest.raises(ValueError, match="xla_force_host_platform_device_count"):
        make_disagg_meshes(jax.devices()[:1])


# --------------------------------------------- forced multi-device subprocs --


def _run(script: str, devices: int = 4, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_kv_transfer_reshards_quantized_pytree():
    """kv_transfer_program moves a QuantKV pytree (packed payload + scale
    planes, mismatched ranks) across the pod split and lands every leaf in
    the decode mesh's NamedSharding."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.core.disagg import kv_transfer_program, split_pod_meshes
    from repro.quant.kv_quant import QuantKV

    devs = np.array(jax.devices()).reshape(2, 2)
    pre, dec = split_pod_meshes(Mesh(devs, ("pod", "data")))
    # rank-5 packed payload + rank-4 scales: P(None, "data") shards dim 1 of
    # both because trailing dims default to replicated
    payload = jnp.arange(2 * 2 * 2 * 8 * 4, dtype=jnp.int8).reshape(2, 2, 2, 8, 4)
    scales = jnp.ones((2, 2, 2, 8), jnp.float32) * 0.5
    kv = QuantKV(jax.device_put(payload, NamedSharding(pre, P(None, "data"))),
                 jax.device_put(scales, NamedSharding(pre, P(None, "data"))))
    moved = kv_transfer_program(dec, P(None, "data"))(kv)
    want = NamedSharding(dec, P(None, "data"))
    for leaf, ref in zip(jax.tree.leaves(moved), (payload, scales)):
        assert leaf.sharding.is_equivalent_to(want, leaf.ndim), leaf.sharding
        np.testing.assert_array_equal(np.asarray(leaf), np.asarray(ref))
    pod_devs = {d for l in jax.tree.leaves(moved) for d in l.devices()}
    assert pod_devs == set(dec.devices.flat)  # landed on the DECODE pod
    print("quantized kv pytree transfer ok")
    """, devices=4)


def test_disagg_engine_on_real_two_pool_mesh_matches_single_device():
    """End to end on a forced 2-device host: DisaggEngine with a real
    (pod=2) mesh split — prefill pool on device 0, decode pool on device 1,
    every KV segment crossing the wire — produces the same greedy tokens as
    the single-device colocated engine."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.configs import reduced_config
    from repro.models import get_model
    from repro.serving import DisaggEngine, EngineCore, Request

    cfg = reduced_config("bitnet-730m", num_layers=2, d_model=64,
                         vocab_size=256, num_heads=4, num_kv_heads=2)
    params = get_model(cfg).init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (7, 11, 18)]

    def serve(cls, **extra):
        kw = dict(n_slots=2, max_len=40, prompt_len=8, cache_layout="paged",
                  block_size=8, num_blocks=16, kv_dtype="int8",
                  prefill_chunk=8)
        eng = cls(cfg, params, **kw, **extra)
        for i, p in enumerate(prompts):
            eng.submit(Request(f"r{i}", p.copy(), max_new=5))
        eng.run()
        return eng, {rid: list(r.out_tokens) for rid, r in eng.finished.items()}

    _, ref = serve(EngineCore)
    mesh = Mesh(np.array(jax.devices()).reshape(2, 1), ("pod", "model"))
    eng, got = serve(DisaggEngine, mesh=mesh)
    assert got == ref, (ref, got)
    snap = eng.snapshot()["disagg"]
    assert snap["prefill_pool"] == {"devices": 1, "axes": {"model": 1}}
    assert snap["decode_pool"] == {"devices": 1, "axes": {"model": 1}}
    assert snap["handoff"]["segments"] > 0 and snap["handoff"]["pending"] == 0
    print("two-pool mesh == single device:", got)
    """, devices=2)
