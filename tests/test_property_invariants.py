"""Hypothesis property tests on the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.configs.base import QuantConfig
from repro.core.dse import DseConfig, run_dse
from repro.core.kv_cache import KVSlotManager
from repro.kernels.decode_attention.ops import _decode_attention_streaming
from repro.kernels.decode_attention.ref import decode_attention_reference
from repro.quant.act_quant import quantize_activations_int8
from repro.quant.ternary import pack_ternary, ternary_quantize, unpack_ternary

SETTINGS = dict(max_examples=25, deadline=None)


# ------------------------------------------------------------ quantization --


@given(st.integers(0, 2**31 - 1), st.integers(1, 8), st.integers(1, 64))
@settings(**SETTINGS)
def test_ternary_pack_roundtrip(seed, rows_q, n):
    rng = np.random.default_rng(seed)
    w = rng.integers(-1, 2, size=(rows_q * 4, n)).astype(np.int8)
    packed = pack_ternary(jnp.asarray(w))
    assert packed.shape == (rows_q, n)
    out = np.asarray(unpack_ternary(packed))
    np.testing.assert_array_equal(out, w)


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_ternary_quantize_codes_and_scale(seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(64, 32)).astype(np.float32)
    wq, beta = ternary_quantize(jnp.asarray(w))
    assert set(np.unique(np.asarray(wq))) <= {-1, 0, 1}
    assert float(beta) > 0
    # absmean property: beta approximates mean |w|
    np.testing.assert_allclose(float(beta), np.abs(w).mean(), rtol=0.3)


@given(st.integers(0, 2**31 - 1), st.floats(0.1, 100.0))
@settings(**SETTINGS)
def test_int8_activation_quant_bounds_error(seed, scale):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(4, 128)) * scale).astype(np.float32)
    xq, s = quantize_activations_int8(jnp.asarray(x))
    assert xq.dtype == jnp.int8
    recon = np.asarray(xq, np.float32) * np.asarray(s)
    err = np.abs(recon - x).max()
    assert err <= np.abs(x).max() / 127.0 + 1e-6  # one quantization step


# ------------------------------------------------ decode attention masking --


@given(st.integers(0, 2**31 - 1), st.integers(1, 3), st.integers(1, 4))
@settings(**SETTINGS)
def test_decode_streaming_matches_oracle(seed, hkv, g):
    rng = np.random.default_rng(seed)
    b, s, d = 2, 32, 16
    q = jnp.asarray(rng.normal(size=(b, hkv, g, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    lengths = jnp.asarray(rng.integers(1, s + 1, size=(b,)), jnp.int32)
    ours = _decode_attention_streaming(q, k, v, lengths, None)
    oracle = decode_attention_reference(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(ours), np.asarray(oracle), atol=2e-5)


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_decode_attention_ignores_positions_beyond_length(seed):
    """Garbage in the cache tail must never leak into the output."""
    rng = np.random.default_rng(seed)
    b, hkv, g, s, d = 2, 2, 2, 24, 8
    q = jnp.asarray(rng.normal(size=(b, hkv, g, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    lengths = jnp.asarray([7, 13], jnp.int32)
    base = _decode_attention_streaming(q, k, v, lengths, None)
    k2 = k.at[:, :, 15:].set(1e6)  # poison the dead tail
    v2 = v.at[:, :, 15:].set(-1e6)
    poisoned = _decode_attention_streaming(q, k2, v2, lengths, None)
    np.testing.assert_allclose(np.asarray(base), np.asarray(poisoned), atol=1e-6)


@given(st.integers(0, 2**31 - 1), st.integers(1, 16))
@settings(**SETTINGS)
def test_sliding_window_equals_truncated_cache(seed, window):
    rng = np.random.default_rng(seed)
    b, hkv, g, s, d = 1, 1, 2, 32, 8
    length = 24
    q = jnp.asarray(rng.normal(size=(b, hkv, g, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    lengths = jnp.full((b,), length, jnp.int32)
    starts = jnp.maximum(0, lengths - window)
    windowed = _decode_attention_streaming(q, k, v, lengths, starts)
    # reference: physically truncate the cache to [start, length)
    lo = int(starts[0])
    kt = k[:, :, lo:length]
    vt = v[:, :, lo:length]
    full = decode_attention_reference(q, kt, vt, jnp.full((b,), length - lo, jnp.int32))
    np.testing.assert_allclose(np.asarray(windowed), np.asarray(full), atol=2e-5)


# ------------------------------------------------------------- DSE (Eq. 2) --


@given(st.sampled_from(["bitnet-730m", "qwen2.5-14b", "deepseek-7b", "hymba-1.5b"]))
@settings(max_examples=8, deadline=None)
def test_dse_feasible_points_satisfy_eq2(arch):
    cfg = get_config(arch)
    from repro.common.hardware import DEFAULT_CHIP

    for pt in run_dse(cfg):
        if pt.feasible:
            c = pt.config
            occ = c.vmem_static() + max(c.vmem_prefill(cfg), c.vmem_decode(cfg))
            assert occ <= DEFAULT_CHIP.vmem_bytes  # Eq. (2)
            assert pt.vmem_bytes == occ


def test_dse_swap_never_loses_to_static():
    """Time-sharing one region (max) dominates co-residency (sum): any
    static-feasible config is swap-feasible, so the swap optimum can only
    be better or equal (Eq. 6)."""
    for arch in ("bitnet-730m", "minicpm-2b"):
        cfg = get_config(arch)
        swap = min(p.objective for p in run_dse(cfg) if p.feasible)
        static = min(p.objective for p in run_dse(cfg, static_baseline=True) if p.feasible)
        assert swap <= static + 1e-9


# ------------------------------------------------------------ slot manager --


@given(st.lists(st.tuples(st.integers(1, 16), st.integers(1, 8)), min_size=1, max_size=24))
@settings(**SETTINGS)
def test_slot_manager_conservation(reqs):
    """Slots are never double-assigned; every request finishes exactly once."""
    mgr = KVSlotManager(4)
    pending = list(enumerate(reqs))
    finished = []
    active = {}
    while pending or mgr.active_slots():
        while pending and mgr.free_slots():
            rid, (length, max_new) = pending.pop()
            slot = mgr.assign(f"r{rid}", length, max_new)
            assert slot not in active
            active[slot] = rid
        assert len(set(mgr.active_slots())) == len(mgr.active_slots())
        mgr.step(finished_cb=lambda i, s: finished.append(active.pop(i)))
    assert sorted(finished) == sorted(r for r, _ in enumerate(reqs))


# ------------------------------------------------------- data determinism --


@given(st.integers(0, 2**31 - 1), st.integers(0, 1000))
@settings(**SETTINGS)
def test_data_pipeline_restart_exact(seed, step):
    from repro.data.pipeline import DataConfig, make_source

    cfg = DataConfig(batch=4, seq_len=32, vocab_size=997, seed=seed)
    a = make_source(cfg).batch(step)
    b = make_source(cfg).batch(step)  # fresh instance = simulated restart
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["targets"], b["targets"])
    assert a["tokens"].max() < 997 and a["tokens"].min() >= 0
