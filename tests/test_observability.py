"""Observability subsystem: tracer ring-buffer semantics and disabled
no-op, Chrome trace-event export schema (nested spans, lanes), the
exactly-once finish invariant across stop/abort/shed/preempt-replay, the
typed metrics registry (+ Prometheus text + ``/metrics`` endpoint +
``snapshot_v2``), and roofline drift attribution sanity."""
import asyncio
import json
import re
import socket
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.launch.serve import serve_http
from repro.models import get_model
from repro.obs.drift import roofline_drift
from repro.obs.metrics import (
    PROMETHEUS_CONTENT_TYPE,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.trace import TRACER, Tracer
from repro.serving import EngineCore, Request, SamplingParams
from repro.serving.slo import SLOAwareSwapPolicy, SLOConfig


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced_config("bitnet-730m", num_layers=3, d_model=128, vocab_size=512,
                         num_heads=4, num_kv_heads=2)
    params = get_model(cfg).init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, params


@pytest.fixture
def tracer():
    """The process-wide TRACER, recording for one test; always reset after
    so later tests (and files) see it disabled with an empty buffer."""
    TRACER.enable()
    yield TRACER
    TRACER.disable()
    TRACER.clear()


# ----------------------------------------------------------- tracer unit --


def test_disabled_tracer_records_nothing():
    t = Tracer()
    assert not t.enabled
    t.complete("x", 0.0, 1.0, foo=1)
    t.instant("y")
    t.finish("r", "stop")
    t.finish("r", "stop")  # no exactly-once enforcement while disabled
    with t.span("z"):
        pass
    assert t.events() == [] and t.dropped == 0


def test_ring_buffer_bounds_and_counts_drops():
    t = Tracer()
    t.enable(capacity=8)
    for i in range(20):
        t.complete("ev", 0.0, 1e-6, i=i)
    evs = t.events()
    assert len(evs) == 8 and t.dropped == 12
    # the ring keeps the most recent window
    assert [e[-1]["i"] for e in evs] == list(range(12, 20))


def test_enable_reconfigures_fresh_buffer_and_finish_set():
    t = Tracer()
    t.enable(capacity=16)
    t.instant("a")
    t.finish("r", "stop")
    t.enable(capacity=16)  # re-enable: fresh buffer, fresh finish set
    assert t.events() == [] and t.dropped == 0
    t.finish("r", "stop")  # does not raise: the set was reset
    t.clear()
    assert t.events() == []
    t.finish("r", "stop")  # clear() also resets the finish set


def test_duplicate_finish_raises_while_enabled():
    t = Tracer()
    t.enable()
    t.finish("req-1", "stop")
    with pytest.raises(RuntimeError, match="exactly once"):
        t.finish("req-1", "abort")


# --------------------------------------------------------- chrome export --


def _synthetic_trace(t: Tracer) -> None:
    with t.span("outer", kind="step"):
        with t.span("inner"):
            time.sleep(0.001)
    s0 = time.perf_counter()
    time.sleep(0.001)
    t.complete("ship", s0, time.perf_counter(), lane="kv-handoff", bytes=128)
    t.instant("mark", request_id="r0")
    t.finish("r0", "stop")


def test_chrome_trace_schema_and_lanes():
    t = Tracer()
    t.enable()
    _synthetic_trace(t)
    trace = t.chrome_trace()
    assert set(trace) == {"traceEvents", "displayTimeUnit"}
    json.loads(json.dumps(trace))  # round-trips as JSON

    evs = trace["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    lane_name = {e["tid"]: e["args"]["name"] for e in meta
                 if e["name"] == "thread_name"}
    # one lane for the test thread, one for the explicit kv-handoff lane
    assert "kv-handoff" in lane_name.values() and len(lane_name) == 2
    assert any(e["name"] == "thread_sort_index" for e in meta)

    spans = [e for e in evs if e["ph"] == "X"]
    instants = [e for e in evs if e["ph"] == "i"]
    assert {e["name"] for e in spans} == {"outer", "inner", "ship"}
    assert {e["name"] for e in instants} == {"mark", "req.finish"}
    for e in spans + instants:
        assert e["pid"] == 1 and e["tid"] in lane_name
        assert e["ts"] >= 0.0  # microseconds since enable()
    ship = next(e for e in spans if e["name"] == "ship")
    assert lane_name[ship["tid"]] == "kv-handoff"
    assert ship["args"]["bytes"] == 128
    outer = next(e for e in spans if e["name"] == "outer")
    inner = next(e for e in spans if e["name"] == "inner")
    assert outer["tid"] == inner["tid"]  # same-thread spans share a lane
    # the context-manager spans nest: inner inside outer
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3


def test_export_chrome_trace_writes_valid_json(tmp_path):
    t = Tracer()
    t.enable()
    _synthetic_trace(t)
    path = tmp_path / "trace.json"
    trace = t.export_chrome_trace(str(path))
    assert json.loads(path.read_text()) == json.loads(json.dumps(trace))


# ------------------------------------------------------ engine lifecycle --


def _assert_nested(trace) -> None:
    """Same-lane complete events must nest monotonically (each span starts
    after the previous ended or sits fully inside it)."""
    by_tid = {}
    for e in trace["traceEvents"]:
        if e["ph"] == "X":
            by_tid.setdefault(e["tid"], []).append((e["ts"], e["ts"] + e["dur"]))
    assert by_tid, "trace has no complete events"
    for ivs in by_tid.values():
        ivs.sort()
        stack = []
        for t0, t1 in ivs:
            while stack and stack[-1] <= t0 + 1e-3:
                stack.pop()
            assert not stack or t1 <= stack[-1] + 1e-3, "non-nested spans"
            stack.append(t1)


def _finishes(trace) -> dict:
    """{request_id: reason} — asserts each id finished exactly once."""
    out = {}
    for e in trace["traceEvents"]:
        if e["ph"] == "i" and e["name"] == "req.finish":
            rid = e["args"]["request_id"]
            assert rid not in out, f"duplicate req.finish for {rid}"
            out[rid] = e["args"]["reason"]
    return out


def test_engine_run_traces_lifecycle_once_per_request(tiny, tracer):
    cfg, params = tiny
    eng = EngineCore(cfg, params, n_slots=2, max_len=40, prompt_len=12)
    rng = np.random.default_rng(0)
    for i in range(3):
        eng.submit(Request(f"obs-a{i}",
                           rng.integers(0, cfg.vocab_size, 10).astype(np.int32),
                           max_new=6))
    eng.run()
    trace = tracer.chrome_trace()
    names = {e["name"] for e in trace["traceEvents"] if e["ph"] != "M"}
    assert {"engine.step", "decode.round", "prefill", "swap",
            "req.submit", "req.admit", "req.finish"} <= names
    _assert_nested(trace)
    fins = _finishes(trace)
    assert set(fins) == {f"obs-a{i}" for i in range(3)}
    assert all(r in ("stop", "length") for r in fins.values())
    # the trace invariant IS the done_t invariant: one terminal stamp
    for rid, reason in fins.items():
        assert eng.finished[rid].finish_reason == reason


def test_abort_and_shed_finish_exactly_once(tiny, tracer):
    cfg, params = tiny
    pol = SLOAwareSwapPolicy(SLOConfig(ttft_target_s=0.05, itl_target_s=0.05))
    eng = EngineCore(cfg, params, n_slots=1, max_len=40, prompt_len=12,
                     swap_policy=pol)
    prompt = np.arange(8, dtype=np.int32)
    eng.submit(Request("obs-live", prompt.copy(), max_new=16))
    eng.submit(Request("obs-queued", prompt.copy(), max_new=16))
    while not eng.scheduler.inflight:
        eng.step()
    assert eng.abort("obs-queued").finish_reason == "abort"
    assert eng.abort("obs-live").finish_reason == "abort"
    doomed = Request("obs-doomed", prompt.copy(), max_new=2)
    eng.submit(doomed)
    doomed.arrival_time_s -= 1.0  # already past its TTFT deadline: shed
    eng.submit(Request("obs-ok", prompt.copy(), max_new=2))
    eng.run()
    fins = _finishes(tracer.chrome_trace())
    assert fins["obs-live"] == "abort" and fins["obs-queued"] == "abort"
    assert fins["obs-doomed"] == "shed"
    assert fins["obs-ok"] in ("stop", "length")
    assert eng.stats.aborts == 2 and eng.stats.sheds == 1


def test_preempt_replay_finishes_exactly_once(tiny, tracer):
    """Pool pressure forces preempt -> restart -> teacher-forced replay;
    the restarted request must still produce exactly one terminal event."""
    cfg, params = tiny
    eng = EngineCore(cfg, params, n_slots=4, max_len=32, prompt_len=16,
                     cache_layout="paged", block_size=8, num_blocks=7)
    rng = np.random.default_rng(4)
    rids = [f"obs-p{i}" for i in range(4)]
    for rid in rids:
        eng.submit(Request(rid,
                           rng.integers(0, cfg.vocab_size, 14).astype(np.int32),
                           max_new=10))
    eng.run()
    assert eng.stats.preemptions > 0
    trace = tracer.chrome_trace()
    names = {e["name"] for e in trace["traceEvents"] if e["ph"] != "M"}
    assert {"req.preempt", "replay"} <= names
    _assert_nested(trace)
    assert set(_finishes(trace)) == set(rids)


# ------------------------------------------------------------ metrics unit --


def test_owned_metric_primitives():
    c = Counter("c_total")
    c.inc()
    c.inc(2)
    assert c.value == 3.0
    with pytest.raises(ValueError):
        c.inc(-1)
    g = Gauge("g")
    g.set(1.5)
    assert g.value == 1.5
    h = Histogram("h_seconds", window=8)
    for v in range(10):
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 10 and s["sum"] == 45.0 and s["mean"] == 4.5
    assert set(s) == {"count", "sum", "mean", "p50", "p90", "p95", "p99"}
    assert s["p50"] <= s["p90"] <= s["p95"] <= s["p99"]


def test_callback_views_are_live_and_readonly():
    box = {"v": 1.0}
    c = Counter("v_total", fn=lambda: box["v"])
    assert c.value == 1.0
    box["v"] = 7.0
    assert c.value == 7.0  # re-read at collect time
    with pytest.raises(TypeError):
        c.inc()
    with pytest.raises(TypeError):
        Gauge("g", fn=lambda: 0.0).set(1.0)
    with pytest.raises(TypeError):
        Histogram("h", source_fn=lambda: None).observe(1.0)


def test_registry_prometheus_text_and_snapshot_include_collectors():
    reg = MetricsRegistry()
    reg.counter("repro_x_total", "a counter").inc(3)
    reg.histogram("repro_lat_seconds", "a histogram").observe(0.5)
    # collector-produced labeled series (the per-tenant / reject-reason shape)
    reg.register_collector(lambda: [
        Counter("repro_lane_total", "per lane", labels={"lane": lane},
                fn=lambda v=v: v)
        for lane, v in (("a", 1.0), ("b", 2.0))])
    text = reg.prometheus_text()
    assert "# TYPE repro_x_total counter" in text
    assert "# TYPE repro_lat_seconds summary" in text  # quantile-window export
    assert 'repro_lane_total{lane="a"} 1' in text
    assert 'repro_lane_total{lane="b"} 2' in text
    assert 'repro_lat_seconds{quantile="0.5"} 0.5' in text
    assert "repro_lat_seconds_count 1" in text
    # every sample line is NAME[{labels}] VALUE
    sample = re.compile(r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? \S+$')
    for line in text.strip().splitlines():
        assert line.startswith("#") or sample.match(line), line
    # one TYPE header per metric family even with several label sets
    assert text.count("# TYPE repro_lane_total") == 1

    snap = reg.snapshot()
    assert snap["counters"]["repro_x_total"] == 3.0
    assert snap["counters"]["repro_lane_total"] == {"lane=a": 1.0, "lane=b": 2.0}
    assert snap["histograms"]["repro_lat_seconds"]["count"] == 1.0


# -------------------------------------------------- engine registry + v2 --


def _run_some(eng, cfg, tag, n=2, max_new=4, seed=0):
    rng = np.random.default_rng(seed)
    for i in range(n):
        eng.submit(Request(f"{tag}{i}",
                           rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                           max_new=max_new))
    eng.run()


def test_engine_registry_is_live_monotonic_and_survives_reset(tiny):
    cfg, params = tiny
    eng = EngineCore(cfg, params, n_slots=2, max_len=32, prompt_len=8)
    reg = eng.metrics_registry()
    assert eng.metrics_registry() is reg  # built once, cached

    def counter(name):
        return reg.snapshot()["counters"][name]

    _run_some(eng, cfg, "obs-m")
    v1 = counter("repro_decode_tokens_total")
    assert v1 == float(eng.stats.decode_tokens) > 0
    _run_some(eng, cfg, "obs-n")
    assert counter("repro_decode_tokens_total") >= v1  # monotonic under load
    eng.reset_stats()
    # views deref core.stats at collect time: the rebind is transparent
    assert counter("repro_decode_tokens_total") == 0.0


def test_snapshot_v2_matches_legacy_snapshot(tiny):
    cfg, params = tiny
    eng = EngineCore(cfg, params, n_slots=2, max_len=32, prompt_len=8)
    _run_some(eng, cfg, "obs-v")
    legacy, v2 = eng.snapshot(), eng.snapshot_v2()
    assert v2["schema"] == "v2"
    # one source of truth: the typed registry reads the same stats the
    # legacy dict reports
    for attr, name in (("decode_tokens", "repro_decode_tokens_total"),
                       ("prefill_tokens", "repro_prefill_tokens_total"),
                       ("swaps", "repro_swaps_total")):
        assert v2["counters"][name] == float(legacy[attr])
    assert v2["gauges"]["repro_kv_cache_bytes"]["kind=allocated"] == \
        float(legacy["kv_bytes"]["allocated"])
    assert {"roofline_drift", "tenants", "kv_bytes"} <= set(legacy)
    assert "repro_ttft_seconds" in v2["histograms"]


def test_roofline_drift_sanity(tiny):
    cfg, params = tiny
    eng = EngineCore(cfg, params, n_slots=2, max_len=32, prompt_len=8)
    assert roofline_drift(eng) == {}  # no tokens yet: no phases
    _run_some(eng, cfg, "obs-d", max_new=6)
    drift = roofline_drift(eng)
    assert set(drift) == {"prefill", "decode"}  # no spec: no verify phase
    for entry in drift.values():
        assert entry["measured_s_per_token"] > 0.0
        assert entry["bound_s_per_token"] > 0.0
        assert entry["residency_ratio"] == pytest.approx(
            entry["bound_s_per_token"] / entry["measured_s_per_token"])
        # a CPU run sits far below a v5e roofline, but never above it
        assert 0.0 < entry["residency_ratio"] <= 1.0
    assert drift["decode"]["context_mean"] > 0.0
    assert drift["decode"]["tokens_per_round"] >= 1.0
    assert drift["prefill"]["n_params"] > 0


# -------------------------------------------------------- /metrics (HTTP) --


def _free_port():
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


async def _request(port, method, path, body=b""):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
                 f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
    await writer.drain()
    data = await reader.read()
    writer.close()
    try:
        await writer.wait_closed()
    except (ConnectionResetError, BrokenPipeError):
        pass
    head, _, payload = data.partition(b"\r\n\r\n")
    lines = head.decode().split("\r\n")
    headers = {k.strip().lower(): v.strip() for k, _, v in
               (ln.partition(":") for ln in lines[1:])}
    return lines[0], headers, payload


def _counter_value(text, name):
    for line in text.splitlines():
        if line.startswith(name + " ") or line.startswith(name + "{"):
            return float(line.rsplit(" ", 1)[1])
    raise AssertionError(f"{name} not in /metrics output")


def test_metrics_endpoint_content_type_and_monotonic_counters(tiny):
    cfg, params = tiny

    async def go():
        core = EngineCore(cfg, params, n_slots=2, max_len=64, prompt_len=8)
        ready, stop = asyncio.Event(), asyncio.Event()
        port = _free_port()
        task = asyncio.create_task(serve_http(
            core, SamplingParams(), "127.0.0.1", port, ready=ready, stop=stop))
        await asyncio.wait_for(ready.wait(), 30)
        status, headers, payload = await _request(port, "GET", "/metrics")
        assert status.startswith("HTTP/1.1 200"), status
        assert headers["content-type"] == PROMETHEUS_CONTENT_TYPE
        before = payload.decode()
        v0 = _counter_value(before, "repro_decode_tokens_total")

        body = json.dumps({"prompt": list(range(3, 9)), "max_new": 8}).encode()
        status, _, _ = await _request(port, "POST", "/generate", body)
        assert status.startswith("HTTP/1.1 200"), status

        status, _, payload = await _request(port, "GET", "/metrics")
        after = payload.decode()
        assert _counter_value(after, "repro_decode_tokens_total") > v0
        assert _counter_value(after, "repro_frontend_accepted_total") == 1.0
        assert "repro_roofline_residency_ratio{phase=" in after
        assert 'repro_ttft_seconds{quantile="0.5"}' in after

        status, _, payload = await _request(port, "GET", "/stats/v2")
        assert status.startswith("HTTP/1.1 200"), status
        v2 = json.loads(payload)
        assert v2["schema"] == "v2"
        assert v2["counters"]["repro_frontend_accepted_total"] == 1.0
        stop.set()
        assert await asyncio.wait_for(task, 60) == 0

    asyncio.run(go())
