"""Multi-device distribution tests.

The main test process sees ONE CpuDevice (the dry-run's 512-device trick
must never leak into tests), so anything needing a real multi-device mesh
runs in a SUBPROCESS with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run(script: str, devices: int = 4, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_compressed_dp_matches_uncompressed():
    """int8 + error-feedback cross-pod gradient exchange converges to the
    same place as exact f32 DP on a toy regression (4 fake devices)."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.optim.compression import compressed_dp_grads, init_error_feedback

    mesh = jax.make_mesh((4,), ("pod",))
    rng = np.random.default_rng(0)
    w_true = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    X = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    y = X @ w_true

    def loss_fn(w, batch):
        xb, yb = batch
        return jnp.mean((xb @ w - yb) ** 2)

    from jax.sharding import PartitionSpec as P
    # jit both paths: tracing shard_map/grad 300x dominates runtime otherwise
    grads_fn = jax.jit(compressed_dp_grads(loss_fn, mesh, batch_spec=(P("pod"), P("pod"))))
    exact_grad = jax.jit(jax.grad(loss_fn))

    w_c = jnp.zeros((8,), jnp.float32); err = init_error_feedback(w_c)
    w_e = jnp.zeros((8,), jnp.float32)
    for step in range(300):
        loss_c, g_c, err = grads_fn(w_c, err, (X, y))
        w_c = w_c - 0.05 * g_c
        g_e = exact_grad(w_e, (X, y))
        w_e = w_e - 0.05 * g_e
    final_c = float(loss_fn(w_c, (X, y)))
    final_e = float(loss_fn(w_e, (X, y)))
    print("compressed", final_c, "exact", final_e)
    assert final_c < 1e-3, final_c   # converged despite int8 wire
    assert abs(final_c - final_e) < 1e-3
    """)


def test_moe_ep_all_to_all_matches_single_device():
    """The EP shard_map path (seq-sharded tokens + a2a) must reproduce the
    no-mesh MoE numerics."""
    _run("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import reduced_config
    from repro.layers.moe import moe_apply, moe_init
    from repro.core.phase_engine import make_pctx

    # capacity high enough that nothing drops: capacity is defined per
    # dispatch group, so drop PATTERNS legitimately differ between the
    # sharded and single-device layouts — only the no-drop regime is
    # bit-comparable.
    cfg = reduced_config("moonshot-v1-16b-a3b", num_experts=4, top_k=2, moe_d_ff=32)
    cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    params = moe_init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)

    ref, _ = moe_apply(params, x, cfg, make_pctx(None, "prefill"), training=False)

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    pctx = make_pctx(mesh, "prefill")
    # jax.set_mesh is newer-jax; the Mesh context manager is the portable form
    with mesh:
        out, _ = jax.jit(lambda p, xx: moe_apply(p, xx, cfg, pctx, training=False))(params, x)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), atol=2e-4)
    print("EP matches single-device reference")
    """, devices=4)


def test_train_step_runs_on_small_mesh():
    """One real optimizer step, FSDPxTP-sharded on a 4-device mesh."""
    _run("""
    import jax, jax.numpy as jnp
    from repro.configs import reduced_config
    from repro.train.trainer import TrainConfig, init_train_state, jit_train_step

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = reduced_config("qwen2.5-14b")
    params, opt = init_train_state(cfg, jax.random.PRNGKey(0), mesh, dtype=jnp.float32)
    step = jit_train_step(cfg, TrainConfig(), mesh, jax.eval_shape(lambda: params))
    batch = {
        "tokens": jnp.zeros((4, 32), jnp.int32),
        "targets": jnp.zeros((4, 32), jnp.int32),
        "mask": jnp.ones((4, 32), jnp.float32),
    }
    params, opt, metrics = step(params, opt, batch, jnp.int32(0))
    loss = float(metrics["loss"])
    assert loss == loss and loss > 0  # finite
    print("mesh train step ok, loss", loss)
    """, devices=4)


def test_spatial_disaggregation_split():
    """core.disagg: pod mesh splits into prefill/decode meshes and the KV
    transfer program moves a buffer across."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.disagg import split_pod_meshes
    from repro.launch.mesh import make_production_mesh  # too big; build small
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    devs = np.array(jax.devices()).reshape(2, 2, 1)
    mesh = Mesh(devs, ("pod", "data", "model"))
    pre, dec = split_pod_meshes(mesh)
    assert pre.devices.size == 2 and dec.devices.size == 2
    kv = jnp.arange(16.0).reshape(4, 4)
    kv_pre = jax.device_put(kv, NamedSharding(pre, P("data", None)))
    kv_dec = jax.device_put(kv_pre, NamedSharding(dec, P("data", None)))
    np.testing.assert_array_equal(np.asarray(kv_dec), np.asarray(kv))
    print("pod split + kv transfer ok")
    """, devices=4)


def test_sharded_decode_matches_unsharded():
    """The full decode_step (batch-leading cache, merge path, scatter) on a
    (data=2, model=2) mesh must agree with the single-device program."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import reduced_config
    from repro.core.phase_engine import PhaseEngine
    from repro.models import get_model

    cfg = reduced_config("deepseek-7b")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    b, prompt, max_len = 4, 8, 32
    tokens = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab_size, (b, prompt)), jnp.int32)

    def roll(mesh):
        eng = PhaseEngine(cfg, mesh, max_len=max_len)
        pa = jax.eval_shape(lambda: params)
        logits, kv = eng.prefill_program(pa, b, prompt).fn(params, tokens)
        cache = eng.relayout_program(b, prompt, max_len).fn(kv)
        dec = eng.decode_program(pa, b, max_len)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs = [tok]
        lengths = jnp.full((b,), prompt, jnp.int32)
        for i in range(3):
            lg, cache = dec.fn(params, tok, cache, lengths + i)
            tok = jnp.argmax(lg, -1).astype(jnp.int32)
            outs.append(tok)
        return np.stack([np.asarray(t) for t in outs])

    ref = roll(None)
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    out = roll(mesh)
    np.testing.assert_array_equal(ref, out)
    print("sharded decode == unsharded decode")
    """, devices=4)
