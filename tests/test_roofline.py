"""Unit tests for the analytic roofline helpers (`repro.core.roofline`).

These are the numbers BOTH gates consume — the `program` analysis pass
audits traced jaxprs against ``predict_phase`` and ``obs.drift`` exports
residency ratios from it — so the helpers get direct edge-case coverage
here: quantization monotonicity, speculation edge cases, and the HLO
collective-bytes parser.
"""
import math

import pytest

from repro.common.hardware import DEFAULT_CHIP
from repro.configs import reduced_config
from repro.core.roofline import (
    collective_bytes_from_hlo,
    decode_arithmetic_intensity,
    decode_kv_stream_time,
    decode_kv_stream_time_speculative,
    expected_accept_length,
    kv_bytes_per_ctx_token,
    predict_phase,
    prefill_compute_time,
)


@pytest.fixture(scope="module")
def cfg():
    return reduced_config("smollm-135m", num_layers=3, d_model=64,
                          vocab_size=128, num_heads=2, num_kv_heads=2,
                          head_dim=32)


# ---------------------------------------------------- dtype monotonicity --

def test_kv_bytes_per_ctx_token_shrinks_with_quantization(cfg):
    fp = kv_bytes_per_ctx_token(cfg, "fp")
    i8 = kv_bytes_per_ctx_token(cfg, "int8")
    i4 = kv_bytes_per_ctx_token(cfg, "int4")
    assert fp > i8 > i4 > 0
    # payload-only figures quote the exact 2x / 4x headline ratios
    i8p = kv_bytes_per_ctx_token(cfg, "int8", include_scales=False)
    i4p = kv_bytes_per_ctx_token(cfg, "int4", include_scales=False)
    assert fp / i8p == pytest.approx(2.0)
    assert fp / i4p == pytest.approx(4.0)
    assert i8 > i8p and i4 > i4p  # scales are charged by default


def test_decode_arithmetic_intensity_monotone_fp_int8_int4(cfg):
    fp = decode_arithmetic_intensity(cfg, "fp")
    i8 = decode_arithmetic_intensity(cfg, "int8")
    i4 = decode_arithmetic_intensity(cfg, "int4")
    # same FLOPs over fewer bytes: intensity climbs as the cache shrinks
    assert 0 < fp < i8 < i4


def test_kv_bytes_rejects_unknown_dtype(cfg):
    with pytest.raises(ValueError, match="kv_dtype"):
        kv_bytes_per_ctx_token(cfg, "int2")


# -------------------------------------------------------- prefill + spec --

def test_prefill_compute_time_is_2n_over_peak():
    n = 1e9
    assert prefill_compute_time(n) == pytest.approx(
        2 * n / DEFAULT_CHIP.peak_flops_bf16)
    assert prefill_compute_time(0.0) == 0.0
    # linear in N
    assert prefill_compute_time(2 * n) == pytest.approx(
        2 * prefill_compute_time(n))


def test_expected_accept_length_edges():
    # k = 0: every round degenerates to plain decode regardless of p
    assert expected_accept_length(0, 0.0) == 1.0
    assert expected_accept_length(0, 1.0) == 1.0
    assert expected_accept_length(-1, 0.7) == 1.0
    # p = 0: only the correction token; p = 1: the whole draft + bonus
    assert expected_accept_length(4, 0.0) == 1.0
    assert expected_accept_length(4, 1.0) == 5.0
    # out-of-range p clamps instead of exploding the geometric series
    assert expected_accept_length(4, 1.5) == 5.0
    assert expected_accept_length(4, -0.5) == 1.0
    # interior: the truncated geometric series, strictly monotone in p
    assert expected_accept_length(2, 0.5) == pytest.approx(1.75)
    assert expected_accept_length(2, 0.4) < expected_accept_length(2, 0.6)


def test_speculative_bound_amortizes_the_stream(cfg):
    plain = decode_kv_stream_time(cfg, context=1024, kv_dtype="int8")
    spec = decode_kv_stream_time_speculative(
        cfg, context=1024, k=3, accept_rate=0.8, kv_dtype="int8")
    assert spec == pytest.approx(
        plain / expected_accept_length(3, 0.8))
    # zero acceptance: speculation buys nothing
    assert decode_kv_stream_time_speculative(
        cfg, context=1024, k=3, accept_rate=0.0, kv_dtype="int8"
    ) == pytest.approx(plain)


# --------------------------------------------------------- predict_phase --

def test_predict_phase_matches_wrappers(cfg):
    assert predict_phase("prefill", n_params=5e8).t_per_token == \
        pytest.approx(prefill_compute_time(5e8))
    assert predict_phase("decode", cfg, context=256,
                         kv_dtype="int4").t_per_token == \
        pytest.approx(decode_kv_stream_time(cfg, 256, "int4"))


def test_predict_phase_countable_quantities(cfg):
    p = predict_phase("prefill", n_params=1e6)
    assert p.flops == 2e6 and p.hbm_bytes == 0.0
    d = predict_phase("decode", cfg, context=100, kv_dtype="int8", batch=4)
    assert d.flops == 0.0
    assert d.hbm_bytes == pytest.approx(
        4 * 100 * kv_bytes_per_ctx_token(cfg, "int8"))
    # spec_verify streams the same bytes, only the per-token time divides
    v = predict_phase("spec_verify", cfg, context=100, kv_dtype="int8",
                      batch=4, k=3, accept_rate=0.9)
    assert v.hbm_bytes == pytest.approx(d.hbm_bytes)
    assert v.t_per_token < d.t_per_token


def test_predict_phase_rejects_unknown_phase(cfg):
    with pytest.raises(ValueError, match="phase"):
        predict_phase("verify", cfg, context=10)


# ------------------------------------------------- HLO collective parser --

HLO_FIXTURE = """\
HloModule jit_step, entry_computation_layout={...}

ENTRY %main {
  %p0 = f32[1024,8]{1,0} parameter(0)
  %ar = f32[1024,8]{1,0} all-reduce(f32[1024,8]{1,0} %p0), replica_groups={}
  %ag = bf16[2048]{0} all-gather(bf16[1024]{0} %x), dimensions={0}
  %start = f32[512]{0} collective-permute-start(f32[512]{0} %y)
  %done = f32[512]{0} collective-permute-done(f32[512]{0} %start)
  %rs = f32[256]{0} reduce-scatter(f32[1024]{0} %z), dimensions={0}
}
"""


def test_collective_bytes_from_hlo_counts_operand_bytes():
    got = collective_bytes_from_hlo(HLO_FIXTURE)
    # operand shapes (inside the call parens) are what travels the wire
    assert got["all-reduce"] == 1024 * 8 * 4
    assert got["all-gather"] == 1024 * 2
    assert got["reduce-scatter"] == 1024 * 4
    # async pair: the -start is counted once, the -done is skipped
    assert got["collective-permute"] == 512 * 4
    assert got["all-to-all"] == 0


def test_collective_bytes_from_hlo_empty_and_plain_text():
    assert set(collective_bytes_from_hlo("")) == {
        "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute"}
    assert sum(collective_bytes_from_hlo("no collectives here").values()) == 0


def test_collective_bytes_ignores_unknown_dtypes():
    txt = "%q = mystery[64]{0} all-reduce(mystery[64]{0} %p)"
    assert collective_bytes_from_hlo(txt)["all-reduce"] == 0
