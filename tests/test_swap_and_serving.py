"""The paper's mechanism end to end: phase programs, logic swap, serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.phase_engine import PhaseEngine
from repro.core.swap import SwapController
from repro.models import get_model
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced_config("bitnet-730m", num_layers=3, d_model=128, vocab_size=512,
                         num_heads=4, num_kv_heads=2)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, api, params


def test_split_prefill_equals_full_prefill(tiny):
    """body+tail (the overlap split at the last layer's attention) must give
    the same logits and KV as the monolithic prefill program."""
    cfg, api, params = tiny
    pa = jax.eval_shape(lambda: params)
    engine = PhaseEngine(cfg, None, max_len=64)
    tokens = (jnp.arange(24, dtype=jnp.int32) % cfg.vocab_size)[None]

    full = engine.prefill_program(pa, 1, 24)
    logits_full, kv_full = full.fn(params, tokens)

    body, tail = engine.prefill_split_programs(pa, 1, 24)
    x_mid, kv_split = body.fn(params, tokens)
    logits_split = tail.fn(params, x_mid)

    np.testing.assert_allclose(np.asarray(logits_full), np.asarray(logits_split),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(kv_full.k), np.asarray(kv_split.k),
                               rtol=2e-4, atol=2e-4)


def test_swap_overlap_preserves_results(tiny):
    cfg, api, params = tiny
    pa = jax.eval_shape(lambda: params)
    engine = PhaseEngine(cfg, None, max_len=64)
    body, tail = engine.prefill_split_programs(pa, 1, 16)
    relayout = engine.relayout_program(1, 16, 64)
    ctl = SwapController(body.fn, tail.fn, relayout.fn)
    tokens = (jnp.arange(16, dtype=jnp.int32) * 3 % cfg.vocab_size)[None]

    lo, co, _ = ctl.prefill_and_swap(params, tokens, overlap=True)
    ls, cs, _ = ctl.prefill_and_swap(params, tokens, overlap=False)
    np.testing.assert_allclose(np.asarray(lo), np.asarray(ls), atol=1e-6)
    for a, b in zip(jax.tree.leaves(co), jax.tree.leaves(cs)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_relayout_is_decode_layout(tiny):
    """The swap output must be the batch-leading decode cache layout, padded
    to max_len, with the prefill values in [0, S)."""
    cfg, api, params = tiny
    engine = PhaseEngine(cfg, None, max_len=48)
    pa = jax.eval_shape(lambda: params)
    prefill = engine.prefill_program(pa, 1, 16)
    tokens = (jnp.arange(16, dtype=jnp.int32) % cfg.vocab_size)[None]
    _, kv = prefill.fn(params, tokens)  # (L, B, Hkv, S, D)
    cache = engine.relayout_program(1, 16, 48).fn(kv)
    assert cache.k.shape == (1, cfg.num_layers, cfg.num_kv_heads, 48, cfg.head_dim)
    np.testing.assert_allclose(
        np.asarray(cache.k[:, :, :, :16]), np.asarray(jnp.moveaxis(kv.k, 0, 1)),
        atol=1e-6)
    assert float(jnp.abs(cache.k[:, :, :, 16:]).max()) == 0.0  # padded tail


@pytest.mark.parametrize("mode", ["pdswap", "static"])
def test_serving_engine_completes_all_requests(tiny, mode):
    cfg, api, params = tiny
    eng = ServingEngine(cfg, params, n_slots=3, max_len=48, prompt_len=12, mode=mode)
    rng = np.random.default_rng(1)
    for i in range(5):
        eng.submit(Request(f"r{i}", rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
                           max_new=6))
    stats = eng.run()
    assert len(eng.finished) == 5
    assert all(len(r.out_tokens) == 6 for r in eng.finished.values())
    assert stats.decode_tokens == 5 * 5  # first token comes from prefill
    if mode == "pdswap":
        assert stats.swaps == 5


def test_pdswap_and_static_agree_greedy(tiny):
    cfg, api, params = tiny
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32) for _ in range(4)]
    outs = {}
    for mode in ("pdswap", "static"):
        eng = ServingEngine(cfg, params, n_slots=2, max_len=48, prompt_len=12, mode=mode)
        for i, prm in enumerate(prompts):
            eng.submit(Request(f"r{i}", prm, max_new=5))
        eng.run()
        outs[mode] = {k: v.out_tokens for k, v in eng.finished.items()}
    assert outs["pdswap"] == outs["static"]


def test_continuous_batching_mixed_ages(tiny):
    """Slots of different ages decode together (per-slot length masking)."""
    cfg, api, params = tiny
    eng = ServingEngine(cfg, params, n_slots=2, max_len=48, prompt_len=12, mode="pdswap")
    rng = np.random.default_rng(3)
    eng.submit(Request("a", rng.integers(0, cfg.vocab_size, 12).astype(np.int32), max_new=9))
    eng.submit(Request("b", rng.integers(0, cfg.vocab_size, 12).astype(np.int32), max_new=2))
    eng.submit(Request("c", rng.integers(0, cfg.vocab_size, 12).astype(np.int32), max_new=2))
    eng.run()
    assert set(eng.finished) == {"a", "b", "c"}  # c takes b's slot mid-flight
    assert len(eng.finished["a"].out_tokens) == 9


def test_relayout_int8_kv_quantization(tiny):
    """Beyond-paper knob: the swap program can quantize KV to int8 during
    relayout — payload halves, dequant error bounded by one quant step."""
    cfg, api, params = tiny
    engine = PhaseEngine(cfg, None, max_len=32, kv_quant="int8")
    pa = jax.eval_shape(lambda: params)
    tokens = (jnp.arange(16, dtype=jnp.int32) % cfg.vocab_size)[None]
    _, kv = engine.prefill_program(pa, 1, 16).fn(params, tokens)
    cache_q = engine.relayout_program(1, 16, 32).fn(kv)

    # bf16 reference relayout
    ref = PhaseEngine(cfg, None, max_len=32).relayout_program(1, 16, 32).fn(kv)

    for (q, s), full in zip([cache_q.k, cache_q.v], [ref.k, ref.v]):
        assert q.dtype == jnp.int8
        recon = np.asarray(q, np.float32) * np.asarray(s, np.float32)
        full = np.asarray(full, np.float32)
        step = np.abs(full).max(axis=-1, keepdims=True) / 127.0
        assert np.all(np.abs(recon - full) <= step + 1e-5)
        # wire/footprint: int8 payload is half the bf16 bytes
        assert q.size * 1 <= full.size * 2 / 2
