"""Chunked prefill with decode interleaving + the satellite serving fixes.

The tentpole property is CHUNK-SIZE INVARIANCE: greedy token streams must be
bit-identical between chunked and whole-prompt prefill across every cache
layout x kv_dtype combination, and across preemption-replay restarts after
chunked admission (chunk boundaries are a pure function of prompt length and
chunk size, and per-token quantize-on-write installs the exact bytes the
monolithic swap would).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import get_model
from repro.serving import (
    DrainPolicy,
    EngineCore,
    Request,
    SamplingParams,
    SchedulerView,
    SwapCostAwarePolicy,
)
from repro.serving.core import ModelRunner


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced_config("bitnet-730m", num_layers=3, d_model=128, vocab_size=512,
                         num_heads=4, num_kv_heads=2)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, api, params


def _prompts(cfg, lengths=(7, 12, 20, 33), seed=11):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32) for n in lengths]


def _serve(cfg, params, prompts, *, chunk, layout, kv_dtype="fp", max_new=6, **kw):
    eng = EngineCore(cfg, params, n_slots=3, max_len=64, prompt_len=12,
                     cache_layout=layout, block_size=8, kv_dtype=kv_dtype,
                     prefill_chunk=chunk, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(f"r{i}", p.copy(), max_new=max_new))
    eng.run()
    assert len(eng.finished) == len(prompts)
    return {k: v.out_tokens for k, v in eng.finished.items()}, eng.stats


# ------------------------------------------------------ chunk-size invariance --


_MONO_CACHE = {}  # (layout, kv_dtype) -> monolithic reference tokens


def _mono_ref(cfg, params, layout, kv_dtype):
    key = (layout, kv_dtype)
    if key not in _MONO_CACHE:
        _MONO_CACHE[key], _ = _serve(cfg, params, _prompts(cfg), chunk=None,
                                     layout=layout, kv_dtype=kv_dtype)
    return _MONO_CACHE[key]


@pytest.mark.parametrize("layout", ["contiguous", "paged"])
@pytest.mark.parametrize("kv_dtype", ["fp", "int8", "int4"])
def test_chunked_equals_monolithic_greedy(tiny, layout, kv_dtype):
    """Bit-identical greedy streams, chunked vs whole-prompt prefill, for
    every layout x kv_dtype — prompts span sub-chunk, exact-multiple and
    multi-chunk-plus-tail lengths."""
    cfg, api, params = tiny
    ref = _mono_ref(cfg, params, layout, kv_dtype)
    got, stats = _serve(cfg, params, _prompts(cfg), chunk=16,
                        layout=layout, kv_dtype=kv_dtype)
    assert got == ref
    # prompts (7, 12, 20, 33) at chunk 16 -> 1 + 1 + 2 + 3 prefill quanta
    assert stats.prefill_chunks == 7
    assert stats.swaps == 4  # still one logical swap per request
    assert stats.prefill_tokens == 7 + 12 + 20 + 33  # offered load, once each


def test_chunked_unaligned_chunk_contiguous(tiny):
    """The contiguous layout accepts any chunk size (no page alignment):
    a prime chunk length must still reproduce the monolithic stream."""
    cfg, api, params = tiny
    ref = _mono_ref(cfg, params, "contiguous", "fp")
    got, _ = _serve(cfg, params, _prompts(cfg), chunk=7, layout="contiguous")
    assert got == ref


def test_chunked_validation(tiny):
    cfg, api, params = tiny
    with pytest.raises(ValueError, match="prefill_chunk"):
        EngineCore(cfg, params, cache_layout="paged", block_size=8, prefill_chunk=12)
    with pytest.raises(ValueError, match="prefill_chunk"):
        EngineCore(cfg, params, prefill_chunk=0)


def test_chunked_preemption_replay_restarts_mid_generation(tiny):
    """A request preempted mid-generation after CHUNKED admission must
    restart deterministically: re-prefill through the same chunk programs,
    teacher-forced replay, continuation bit-identical to an unpreempted run
    — under temperature/top-k/top-p sampling."""
    cfg, api, params = tiny
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, 14).astype(np.int32) for _ in range(4)]
    sps = [SamplingParams(temperature=0.8, top_k=64, top_p=0.95, seed=100 + i)
           for i in range(4)]

    def serve(layout, **kw):
        eng = EngineCore(cfg, params, n_slots=3, max_len=64, prompt_len=12,
                         mode="static", cache_layout=layout, block_size=8,
                         prefill_chunk=8, **kw)
        for i, p in enumerate(prompts):
            eng.submit(Request(f"r{i}", p.copy(), max_new=10, priority=i,
                               params=sps[i]))
        stats = eng.run()
        return stats, {k: v.out_tokens for k, v in eng.finished.items()}

    _, ref = serve("contiguous")  # ample capacity: never preempts
    stats, got = serve("paged", num_blocks=7)  # starved pool: must evict
    assert stats.preemptions > 0 and stats.replayed_tokens > 0
    assert got == ref


def test_chunked_decode_interleaves_between_chunks(tiny):
    """THE serving property this PR exists for: while a long prompt
    prefills chunk by chunk, active streams receive decode rounds between
    chunks — monolithic prefill executes zero rounds in that window."""
    cfg, api, params = tiny
    rng = np.random.default_rng(7)
    short = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    long = rng.integers(0, cfg.vocab_size, 96).astype(np.int32)

    def window_rounds(chunk):
        eng = EngineCore(cfg, params, n_slots=2, max_len=128, prompt_len=12,
                         cache_layout="paged", block_size=8, prefill_chunk=chunk)
        eng.submit(Request("short", short.copy(), max_new=60))
        while not eng.scheduler.inflight:  # short stream reaches decode
            eng.step()
        eng.submit(Request("long", long.copy(), max_new=4))
        d0, first = eng.stats.decode_rounds, None
        while eng.has_unfinished():
            outs = eng.step()
            if first is None and any(o.request_id == "long" for o in outs):
                first = eng.stats.decode_rounds
        assert set(eng.finished) == {"short", "long"}
        return first - d0 - 1  # rounds strictly before the completing quantum

    assert window_rounds(None) == 0  # monolithic starves decode
    assert window_rounds(16) > 0  # chunked interleaves (96/16 - 1 boundaries)


def test_chunked_policy_sees_pending_chunks(tiny):
    """SwapCostAwarePolicy must never defer the continuation of a
    partially-prefilled request (it holds a slot and pages while producing
    nothing), while still deferring fresh admissions on shallow queues."""
    view = dict(queue_depth=1, free_slots=1, active_slots=2,
                swap_cost=0.04, decode_round_cost=0.01)
    pol = SwapCostAwarePolicy(max_defer_rounds=100)
    assert not pol.should_prefill(SchedulerView(**view))  # shallow queue: defer
    assert pol.should_prefill(SchedulerView(**view, pending_chunks=3))
    assert SchedulerView(**view).pending_chunks == 0  # monolithic default

    # end to end: a chunked engine under the cost-aware policy still
    # completes everything with drain-identical tokens
    cfg, api, params = tiny
    prompts = _prompts(cfg, lengths=(7, 20), seed=3)
    drain, _ = _serve(cfg, params, prompts, chunk=16, layout="paged",
                      swap_policy=DrainPolicy())
    aware, _ = _serve(cfg, params, prompts, chunk=16, layout="paged",
                      swap_policy=SwapCostAwarePolicy(min_queue=2, max_defer_rounds=4))
    assert aware == drain


# ------------------------------------------------------------- satellites --


def test_generate_defaults_to_headroom_budget(tiny):
    """generate() without max_new/max_tokens used to cap output at 16
    tokens silently; it must default to the request's full slot headroom
    (max_len - prompt_len)."""
    cfg, api, params = tiny
    eng = EngineCore(cfg, params, n_slots=1, max_len=48, prompt_len=12)
    outs = list(eng.generate(np.arange(10, dtype=np.int32)))
    req = eng.finished[outs[-1].request_id]
    assert len(req.out_tokens) == 48 - 10  # the full headroom, not 16
    assert req.finish_reason == "length"
    # an explicit SamplingParams.max_tokens still wins
    eng2 = EngineCore(cfg, params, n_slots=1, max_len=48, prompt_len=12)
    outs = list(eng2.generate(np.arange(10, dtype=np.int32),
                              SamplingParams(max_tokens=3)))
    assert len(eng2.finished[outs[-1].request_id].out_tokens) == 3
    # paged: the default budget additionally clamps to pool capacity — an
    # unbudgeted generate() on a small pool degrades instead of raising
    eng3 = EngineCore(cfg, params, n_slots=1, max_len=64, prompt_len=8,
                      cache_layout="paged", block_size=8, num_blocks=4)
    outs = list(eng3.generate(np.arange(10, dtype=np.int32)))
    req = eng3.finished[outs[-1].request_id]
    assert len(req.out_tokens) == 4 * 8 - 10 + 1  # pool tokens - prompt + 1
    assert req.finish_reason == "length"


def test_admission_after_prefix_cache_fills_pool(tiny):
    """Satellite regression: fill the paged pool with refcount-0 prefix-
    cache pages, drain every slot, then admit a request that needs most of
    the pool — evictable pages must be reclaimed (LRU), never surfacing a
    'can never be admitted' livelock error to a satisfiable request."""
    cfg, api, params = tiny
    eng = EngineCore(cfg, params, n_slots=2, max_len=64, prompt_len=8,
                     mode="static", cache_layout="paged", block_size=8,
                     num_blocks=8)
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.submit(Request(f"w{i}", rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                           max_new=2))
    eng.run()
    pool = eng.runner.paged.pool
    assert not eng.has_unfinished() and pool.num_live == 0
    assert len(pool.evictable) > 0  # drained prompts left cached pages behind
    eng.submit(Request("big", rng.integers(0, cfg.vocab_size, 48).astype(np.int32),
                       max_new=4))
    eng.run()
    assert len(eng.finished["big"].out_tokens) == 4


def test_admission_livelock_evicts_cached_pages_before_raising(tiny):
    """The livelock branch itself: with evictable pages present it must
    reclaim them and return (retry next step); only an unreclaimable pool
    proves livelock and raises."""
    cfg, api, params = tiny
    eng = EngineCore(cfg, params, n_slots=2, max_len=64, prompt_len=8,
                     mode="static", cache_layout="paged", block_size=8,
                     num_blocks=8)
    rng = np.random.default_rng(1)
    for i in range(3):
        eng.submit(Request(f"w{i}", rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                           max_new=2))
    eng.run()
    pool = eng.runner.paged.pool
    n_evictable = len(pool.evictable)
    assert n_evictable > 0
    eng.scheduler.queue.append(Request("head", np.arange(8, dtype=np.int32), max_new=2))
    eng._unblock_admission_or_raise()  # reclaims, must NOT raise
    assert len(pool.evictable) == 0
    assert pool.num_free == pool.num_blocks
    with pytest.raises(RuntimeError, match="can never be admitted"):
        eng._unblock_admission_or_raise()  # nothing left to reclaim


def test_block_pool_evict_all_cached(tiny):
    from repro.serving.paging import BlockPool

    pool = BlockPool(num_blocks=4, block_size=4)
    pids = [pool.alloc() for _ in range(3)]
    for i, pid in enumerate(pids):
        pool.register(hash(("h", i)), pid, tokens=(i,) * 4)
        pool.decref(pid)  # registered + refcount 0 -> evictable
    assert len(pool.evictable) == 3
    assert pool.evict_all_cached() == 3
    assert len(pool.evictable) == 0 and len(pool.free_list) == 4
    assert pool.lookup(hash(("h", 0)), (0,) * 4) is None  # unregistered


def test_bucket_contiguous_quantum_alignment(tiny):
    """Satellite regression: with max_len not a multiple of the contiguous
    quantum, every bucket must be quantum-aligned — except the single exact
    max_len shape reserved for prompts longer than the aligned cap."""
    cfg, api, params = tiny
    runner = ModelRunner(cfg, params, n_slots=1, max_len=50, prompt_len=12)
    assert runner.max_len % runner.prompt_len != 0  # the regression setup
    cap = 50 - 50 % 12  # 48
    for n in range(1, 51):
        b = runner.bucket(n)
        assert n <= b <= runner.max_len, (n, b)
        if n <= cap:
            assert b % 12 == 0, f"bucket({n}) = {b} is not quantum-aligned"
        else:
            assert b == 50  # the one exact fallback shape
    # paged buckets stay block-aligned under a misaligned max_len too
    prunner = ModelRunner(cfg, params, n_slots=1, max_len=50, prompt_len=12,
                          cache_layout="paged", block_size=8)
    for n in range(1, 51):
        assert prunner.bucket(n) % 8 == 0
