"""Checkpoint/restore, elastic resharding, and the fault-tolerance loop."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layers": {"w": jnp.asarray(rng.normal(size=(4, 8, 8)), jnp.float32)},
        "emb": jnp.asarray(rng.normal(size=(16, 8)), jnp.float32),
        "step_scalar": jnp.float32(3.5),
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(7, tree)
    restored, step = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _tree(s))
    mgr.wait()
    assert mgr.all_steps() == [3, 4]  # gc keeps the last 2
    restored, step = mgr.restore(jax.tree.map(jnp.zeros_like, _tree()))
    assert step == 4


def test_crash_safety_partial_write_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, _tree())
    # simulate a crashed write: tmp dir + a step dir without meta.json
    (tmp_path / ".tmp_step_00000009").mkdir()
    broken = tmp_path / "step_00000777"
    broken.mkdir()
    (broken / "arrays.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 5  # incomplete checkpoints invisible
    _, step = mgr.restore(jax.tree.map(jnp.zeros_like, _tree()))
    assert step == 5


def test_elastic_restore_resharding(tmp_path):
    """Restore device_puts against a DIFFERENT sharding than the save —
    the elastic shrink/grow path (here: replicated -> host mesh)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_host_mesh

    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(1, tree)
    mesh = make_host_mesh()
    sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), tree)
    restored, _ = mgr.restore(jax.tree.map(jnp.zeros_like, tree), shardings=sh)
    for leaf in jax.tree.leaves(restored):
        assert leaf.sharding.mesh.shape == mesh.shape
    np.testing.assert_array_equal(np.asarray(restored["emb"]), np.asarray(tree["emb"]))


def test_train_cli_fault_recovery(tmp_path):
    """End-to-end: train, kill, restart-with-restore continues at the right
    step and reproduces the exact data stream."""
    from repro.launch import train as train_cli

    ckpt = str(tmp_path / "ck")
    rc = train_cli.main([
        "--arch", "smollm-135m", "--reduced", "--steps", "6", "--batch", "2",
        "--seq", "32", "--ckpt-dir", ckpt, "--ckpt-every", "3", "--log-every", "2",
    ])
    assert rc == 0
    mgr = CheckpointManager(ckpt)
    assert mgr.latest_step() == 6
    rc = train_cli.main([
        "--arch", "smollm-135m", "--reduced", "--steps", "8", "--batch", "2",
        "--seq", "32", "--ckpt-dir", ckpt, "--ckpt-every", "3", "--log-every", "2",
        "--restore",
    ])
    assert rc == 0
    assert CheckpointManager(ckpt).latest_step() == 8
