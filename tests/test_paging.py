"""Paged KV-cache subsystem: allocator invariants, prefix caching, paged
kernel parity, and end-to-end paged-vs-contiguous serving equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import get_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.paging import BlockPool, PagedKVCache, PoolExhausted


# ------------------------------------------------------------- BlockPool --


def _pool_invariant(pool: BlockPool):
    assert len(pool.free_list) + len(pool.evictable) + pool.num_live == pool.num_blocks
    for pid in pool.evictable:
        assert pool.meta[pid].refcount == 0
        assert pool.meta[pid].hash is not None


def test_blockpool_alloc_free_refcount():
    pool = BlockPool(4, 8)
    a, b = pool.alloc(), pool.alloc()
    assert a != b and pool.refcount(a) == pool.refcount(b) == 1
    assert pool.num_free == 2
    pool.incref(a)
    assert pool.refcount(a) == 2
    assert pool.decref(a) == 1  # still live
    _pool_invariant(pool)
    assert pool.decref(a) == 0  # unregistered -> straight back to free list
    assert pool.num_free == 3 and pool.num_live == 1
    _pool_invariant(pool)
    pool.decref(b)
    assert pool.num_free == 4 and pool.num_live == 0


def test_blockpool_exhaustion_and_rollback():
    pool = BlockPool(2, 8)
    pool.alloc(), pool.alloc()
    with pytest.raises(PoolExhausted):
        pool.alloc()
    _pool_invariant(pool)


def test_blockpool_copy_on_write():
    pool = BlockPool(3, 8)
    p = pool.alloc()
    # uniquely held: write in place, no copy
    same, copied = pool.copy_on_write(p)
    assert same == p and not copied
    # shared: fork — writer gets a fresh page, the other holder keeps p
    pool.incref(p)
    new, copied = pool.copy_on_write(p)
    assert copied and new != p
    assert pool.refcount(p) == 1 and pool.refcount(new) == 1
    assert pool.stats.cow_copies == 1
    _pool_invariant(pool)


def test_blockpool_prefix_cache_hand_computed_hashes():
    pool = BlockPool(8, 4)
    toks = np.arange(12, dtype=np.int32)  # three full 4-token pages
    h0 = hash((None, (0, 1, 2, 3)))
    h1 = hash((h0, (4, 5, 6, 7)))
    assert BlockPool.chain_hash(None, toks[:4]) == h0
    assert BlockPool.chain_hash(h0, toks[4:8]) == h1

    p0, p1 = pool.alloc(), pool.alloc()
    pool.register(h0, p0, toks[:4])
    pool.register(h1, p1, toks[4:8])
    assert pool.lookup(h0, toks[:4]) == p0 and pool.refcount(p0) == 2
    assert pool.lookup(hash((None, (9, 9, 9, 9)))) is None
    # a hash collision with DIFFERENT tokens must miss, not serve wrong KV
    assert pool.lookup(h0, (9, 9, 9, 9)) is None
    assert pool.refcount(p0) == 2  # collision probe took no reference
    assert pool.stats.prefix_hits == 1 and pool.stats.prefix_misses == 2


def test_blockpool_evictable_revive_and_lru_eviction():
    pool = BlockPool(2, 4)
    p0, p1 = pool.alloc(), pool.alloc()
    pool.register(100, p0)
    pool.register(200, p1)
    pool.decref(p0)  # registered -> evictable, contents retained
    pool.decref(p1)
    assert pool.num_free == 2 and len(pool.evictable) == 2
    # a hit on an evictable page revives it (no data movement)
    assert pool.lookup(100) == p0 and pool.refcount(p0) == 1
    # allocation under pressure evicts the LRU cached page (p1)
    fresh = pool.alloc()
    assert fresh == p1 and pool.meta[p1].hash is None
    assert pool.lookup(200) is None  # its hash is gone
    _pool_invariant(pool)


# ----------------------------------------------------------- PagedKVCache --


def _paged_cache(n_blocks=8, bs=4, n_slots=2, max_len=32):
    kv_shape = (n_blocks, 2, 2, bs, 8)
    from repro.layers.attention import KVCache

    kv = KVCache(jnp.zeros(kv_shape, jnp.bfloat16), jnp.zeros(kv_shape, jnp.bfloat16))
    return PagedKVCache(kv, n_slots=n_slots, max_len=max_len, block_size=bs)


def test_allocate_prompt_prefix_sharing_and_rollback():
    cache = _paged_cache(n_blocks=6, bs=4, n_slots=3)
    toks = np.arange(10, dtype=np.int32)  # 2 full pages + 1 partial
    m0 = cache.allocate_prompt(0, toks)
    assert len(m0.pages) == 3 and m0.cached_pages == 0
    cache.register_prompt_pages(m0)
    # same prompt on the next slot: both full pages shared, partial fresh
    m1 = cache.allocate_prompt(1, toks)
    assert m1.cached_pages == 2
    assert m1.pages[:2] == m0.pages[:2] and m1.pages[2] != m0.pages[2]
    assert cache.pool.refcount(m0.pages[0]) == 2
    # pool now holds 4 live pages of 6; a distinct 3-page prompt cannot fit
    # -> the failed admission must roll back completely
    live_before = cache.pool.num_live
    with pytest.raises(PoolExhausted):
        cache.allocate_prompt(2, np.full(12, 77, np.int32))
    assert cache.pool.num_live == live_before
    assert not cache.tables[2]


def test_ensure_append_page_growth_and_cow():
    cache = _paged_cache(n_blocks=8, bs=4)
    toks = np.arange(8, dtype=np.int32)  # exactly 2 full pages
    m0 = cache.allocate_prompt(0, toks)
    cache.register_prompt_pages(m0)
    # position 8 starts page 2 -> grows the table
    assert cache.ensure_append_page(0, 8) is None
    assert len(cache.tables[0]) == 3
    # share page 1 with slot 1, then force a write into it on slot 0:
    cache.pool.incref(m0.pages[1])
    copy = cache.ensure_append_page(0, 6)  # position 6 lives in page 1
    assert copy is not None
    dst, src = copy
    assert src == m0.pages[1] and cache.tables[0][1] == dst != src
    cache.pool.decref(m0.pages[1])


def test_block_tables_array_layout():
    cache = _paged_cache(n_blocks=8, bs=4, n_slots=3)
    m = cache.allocate_prompt(1, np.arange(9, dtype=np.int32))
    arr = np.asarray(cache.block_tables_array())
    assert arr.shape == (3, cache.max_pages)
    np.testing.assert_array_equal(arr[1, :3], m.pages)
    assert (arr[0] == 0).all() and (arr[2] == 0).all()


# ------------------------------------------------------------ paged kernel --


@pytest.mark.parametrize(
    "b,hkv,g,d,bs,n_pages_seq",
    [
        (2, 2, 2, 32, 8, 3),
        (1, 1, 4, 64, 16, 2),  # MHA-as-GQA grouping
        (3, 2, 1, 32, 4, 4),  # g=1
    ],
)
def test_paged_kernel_matches_reference_at_ragged_lengths(b, hkv, g, d, bs, n_pages_seq):
    from repro.kernels.paged_attention.kernel import paged_decode_attention_pallas
    from repro.kernels.paged_attention.ref import paged_decode_attention_reference

    rng = np.random.default_rng(0)
    n_blocks = b * n_pages_seq + 2
    q = jnp.asarray(rng.normal(size=(b, hkv, g, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_blocks, hkv, bs, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_blocks, hkv, bs, d)), jnp.float32)
    # distinct shuffled tables per sequence; ragged lengths incl. partial pages
    perm = rng.permutation(n_blocks)[: b * n_pages_seq].reshape(b, n_pages_seq)
    tables = jnp.asarray(perm, jnp.int32)
    lengths = jnp.asarray(rng.integers(1, n_pages_seq * bs + 1, size=b), jnp.int32)
    ref = paged_decode_attention_reference(q, kp, vp, tables, lengths)
    out, _, _ = paged_decode_attention_pallas(q, kp, vp, tables, lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_paged_kernel_sliding_window_starts():
    from repro.kernels.paged_attention.kernel import paged_decode_attention_pallas
    from repro.kernels.paged_attention.ref import paged_decode_attention_reference

    rng = np.random.default_rng(1)
    b, hkv, g, d, bs, P = 2, 2, 2, 32, 8, 3
    q = jnp.asarray(rng.normal(size=(b, hkv, g, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(8, hkv, bs, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(8, hkv, bs, d)), jnp.float32)
    tables = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    lengths = jnp.asarray([20, 23], jnp.int32)
    starts = jnp.asarray([9, 0], jnp.int32)
    ref = paged_decode_attention_reference(q, kp, vp, tables, lengths, starts)
    out, _, _ = paged_decode_attention_pallas(q, kp, vp, tables, lengths, starts, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_decode_kernel_partial_final_block():
    """Satellite: s % bk != 0 needs no caller-side padding any more."""
    from repro.kernels.decode_attention.kernel import decode_attention_pallas
    from repro.kernels.decode_attention.ref import decode_attention_reference

    rng = np.random.default_rng(2)
    b, hkv, g, d, s = 2, 2, 2, 32, 37  # prime-ish, far from any block multiple
    q = jnp.asarray(rng.normal(size=(b, hkv, g, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    lengths = jnp.asarray([s, 11], jnp.int32)
    ref = decode_attention_reference(q, k, v, lengths)
    out, _, _ = decode_attention_pallas(q, k, v, lengths, bk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------- end to end --


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced_config("bitnet-730m", num_layers=3, d_model=128, vocab_size=512,
                         num_heads=4, num_kv_heads=2)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, api, params


def _serve(cfg, params, prompts, *, layout, mode="pdswap", max_new=6, **kw):
    eng = ServingEngine(cfg, params, n_slots=3, max_len=64, prompt_len=12,
                        mode=mode, cache_layout=layout, block_size=8, **kw)
    for i, (p, prio) in enumerate(prompts):
        eng.submit(Request(f"r{i}", p, max_new=max_new, priority=prio))
    stats = eng.run()
    return eng, stats, {k: v.out_tokens for k, v in eng.finished.items()}


@pytest.mark.parametrize("mode", ["pdswap", "static"])
def test_paged_matches_contiguous_token_for_token(tiny, mode):
    cfg, api, params = tiny
    rng = np.random.default_rng(3)
    base = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    prompts = [  # ragged lengths, two sharing a 16-token (2-page) prefix
        (rng.integers(0, cfg.vocab_size, 12).astype(np.int32), 0),
        (base.copy(), 0),
        (rng.integers(0, cfg.vocab_size, 7).astype(np.int32), 0),
        (np.concatenate([base[:16], rng.integers(0, cfg.vocab_size, 5).astype(np.int32)]), 0),
    ]
    _, _, ref = _serve(cfg, params, prompts, layout="contiguous", mode=mode)
    eng, stats, got = _serve(cfg, params, prompts, layout="paged", mode=mode)
    assert got == ref  # token-for-token across the layout swap
    assert stats.prefix_hits > 0  # shared-prefix workload reuses pages
    kb = eng.kv_bytes()
    assert kb["peak_in_use"] < kb["allocated"]  # ragged lengths don't pay max_len


def test_paged_preemption_is_deterministic(tiny):
    """A pool too small for the offered load forces eviction; the replayed
    restart continues bit-identically to an unpreempted run."""
    cfg, api, params = tiny
    rng = np.random.default_rng(4)
    prompts = [(rng.integers(0, cfg.vocab_size, 14).astype(np.int32), i) for i in range(4)]
    _, _, ref = _serve(cfg, params, prompts, layout="contiguous", mode="static", max_new=10)
    _, stats, got = _serve(cfg, params, prompts, layout="paged", mode="static",
                           max_new=10, num_blocks=7)
    assert stats.preemptions > 0 and stats.replayed_tokens > 0
    assert got == ref


def test_paged_heavy_pressure_no_livelock(tiny):
    """Regression: pool sized well below the offered load (3 slots x 4 pages
    wanted, 6 pages held) forces repeated preempt-restart cycles; the resume
    headroom check must keep the engine making progress (an earlier version
    livelocked with two restarts evicting each other during replay)."""
    cfg, api, params = tiny
    rng = np.random.default_rng(6)
    prompts = [(rng.integers(0, cfg.vocab_size, 16).astype(np.int32), 0) for _ in range(4)]
    _, _, ref = _serve(cfg, params, prompts, layout="contiguous", mode="static", max_new=12)
    eng, stats, got = _serve(cfg, params, prompts, layout="paged", mode="static",
                             max_new=12, num_blocks=6)
    assert len(eng.finished) == 4
    assert stats.preemptions > 1
    assert got == ref


def test_kv_bytes_payload_ratio_across_dtypes(tiny):
    """kv_bytes() must reflect the REAL pool footprint: the packed payload
    is exactly 2x (int8) / 4x (int4) smaller than fp; the allocated total
    additionally carries the fp32 scale planes."""
    cfg, api, params = tiny
    kb = {}
    for kv_dtype in ("fp", "int8", "int4"):
        eng = ServingEngine(cfg, params, n_slots=2, max_len=64, prompt_len=12,
                            mode="static", cache_layout="paged", block_size=8,
                            kv_dtype=kv_dtype)
        kb[kv_dtype] = eng.kv_bytes()
    assert kb["fp"]["payload"] == 2 * kb["int8"]["payload"] == 4 * kb["int4"]["payload"]
    assert kb["fp"]["payload"] == kb["fp"]["allocated"]  # fp carries no scales
    for dt in ("int8", "int4"):
        assert kb[dt]["allocated"] > kb[dt]["payload"]  # + scale planes
        assert kb[dt]["allocated"] < kb["fp"]["allocated"]  # still a net win
        assert kb[dt]["kv_dtype"] == dt
    # contiguous accounting agrees on the ratio
    kc = {dt: ServingEngine(cfg, params, n_slots=2, max_len=64, prompt_len=12,
                            mode="static", kv_dtype=dt).kv_bytes()
          for dt in ("fp", "int4")}
    assert kc["fp"]["payload"] == 4 * kc["int4"]["payload"]


def test_paged_preemption_replay_bit_identical_int4(tiny):
    """THE quantized-replay property: under kv_dtype="int4" a preempted +
    replayed request continues bit-identically to an int4 run that was never
    preempted — requantizing the same values reproduces the same pages, so
    eviction/restart is invisible in the token stream."""
    cfg, api, params = tiny
    rng = np.random.default_rng(4)
    prompts = [(rng.integers(0, cfg.vocab_size, 14).astype(np.int32), i) for i in range(4)]
    # ample capacity (contiguous) int4 reference: never preempts
    _, _, ref = _serve(cfg, params, prompts, layout="contiguous", mode="static",
                       max_new=10, kv_dtype="int4")
    _, stats, got = _serve(cfg, params, prompts, layout="paged", mode="static",
                           max_new=10, num_blocks=7, kv_dtype="int4")
    assert stats.preemptions > 0 and stats.replayed_tokens > 0
    assert got == ref


def test_varlen_prompts_not_truncated(tiny):
    """Satellite: prompts longer than prompt_len keep every token (the seed
    engine silently dropped them)."""
    cfg, api, params = tiny
    rng = np.random.default_rng(5)
    long_prompt = rng.integers(0, cfg.vocab_size, 30).astype(np.int32)  # > prompt_len=12
    outs = {}
    for layout in ("contiguous", "paged"):
        eng = ServingEngine(cfg, params, n_slots=2, max_len=64, prompt_len=12,
                            mode="static", cache_layout=layout, block_size=8)
        eng.submit(Request("long", long_prompt.copy(), max_new=4))
        eng.run()
        assert eng.stats.prefill_tokens == 30  # all 30 tokens prefilled
        outs[layout] = eng.finished["long"].out_tokens
    assert outs["contiguous"] == outs["paged"]
    # truncation would have produced the 12-token prompt's continuation:
    eng = ServingEngine(cfg, params, n_slots=2, max_len=64, prompt_len=12,
                        mode="static", cache_layout="contiguous")
    eng.submit(Request("short", long_prompt[:12].copy(), max_new=4))
    eng.run()
    assert eng.finished["short"].out_tokens != outs["contiguous"]


def test_oversized_prompt_rejected_with_clear_error(tiny):
    cfg, api, params = tiny
    eng = ServingEngine(cfg, params, n_slots=2, max_len=32, prompt_len=12)
    with pytest.raises(ValueError, match="never truncated"):
        eng.submit(Request("big", np.zeros(40, np.int32), max_new=4))
    with pytest.raises(ValueError, match="never truncated"):
        eng.submit(Request("edge", np.zeros(30, np.int32), max_new=4))


def test_pool_too_small_rejected_at_submit(tiny):
    """A request whose full trajectory (prompt + max_new) exceeds the pool
    can never complete — it must be rejected up front, not self-preempt
    forever."""
    cfg, api, params = tiny
    eng = ServingEngine(cfg, params, n_slots=2, max_len=64, prompt_len=12,
                        mode="static", cache_layout="paged", block_size=8, num_blocks=2)
    with pytest.raises(ValueError, match="pool holds 2"):
        eng.submit(Request("big", np.arange(30, dtype=np.int32) % cfg.vocab_size, max_new=4))
    # trajectory that exactly fits is accepted and completes
    eng.submit(Request("fits", np.arange(9, dtype=np.int32), max_new=8))  # 16 tokens, 2 pages
    eng.run()
    assert len(eng.finished["fits"].out_tokens) == 8
