"""Decode interference under concurrent long-prompt prefill: disagg vs colocated.

THE payoff measurement for the disaggregated two-pool runtime
(``repro.serving.disagg``): on a single engine, every prefill chunk of a
long prompt runs on the same device as the decode round next to it, so
concurrent admissions inflate the inter-token latency (ITL) of every
in-flight decode stream — chunked prefill bounds the stall to one chunk,
but the stall is still there.  With the pools split, chunks compute on the
PREFILL device while decode rounds run on the DECODE device; finished-chunk
KV ships eagerly over the ``KVHandoffChannel`` and its decode-side install
is deferred until the final chunk, so a decode round never acquires a data
dependency on the in-flight prefill and its ITL barely moves.

Protocol (same seeded workload against both engines, same step loop):

1. warm both engines' XLA programs on a throwaway pass (all shape buckets);
2. **baseline phase** — K short-prompt decode streams, no other traffic;
   per-stream ITL is stamped benchmark-side from ``step()`` deltas;
3. **interference phase** — the same K streams, plus long chunked-prefill
   prompts injected on a stagger while they decode.

The claim: disagg decode ITL p95 under interference stays within ~1.1x of
its own no-prefill baseline, while the colocated engine clearly degrades
(its interference p95 >= ~1.25x baseline).  Both ratio checks are
wall-clock and gate only the full run; ``--tiny`` (CI smoke on forced host
devices) keeps the structural checks — gaps recorded, every request
finished, KV actually crossed the channel.

Needs two devices, so direct runs force
``--xla_force_host_platform_device_count=2`` before importing jax, and the
harness entry (``benchmarks.run``) re-executes this module in a subprocess
(the parent's jax is already initialized with one device).

    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
        PYTHONPATH=src python -m benchmarks.disagg_interference [--tiny]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np

from .common import (LATENCY_COLUMNS, add_trace_arg, finish_trace,
                     latency_rows, markdown_table, save_result, start_trace)

REPO = Path(__file__).resolve().parent.parent
MARKER = "DISAGG_INTERFERENCE_JSON:"


def _ensure_devices(n: int = 2) -> None:
    """Force ``n`` host devices — only effective before jax first imports,
    which is why ``run()`` goes through a subprocess."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={n} {flags}".strip()


def _drive_phase(eng, decoders, longs, *, max_new_dec, stagger, tag):
    """Submit K decode streams (plus staggered long prompts), step the
    engine to completion, and return the pooled decoder inter-token gaps
    stamped around ``step()`` — the engine's own ``stats.itl`` would mix in
    the long prompts' deltas, so the decoders are timed benchmark-side."""
    from repro.serving import Request

    dec_ids = [f"{tag}-dec{i}" for i in range(len(decoders))]
    for rid, p in zip(dec_ids, decoders):
        eng.submit(Request(rid, p.copy(), max_new=max_new_dec))
    stamps = {rid: [] for rid in dec_ids}

    def absorb():
        outs = eng.step()
        t = time.perf_counter()
        for o in outs:
            if o.request_id in stamps and o.new_token_ids:
                stamps[o.request_id].append(t)

    # first tokens out: every decoder is mid-decode when the storm starts
    while any(not stamps[r] for r in dec_ids) and eng.has_unfinished():
        absorb()
    steps, pending = 0, list(longs)
    while eng.has_unfinished():
        if pending and steps % stagger == 0:
            eng.submit(Request(f"{tag}-long{len(longs) - len(pending)}",
                               pending.pop(0), max_new=2))
        absorb()
        steps += 1
    gaps = [g for rid in dec_ids for g in np.diff(stamps[rid])]
    assert all(len(stamps[rid]) >= max_new_dec for rid in dec_ids)
    return np.asarray(gaps, float)


def _measure(tiny: bool) -> dict:
    import jax

    # The prefill pool's dispatch thread holds the GIL for the Python
    # portion of each chunk dispatch; with CPython's default 5ms switch
    # interval the engine thread can stall that long waiting for it, which
    # is the same order as a whole decode round.  GIL handoff is not
    # priority-aware, so the pool's idle scheduling class can't help here —
    # shorten the interval instead.
    sys.setswitchinterval(5e-4)

    # On a shared-CPU host, XLA's async dispatch executes BOTH pools'
    # programs on one normal-priority helper thread, letting chunk compute
    # steal cycles mid-decode-round no matter how the pools prioritize
    # their dispatch.  Synchronous dispatch runs each program on the thread
    # that called it, so the prefill pool's self-deprioritized dispatch
    # thread (see PrefillPool) really does yield the core to decode — the
    # single-host analogue of prefill owning its own devices.
    jax.config.update("jax_cpu_enable_async_dispatch", False)
    import jax.numpy as jnp

    from repro.configs import reduced_config
    from repro.models import get_model
    from repro.serving import DisaggEngine, EngineCore, Request, make_disagg_meshes

    if tiny:
        cfg = reduced_config("bitnet-730m", num_layers=2, d_model=64,
                             vocab_size=256, num_heads=4, num_kv_heads=2)
        n_dec, max_new_dec, long_len, n_long, chunk = 2, 12, 48, 2, 16
        max_len, stagger, rounds = 64, 3, 1
    else:
        cfg = reduced_config("bitnet-730m", num_layers=4, d_model=512,
                             vocab_size=512, num_heads=8, num_kv_heads=4)
        n_dec, max_new_dec, long_len, n_long, chunk = 3, 100, 192, 2, 16
        max_len, stagger, rounds = 256, 4, 3
    params = get_model(cfg).init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    rng = np.random.default_rng(0)
    decoders = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
                for _ in range(n_dec)]
    longs = [rng.integers(0, cfg.vocab_size, long_len).astype(np.int32)
             for _ in range(n_long)]
    knobs = dict(n_slots=n_dec + 1, max_len=max_len, prompt_len=long_len,
                 prefill_chunk=chunk)

    pmesh, dmesh = make_disagg_meshes()
    engines = {
        "colocated": EngineCore(cfg, params, **knobs),
        "disagg": DisaggEngine(cfg, params, prefill_mesh=pmesh,
                               decode_mesh=dmesh, **knobs),
    }

    from repro.obs.trace import TRACER

    rows, lat_rows, itl, handoff = [], [], {}, None
    for mode, eng in engines.items():
        if TRACER.enabled:
            # one engine per trace window: warmup ids repeat across engines
            # and the tracer's exactly-once finish assertion is per-process,
            # so each mode starts a fresh buffer (the export keeps the LAST
            # mode — disagg, the one whose lane overlap the trace is for)
            TRACER.clear()
        # warmup hits every shape bucket the measured phases use (decoder
        # prompt, full + final chunk, decode round), on THIS engine's
        # program caches
        for i, p in enumerate(decoders):
            eng.submit(Request(f"warm-dec{i}", p.copy(), max_new=2))
        eng.submit(Request("warm-long", longs[0].copy(), max_new=2))
        eng.run()
        eng.reset_stats()
        # baseline and interference alternate round-robin, and each phase
        # pools its gaps across rounds: slow ambient drift (a shared host's
        # noisy neighbors, thermal throttling) hits both phases alike
        # instead of landing entirely on whichever was measured last
        per_phase = {"baseline": [], "interference": []}
        for r in range(rounds):
            for phase, storm in (("baseline", []), ("interference", longs)):
                per_phase[phase].append(_drive_phase(
                    eng, decoders, storm, tag=f"{mode[:3]}-{phase[:5]}-r{r}",
                    max_new_dec=max_new_dec, stagger=stagger))
        for phase, storm in (("baseline", []), ("interference", longs)):
            gaps = np.concatenate(per_phase[phase])
            itl[(mode, phase)] = gaps
            rows.append({
                "mode": mode, "phase": phase,
                "concurrent_prefill_tokens": len(storm) * long_len,
                "decode_gaps": len(gaps),
                "itl_p50_ms": 1e3 * float(np.percentile(gaps, 50)),
                "itl_p95_ms": 1e3 * float(np.percentile(gaps, 95)),
                "itl_max_ms": 1e3 * float(np.max(gaps)),
            })
        lat_rows.extend(latency_rows(eng, label=mode))
        if mode == "disagg":
            handoff = eng.snapshot()["disagg"]["handoff"]

    def ratio(mode):
        base = float(np.percentile(itl[(mode, "baseline")], 95))
        storm = float(np.percentile(itl[(mode, "interference")], 95))
        return storm / max(base, 1e-9)

    ratios = {m: ratio(m) for m in engines}
    for m in engines:
        rows.append({"mode": m, "phase": "p95 ratio (interference/baseline)",
                     "concurrent_prefill_tokens": n_long * long_len,
                     "decode_gaps": len(itl[(m, "interference")]),
                     "itl_p50_ms": "", "itl_p95_ms": round(ratios[m], 3),
                     "itl_max_ms": ""})

    checks = {
        "ITL gaps recorded in every phase": all(len(g) > 0 for g in itl.values()),
        "KV crossed the handoff channel": bool(
            handoff and handoff["segments"] > 0 and handoff["pending"] == 0),
        "eager chunk segments shipped": bool(
            handoff and handoff["eager_segments"] > 0),
    }
    timing = {
        "disagg interference p95 <= 1.1x its baseline": ratios["disagg"] <= 1.1,
        "colocated clearly degraded (>= 1.25x baseline)": ratios["colocated"] >= 1.25,
        "disagg degrades less than colocated": ratios["disagg"] < ratios["colocated"],
    }
    if not tiny:
        # full scale is where the claim is made: the ratio checks gate
        checks.update(timing)
    return {
        "name": "disagg_interference" + ("_tiny" if tiny else ""),
        "rows": rows,
        "latency_rows": lat_rows,
        "handoff": handoff,
        "ratios": ratios,
        "notes": (
            f"Decode ITL of {n_dec} streams (max_new={max_new_dec}) without vs "
            f"with {n_long} concurrent {long_len}-token chunked prefills "
            f"(chunk={chunk}), colocated single engine vs two-pool "
            f"DisaggEngine on forced host devices (prefill pool "
            f"{pmesh.devices.size} dev, decode pool "
            f"{dmesh.devices.size} dev); {rounds} alternating "
            f"baseline/interference round(s) pooled per phase.  Checks: "
            + ", ".join(
                f"{k}={'PASS' if v else 'FAIL'}"
                for k, v in {**checks, **timing}.items())),
        "checks": checks,
        "timing_checks": timing,
        "columns": ["mode", "phase", "concurrent_prefill_tokens", "decode_gaps",
                    "itl_p50_ms", "itl_p95_ms", "itl_max_ms"],
    }


def run(tiny: bool = False) -> dict:
    """Harness entry: the parent process's jax is already pinned to one
    device, so the measurement re-executes this module in a subprocess with
    the forced-device flag and parses its JSON marker line."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count=2 {flags}".strip()
    src = str(REPO / "src")
    env["PYTHONPATH"] = src + os.pathsep * bool(env.get("PYTHONPATH", "")) \
        + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "benchmarks.disagg_interference", "--emit-json"]
    if tiny:
        cmd.append("--tiny")
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=1200,
                         env=env, cwd=REPO)
    for line in out.stdout.splitlines():
        if line.startswith(MARKER):
            result = json.loads(line[len(MARKER):])
            save_result(result)
            return result
    raise RuntimeError(
        f"disagg_interference subprocess produced no result marker\n"
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}")


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--tiny", action="store_true",
                   help="CI smoke: small model/workload, structural checks only")
    p.add_argument("--emit-json", action="store_true",
                   help="print the machine-readable result marker (harness)")
    add_trace_arg(p)
    args = p.parse_args(argv)
    _ensure_devices(2)
    start_trace(args.trace_out)
    result = _measure(tiny=args.tiny)
    finish_trace(args.trace_out)
    save_result(result)
    print(markdown_table(result["rows"], result.get("columns")))
    print()
    print("engine latency (metrics registry — the /metrics summaries):")
    print(markdown_table(result["latency_rows"], list(LATENCY_COLUMNS)))
    print()
    print(result["notes"])
    if args.emit_json:
        print(MARKER + json.dumps(result, default=float))
    return 0 if all(result["checks"].values()) else 1


if __name__ == "__main__":
    sys.exit(main())
