"""Table 1 analogue: cross-platform edge LLM inference comparison.

Reprints the paper's measured rows (for context) and adds the v5e rows this
framework targets, derived from the same roofline arithmetic the paper uses:
decode is memory-bound -> tok/s = bw / bytes-per-token; energy efficiency =
tok/s / W.  The point of the row is the *technique transfer*: ternary
weights resident at 0.25 B/param keep decode weight traffic 8x below bf16,
on TPU exactly as on the FPGA.
"""
from __future__ import annotations

from repro.common.hardware import TPU_V5E
from repro.configs import get_config

from .common import save_result

# Paper Table 1 (measured, reprinted for comparison)
PAPER_ROWS = [
    {"work": "Raspberry Pi 5 [19]", "platform": "SoC", "model": "Qwen 0.6B W4", "power_W": 7.8,
     "prefill_tok/s": 61.8, "decode_tok/s": 16.6, "decode_tok/J": 2.12},
    {"work": "Jetson Orin Nano [20]", "platform": "GPU SoC", "model": "TinyLLaMA 1.1B W4", "power_W": 25,
     "prefill_tok/s": 324.9, "decode_tok/s": 67.6, "decode_tok/J": 2.70},
    {"work": "LLaMAF [21]", "platform": "ZCU102", "model": "TinyLLaMA 1.1B W8", "power_W": 5.1,
     "prefill_tok/s": 100, "decode_tok/s": 1.5, "decode_tok/J": 0.29},
    {"work": "MEADOW [1]", "platform": "ZCU102", "model": "OPT 1.3B W8", "power_W": 10,
     "prefill_tok/s": 143, "decode_tok/s": 2, "decode_tok/J": 0.20},
    {"work": "TeLLMe [10]", "platform": "KV260", "model": "BitNet 0.73B W1.58", "power_W": 4.8,
     "prefill_tok/s": "-", "decode_tok/s": 25, "decode_tok/J": 5.2},
    {"work": "PD-Swap (paper)", "platform": "KV260", "model": "BitNet 0.73B W1.58", "power_W": 4.9,
     "prefill_tok/s": 148, "decode_tok/s": 27.8, "decode_tok/J": 5.67},
]

V5E_POWER_W = 170  # chip TDP-class figure for the efficiency column


def _v5e_row(arch: str, ternary: bool, batch: int, ctx: int) -> dict:
    cfg = get_config(arch, quant_mode="ternary" if ternary else "bf16")
    chip = TPU_V5E
    wbytes = cfg.active_param_count() * (0.25 if ternary else 2.0)
    kv_per_tok = 2 * cfg.num_layers * cfg.num_kv_heads * cfg.head_dim * 2
    t_dec = (wbytes + kv_per_tok * ctx * batch) / chip.hbm_bw
    decode_tps = batch / t_dec
    # prefill: compute-bound at peak (int8 path for ternary)
    peak = chip.peak_flops_int8 if ternary else chip.peak_flops_bf16
    prefill_tps = peak / (2 * cfg.active_param_count())
    return {
        "work": f"this repo ({'W1.58' if ternary else 'bf16'}, b={batch})",
        "platform": "TPU v5e x1",
        "model": f"{arch} ctx={ctx}",
        "power_W": V5E_POWER_W,
        "prefill_tok/s": prefill_tps,
        "decode_tok/s": decode_tps,
        "decode_tok/J": decode_tps / V5E_POWER_W,
    }


def run() -> dict:
    rows = list(PAPER_ROWS)
    rows.append(_v5e_row("bitnet-730m", ternary=True, batch=1, ctx=512))
    rows.append(_v5e_row("bitnet-730m", ternary=False, batch=1, ctx=512))
    rows.append(_v5e_row("bitnet-730m", ternary=True, batch=64, ctx=512))
    t = next(r for r in rows if r["work"].startswith("this repo (W1.58, b=1)"))
    b = next(r for r in rows if r["work"].startswith("this repo (bf16"))
    checks = {
        "ternary decode > 4x bf16 decode at b=1 (weight-bound)": t["decode_tok/s"] > 4 * b["decode_tok/s"],
    }
    result = {
        "name": "table1_comparison",
        "rows": rows,
        "notes": (
            "Paper rows reprinted (measured on-device); v5e rows are roofline-"
            "derived for the same BitNet 0.73B.  The ternary-vs-bf16 pair shows "
            "the TLMM memory-system win transfers to TPU: "
            + ", ".join(f"{k}={'PASS' if v else 'FAIL'}" for k, v in checks.items())
        ),
        "checks": checks,
    }
    save_result(result)
    return result
