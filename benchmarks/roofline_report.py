"""§Roofline deliverable: the full (arch x shape x mesh) three-term table.

Reads results/dryrun/*.json (produced by ``repro.launch.dryrun``) and reports
per cell:

  t_compute   = HLO_FLOPs_per_dev / peak_FLOP/s        (trip-count folded)
  t_memory    = HLO_bytes_per_dev / HBM_bw
  t_collective= collective_bytes_per_dev / ICI_bw
  dominant    = argmax of the three  (the bottleneck the perf loop works on)
  useful      = MODEL_FLOPS / HLO_FLOPs_global  (remat/replication waste)
  rf          = roofline fraction: ideal model-flops time / max-term
"""
from __future__ import annotations

from repro.common.hardware import DEFAULT_CHIP

from .common import load_dryrun_records, save_result

_SUGGEST = {
    # dominant-term -> what would move it down (reported per row)
    "compute": "raise useful_frac: remove replicated compute (shard heads/ffn finer) or drop remat",
    "memory": "cut materialized traffic: fuse converts, bf16 KV streaming, larger kernel blocks",
    "collective": "reshard to cut all-gathers: FSDP prefetch overlap, 2D sharding, EP all_to_all",
}


def _kv_dtype_bound_note(chip) -> str:
    """One line showing how the analytic Eq.(5) decode bound shifts with the
    KV-cache storage precision (the kv_dtype subsystem's roofline lever)."""
    from repro.configs import get_config
    from repro.core.roofline import (
        decode_kv_stream_time,
        decode_kv_stream_time_speculative,
        kv_bytes_per_ctx_token,
    )

    cfg = get_config("bitnet-730m")  # the paper's model
    ctx = 2048
    spec_k, spec_p = 4, 0.7  # representative prompt-lookup operating point
    parts = []
    spec_parts = []
    for kv_dtype in ("fp", "int8", "int4"):
        b = kv_bytes_per_ctx_token(cfg, kv_dtype)
        t = decode_kv_stream_time(cfg, ctx, kv_dtype, chip)
        parts.append(f"{kv_dtype}: {b:.0f} B/ctx-tok -> {1e3 * t:.3f} ms/tok")
        ts = decode_kv_stream_time_speculative(cfg, ctx, spec_k, spec_p, kv_dtype, chip)
        spec_parts.append(f"{kv_dtype}: {1e3 * ts:.3f} ms/tok")
    return (
        f"Eq.(5) KV-stream decode bound, bitnet-730m @ ctx {ctx} on {chip.name} "
        "(payload + fp32 scale planes; see benchmarks/kv_quant_sweep.py): "
        + "; ".join(parts) + ".  "
        f"Speculative VERIFY bound at k={spec_k}, accept p={spec_p} "
        "(one round streams the same dtype-dependent packed bytes and emits "
        "E[accept] tokens — the kv_dtype and speculation levers multiply; see "
        "benchmarks/spec_decode.py): " + "; ".join(spec_parts) + "."
    )


def run() -> dict:
    chip = DEFAULT_CHIP
    rows = []
    for rec in load_dryrun_records():
        if rec.get("status") == "skipped":
            rows.append({
                "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
                "dominant": "SKIP", "note": rec["reason"][:60],
            })
            continue
        r = rec["roofline"]
        t = {"compute": r["t_compute"], "memory": r["t_memory"], "collective": r["t_collective"]}
        t_bound = max(t.values())
        t_ideal = r["model_flops"] / (r["chips"] * chip.peak_flops_bf16)
        rows.append({
            "arch": rec["arch"],
            "shape": rec["shape"],
            "mesh": rec["mesh"],
            "t_compute_s": r["t_compute"],
            "t_memory_s": r["t_memory"],
            "t_coll_s": r["t_collective"],
            "dominant": r["dominant"],
            "useful_frac": r["useful_frac"],
            "roofline_frac": (t_ideal / t_bound) if t_bound else 0.0,
            "peak_GiB/dev": (r.get("peak_mem/dev") or 0) / 2**30,
            "note": _SUGGEST[r["dominant"]][:64],
        })
    rows.sort(key=lambda x: (x["arch"], x["shape"], x["mesh"]))
    result = {
        "name": "roofline_report",
        "rows": rows,
        "notes": (
            f"Three-term roofline per dry-run cell on {chip.name} "
            f"({chip.peak_flops_bf16/1e12:.0f} TF/s bf16, {chip.hbm_bw/1e9:.0f} GB/s HBM, "
            f"{chip.ici_bw_per_link*chip.ici_links/1e9:.0f} GB/s ICI/chip). "
            "FLOPs/bytes are while-loop trip-count folded (repro.core.hlo_cost); "
            "collective bytes summed over all-gather/all-reduce/reduce-scatter/"
            "all-to-all/collective-permute operands in the optimized HLO.  "
            + _kv_dtype_bound_note(chip)
        ),
    }
    save_result(result)
    return result
