"""Tracing overhead: the disabled tracer must be free on the decode loop.

The instrumentation contract (``repro.obs.trace``): every hot-path site
guards with ``if TRACER.enabled`` and reuses ``perf_counter`` stamps the
stats accounting already takes, so the DISABLED cost per decode round is a
handful of predicted-not-taken branches.  This benchmark measures that
claim and gates it — the observability PR must not tax serving when nobody
is watching.

Protocol: one warm engine, one seeded workload replayed as K segments per
mode, modes INTERLEAVED (disabled, enabled, disabled, enabled, ...) so slow
ambient drift (noisy neighbors, thermal) hits both alike instead of landing
on whichever ran last.  Per segment the decode-round cost comes from the
engine's own stats delta; per mode the MEDIAN segment cost is compared.

Gate: enabled-median overhead < 3 % of the disabled median, OR the absolute
delta is under 150 us/round — on a tiny CI model a decode round is sub-ms,
where 3 % is below timer/scheduler noise; on any real model the relative
gate is the binding one.  Enabled-mode tracing also exercises the ring
bound (capacity is set small enough that long runs wrap) to show overhead
does not grow when the buffer is full.

    PYTHONPATH=src python -m benchmarks.tracing_overhead [--tiny]
"""
from __future__ import annotations

import sys

import numpy as np

from .common import markdown_table, save_result

# absolute floor under which the relative gate is timer noise, not cost
ABS_FLOOR_S = 150e-6
REL_GATE = 0.03


def _decode_cost_segment(eng, prompts, *, max_new, tag):
    """Replay one workload segment; return (decode seconds, decode rounds)
    from the engine's own stats delta."""
    from repro.serving import Request

    t0, r0 = eng.stats.t_decode, eng.stats.decode_rounds
    for i, p in enumerate(prompts):
        eng.submit(Request(f"{tag}-{i}", p.copy(), max_new=max_new))
    eng.run()
    rounds = eng.stats.decode_rounds - r0
    return eng.stats.t_decode - t0, max(rounds, 1)


def run(tiny: bool = False) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import reduced_config
    from repro.models import get_model
    from repro.obs.trace import TRACER
    from repro.serving import EngineCore

    if tiny:
        cfg = reduced_config("bitnet-730m", num_layers=2, d_model=64,
                             vocab_size=256, num_heads=4, num_kv_heads=2)
        n_req, max_new, segments = 2, 24, 5
    else:
        cfg = reduced_config("bitnet-730m", num_layers=4, d_model=256,
                             vocab_size=512, num_heads=4, num_kv_heads=2)
        n_req, max_new, segments = 3, 64, 9
    params = get_model(cfg).init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    eng = EngineCore(cfg, params, n_slots=n_req, max_len=16 + max_new + 8)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
               for _ in range(n_req)]

    was_enabled = TRACER.enabled
    TRACER.disable()
    _decode_cost_segment(eng, prompts, max_new=max_new, tag="warm")  # compile
    eng.reset_stats()

    per_round = {"disabled": [], "enabled": []}
    seg = 0
    for _ in range(segments):
        for mode in ("disabled", "enabled"):
            if mode == "enabled":
                # small capacity on purpose: segments wrap the ring, so the
                # measured enabled cost includes full-buffer eviction
                TRACER.enable(capacity=4096)
            else:
                TRACER.disable()
            t, rounds = _decode_cost_segment(
                eng, prompts, max_new=max_new, tag=f"{mode[:3]}{seg}")
            per_round[mode].append(t / rounds)
            seg += 1
    TRACER.disable()
    events_recorded = TRACER._emitted  # last enabled segment's total
    if was_enabled:  # an outer --trace-out run owns the tracer
        TRACER.enable()

    med = {m: float(np.median(v)) for m, v in per_round.items()}
    delta = med["enabled"] - med["disabled"]
    rel = delta / med["disabled"] if med["disabled"] > 0 else 0.0
    ok = rel < REL_GATE or delta < ABS_FLOOR_S

    rows = [{
        "mode": m,
        "segments": len(per_round[m]),
        "round_cost_us_median": 1e6 * med[m],
        "round_cost_us_min": 1e6 * float(np.min(per_round[m])),
        "round_cost_us_max": 1e6 * float(np.max(per_round[m])),
    } for m in ("disabled", "enabled")]
    rows.append({"mode": "overhead", "segments": "",
                 "round_cost_us_median": 1e6 * delta,
                 "round_cost_us_min": f"{100 * rel:+.2f}%",
                 "round_cost_us_max": ""})

    result = {
        "name": "tracing_overhead" + ("_tiny" if tiny else ""),
        "rows": rows,
        "overhead": {"relative": rel, "absolute_s": delta,
                     "rel_gate": REL_GATE, "abs_floor_s": ABS_FLOOR_S},
        "checks": {
            f"tracing disabled costs < {100 * REL_GATE:.0f}% per decode round "
            f"(or < {1e6 * ABS_FLOOR_S:.0f}us absolute)": bool(ok),
            "enabled segments recorded events": events_recorded > 0,
        },
        "notes": (
            f"Median decode-round cost over {segments} interleaved segments "
            f"per mode ({n_req} streams x {max_new} tokens each, warm "
            f"engine, stats-delta timing).  enabled runs with a 4096-event "
            f"ring so eviction cost is included.  Overhead "
            f"{100 * rel:+.2f}% ({1e6 * delta:+.1f} us/round) — gate: "
            f"< {100 * REL_GATE:.0f}% relative or "
            f"< {1e6 * ABS_FLOOR_S:.0f} us absolute."),
        "columns": ["mode", "segments", "round_cost_us_median",
                    "round_cost_us_min", "round_cost_us_max"],
    }
    save_result(result)
    return result


def main(argv=None) -> int:
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--tiny", action="store_true",
                   help="CI smoke: small model, fewer segments")
    args = p.parse_args(argv)
    res = run(tiny=args.tiny)
    print(markdown_table(res["rows"], res.get("columns")))
    print()
    print(res["notes"])
    return 0 if all(res["checks"].values()) else 1


if __name__ == "__main__":
    sys.exit(main())
