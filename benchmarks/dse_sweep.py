"""Eq. (2)-(6) DSE sweep: the paper's design-space exploration, per arch.

For each architecture: enumerate (prefill blk, decode bk, TLMM tile)
configurations, apply the Eq. (2) time-sharing constraint and the Eq. (6)
objective (alpha=0.7 long/short decode weighting, TTFT bound), and report
the chosen point vs the best *static* point (both RMs co-resident).
"""
from __future__ import annotations

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.dse import run_dse

from .common import save_result


def run() -> dict:
    rows = []
    for arch in ["bitnet-730m"] + ASSIGNED_ARCHS:
        cfg = get_config(arch)
        if cfg.attention_free:
            rows.append({"arch": arch, "note": "attention-free: no attention RM to swap "
                        "(phase programs still split; see DESIGN.md §4)"})
            continue
        pts = run_dse(cfg)
        best = next((p for p in pts if p.feasible), pts[0])
        spts = run_dse(cfg, static_baseline=True)
        sbest = next((p for p in spts if p.feasible), spts[0])
        rows.append({
            "arch": arch,
            "blk_pre": best.config.prefill_blk,
            "bk_dec": best.config.decode_bk,
            "tlmm": f"{best.config.tlmm_bm}x{best.config.tlmm_bk}x{best.config.tlmm_bn}",
            "vmem_KiB": best.vmem_bytes / 1024,
            "obj_s (Eq.6)": best.objective,
            "static_obj_s": sbest.objective,
            "swap_gain": sbest.objective / best.objective,
        })
    gains = [r["swap_gain"] for r in rows if "swap_gain" in r]
    checks = {"DSE prefers swap over static for every arch": all(g >= 1.0 for g in gains)}
    result = {
        "name": "dse_sweep",
        "rows": rows,
        "notes": (
            "Roofline-DSE per arch (alpha=0.7, L_short=128, L_long=2048, prefill 512). "
            "swap_gain = static-best objective / swap-best objective.  Claim checks: "
            + ", ".join(f"{k}={'PASS' if v else 'FAIL'}" for k, v in checks.items())
        ),
        "checks": checks,
    }
    save_result(result)
    return result
