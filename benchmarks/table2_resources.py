"""Table 2 analogue: per-module resource breakdown + 'equivalent utilization'.

The paper's headline is the 106% "equivalent total": because prefill and
decode attention time-share one reconfigurable region, the design implements
more logic than the chip statically holds.

TPU analogue: each phase program claims a VMEM working set (kernel tiles —
the LUT/URAM stand-in, DESIGN.md §2).  We report, per phase RM, the DSE-
chosen kernel block footprints plus the compiled per-device HBM footprint
from the dry-run, and compute equivalent utilization =
(static TLMM tiles + prefill RM + decode RM) / VMEM — >100% means the
logic-swap packs more than a static design could co-host, without either
phase shrinking (Eq. 2 uses max, a static design uses sum).
"""
from __future__ import annotations

from repro.common.hardware import TPU_V5E
from repro.configs import get_config
from repro.core.dse import DseConfig, best_config, run_dse

from .common import load_dryrun_records, save_result


def run() -> dict:
    chip = TPU_V5E
    cfg = get_config("bitnet-730m")
    p = best_config(cfg)
    vm_static = p.vmem_static()
    vm_pre = p.vmem_prefill(cfg)
    vm_dec = p.vmem_decode(cfg)

    # a static design must co-host both attention configs: shrink until the
    # SUM fits (the paper's "shrink modules for simultaneous fit")
    static_pts = run_dse(cfg, static_baseline=True)
    static_best = next((x for x in static_pts if x.feasible), static_pts[0])

    rows = [
        {"module": "TLMM linear tiles (static region)", "vmem_KiB": vm_static / 1024,
         "resident": "always", "phase": "both"},
        {"module": "prefill attention RM", "vmem_KiB": vm_pre / 1024,
         "resident": "prefill only", "phase": f"blk={p.prefill_blk}"},
        {"module": "decode attention RM", "vmem_KiB": vm_dec / 1024,
         "resident": "decode only", "phase": f"bk={p.decode_bk}"},
        {"module": "PD-Swap occupancy (Eq. 2: static+max)", "vmem_KiB": (vm_static + max(vm_pre, vm_dec)) / 1024,
         "resident": "-", "phase": f"{100*(vm_static+max(vm_pre,vm_dec))/chip.vmem_bytes:.1f}% of VMEM"},
        {"module": "equivalent total (static+sum)", "vmem_KiB": (vm_static + vm_pre + vm_dec) / 1024,
         "resident": "-", "phase": f"{100*(vm_static+vm_pre+vm_dec)/chip.vmem_bytes:.1f}% equiv-util"},
        {"module": "static-design best (both RMs co-resident)", "vmem_KiB": static_best.vmem_bytes / 1024,
         "resident": "always", "phase": f"blk=bk={static_best.config.prefill_blk} (shrunk)"},
    ]

    # per-phase compiled footprints from the dry-run (HBM bytes per device)
    for rec in load_dryrun_records():
        if rec.get("status") != "ok" or rec["arch"] not in ("bitnet-730m", "deepseek-7b"):
            continue
        if rec["mesh"] != "pod16x16":
            continue
        rows.append({
            "module": f"compiled {rec['arch']} {rec['shape']} program",
            "vmem_KiB": "-",
            "resident": f"{(rec.get('peak_memory_per_device') or 0)/2**30:.2f} GiB HBM/dev",
            "phase": rec["kind"],
        })

    swap_obj = run_dse(cfg)[0].objective
    checks = {
        "equivalent utilization > PD-Swap occupancy": (vm_static + vm_pre + vm_dec)
        > (vm_static + max(vm_pre, vm_dec)),
        "swap objective beats static-best (Eq. 6)": swap_obj <= static_best.objective,
    }
    result = {
        "name": "table2_resources",
        "rows": rows,
        "notes": (
            "VMEM working-set budget per RM (the LUT/URAM analogue) for the DSE-"
            "chosen bitnet-730m configs, plus compiled HBM/device footprints from "
            "the dry-run.  Claim checks: "
            + ", ".join(f"{k}={'PASS' if v else 'FAIL'}" for k, v in checks.items())
        ),
        "checks": checks,
    }
    save_result(result)
    return result
