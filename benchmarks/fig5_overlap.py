"""Fig. 5 analogue: latency-overlapped logic swap, measured end-to-end.

The paper hides ~75% of the 45 ms reconfiguration by starting it right after
the LAST layer's attention, overlapping it with the remaining prefill tail
(last O-proj + FFN + logits, ~31 ms at L=128).

Here the swap is the ``kv_relayout`` program; the SwapController dispatches
it between ``prefill_body`` and ``prefill_tail`` so JAX's async dispatch
overlaps the two.  We measure REAL wall-clock on this host (CPU backend;
functional validation of the mechanism) and report the v5e-modeled latencies
(relayout = KV bytes / HBM bw; tail = tail FLOPs / peak).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.hardware import TPU_V5E
from repro.configs import reduced_config
from repro.core.phase_engine import PhaseEngine
from repro.core.swap import SwapController
from repro.models import get_model

from .common import save_result


def _measured(cfg, seq: int, max_len: int, iters: int = 3) -> dict:
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    engine = PhaseEngine(cfg, None, max_len=max_len)
    body, tail = engine.prefill_split_programs(jax.eval_shape(lambda: params), 1, seq)
    relayout = engine.relayout_program(1, seq, max_len)
    ctl = SwapController(body.fn, tail.fn, relayout.fn)
    tokens = jnp.arange(seq, dtype=jnp.int32)[None] % cfg.vocab_size

    # warmup (compile)
    ctl.measure_both(params, tokens)
    best = None
    for _ in range(iters):
        t = ctl.measure_both(params, tokens)
        if best is None or t.t_total_overlapped < best.t_total_overlapped:
            best = t
    return {
        "t_body_ms": best.t_body * 1e3,
        "t_tail_ms": best.t_tail * 1e3,
        "t_relayout_ms": best.t_relayout * 1e3,
        "serialized_ms": best.t_total_serialized * 1e3,
        "overlapped_ms": best.t_total_overlapped * 1e3,
        "hidden_frac": best.hidden_fraction,
    }


def _v5e_model(arch: str, seq: int, batch: int) -> dict:
    """Analytic v5e swap-overlap budget for the full-size arch."""
    from repro.configs import get_config

    cfg = get_config(arch)
    chip = TPU_V5E
    kv_bytes = 2 * cfg.num_layers * cfg.num_kv_heads * cfg.head_dim * 2 * seq * batch
    # relayout = one read + one write of the KV + the reshard collective
    t_relayout = 2 * kv_bytes / chip.hbm_bw + kv_bytes / (chip.ici_bw_per_link * chip.ici_links)
    # tail = last layer FFN+O-proj + final norm + logits
    d, f, v = cfg.d_model, cfg.ffn_hidden or cfg.d_ff, cfg.padded_vocab()
    tail_flops = 2 * seq * batch * (d * (cfg.num_heads * cfg.head_dim) + 3 * d * f) + 2 * seq * batch * d * v
    t_tail = tail_flops / chip.peak_flops_bf16
    hidden = min(t_tail, t_relayout) / t_relayout
    return {
        "t_relayout_ms": t_relayout * 1e3,
        "t_tail_ms": t_tail * 1e3,
        "hidden_frac": hidden,
    }


def run() -> dict:
    rows = []
    cfg = reduced_config("smollm-135m", num_layers=4, d_model=256, vocab_size=4096)
    for seq in (128, 256):
        m = _measured(cfg, seq, max_len=2 * seq)
        rows.append({"mode": f"measured CPU (reduced, seq={seq})", **m})
    for arch, seq, batch in (("bitnet-730m", 128, 1), ("deepseek-7b", 4096, 8), ("qwen2.5-14b", 32768, 4)):
        v = _v5e_model(arch, seq, batch)
        rows.append({"mode": f"v5e model {arch} seq={seq} b={batch}", **v})

    measured_hidden = [r["hidden_frac"] for r in rows if str(r["mode"]).startswith("measured")]
    checks = {
        "overlap hides >40% of swap (measured, CPU)": all(h > 0.4 for h in measured_hidden),
        # this host has ONE core: the overlapped dispatch cannot actually run
        # concurrently, so parity (not speedup) is the pass condition — the
        # check guards against the overlap path ADDING latency
        "overlapped <= serialized + 20% (1-core host)": all(
            r["overlapped_ms"] <= 1.2 * r["serialized_ms"] for r in rows if "serialized_ms" in r
        ),
    }
    result = {
        "name": "fig5_overlap",
        "rows": rows,
        "notes": (
            "Latency-overlapped swap (paper: ~75% of 45 ms hidden at L=128). "
            "Measured rows run the real SwapController on this host; v5e rows "
            "are the roofline budget (relayout = 2x KV HBM pass + reshard). "
            "Claim checks: "
            + ", ".join(f"{k}={'PASS' if v else 'FAIL'}" for k, v in checks.items())
        ),
        "checks": checks,
    }
    save_result(result)
    return result
