"""KV-cache quantization sweep: footprint, roofline position, and accuracy.

The paper's decode bound (Eq. 5) is KV bytes streamed per token, so the
``kv_dtype`` subsystem (packed int8/int4 payload + fp32 scale planes, fused
dequant in the decode kernels) moves the decode roofline directly.  This
benchmark runs the REAL serving engine (tiny functional config on this host)
per kv_dtype across context-length regimes and reports:

* per-context-token KV bytes (payload + scales, the Eq. (5) coefficient)
  and the decode-attention arithmetic intensity (flops per KV byte) — the
  shared ``KV_COLUMNS`` schema from ``benchmarks.common``,
* the engine's measured pool payload bytes (must shrink exactly 2x / 4x),
* greedy-output divergence vs the fp engine: fraction of tokens that match
  token-for-token and the earliest step at which any request diverges,
* the modeled v5e Eq. (5) KV-stream time per decoded token at the regime's
  mean context, per precision.

``--tiny`` is the CI smoke mode (single regime), run alongside
``paged_vs_contiguous --tiny``.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.common.hardware import TPU_V5E

from .common import kv_cache_columns, render, save_result

KV_DTYPES = ("fp", "int8", "int4")


def _divergence(ref: dict, got: dict):
    """(positionwise token match fraction, earliest diverging step or -1,
    #requests matching exactly).  Positionwise: tokens after a mismatch
    still count when they re-agree, so the fraction measures agreement,
    not just the shared prefix."""
    matched = total = 0
    first_div = -1
    exact = 0
    for rid, ref_toks in ref.items():
        toks = got[rid]
        assert len(toks) == len(ref_toks), rid
        total += len(ref_toks)
        mismatches = [i for i, (a, b) in enumerate(zip(ref_toks, toks)) if a != b]
        matched += len(ref_toks) - len(mismatches)
        if not mismatches:
            exact += 1
        elif first_div < 0 or mismatches[0] < first_div:
            first_div = mismatches[0]
    return (matched / total if total else 1.0), first_div, exact


def run(tiny: bool = False) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import reduced_config
    from repro.models import get_model
    from repro.serving import EngineCore, Request

    cfg = reduced_config("bitnet-730m", num_layers=3, d_model=128, vocab_size=512,
                         num_heads=4, num_kv_heads=2)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)

    regimes = [  # (max_len, prompt range, max_new)
        (64, (8, 24), 6),
        (128, (16, 56), 8),
        (256, (32, 120), 8),
    ]
    if tiny:
        regimes = regimes[:1]

    rows = []
    payloads: dict = {}
    rng = np.random.default_rng(0)
    for max_len, (lo, hi), max_new in regimes:
        prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(lo, hi + 1))).astype(np.int32)
                   for _ in range(4)]
        mean_ctx = float(np.mean([len(p) + max_new for p in prompts]))
        per_dtype = {}
        for kv_dtype in KV_DTYPES:
            eng = EngineCore(cfg, params, n_slots=3, max_len=max_len, prompt_len=16,
                             mode="static", cache_layout="paged", block_size=16,
                             kv_dtype=kv_dtype)
            for i, p in enumerate(prompts):
                eng.submit(Request(f"r{i}", p.copy(), max_new=max_new))
            stats = eng.run()
            assert len(eng.finished) == len(prompts)
            per_dtype[kv_dtype] = (eng.kv_bytes(),
                                   {k: v.out_tokens for k, v in eng.finished.items()},
                                   stats)
        ref_out = per_dtype["fp"][1]
        for kv_dtype in KV_DTYPES:
            kb, out, stats = per_dtype[kv_dtype]
            match_frac, first_div, exact = _divergence(ref_out, out)
            cols = kv_cache_columns(cfg, kv_dtype)
            payloads.setdefault(kv_dtype, kb["payload"])
            rows.append({
                "max_len": max_len,
                "mean_ctx": mean_ctx,
                **cols,
                "pool_payload_bytes": kb["payload"],
                "pool_bytes": kb["allocated"],
                "tok/s (host)": stats.decode_tput(),
                "token_match_vs_fp": match_frac,
                "first_divergence": first_div,
                "exact_requests": f"{exact}/{len(prompts)}",
                "v5e_kv_stream_ms/tok": 1e3 * cols["kv_bytes/ctx_tok"] * mean_ctx / TPU_V5E.hbm_bw,
            })

    fp_rows = [r for r in rows if r["kv_dtype"] == "fp"]
    i8_rows = [r for r in rows if r["kv_dtype"] == "int8"]
    i4_rows = [r for r in rows if r["kv_dtype"] == "int4"]
    checks = {
        "int8 pool payload is exactly half of fp": payloads["fp"] == 2 * payloads["int8"],
        "int4 pool payload is exactly a quarter of fp": payloads["fp"] == 4 * payloads["int4"],
        "fp-vs-fp divergence is zero": all(r["token_match_vs_fp"] == 1.0 for r in fp_rows),
        "arithmetic intensity rises with compression": all(
            a["kv_arith_intensity"] < b["kv_arith_intensity"] < c["kv_arith_intensity"]
            for a, b, c in zip(fp_rows, i8_rows, i4_rows)
        ),
        "int8 tracks fp greedy closely (>=95% tokens)": all(
            r["token_match_vs_fp"] >= 0.95 for r in i8_rows
        ),
        "int4 tracks fp greedy at half the tokens or better": all(
            r["token_match_vs_fp"] >= 0.5 for r in i4_rows
        ),
    }
    result = {
        "name": "kv_quant_sweep" + ("_tiny" if tiny else ""),
        "rows": rows,
        "notes": (
            "Quantized KV cache (paged layout, real engine, tiny config, host "
            "CPU) per kv_dtype and context regime.  kv_bytes/ctx_tok and "
            "kv_arith_intensity are the analytic Eq.(5) terms from "
            "repro.core.roofline; v5e column = modeled KV-stream time per "
            "decoded token at the regime's mean context.  Divergence is "
            "greedy token agreement vs the fp engine.  Claim checks: "
            + ", ".join(f"{k}={'PASS' if v else 'FAIL'}" for k, v in checks.items())
        ),
        "checks": checks,
    }
    save_result(result)
    return result


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--tiny", action="store_true",
                   help="single-regime smoke mode (CI tier-1)")
    args = p.parse_args(argv)
    result = run(tiny=args.tiny)
    print(render(result))
    return 0 if all(result["checks"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
