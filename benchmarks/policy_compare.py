"""SwapPolicy + sampling comparison on the step-driven serving core.

Drives ``EngineCore.step()`` with staggered single-request arrivals — the
regime where the prefill<->decode transition decision matters — and compares
the paper's ``DrainPolicy`` (flip the fabric the moment work is queued)
against ``SwapCostAwarePolicy`` (defer the flip while the queue is shallow
relative to the measured swap cost).  Each admitted request costs one logic
swap either way; what the policy changes is how many *prefill bursts*
(fabric flips, each stalling every active decode slot by the exposed swap
latency) serve the same load.  Greedy trajectories are slot-independent, so
both policies must produce identical tokens — checked.

A second table exercises per-request ``SamplingParams``: seeded sampling
must be bit-repeatable across runs (and across policies), and distinct
seeds must actually diverge.
"""
from __future__ import annotations

import numpy as np

from .common import save_result


def _drive(policy, cfg, params, prompts, sp, *, n_slots=4, max_new=10):
    from repro.serving import EngineCore, Request

    eng = EngineCore(cfg, params, n_slots=n_slots, max_len=64, prompt_len=12,
                     swap_policy=policy)
    pending = [Request(f"r{i}", p.copy(), max_new=max_new, params=sp)
               for i, p in enumerate(prompts)]
    eng.submit(pending.pop(0))
    step = 0
    while eng.has_unfinished() or pending:
        step += 1
        if pending and step % 2 == 0:  # one arrival every other step
            eng.submit(pending.pop(0))
        eng.step()
    outs = {rid: r.out_tokens for rid, r in eng.finished.items()}
    # arrival-stamped TTFT (queue wait included), not the re-stamped enqueue_t
    ttfts = [r.first_token_t - r.arrival_time_s for r in eng.finished.values()]
    return eng.stats, outs, float(np.mean(ttfts))


def run() -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import reduced_config
    from repro.models import get_model
    from repro.serving import SamplingParams
    from repro.serving.policy import DrainPolicy, SwapCostAwarePolicy

    cfg = reduced_config("bitnet-730m", num_layers=3, d_model=128, vocab_size=512,
                         num_heads=4, num_kv_heads=2)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32) for _ in range(8)]

    greedy = SamplingParams()
    policies = {
        "drain": DrainPolicy(),
        "swap-aware": SwapCostAwarePolicy(min_queue=2, max_defer_rounds=6),
    }
    rows, outs, ttft = [], {}, {}
    for name, pol in policies.items():
        stats, outs[name], ttft[name] = _drive(pol, cfg, params, prompts, greedy)
        rows.append({
            "policy": name,
            "swaps": stats.swaps,
            "prefill_bursts": stats.prefill_bursts,
            "mean_exposed_swap_ms": 1e3 * stats.swap_agg.mean_cost,
            "decode_tok/s (host)": stats.decode_tput(),
            "mean_ttft_ms": 1e3 * ttft[name],
        })

    sp_a = SamplingParams(temperature=0.8, top_k=64, top_p=0.9, seed=7)
    sp_b = SamplingParams(temperature=0.8, top_k=64, top_p=0.9, seed=8)
    _, sampled_1, _ = _drive(DrainPolicy(), cfg, params, prompts[:4], sp_a)
    _, sampled_2, _ = _drive(SwapCostAwarePolicy(min_queue=2), cfg, params,
                             prompts[:4], sp_a)
    _, sampled_3, _ = _drive(DrainPolicy(), cfg, params, prompts[:4], sp_b)

    checks = {
        "identical greedy tokens across policies": outs["drain"] == outs["swap-aware"],
        "swap-aware enters fewer prefill bursts": (
            rows[1]["prefill_bursts"] < rows[0]["prefill_bursts"]),
        "one swap per request under both policies": all(
            r["swaps"] == len(prompts) for r in rows[:2]),
        "seeded sampling repeatable across policies": sampled_1 == sampled_2,
        "distinct seeds diverge": sampled_1 != sampled_3,
    }
    result = {
        "name": "policy_compare",
        "rows": rows,
        "notes": (
            "Drain vs swap-cost-aware scheduling under staggered arrivals on "
            "the step-driven core (tiny config, host CPU).  Bursts = fabric "
            "flips; the cost-aware policy batches admissions to amortize the "
            "modeled reconfiguration cost.  Claim checks: "
            + ", ".join(f"{k}={'PASS' if v else 'FAIL'}" for k, v in checks.items())
        ),
        "checks": checks,
    }
    save_result(result)
    return result
