"""End-to-end measured serving: PD-Swap vs static engine on this host.

Functional companion to fig6: drives the real step-driven serving core
(``EngineCore.step()`` + SwapController) with batched requests on a
reduced-config model, CPU backend.  Absolute tok/s is a CPU number; the *comparison* exercises the
identical code paths the TPU deployment uses (program swap, KV relayout,
decode masking, slot management).  Correctness cross-check: both modes must
emit identical tokens for identical prompts (greedy).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models import get_model
from repro.serving import EngineCore, Request

from .common import save_result


def _drive(mode: str, cfg, params, prompts, *, n_slots=4, max_len=96, prompt_len=24, max_new=16):
    eng = EngineCore(cfg, params, n_slots=n_slots, max_len=max_len,
                     prompt_len=prompt_len, mode=mode)
    for i, p in enumerate(prompts):
        eng.submit(Request(f"r{i}", p, max_new=max_new))
    streamed = {f"r{i}": [] for i in range(len(prompts))}
    while eng.has_unfinished():
        for out in eng.step():  # incremental RequestOutput deltas
            streamed[out.request_id].extend(out.new_token_ids)
    outs = {rid: r.out_tokens for rid, r in eng.finished.items()}
    assert streamed == outs, "streaming deltas must reassemble the outputs"
    return eng.stats, outs


def run() -> dict:
    cfg = reduced_config("smollm-135m", num_layers=3, d_model=192, vocab_size=2048,
                         num_heads=6, num_kv_heads=2)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=24).astype(np.int32) for _ in range(6)]

    stats_pd, outs_pd = _drive("pdswap", cfg, params, prompts)
    stats_st, outs_st = _drive("static", cfg, params, prompts)

    same = all(outs_pd[k] == outs_st[k] for k in outs_pd)
    hidden = [t.hidden_fraction for t in stats_pd.swap_timings if t.t_relayout or t.t_total_overlapped]
    rows = [
        {"engine": "pdswap", "decode_tokens": stats_pd.decode_tokens,
         "decode_tok/s (CPU)": stats_pd.decode_tput(), "swaps": stats_pd.swaps,
         "prefill_s": stats_pd.t_prefill},
        {"engine": "static", "decode_tokens": stats_st.decode_tokens,
         "decode_tok/s (CPU)": stats_st.decode_tput(), "swaps": stats_st.swaps,
         "prefill_s": stats_st.t_prefill},
    ]
    checks = {
        "identical greedy tokens across engines": same,
        "all requests finished (both engines)": len(outs_pd) == len(prompts) == len(outs_st),
    }
    result = {
        "name": "serving_e2e",
        "rows": rows,
        "notes": (
            "Measured continuous-batching run on this host (reduced config; CPU "
            "numbers validate the mechanism, not TPU perf).  Claim checks: "
            + ", ".join(f"{k}={'PASS' if v else 'FAIL'}" for k, v in checks.items())
        ),
        "checks": checks,
    }
    save_result(result)
    return result
