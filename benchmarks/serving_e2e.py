"""End-to-end measured serving: PD-Swap vs static engine on this host.

Functional companion to fig6: drives the real step-driven serving core
(``EngineCore.step()`` + SwapController) with batched requests on a
reduced-config model, CPU backend.  Absolute tok/s is a CPU number; the *comparison* exercises the
identical code paths the TPU deployment uses (program swap, KV relayout,
decode masking, slot management).  Correctness cross-check: both modes must
emit identical tokens for identical prompts (greedy).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models import get_model
from repro.serving import EngineCore, Request

from .common import save_result, stats_block


def _drive(mode: str, cfg, params, prompts, *, n_slots=4, max_len=96, prompt_len=24, max_new=16):
    eng = EngineCore(cfg, params, n_slots=n_slots, max_len=max_len,
                     prompt_len=prompt_len, mode=mode)
    for i, p in enumerate(prompts):
        eng.submit(Request(f"r{i}", p, max_new=max_new))
    streamed = {f"r{i}": [] for i in range(len(prompts))}
    while eng.has_unfinished():
        for out in eng.step():  # incremental RequestOutput deltas
            streamed[out.request_id].extend(out.new_token_ids)
    outs = {rid: r.out_tokens for rid, r in eng.finished.items()}
    assert streamed == outs, "streaming deltas must reassemble the outputs"
    return eng, outs


def run() -> dict:
    cfg = reduced_config("smollm-135m", num_layers=3, d_model=192, vocab_size=2048,
                         num_heads=6, num_kv_heads=2)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=24).astype(np.int32) for _ in range(6)]

    eng_pd, outs_pd = _drive("pdswap", cfg, params, prompts)
    eng_st, outs_st = _drive("static", cfg, params, prompts)
    stats_pd, stats_st = eng_pd.stats, eng_st.stats

    same = all(outs_pd[k] == outs_st[k] for k in outs_pd)
    hidden = [t.hidden_fraction for t in stats_pd.swap_timings if t.t_relayout or t.t_total_overlapped]

    def _row(engine, stats):
        return {"engine": engine, "decode_tokens": stats.decode_tokens,
                "decode_tok/s (CPU)": stats.decode_tput(), "swaps": stats.swaps,
                "prefill_s": stats.t_prefill,
                # client-visible latency aggregates (arrival-stamped)
                "queue_wait_p95_ms": 1e3 * stats.queue_wait.p95,
                "ttft_p95_ms": 1e3 * stats.ttft.p95,
                "itl_p95_ms": 1e3 * stats.itl.p95}

    rows = [_row("pdswap", stats_pd), _row("static", stats_st)]
    checks = {
        "identical greedy tokens across engines": same,
        "all requests finished (both engines)": len(outs_pd) == len(prompts) == len(outs_st),
        "queue wait + TTFT recorded for every admission": (
            stats_pd.queue_wait.count == len(prompts)
            and stats_pd.ttft.count == len(prompts)),
    }
    result = {
        "name": "serving_e2e",
        "rows": rows,
        "notes": (
            "Measured continuous-batching run on this host (reduced config; CPU "
            "numbers validate the mechanism, not TPU perf).  Claim checks: "
            + ", ".join(f"{k}={'PASS' if v else 'FAIL'}" for k, v in checks.items())
        ),
        "checks": checks,
        "stats": {"pdswap": stats_block(eng_pd), "static": stats_block(eng_st)},
    }
    save_result(result)
    return result
