"""Benchmark harness entry: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure (DESIGN.md §6).  Each prints a markdown
table and persists raw rows under results/bench/.  Modules that need the
dry-run artifacts degrade gracefully when results/dryrun is incomplete.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (
    beyond_paper,
    chunked_prefill_interleave,
    disagg_interference,
    dse_sweep,
    fig5_overlap,
    fig6_decode_throughput,
    fig6_ttft,
    kv_quant_sweep,
    paged_vs_contiguous,
    policy_compare,
    roofline_report,
    serving_e2e,
    spec_decode,
    table1_comparison,
    table2_resources,
    tracing_overhead,
    traffic_storm,
)
from .common import render

BENCHES = {
    "roofline_report": roofline_report,
    "dse_sweep": dse_sweep,
    "fig6a_decode_throughput": fig6_decode_throughput,
    "fig6b_ttft": fig6_ttft,
    "table1_comparison": table1_comparison,
    "table2_resources": table2_resources,
    "fig5_overlap": fig5_overlap,
    "serving_e2e": serving_e2e,
    "paged_vs_contiguous": paged_vs_contiguous,
    "kv_quant_sweep": kv_quant_sweep,
    "chunked_prefill_interleave": chunked_prefill_interleave,
    "spec_decode": spec_decode,
    "policy_compare": policy_compare,
    "traffic_storm": traffic_storm,
    "tracing_overhead": tracing_overhead,
    "disagg_interference": disagg_interference,
    "beyond_paper": beyond_paper,
}


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--only", nargs="*", choices=list(BENCHES), default=None)
    args = p.parse_args(argv)
    names = args.only or list(BENCHES)

    failures, all_checks = [], []
    for name in names:
        t0 = time.time()
        try:
            result = BENCHES[name].run()
            print(render(result))
            print(f"\n[{name}: {time.time()-t0:.1f}s]")
            for k, v in result.get("checks", {}).items():
                all_checks.append((name, k, v))
        except Exception as e:
            failures.append((name, repr(e)))
            print(f"\n## {name}\nFAILED: {e}")
            traceback.print_exc()

    print("\n# Claim-check summary")
    for name, k, v in all_checks:
        print(f"  [{'PASS' if v else 'FAIL'}] {name}: {k}")
    if failures:
        print(f"\n{len(failures)} benchmark(s) failed: {[f[0] for f in failures]}")
        return 1
    n_fail = sum(1 for _, _, v in all_checks if not v)
    print(f"\n{len(all_checks) - n_fail}/{len(all_checks)} claim checks pass.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
