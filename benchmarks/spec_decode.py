"""Speculative decoding via prompt-lookup drafting: acceptance + throughput.

Decode is memory-bandwidth-bound (Eq. 5): every token streams the whole KV
cache and weight set for one row of output.  With ``spec_decode=k`` the
engine drafts up to ``k`` tokens per slot by matching its trailing n-gram
against its own prompt + output history and scores all ``k + 1`` positions
in ONE batched verify pass — the stream is paid once per ROUND, so the
effective per-token bound divides by the tokens emitted per round
(``repro.core.roofline.expected_accept_length``).

This benchmark runs the REAL engine (tiny functional config on this host)
on two workload poles:

* **repetitive** — prompts built from a repeated pattern, the regime prompt
  lookup is built for (summarization/code-edit/RAG-style self-copying):
  the drafter finds its n-grams and the verify pass confirms them, so
  accepted tokens per SLOT per round must exceed 1 (the headline claim
  check, pinned by tests/test_spec_decode.py too — a pure count, never
  wall clock, and normalized per slot so concurrent batch width cannot
  masquerade as speculative amortization);
* **random** — i.i.d. random prompts, the adversarial pole: drafts rarely
  match, tokens/round degrades toward 1, and the only cost is wasted
  verify columns — never a wrong token (greedy streams must stay
  bit-identical to the non-speculative engine, also checked here).

Per (workload x draft depth) the table reports acceptance rate, measured
tokens/round, host decode throughput vs the k=0 baseline, and the modeled
v5e Eq. (5) per-token KV-stream time amortized by the MEASURED acceptance
(the dtype-dependent verify bound: the verify pass reads the same packed
bytes decode does, so ``--kv-dtype`` and speculation compose).

Run directly (``python -m benchmarks.spec_decode [--tiny]``) or via
``benchmarks.run``.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.common.hardware import TPU_V5E

from .common import kv_cache_columns, render, save_result

NGRAM = 3


def _workloads(cfg, rng, *, n_requests: int, rep_len: int, rand_len: int):
    pat = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
    reps = min(n_requests, 4)
    repetitive = [np.tile(pat, rep_len // len(pat) + 1)[:rep_len].copy()
                  for _ in range(reps)]
    random = [rng.integers(0, cfg.vocab_size, rand_len).astype(np.int32)
              for _ in range(n_requests)]
    return {"repetitive": repetitive, "random": random}


def _serve(cfg, params, prompts, *, spec, kv_dtype, max_new, max_len):
    from repro.serving import EngineCore, Request

    eng = EngineCore(cfg, params, n_slots=3, max_len=max_len, prompt_len=16,
                     mode="static", cache_layout="paged", block_size=8,
                     kv_dtype=kv_dtype, spec_decode=spec, spec_ngram=NGRAM)
    for i, p in enumerate(prompts):
        eng.submit(Request(f"r{i}", p.copy(), max_new=max_new))
    stats = eng.run()
    assert len(eng.finished) == len(prompts)
    return stats, {k: v.out_tokens for k, v in eng.finished.items()}


def run(tiny: bool = False) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import reduced_config
    from repro.core.roofline import decode_kv_stream_time_speculative
    from repro.models import get_model

    cfg = reduced_config("bitnet-730m", num_layers=3, d_model=128, vocab_size=512,
                         num_heads=4, num_kv_heads=2)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)

    max_len, max_new = 96, 16
    kv_dtype = "fp"
    depths = (2, 4) if tiny else (2, 4, 8)
    rng = np.random.default_rng(3)
    workloads = _workloads(cfg, rng, n_requests=2 if tiny else 4,
                           rep_len=28, rand_len=20)

    rows = []
    checks = {}
    for name, prompts in workloads.items():
        base_stats, base_out = _serve(cfg, params, prompts, spec=None,
                                      kv_dtype=kv_dtype, max_new=max_new,
                                      max_len=max_len)
        mean_ctx = float(np.mean([len(p) + max_new for p in prompts]))
        for k in depths:
            stats, out = _serve(cfg, params, prompts, spec=k,
                                kv_dtype=kv_dtype, max_new=max_new,
                                max_len=max_len)
            identical = out == base_out
            checks[f"{name} k={k}: greedy bit-identical to baseline"] = identical
            rows.append({
                "workload": name,
                "spec_k": k,
                **kv_cache_columns(cfg, kv_dtype),
                "draft_tokens": stats.draft_tokens,
                "accepted": stats.accepted_tokens,
                "accept_rate": round(stats.acceptance_rate(), 3),
                # per SLOT per round — batch width normalized out, so 1.0
                # is exactly the non-speculative baseline
                "tokens/slot-round": round(stats.tokens_per_round(), 2),
                "accepted/slot-round": round(
                    stats.accepted_tokens / max(stats.slot_rounds, 1), 2),
                "rounds": stats.decode_rounds,
                "rounds_base": base_stats.decode_rounds,
                "tok/s (host)": round(stats.decode_tput(), 1),
                "tok/s base": round(base_stats.decode_tput(), 1),
                "v5e_kv_ms/tok@accept": 1e3 * decode_kv_stream_time_speculative(
                    cfg, int(mean_ctx), k, stats.acceptance_rate(), kv_dtype,
                    TPU_V5E),
            })
    rep_rows = [r for r in rows if r["workload"] == "repetitive"]
    rand_rows = [r for r in rows if r["workload"] == "random"]
    # per-SLOT normalization: a concurrent batch already emits batch-many
    # tokens per round without speculation, so the claim is pinned on
    # accepted drafts per slot-round — batch width cannot dilute it
    checks[">1 accepted token per slot per decode round (repetitive)"] = all(
        r["accepted/slot-round"] > 1.0 for r in rep_rows)
    checks["repetitive runs fewer decode rounds than baseline"] = all(
        r["rounds"] < r["rounds_base"] for r in rep_rows)
    checks["random workload never emits a wrong token (bit-identical)"] = all(
        checks[f"random k={k}: greedy bit-identical to baseline"] for k in depths)
    checks["repetitive acceptance beats random"] = (
        min(r["accept_rate"] for r in rep_rows)
        >= max(r["accept_rate"] for r in rand_rows))

    result = {
        "name": "spec_decode" + ("_tiny" if tiny else ""),
        "rows": rows,
        "notes": (
            "Self-speculative decoding (prompt-lookup drafting, paged layout, "
            "real engine, tiny config, host CPU).  tokens/slot-round is the "
            "per-stream Eq. (5) amortization factor (1.0 = plain decode) — "
            "one verify round streams KV + weights once per slot "
            "and emits that many tokens; v5e_kv_ms/tok@accept is the modeled "
            "per-token KV-stream bound at the MEASURED acceptance rate "
            "(repro.core.roofline.decode_kv_stream_time_speculative; composes "
            "with --kv-dtype since verify reads the same packed bytes).  "
            "Host tok/s is informational only — claim checks are counts, "
            "never wall clock.  Claim checks: "
            + ", ".join(f"{k}={'PASS' if v else 'FAIL'}" for k, v in checks.items())
        ),
        "checks": checks,
    }
    save_result(result)
    return result


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--tiny", action="store_true",
                   help="two draft depths, two requests (CI smoke mode)")
    args = p.parse_args(argv)
    result = run(tiny=args.tiny)
    print(render(result))
    return 0 if all(result["checks"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
