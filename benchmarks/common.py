"""Shared helpers for the benchmark harness.

Every benchmark module exposes ``run() -> dict`` returning
``{"name", "rows": [dict, ...], "notes": str}``; ``benchmarks.run`` renders
each as a markdown table and writes the raw rows to
``results/bench/<name>.json``.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DRYRUN_DIR = REPO / "results" / "dryrun"
BENCH_DIR = REPO / "results" / "bench"

# Shared KV-cache footprint columns (kv_dtype subsystem): every benchmark
# that touches a serving engine can merge these into its rows so cache
# footprint and roofline position are reported uniformly.
KV_COLUMNS = ("kv_dtype", "kv_bytes/ctx_tok", "kv_arith_intensity")


def kv_cache_columns(cfg, kv_dtype: str = "fp") -> dict:
    """The ``KV_COLUMNS`` cells for one (config, kv_dtype): Eq.(5) bytes per
    cached token streamed per decode step (payload + scale planes) and the
    decode-attention arithmetic intensity (flops per KV byte)."""
    from repro.core.roofline import decode_arithmetic_intensity, kv_bytes_per_ctx_token

    return {
        "kv_dtype": kv_dtype,
        "kv_bytes/ctx_tok": kv_bytes_per_ctx_token(cfg, kv_dtype),
        "kv_arith_intensity": decode_arithmetic_intensity(cfg, kv_dtype),
    }


def stats_block(eng) -> dict:
    """Uniform JSON-serializable engine-stats block for a benchmark result:
    ``EngineCore.snapshot()`` / ``AsyncEngine.snapshot()`` — counters,
    derived rates, swap/speculation aggregates, queue-wait/TTFT/ITL latency
    summaries, and (paged) KV pool bytes.  Store it under ``result["stats"]``
    so every serving benchmark persists the same observability surface the
    ``/stats`` endpoint serves."""
    return eng.snapshot()


LATENCY_COLUMNS = ("engine", "metric", "count", "mean_ms", "p50_ms", "p95_ms")


def latency_rows(eng, label: str = "engine") -> list[dict]:
    """Per-engine latency table rows from the typed metrics registry — the
    SAME histogram summaries ``/metrics`` serves, so a benchmark's printed
    latency table cannot drift from the scrape surface.  One row per
    engine-latency histogram (queue wait, TTFT, ITL)."""
    rows = []
    snap = eng.metrics_registry().snapshot()
    for name, h in sorted(snap["histograms"].items()):
        # labeled histograms (per-tenant) nest one summary per label set
        series = h.items() if "count" not in h else [("", h)]
        for labels, s in series:
            rows.append({
                "engine": label,
                "metric": f"{name}{{{labels}}}" if labels else name,
                "count": s["count"],
                "mean_ms": 1e3 * s["mean"],
                "p50_ms": 1e3 * s["p50"],
                "p95_ms": 1e3 * s["p95"],
            })
    return rows


def add_trace_arg(parser) -> None:
    """The shared ``--trace-out PATH`` benchmark flag (Chrome trace JSON)."""
    parser.add_argument("--trace-out", default=None, metavar="PATH",
                        help="record engine spans during the measurement and "
                             "write a Chrome trace-event JSON here "
                             "(chrome://tracing / ui.perfetto.dev)")


def start_trace(path) -> None:
    if path:
        from repro.obs.trace import TRACER

        TRACER.enable()


def finish_trace(path) -> None:
    if path:
        from repro.obs.trace import TRACER

        trace = TRACER.export_chrome_trace(path)
        TRACER.disable()
        print(f"trace: {len(trace['traceEvents'])} events -> {path} "
              f"({TRACER.dropped} dropped)")


def load_dryrun_records() -> list[dict]:
    if not DRYRUN_DIR.exists():
        return []
    return [json.loads(p.read_text()) for p in sorted(DRYRUN_DIR.glob("*.json"))]


def fmt(v) -> str:
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e4 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)


def markdown_table(rows: list[dict], columns: list[str] | None = None) -> str:
    if not rows:
        return "(no rows)"
    columns = columns or list(rows[0])
    head = "| " + " | ".join(columns) + " |"
    sep = "|" + "|".join("---" for _ in columns) + "|"
    body = "\n".join(
        "| " + " | ".join(fmt(r.get(c, "")) for c in columns) + " |" for r in rows
    )
    return "\n".join([head, sep, body])


def save_result(result: dict) -> None:
    BENCH_DIR.mkdir(parents=True, exist_ok=True)
    out = dict(result)
    out["timestamp"] = time.time()
    (BENCH_DIR / f"{result['name']}.json").write_text(json.dumps(out, indent=2, default=str))


def render(result: dict, columns: list[str] | None = None) -> str:
    lines = [f"\n## {result['name']}", ""]
    if result.get("notes"):
        lines += [result["notes"], ""]
    lines.append(markdown_table(result["rows"], columns))
    return "\n".join(lines)
