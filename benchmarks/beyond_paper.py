"""Beyond-paper extensions, quantified on the v5e roofline.

The paper's floor is reproduced elsewhere (fig5/fig6/tables).  This module
quantifies the extensions the TPU scale-out enables:

1. **int8 KV cache** (`PhaseEngine(kv_quant="int8")`): the relayout program
   quantizes KV during the swap — decode attention streams half the bytes.
   Costed with the decode kernel's analytic model at elt=1 (+ per-block f32
   scales, +~3% traffic).
2. **Multi-pod decode scale-out**: the same decode program on the
   (pod=2,16,16) mesh — measured from the compiled dry-run records.
3. **Temporal vs spatial PD-disaggregation**: the paper time-multiplexes
   one fabric (temporal).  At pod scale the same asymmetry supports
   dedicating pod 0 to prefill and pod 1 to decode; the "bitstream load"
   becomes a one-shot DCN KV transfer.  Break-even: spatial wins when
   decode dwell time per request exceeds the DCN transfer + lost-pod
   opportunity cost; temporal wins for short generations.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.common.hardware import TPU_V5E
from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.core.kernel_substitution import kernel_costs_for_cell
from repro.kernels.costs import decode_attention_cost

from .common import DRYRUN_DIR, save_result

ARCHS = ["bitnet-730m", "deepseek-7b", "qwen2.5-14b", "moonshot-v1-16b-a3b"]


def _rec(arch, shape, mesh):
    p = DRYRUN_DIR / f"{arch}__{shape}__{mesh}.json"
    return json.loads(p.read_text()) if p.exists() else None


def run() -> dict:
    chip = TPU_V5E
    cell = SHAPES["decode_32k"]
    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        r1 = _rec(arch, "decode_32k", "pod16x16")
        r2 = _rec(arch, "decode_32k", "pod2x16x16")
        if not r1 or r1.get("status") != "ok":
            continue
        t1 = max(r1["roofline"][k] for k in ("t_compute", "t_memory", "t_collective"))
        t2 = (max(r2["roofline"][k] for k in ("t_compute", "t_memory", "t_collective"))
              if r2 and r2.get("status") == "ok" else float("nan"))
        # int8 KV: replace the kernel's bf16 KV stream with int8 (+3% scales)
        kc16 = kernel_costs_for_cell(cfg, cell, dp=16, tp=16)
        h, hkv, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        b_loc, s_loc = cell.global_batch // 16, cell.seq_len // 16
        kc8 = decode_attention_cost(b_loc, h, hkv, s_loc, d, elt=1)
        kv8_bytes = 1.03 * kc8.hbm_bytes * cfg.num_layers
        delta = (kc16.hbm_bytes - kv8_bytes) / chip.hbm_bw
        t_int8 = max(t1 - delta, t1 / 4)
        rows.append({
            "arch": arch,
            "decode step, 1 pod (s)": t1,
            "decode step, int8 KV (s)": t_int8,
            "decode step, 2 pods (s)": t2,
            "tok/s/seq 1pod": 1.0 / t1,
            "tok/s/seq int8": 1.0 / t_int8,
        })

    # temporal vs spatial disaggregation break-even (bitnet, per request)
    cfg = get_config("bitnet-730m")
    ctx = 2048
    kv_bytes = 2 * cfg.num_layers * cfg.num_kv_heads * cfg.head_dim * 2 * ctx
    t_transfer_dcn = kv_bytes / chip.dcn_bw  # spatial: one-shot DCN move
    t_relayout = 3 * kv_bytes / chip.hbm_bw  # temporal: in-pod relayout (2r+1w)
    r1 = _rec("bitnet-730m", "decode_32k", "pod16x16")
    t_dec = max(r1["roofline"][k] for k in ("t_compute", "t_memory", "t_collective")) if r1 else 0.005
    for gen_len in (32, 256, 2048):
        t_temporal = t_relayout + gen_len * t_dec  # pod swaps then decodes
        # spatial: decode pod runs continuously; transfer pipelines with the
        # previous request's tail -> only non-overlapped fraction exposed
        t_spatial = max(t_transfer_dcn - gen_len * t_dec * 0.5, 0) + gen_len * t_dec
        rows.append({
            "arch": f"bitnet-730m spatial-vs-temporal gen={gen_len}",
            "decode step, 1 pod (s)": t_temporal,
            "decode step, int8 KV (s)": "",
            "decode step, 2 pods (s)": t_spatial,
            "tok/s/seq 1pod": gen_len / t_temporal,
            "tok/s/seq int8": "",
        })
    checks = {
        "int8 KV improves every decode cell": all(
            r["decode step, int8 KV (s)"] < r["decode step, 1 pod (s)"]
            for r in rows if isinstance(r["decode step, int8 KV (s)"], float)
        ),
        "2 pods never slower than 1": all(
            not (r["decode step, 2 pods (s)"] == r["decode step, 2 pods (s)"])  # NaN ok
            or r["decode step, 2 pods (s)"] <= r["decode step, 1 pod (s)"] * 1.05
            for r in rows if isinstance(r["decode step, 2 pods (s)"], float)
        ),
    }
    result = {
        "name": "beyond_paper",
        "rows": rows,
        "notes": (
            "Beyond-paper knobs on the v5e roofline: int8 KV relayout "
            "(PhaseEngine kv_quant), multi-pod decode scale-out (from the "
            "compiled 512-chip dry-run), and the temporal-vs-spatial PD-"
            "disaggregation break-even (spatial amortizes the swap into a DCN "
            "transfer; temporal wins only for very short generations).  "
            "Claim checks: "
            + ", ".join(f"{k}={'PASS' if v else 'FAIL'}" for k, v in checks.items())
        ),
        "checks": checks,
    }
    save_result(result)
    return result
