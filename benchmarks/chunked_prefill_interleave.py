"""Chunked prefill vs monolithic: decode ITL while a long prompt prefills.

The serving regression this PR fixes: the step-driven core ran each admitted
prompt's ENTIRE prefill as one atomic burst, so one long prompt froze every
active decode slot for the whole burst and inter-token latency (ITL) spiked
by the full prefill duration.  Chunked prefill bounds the per-quantum
compute — ``EngineCore.step()`` runs at most one chunk, then a decode round
over the active streams — so decode makes progress *between* chunks.

Scenario: a few short-prompt streams decode steadily; then one long prompt
arrives.  We drive ``step()`` one quantum at a time, stamp each active
stream's tokens, and compare:

* ``decode_rounds_between_chunks`` — decode rounds executed from the long
  prompt's admission to its first token, excluding the completion quantum's
  own round.  Monolithic: 0 (decode is starved for the whole burst).
  Chunked: one per chunk boundary (> 0) — the headline claim check.
* ITL percentiles (p50 / p95 / max) of the active streams across the long
  prompt's prefill window — the monolithic max ITL is the whole burst; the
  chunked max ITL is one chunk.
* greedy tokens, chunked vs monolithic — must be identical (chunk-size
  invariance).

Run directly (``python -m benchmarks.chunked_prefill_interleave [--tiny]``)
or via ``benchmarks.run``.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from .common import (
    KV_COLUMNS,
    kv_cache_columns,
    markdown_table,
    save_result,
    stats_block,
)


def _drive(cfg, params, *, prefill_chunk, long_len, short_len, max_len,
           block_size, kv_dtype, n_short=3):
    from repro.serving import EngineCore, Request

    eng = EngineCore(cfg, params, n_slots=n_short + 1, max_len=max_len,
                     prompt_len=short_len, cache_layout="paged",
                     block_size=block_size, kv_dtype=kv_dtype,
                     prefill_chunk=prefill_chunk)
    rng = np.random.default_rng(0)
    shorts = [rng.integers(0, cfg.vocab_size, short_len).astype(np.int32)
              for _ in range(n_short)]
    long_prompt = rng.integers(0, cfg.vocab_size, long_len).astype(np.int32)

    for i, p in enumerate(shorts):
        # long enough to outlive the measured window, short enough that the
        # post-window drain stays cheap
        eng.submit(Request(f"s{i}", p.copy(), max_new=min(64, max_len - short_len)))
    # warm the decode phase: every short stream admitted and decoding
    guard = 0
    while len(eng.scheduler.inflight) < n_short:
        eng.step()
        guard += 1
        assert guard < 200, "short streams never reached the decode phase"
    # warm the long-prompt prefill programs (chunk + tail buckets, or the
    # monolithic bucket) with a sacrificial request, so the measured window
    # times execution, not XLA compilation
    warm = rng.integers(0, cfg.vocab_size, long_len).astype(np.int32)
    eng.submit(Request("warm", warm, max_new=1))
    guard = 0
    while "warm" not in eng.finished:
        eng.step()
        guard += 1
        assert guard < 500, "warmup request never finished"
    for _ in range(3):  # steady-state decode rounds
        eng.step()

    eng.submit(Request("long", long_prompt.copy(), max_new=4))
    d0 = eng.stats.decode_rounds
    t_submit = time.perf_counter()
    itls: list[float] = []  # per-quantum latency of the short streams' tokens
    first_round = None
    ttft_long = None
    while eng.has_unfinished():
        t0 = time.perf_counter()
        outs = eng.step()
        dt = time.perf_counter() - t0
        if ttft_long is None:
            # every quantum in the long prefill window counts: with a decode
            # round per quantum the short streams' ITL is the quantum wall
            # time — monolithically the single burst+round quantum IS the
            # spike, chunked it is one bounded chunk + one round
            itls.append(dt)
            if any(o.request_id == "long" for o in outs):
                first_round = eng.stats.decode_rounds
                ttft_long = time.perf_counter() - t_submit
    # rounds strictly between chunks: exclude the completion quantum's round
    between = max(first_round - d0 - 1, 0)
    toks = {rid: r.out_tokens for rid, r in eng.finished.items()}
    itl = np.asarray(itls) if itls else np.asarray([0.0])
    return {
        "prefill": "monolithic" if prefill_chunk is None else f"chunk={prefill_chunk}",
        "prefill_chunks": eng.stats.prefill_chunks,
        "decode_rounds_between_chunks": between,
        "itl_p50_ms": 1e3 * float(np.percentile(itl, 50)),
        "itl_p95_ms": 1e3 * float(np.percentile(itl, 95)),
        "itl_max_ms": 1e3 * float(itl.max()),
        "ttft_long_ms": 1e3 * ttft_long,
        # engine-side queue wait (arrival-stamped at submit, satellite fix)
        "queue_wait_p95_ms": 1e3 * eng.stats.queue_wait.p95,
        **kv_cache_columns(cfg, kv_dtype),
    }, toks, stats_block(eng)


def run(tiny: bool = False) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import reduced_config
    from repro.models import get_model

    cfg = reduced_config("bitnet-730m", num_layers=3, d_model=128,
                         vocab_size=512, num_heads=4, num_kv_heads=2)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)

    # the long prompt must be long enough that its quadratic burst clearly
    # dominates one chunk quantum on this host, or the ITL comparison is
    # dispatch-overhead noise
    if tiny:
        knobs = dict(long_len=256, short_len=8, max_len=320, block_size=8,
                     kv_dtype="fp")
        chunks = [None, 32]
    else:
        knobs = dict(long_len=384, short_len=16, max_len=448, block_size=16,
                     kv_dtype="fp")
        chunks = [None, 32, 64]

    rows, toks, snaps = [], {}, {}
    for chunk in chunks:
        row, toks[chunk], snaps[row["prefill"]] = _drive(
            cfg, params, prefill_chunk=chunk, **knobs)
        rows.append(row)

    mono, chunked = rows[0], rows[1:]
    # structural checks gate CI; the ITL-spike comparison is wall-clock and
    # can lose to an OS scheduling stall on a noisy runner, so it is
    # reported but never fails the build
    checks = {
        "monolithic starves decode during the long prefill": (
            mono["decode_rounds_between_chunks"] == 0),
        "chunked interleaves decode rounds between chunks (> 0)": all(
            r["decode_rounds_between_chunks"] > 0 for r in chunked),
        "greedy tokens invariant to chunking": all(
            toks[c] == toks[None] for c in chunks[1:]),
    }
    timing = {
        "chunking bounds the ITL spike (max ITL below monolithic; informational)": all(
            r["itl_max_ms"] < mono["itl_max_ms"] for r in chunked),
    }
    result = {
        "name": "chunked_prefill_interleave" + ("_tiny" if tiny else ""),
        "rows": rows,
        "notes": (
            "Decode ITL of active streams while one long prompt prefills "
            "(paged layout, tiny config, host CPU).  Monolithic prefill "
            "stalls every stream for the whole burst; chunked prefill runs "
            "one bounded chunk per quantum with a decode round between "
            "chunks.  Claim checks: "
            + ", ".join(f"{k}={'PASS' if v else 'FAIL'}"
                        for k, v in {**checks, **timing}.items())
        ),
        "checks": checks,
        "timing_checks": timing,
        "stats": snaps,
        "columns": ["prefill", "prefill_chunks", "decode_rounds_between_chunks",
                    "itl_p50_ms", "itl_p95_ms", "itl_max_ms", "ttft_long_ms",
                    "queue_wait_p95_ms", *KV_COLUMNS],
    }
    save_result(result)
    return result


if __name__ == "__main__":
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--tiny", action="store_true",
                   help="CI smoke mode: one chunked configuration, short prompts")
    args = p.parse_args()
    res = run(tiny=args.tiny)
    print(markdown_table(res["rows"], res.get("columns")))
    print()
    print(res["notes"])
    sys.exit(0 if all(res["checks"].values()) else 1)
