"""Fig. 6b analogue: prefill time (TTFT) vs prompt length.

Paper: PD-Swap cuts TTFT 20-25% vs TeLLMe (11.10 s -> 8.80 s at 768 tokens)
because the prefill RM owns the whole dynamic region instead of sharing the
fabric with a resident decode attention engine.

Model: Eq. (3) with attention throughput proportional to the LUT area the
prefill engine gets (paper Table 2: prefill attention alone = 28,400 LUT;
in a static design prefill+decode engines must co-reside in the same budget,
so prefill's share shrinks by the decode engine's footprint).
"""
from __future__ import annotations

from repro.common.hardware import KV260_DDR_BW
from repro.configs import get_config

from .common import save_result

# Table 2 LUT numbers (the resource model for both designs)
LUT_DYNAMIC_REGION = 32_140
LUT_PREFILL_ALONE = 28_400
LUT_DECODE_ALONE = 26_418
PAPER_TTFT_768 = {"static": 11.10, "pdswap": 8.80}


def run() -> dict:
    cfg = get_config("bitnet-730m")
    # static: both attention engines co-resident -> prefill runs at a reduced
    # area share.  TeLLMe shrinks its decode engine hard (the Fig. 6a cost),
    # so prefill keeps ~3/4 of the area PD-Swap gives it exclusively; the
    # share is calibrated so static TTFT@768 hits the paper's 11.10 s
    # (PD-Swap's 8.80 s anchors the attention coefficient below).
    share_static = 0.757
    area_pdswap = min(LUT_PREFILL_ALONE, LUT_DYNAMIC_REGION)
    area_static = area_pdswap * share_static

    # Calibrate the per-(token^2) attention coefficient so the PD-Swap curve
    # passes through the paper's measured 8.80 s at 768 tokens, after
    # removing the linear projection term (TLMM-bound, identical in both).
    kv_bytes = 2 * cfg.num_layers * cfg.num_kv_heads * cfg.head_dim * 2
    n_active = cfg.active_param_count()

    def t_proj(length):  # ternary weights on-chip: activation-bound, linear in L
        return n_active * 0.25 / KV260_DDR_BW + length * 2.1e-3  # measured-scale const

    c_attn = (PAPER_TTFT_768["pdswap"] - t_proj(768)) / (768**2 / area_pdswap)

    rows = []
    for length in (128, 256, 512, 768, 1024, 2048):
        t_pd = t_proj(length) + c_attn * length**2 / area_pdswap
        t_st = t_proj(length) + c_attn * length**2 / area_static
        rows.append({
            "prompt_len": length,
            "static_TTFT_s": t_st,
            "pdswap_TTFT_s": t_pd,
            "reduction_%": 100 * (1 - t_pd / t_st),
            "paper_static_s": PAPER_TTFT_768["static"] if length == 768 else "",
            "paper_pdswap_s": PAPER_TTFT_768["pdswap"] if length == 768 else "",
        })
    r768 = next(r for r in rows if r["prompt_len"] == 768)
    checks = {
        "768-token TTFT reduction in paper band (15-30%)": 15 <= r768["reduction_%"] <= 30,
        "static TTFT@768 near paper (11.1s +/- 1.5)": abs(r768["static_TTFT_s"] - 11.10) < 1.5,
    }
    result = {
        "name": "fig6b_ttft",
        "rows": rows,
        "notes": (
            "TTFT vs prompt length, BitNet 0.73B on the KV260 model.  PD-Swap's "
            "prefill RM owns the full dynamic region; the static design hosts both "
            "attention engines so prefill runs at a ~"
            f"{share_static:.2f} area share.  Claim checks: "
            + ", ".join(f"{k}={'PASS' if v else 'FAIL'}" for k, v in checks.items())
        ),
        "checks": checks,
    }
    save_result(result)
    return result
