"""Fig. 6a analogue: decode throughput vs context — PD-Swap vs static.

The paper measures BitNet 0.73B on KV260: PD-Swap's decode gain over the
static TeLLMe baseline grows from 1.11x at 64-token context to 2.02x at
2048, staying >10 tok/s where the static design drops to ~5 tok/s.

We reproduce the *mechanism* with the Eq. (3)/(5) latency model of
``repro.core.dse`` instantiated with the paper's platform constants
(KV260 LPDDR4), then port the same model to the v5e target:

* static engine (TeLLMe mode): ONE attention configuration must fit both
  phases in fabric simultaneously — Eq. (2) becomes r_p + r_pre + r_dec <= R
  — and decode runs with port mapping tuned for prefill (1x KV bandwidth).
* PD-Swap: the decode RM owns the whole dynamic region (bigger KV blocks)
  and the HP-port remap gives ~2x effective KV-read bandwidth (paper §3.2.3).

The benchmark validates the paper's two claims: the speedup GROWS with
context, and its magnitude brackets the measured 1.11x-2.02x.
"""
from __future__ import annotations

import dataclasses

from repro.common.hardware import KV260_DDR_BW, TPU_V5E
from repro.configs import get_config

from .common import save_result

# Paper-measured reference points (Fig. 6a, read off the plot).
PAPER_RATIOS = {64: 1.11, 512: 1.4, 1024: 1.7, 2048: 2.02}
PAPER_PDSWAP_2048_TPS = 10.0  # ">10 token/s at 2048"
PAPER_PEAK_TPS = 27.8  # Table 1


@dataclasses.dataclass
class EdgeDecodeModel:
    """Eq. (5) with the paper's platform numbers for BitNet 0.73B.

    All terms are bytes-over-bandwidth (decode is memory-bound on KV260);
    the static-vs-swap difference is (i) effective KV bandwidth (port remap,
    ~2x) and (ii) the non-attention overhead that a compromise dataflow pays
    (calibrated so the static curve matches TeLLMe's published 25 tok/s short
    -context throughput).
    """

    ddr_bw: float = KV260_DDR_BW
    kv_port_frac_static: float = 0.5  # K/V get 2 of 4 HP ports (Q/K/V/O map)
    kv_port_frac_pdswap: float = 1.0  # 2xK + 2xV remap (§3.2.3): all 4 ports
    # Fixed per-token cost (TLMM projections + element-wise); like the
    # paper's P/D coefficients these are "empirically measured under a
    # baseline configuration" — here, calibrated to the paper's published
    # short-context throughputs (TeLLMe 25 tok/s, PD-Swap 27.8 tok/s).
    t_fixed_static: float = 1 / 26.5
    t_fixed_pdswap: float = 1 / 28.5
    # Attention-engine compute seconds per context token: the static design's
    # decode attention shares fabric with the resident prefill engine and is
    # underprovisioned (paper Fig. 4a); the decode RM owns the whole dynamic
    # region, ~3x the parallelism.  Calibrated at the paper's 2048-context
    # endpoints (static ~5 tok/s, PD-Swap ~10 tok/s).
    c_attn_static: float = 4.65e-5
    c_attn_pdswap: float = 1.49e-5

    def kv_bytes_per_ctx_token(self, cfg) -> float:
        # fp16 K+V across layers (paper: FP16 QKV)
        return 2 * cfg.num_layers * cfg.num_kv_heads * cfg.head_dim * 2

    def tok_per_s(self, cfg, context: int, *, pdswap: bool) -> float:
        frac = self.kv_port_frac_pdswap if pdswap else self.kv_port_frac_static
        t_kv = self.kv_bytes_per_ctx_token(cfg) * context / (self.ddr_bw * frac)
        t_fixed = self.t_fixed_pdswap if pdswap else self.t_fixed_static
        c = self.c_attn_pdswap if pdswap else self.c_attn_static
        return 1.0 / (t_fixed + t_kv + c * context)


def v5e_decode_tps(cfg, context: int, batch: int = 1) -> float:
    """Same roofline on one v5e chip (weights ternary-resident in HBM)."""
    chip = TPU_V5E
    wbytes = cfg.active_param_count() * (0.25 if cfg.quant.ternary else 2.0)
    kv = 2 * cfg.num_layers * cfg.num_kv_heads * cfg.head_dim * 2 * context * batch
    t = (wbytes + kv) / chip.hbm_bw
    return batch / t


def run() -> dict:
    cfg = get_config("bitnet-730m")
    model = EdgeDecodeModel()
    rows = []
    for ctx in (64, 128, 256, 512, 1024, 2048):
        tps_static = model.tok_per_s(cfg, ctx, pdswap=False)
        tps_pdswap = model.tok_per_s(cfg, ctx, pdswap=True)
        ratio = tps_pdswap / tps_static
        rows.append({
            "context": ctx,
            "static_tok/s (TeLLMe-mode)": tps_static,
            "pdswap_tok/s": tps_pdswap,
            "speedup": ratio,
            "paper_speedup": PAPER_RATIOS.get(ctx, ""),
            "v5e_tok/s (1 chip, b=1)": v5e_decode_tps(cfg, ctx),
        })

    # claim checks
    ratios = [r["speedup"] for r in rows]
    checks = {
        "speedup grows with context": all(b >= a for a, b in zip(ratios, ratios[1:])),
        "2048-ctx speedup in paper band (1.8-2.2)": 1.8 <= rows[-1]["speedup"] <= 2.2,
        "64-ctx speedup in paper band (1.05-1.2)": 1.05 <= rows[0]["speedup"] <= 1.2,
        "pdswap >10 tok/s at 2048": rows[-1]["pdswap_tok/s"] > PAPER_PDSWAP_2048_TPS,
        "peak pdswap ~27 tok/s": abs(rows[0]["pdswap_tok/s"] - PAPER_PEAK_TPS) < 2.0,
    }
    result = {
        "name": "fig6a_decode_throughput",
        "rows": rows,
        "notes": (
            "Decode tok/s vs context, BitNet 0.73B.  Edge columns use the paper's "
            "KV260 platform model (Eq. 5; static = one compromise config & prefill-"
            "tuned ports, PD-Swap = decode RM + 2x KV port remap).  Claim checks: "
            + ", ".join(f"{k}={'PASS' if v else 'FAIL'}" for k, v in checks.items())
        ),
        "checks": checks,
    }
    save_result(result)
    return result
