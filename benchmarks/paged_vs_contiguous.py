"""Paged vs contiguous KV cache: decode throughput and KV memory footprint.

The paper's decode engine is bandwidth-optimized and KV-cache-centric: every
decoded token streams the accumulated KV (Eq. 5), so both the *bytes held*
and the *bytes streamed* scale with context.  The seed runtime reserved
``max_len`` positions per slot; the paged layout
(``repro.serving.paging``) allocates ``block_size``-token pages on demand
and shares page-aligned prompt prefixes, so a ragged-length workload holds
only what it uses.

This benchmark runs the REAL ServingEngine (tiny functional config on this
host) across context-length regimes in the style of
``fig6_decode_throughput.py`` and reports, per regime and layout:

* decode tok/s measured on this host (functional, not TPU-representative),
* KV bytes reserved up front vs peak bytes actually backing live tokens,
* prefix-cache hit pages and preemption counts (paged only),
* the modeled v5e decode time saved by streaming actual-length rather than
  max_len KV (the bandwidth term of Eq. 5 — the quantity the Pallas paged
  kernel's block-table walk realizes on real hardware).
"""
from __future__ import annotations

import time

import numpy as np

import argparse

from repro.common.hardware import TPU_V5E

from .common import kv_cache_columns, render, save_result


def _workload(rng, vocab, n_req, lo, hi, shared_frac=0.5):
    """Ragged prompts; ~half the requests share a common prefix."""
    base = rng.integers(0, vocab, size=hi).astype(np.int32)
    prompts = []
    for i in range(n_req):
        n = int(rng.integers(lo, hi + 1))
        if i % 2 == 1:  # shared-prefix cohort
            keep = max(lo, int(n * shared_frac))
            p = np.concatenate([base[:keep], rng.integers(0, vocab, size=n - keep).astype(np.int32)])
        else:
            p = rng.integers(0, vocab, size=n).astype(np.int32)
        prompts.append(p)
    return prompts


def run(tiny: bool = False, kv_dtype: str = "fp") -> dict:
    """``tiny=True`` is the CI smoke mode: one regime only, so benchmark
    drift is caught in tier-1 without paying for the full sweep.
    ``kv_dtype`` runs both layouts over the quantized KV cache (the paged-
    vs-contiguous token parity must hold at any storage precision)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import reduced_config
    from repro.models import get_model
    from repro.serving import EngineCore, Request

    cfg = reduced_config("bitnet-730m", num_layers=3, d_model=128, vocab_size=512,
                         num_heads=4, num_kv_heads=2)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)

    rows = []
    regimes = [  # (max_len, prompt range, max_new)
        (128, (8, 40), 8),
        (256, (16, 96), 8),
        (512, (16, 200), 8),
    ]
    if tiny:
        regimes = regimes[:1]
    rng = np.random.default_rng(0)
    for max_len, (lo, hi), max_new in regimes:
        prompts = _workload(rng, cfg.vocab_size, 6, lo, hi)
        per_layout = {}
        for layout in ("contiguous", "paged"):
            eng = EngineCore(cfg, params, n_slots=3, max_len=max_len,
                             prompt_len=32, mode="static",
                             cache_layout=layout, block_size=16, kv_dtype=kv_dtype)
            for i, p in enumerate(prompts):
                eng.submit(Request(f"r{i}", p.copy(), max_new=max_new))
            stats = eng.run()
            assert len(eng.finished) == len(prompts)
            per_layout[layout] = (eng, stats, {k: v.out_tokens for k, v in eng.finished.items()})
        (ec, sc, oc), (ep, sp, op) = per_layout["contiguous"], per_layout["paged"]
        assert oc == op, "paged must match contiguous token-for-token"
        kb_c, kb_p = ec.kv_bytes(), ep.kv_bytes()

        # Eq. (5) bandwidth term on v5e: bytes of KV streamed per decoded
        # token at max_len-resident vs actual-length-resident caches —
        # kv_dtype-dependent (the quantized subsystem's roofline shift).
        kv_cols = kv_cache_columns(cfg, kv_dtype)
        tok_bytes = kv_cols["kv_bytes/ctx_tok"]
        mean_ctx = np.mean([len(p) + max_new for p in prompts])
        t_kv_max = tok_bytes * max_len / TPU_V5E.hbm_bw
        t_kv_actual = tok_bytes * mean_ctx / TPU_V5E.hbm_bw
        rows.append({
            "max_len": max_len,
            "mean_ctx": float(mean_ctx),
            **kv_cols,
            "contig_kv_bytes": kb_c["allocated"],
            "paged_kv_peak_bytes": kb_p["peak_in_use"],
            "kv_footprint_ratio": kb_p["peak_in_use"] / kb_c["allocated"],
            "contig_tok/s (host)": sc.decode_tput(),
            "paged_tok/s (host)": sp.decode_tput(),
            "prefix_hit_pages": sp.prefix_hits,
            "preemptions": sp.preemptions,
            "v5e_kv_stream_ms_saved/tok": 1e3 * (t_kv_max - t_kv_actual),
        })

    shrink = [r["kv_footprint_ratio"] for r in rows]
    checks = {
        "paged footprint < contiguous at every regime": all(s < 1.0 for s in shrink),
        "prefix cache hits on shared-prefix workload": all(r["prefix_hit_pages"] > 0 for r in rows),
        "paged outputs token-identical to contiguous": True,  # asserted above
        "paged holds <= half the contiguous KV at ragged lengths": all(s <= 0.5 for s in shrink),
    }
    result = {
        "name": "paged_vs_contiguous" + ("_tiny" if tiny else "")
        + ("" if kv_dtype == "fp" else f"_{kv_dtype}"),
        "rows": rows,
        "notes": (
            "Paged vs contiguous KV cache on a ragged shared-prefix workload "
            "(real engine, tiny config, host CPU; v5e column = Eq.(5) KV "
            "bandwidth term).  Outputs verified token-identical per regime.  "
            "Claim checks: "
            + ", ".join(f"{k}={'PASS' if v else 'FAIL'}" for k, v in checks.items())
        ),
        "checks": checks,
    }
    save_result(result)
    return result


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--tiny", action="store_true",
                   help="single-regime smoke mode (CI tier-1)")
    p.add_argument("--kv-dtype", default="fp", choices=["fp", "int8", "int4"],
                   help="KV-cache precision for both layouts (parity must "
                        "hold at any storage precision)")
    args = p.parse_args(argv)
    result = run(tiny=args.tiny, kv_dtype=args.kv_dtype)
    print(render(result))
    return 0 if all(result["checks"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
