"""Traffic storm: SLO goodput of the async front-end across swap policies.

The serving question the static benchmarks cannot answer: under *real*
arrival pressure — a seeded Poisson baseline and a bursty square-wave storm
(``repro.serving.arrivals``), mixed prompt lengths, two tenants on weighted
fair queueing — which prefill<->decode swap policy keeps the most requests
inside their latency SLO?

Each (trace x policy) cell drives the SAME seeded trace through a fresh
``AsyncEngine`` (bounded admission queue, streaming consumers) and measures
client-side, off the stream:

* **TTFT** — submit to first streamed token (queue wait included);
* **per-request ITL p95** — gaps between that request's deltas;
* **goodput under SLO** — the fraction of *offered* requests that finished
  AND met both targets (rejected and late requests both count against it);
* queue-wait distribution (engine aggregates) and the rejection rate.

SLO targets are calibrated from this host's measured decode-round and
prefill cost (a throwaway warmup engine), so the same benchmark is
meaningful on any machine: the targets sit between "trivially satisfied"
and "unreachable" for the policies under test.

Policies compared on identical traces:

* ``drain`` (paper): flip to prefill the moment work is queued — best
  TTFT, but every storm burst stalls all decode streams (ITL spikes);
* ``swap-aware``: amortize the modeled swap cost against queue depth —
  fewer fabric flips, but the TTFT clock keeps running while it defers;
* ``slo-aware``: steer the flip from *observed* p95 TTFT/ITL against the
  targets, and shed queue heads that can no longer meet the TTFT deadline
  (the PR's closed loop — a doomed request counts against goodput served
  or dropped, but serving it dooms its followers too).

Greedy tokens are slot- and policy-invariant, so every request completed by
multiple policies must stream identical tokens — checked.  Wall-clock
checks (the goodput ordering) are reported but never gate CI; structural
checks do.

Run directly (``python -m benchmarks.traffic_storm [--tiny]``) or via
``benchmarks.run``.
"""
from __future__ import annotations

import asyncio
import sys
import time

import numpy as np

from .common import (LATENCY_COLUMNS, add_trace_arg, finish_trace,
                     latency_rows, markdown_table, save_result, start_trace,
                     stats_block)

TENANTS = (("interactive", 2.0, 0.5), ("batch", 1.0, 0.5))


def _calibrate(cfg, params, knobs, *, max_new, prompt_lens):
    """Measure this host's steady-state serving costs on throwaway engines:
    decode-round and prefill-chunk cost from a warm synchronous pass (kernel
    costs, for the SLO targets), and the end-to-end service rate from an
    async saturation probe — the storm replays through ``AsyncEngine``, so
    the rate that decides whether an arrival trace overloads it must include
    the front-end's own step/streaming overhead, not just kernel time (a
    sync-measured rate overestimates by ~1.5x and turns every 'moderate'
    burst into a drowning)."""
    from repro.serving import AsyncEngine, EngineCore, Request

    eng = EngineCore(cfg, params, swap_policy="drain", **knobs)
    lo, hi = 8, knobs["prompt_len"]

    def _batch(tag):
        # fresh identically-seeded rng per pass: the warm pass hits exactly
        # the shape buckets (page counts) the measured pass will hit
        rng = np.random.default_rng(99)
        for i in range(knobs["n_slots"]):
            n = int(rng.integers(lo, hi + 1))
            eng.submit(Request(f"{tag}{i}",
                               rng.integers(0, cfg.vocab_size, n).astype(np.int32),
                               max_new=max_new))
        eng.run()

    _batch("warm")  # first pass pays XLA compilation
    eng.reset_stats()
    _batch("cal")  # second pass measures steady-state kernel costs
    stats = eng.stats
    round_cost = stats.t_decode / max(stats.decode_rounds, 1)
    # chunked: the prefill quantum is one chunk; monolithic: one burst
    quanta = stats.prefill_chunks or stats.prefill_bursts
    prefill_cost = stats.t_prefill / max(quanta, 1)

    async def probe():
        core = EngineCore(cfg, params, swap_policy="drain", **knobs)
        bs = knobs["block_size"]
        wrng = np.random.default_rng(55)
        for j, pages in enumerate(sorted({-(-p // bs) for p in prompt_lens})):
            n = min(pages * bs, knobs["prompt_len"])
            core.submit(Request(
                f"w{j}", wrng.integers(0, cfg.vocab_size, n).astype(np.int32),
                max_new=2))
        core.run()
        core.reset_stats()
        rng = np.random.default_rng(99)
        n_req = 6 * knobs["n_slots"]
        async with AsyncEngine(core, max_queue=n_req) as aeng:
            t0 = time.perf_counter()
            tasks = []
            for i in range(n_req):
                plen = prompt_lens[i % len(prompt_lens)]
                stream = await aeng.submit(
                    rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                    request_id=f"p{i}", max_new=max_new)
                tasks.append(asyncio.create_task(_consume(stream, t0)))
            gaps = []
            for t in tasks:
                gaps.extend((await t)["gaps"])
            rate = n_req / max(time.perf_counter() - t0, 1e-3)
            return rate, float(np.median(gaps)) if gaps else round_cost

    # svc: requests/s through the real front-end; gap_p50: the median
    # CLIENT-visible inter-token gap under a saturated pipeline — the same
    # layer the goodput check measures, so it already includes step and
    # streaming overhead kernel costs alone miss
    svc, gap_p50 = asyncio.run(probe())
    return round_cost, prefill_cost, svc, gap_p50


async def _consume(stream, t_submit):
    """Drain one request's stream, stamping client-side latencies."""
    ttft, prev, gaps, toks, reason = None, None, [], [], None
    async for out in stream:
        now = time.perf_counter()
        if out.new_token_ids:
            if prev is None:
                ttft = now - t_submit
            else:
                gaps.append(now - prev)
            prev = now
            toks.extend(out.new_token_ids)
        if out.finished:
            reason = out.finish_reason
    return {"ttft_s": ttft, "gaps": gaps, "tokens": toks, "finish_reason": reason}


def _drive(policy, cfg, params, trace, knobs, *, max_new, max_queue,
           prompt_seed, label="engine"):
    """One (trace x policy) cell: replay the trace against a fresh engine."""
    from repro.serving import AdmissionRejected, AsyncEngine, EngineCore, Request

    async def go():
        from repro.obs.trace import TRACER

        if TRACER.enabled:
            # fresh buffer per cell: request ids repeat across cells and the
            # tracer's exactly-once finish assertion is process-wide, so the
            # exported trace covers the LAST (trace x policy) cell
            TRACER.clear()
        core = EngineCore(cfg, params, swap_policy=policy, **knobs)
        # warm this engine's XLA programs before the trace clock starts, so
        # the storm measures serving, not compilation: one warmup prompt
        # per prefill shape bucket (page count) the trace will hit
        bs = knobs["block_size"]
        buckets = sorted({-(-a.prompt_len // bs) for a in trace})
        wrng = np.random.default_rng(55)
        for j, pages in enumerate(buckets):
            n = min(pages * bs, knobs["prompt_len"])
            core.submit(Request(
                f"warm{j}",
                wrng.integers(0, cfg.vocab_size, n).astype(np.int32),
                max_new=2))
            core.run()  # one at a time: a multi-second first-bucket compile
            # must not age queued warmups past a shedding policy's deadline
        core.reset_stats()
        rng = np.random.default_rng(prompt_seed)  # prompt CONTENT: same per policy
        prompts = [rng.integers(0, cfg.vocab_size, a.prompt_len).astype(np.int32)
                   for a in trace]
        rejected, consumers, results = 0, {}, {}
        async with AsyncEngine(core, max_queue=max_queue) as eng:
            t0 = time.perf_counter()
            for i, a in enumerate(trace):
                delay = a.t - (time.perf_counter() - t0)
                if delay > 0:
                    await asyncio.sleep(delay)
                t_submit = time.perf_counter()
                try:
                    stream = await eng.submit(
                        prompts[i], request_id=f"r{i}", max_new=max_new,
                        tenant=a.tenant, weight=a.weight)
                except AdmissionRejected:
                    rejected += 1
                    continue
                consumers[f"r{i}"] = asyncio.create_task(_consume(stream, t_submit))
            for rid, task in consumers.items():
                results[rid] = await task
            snap = stats_block(eng)
            lat = latency_rows(eng, label=label)
        return results, rejected, snap, lat

    return asyncio.run(go())


def _summarize(trace_name, policy, results, rejected, snap, slo, offered):
    completed = {rid: r for rid, r in results.items()
                 if r["finish_reason"] in ("stop", "length")}
    shed = sum(1 for r in results.values() if r["finish_reason"] == "shed")
    good = 0
    for r in completed.values():
        itl95 = float(np.percentile(r["gaps"], 95)) if r["gaps"] else 0.0
        if r["ttft_s"] is not None and r["ttft_s"] <= slo.ttft_target_s \
                and itl95 <= slo.itl_target_s:
            good += 1
    ttfts = [r["ttft_s"] for r in completed.values() if r["ttft_s"] is not None]
    gaps = [g for r in completed.values() for g in r["gaps"]]
    qw = snap["queue_wait_s"]
    return {
        "trace": trace_name,
        "policy": policy,
        "offered": offered,
        "rejected": rejected,
        "shed": shed,
        "completed": len(completed),
        "goodput_slo_pct": 100.0 * good / offered,
        "reject_pct": 100.0 * rejected / offered,
        "ttft_p95_ms": 1e3 * float(np.percentile(ttfts, 95)) if ttfts else 0.0,
        "itl_p95_ms": 1e3 * float(np.percentile(gaps, 95)) if gaps else 0.0,
        "queue_wait_p50_ms": 1e3 * qw["p50"],
        "queue_wait_p95_ms": 1e3 * qw["p95"],
        "swaps": snap["swaps"],
        "prefill_bursts": snap["prefill_bursts"],
    }


def run(tiny: bool = False) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import reduced_config
    from repro.models import get_model
    from repro.serving.arrivals import make_trace

    # tiny (CI smoke) keeps the model minimal; full scale uses a model big
    # enough that kernel time dominates per-step dispatch overhead — with a
    # too-small model the calibrated SLO targets describe kernel costs while
    # the observed gaps are mostly Python/asyncio overhead, and every policy
    # blurs together
    if tiny:
        cfg = reduced_config("bitnet-730m", num_layers=3, d_model=128,
                             vocab_size=512, num_heads=4, num_kv_heads=2)
    else:
        cfg = reduced_config("bitnet-730m", num_layers=4, d_model=256,
                             vocab_size=512, num_heads=4, num_kv_heads=2)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)

    # chunked prefill, PREFILL-HEAVY traffic (long prompts, short
    # generations — the paper's edge regime: summarization / RAG): the
    # regime where the policies actually differ.  Per step the engine runs
    # `quanta` chunk(s) plus one decode round; with long prompts the fixed
    # decode round is pure queue-aging overhead during a storm, so the
    # slo-aware policy's widened quanta drain the prefill backlog ~2x
    # faster than the static policies' one-chunk steps.  (Decode-heavy
    # traffic is the degenerate case: prefill is a sliver of engine time,
    # no quanta choice can move TTFT, and every policy converges.)
    if tiny:
        knobs = dict(n_slots=4, max_len=96, prompt_len=32, cache_layout="paged",
                     block_size=16, num_blocks=64, prefill_chunk=16)
        max_new, n, max_queue = 6, 10, 8
        prompt_lens = (8, 32)
    else:
        knobs = dict(n_slots=4, max_len=128, prompt_len=112, cache_layout="paged",
                     block_size=16, num_blocks=48, prefill_chunk=16)
        max_new, n, max_queue = 8, 96, 12
        prompt_lens = (48, 112)

    from repro.serving.slo import SLOConfig

    round_cost, prefill_cost, svc, gap_p50 = _calibrate(
        cfg, params, knobs, max_new=max_new, prompt_lens=prompt_lens)
    # SLO targets between "trivially satisfied" and "unreachable":
    # * ITL — twice the median client-visible gap of a saturated pipeline:
    #   a bounded chunk quantum between two deltas passes; a decode
    #   stalled behind an unbounded prefill run does not (calibrated at
    #   the client layer, where the goodput check measures);
    # * TTFT — the prompt's own chunks plus a partial admission queue's
    #   drain time; violated when a policy lets the queue head age or the
    #   prefill backlog build.
    chunks_per_prompt = -(-knobs["prompt_len"] // knobs["prefill_chunk"])
    slo = SLOConfig(
        ttft_target_s=max(0.08, 2.0 * chunks_per_prompt * prefill_cost
                          + 0.4 * max_queue / svc),
        itl_target_s=max(0.004, 2.0 * gap_p50),
    )

    # arrival rates relative to this host's measured end-to-end service
    # rate, so the storm is a storm everywhere: the burst phase offers
    # ~1.6x what the engine can serve — deep enough that a
    # run-every-request policy queues each burst's arrivals far past the
    # TTFT target (serving doomed requests dooms their followers too),
    # which is exactly the regime deadline shedding converts into goodput;
    # the base phase leaves recovery room, and the period is chosen so the
    # trace spans ~3 full storm cycles (not one long base phase the
    # bursts never interrupt)
    base_rate = max(0.5, 0.4 * svc)
    burst_rate = max(2.0, 1.6 * svc)
    period_s = max(0.2, n / ((base_rate + burst_rate) / 2.0) / 3.0)
    traces = {
        "poisson": make_trace(n, kind="poisson", rate=base_rate, seed=7,
                              prompt_lens=prompt_lens, tenants=TENANTS),
        "bursty": make_trace(n, kind="bursty", rate=base_rate,
                             burst_rate=burst_rate, period_s=period_s, seed=7,
                             prompt_lens=prompt_lens, tenants=TENANTS),
    }

    # the slo-aware policy must chase the CALIBRATED targets (a policy
    # steering toward the library defaults on a host 100x faster or slower
    # is chasing the wrong SLO)
    def _make_policy(name):
        if name == "slo-aware":
            from repro.serving.slo import SLOAwareSwapPolicy
            return SLOAwareSwapPolicy(slo)
        return name

    policies = ["drain", "swap-aware", "slo-aware"]
    rows, lat_rows, tokens = [], [], {}
    for tname, trace in traces.items():
        for policy in policies:
            results, rejected, snap, lat = _drive(
                _make_policy(policy), cfg, params, trace, knobs,
                max_new=max_new, max_queue=max_queue, prompt_seed=3,
                label=f"{tname}/{policy}")
            lat_rows.extend(lat)
            rows.append(_summarize(tname, policy, results, rejected, snap,
                                   slo, offered=len(trace)))
            tokens[(tname, policy)] = {
                rid: r["tokens"] for rid, r in results.items()
                if r["finish_reason"] in ("stop", "length")}

    # greedy tokens must agree wherever two policies completed the same
    # request of the same trace (admission sets may differ under rejection)
    identical = True
    for tname in traces:
        sets = [tokens[(tname, p)] for p in policies]
        for rid in set(sets[0]) & set(sets[1]) & set(sets[2]):
            if not (sets[0][rid] == sets[1][rid] == sets[2][rid]):
                identical = False

    by = {(r["trace"], r["policy"]): r for r in rows}
    checks = {
        "greedy tokens identical across policies (common completions)": identical,
        "every offered request accounted (completed+rejected+shed <= offered)": all(
            r["completed"] + r["rejected"] + r["shed"] <= r["offered"]
            for r in rows),
        "queue wait recorded for admitted requests": all(
            r["queue_wait_p95_ms"] >= 0.0 for r in rows),
    }
    timing = {
        "slo-aware goodput >= drain on bursty trace (informational)": (
            by[("bursty", "slo-aware")]["goodput_slo_pct"]
            >= by[("bursty", "drain")]["goodput_slo_pct"]),
        "slo-aware goodput >= swap-aware on bursty trace (informational)": (
            by[("bursty", "slo-aware")]["goodput_slo_pct"]
            >= by[("bursty", "swap-aware")]["goodput_slo_pct"]),
    }
    result = {
        "name": "traffic_storm" + ("_tiny" if tiny else ""),
        "rows": rows,
        "latency_rows": lat_rows,
        "slo": {"ttft_target_ms": 1e3 * slo.ttft_target_s,
                "itl_target_ms": 1e3 * slo.itl_target_s,
                "measured_round_cost_ms": 1e3 * round_cost,
                "measured_prefill_cost_ms": 1e3 * prefill_cost,
                "measured_service_rate_rps": svc},
        "notes": (
            f"Async front-end under seeded Poisson ({base_rate:.1f} req/s) and "
            f"bursty square-wave (base {base_rate:.1f}, burst {burst_rate:.1f} "
            f"req/s) arrival traces, two tenants on weighted fair queueing, "
            f"bounded admission queue ({max_queue}).  SLO calibrated to this "
            f"host: TTFT <= {1e3*slo.ttft_target_s:.0f} ms, per-request ITL "
            f"p95 <= {1e3*slo.itl_target_s:.1f} ms.  Goodput = completed "
            "within SLO / offered (rejections and sheds count against it; "
            "only the slo-aware policy sheds queue heads already past the "
            "TTFT deadline, spending their capacity on requests that can "
            "still meet it).  Claim checks: " + ", ".join(
                f"{k}={'PASS' if v else 'FAIL'}"
                for k, v in {**checks, **timing}.items())
        ),
        "checks": checks,
        "timing_checks": timing,
        "columns": ["trace", "policy", "offered", "rejected", "shed",
                    "completed",
                    "goodput_slo_pct", "reject_pct", "ttft_p95_ms", "itl_p95_ms",
                    "queue_wait_p50_ms", "queue_wait_p95_ms", "swaps",
                    "prefill_bursts"],
    }
    save_result(result)
    return result


if __name__ == "__main__":
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--tiny", action="store_true",
                   help="CI smoke mode: short trace, structural checks only")
    add_trace_arg(p)
    args = p.parse_args()
    start_trace(args.trace_out)
    res = run(tiny=args.tiny)
    finish_trace(args.trace_out)
    print(markdown_table(res["rows"], res.get("columns")))
    print()
    print("engine latency (metrics registry — the /metrics summaries):")
    print(markdown_table(res["latency_rows"], list(LATENCY_COLUMNS)))
    print()
    print(res["notes"])
    sys.exit(0 if all(res["checks"].values()) else 1)
