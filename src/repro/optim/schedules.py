"""LR schedules: cosine, linear, and WSD (warmup-stable-decay, MiniCPM)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr: float, warmup: int, total: int, final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def wsd(step, *, peak_lr: float, warmup: int, total: int, decay_frac: float = 0.1,
        final_frac: float = 0.01):
    """MiniCPM's warmup-stable-decay: flat plateau, late exponential-ish decay."""
    step = jnp.asarray(step, jnp.float32)
    decay_start = total * (1 - decay_frac)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - decay_start) / jnp.maximum(total - decay_start, 1), 0.0, 1.0)
    dec = peak_lr * jnp.exp(jnp.log(final_frac) * prog)
    out = jnp.where(step < warmup, warm, peak_lr)
    return jnp.where(step > decay_start, dec, out)


SCHEDULES = {"cosine": warmup_cosine, "wsd": wsd}
