from repro.optim.adamw import AdamWConfig, AdamWState, adamw_init, adamw_update, global_norm
from repro.optim.schedules import warmup_cosine, wsd, SCHEDULES
from repro.optim.compression import compressed_mean_over_axis, init_error_feedback
