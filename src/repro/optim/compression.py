"""Gradient compression for cross-pod (DCN) reduction, with error feedback.

At multi-pod scale the gradient all-reduce over the pod axis crosses DCN
(~25 GB/s vs 200 GB/s aggregate ICI), so it dominates the collective term of
the training roofline.  This module implements int8 gradient exchange with
error feedback (1-bit-Adam-style): each pod quantizes (grad + carried error)
per-tensor to int8, all-gathers the int8 payload + f32 scales over the pod
axis (wire bytes ~ 1/4 of f32), dequantizes and averages locally, and carries
the quantization residual into the next step.

Use inside shard_map over the pod axis (see trainer's compressed-DP mode);
``tests/test_distributed.py`` validates convergence + exactness bounds on a
4-device fake mesh.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.common.compat import shard_map


def _quant_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    absmax = jnp.max(jnp.abs(x))
    scale = absmax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_mean_over_axis(grads: Any, err: Any, axis: str) -> Tuple[Any, Any]:
    """Mean of grads over mesh axis ``axis`` using int8 wire format.

    Returns (mean_grads f32, new_error_feedback).  Must run inside shard_map
    with ``axis`` manual.
    """
    # jax.lax.axis_size is newer-jax; psum of 1 is the portable axis size
    n = jax.lax.psum(1, axis)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quant_int8(g32)
        deq_local = q.astype(jnp.float32) * scale
        new_err = g32 - deq_local  # residual carried to next step
        # all_gather int8 payload (the wire savings) + tiny scale vector
        q_all = jax.lax.all_gather(q, axis)  # (n, ...)
        s_all = jax.lax.all_gather(scale, axis)  # (n,)
        mean = jnp.tensordot(s_all, q_all.astype(jnp.float32), axes=(0, 0)) / n
        return mean.astype(g.dtype), new_err

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_err = jax.tree.unflatten(tdef, [o[1] for o in out])
    return mean, new_err


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_dp_grads(loss_fn, mesh, *, pod_axis: str = "pod", batch_spec=None):
    """DDP-style compressed data parallelism over the pod (DCN) axis.

    Returns ``grads_fn(params, err, batch) -> (loss_mean, grads_mean, err)``
    where each pod computes grads on its batch shard and the cross-pod mean
    uses the int8 + error-feedback wire format (1/4 the DCN bytes of f32).

    This is the integration point for the global-view trainer: in pjit the
    gradient reduction is implicit in the backward, so compression must own
    the reduction — hence the shard_map wrapper.
    """
    from jax.sharding import PartitionSpec as P

    batch_spec = batch_spec if batch_spec is not None else P(pod_axis)

    def local(params, err, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        mean, err = compressed_mean_over_axis(grads, err, pod_axis)
        loss = jax.lax.pmean(loss, pod_axis)
        return loss, mean, err

    rep = None  # replicated pytrees: spec inferred as fully-replicated
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P(), batch_spec),
        out_specs=(P(), P(), P()),
        check_vma=False,
    )
