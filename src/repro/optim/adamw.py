"""AdamW with pytree states (no optax in this environment).

Optimizer state lives in fp32 and is sharded exactly like the parameters
(ZeRO-style: the FSDP axis shards both), so restore-with-resharding works on
the whole (params, opt_state) bundle.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(
    grads, state: AdamWState, params, lr, cfg: AdamWConfig = AdamWConfig()
) -> Tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), {"grad_norm": gnorm}
