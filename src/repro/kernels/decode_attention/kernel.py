"""Pallas TPU kernel: bandwidth-optimized decode attention (the decode RM).

Paper (C3 + §3.2.3): in decode, L=1 — no Q reuse exists; attention degenerates
to q_t · K^T -> softmax -> · V streaming the whole KV cache.  The FPGA design
re-maps the four DDR HP ports to 2xK + 2xV (instead of Q/K/V/O), streams the
one Q token into an on-chip buffer before the walk, and holds the output
token locally until the KV transfer finishes.

TPU mapping (DESIGN.md §2):
  * Q tile (G, D) for one KV head's query group is pinned in VMEM for the
    whole kernel (BlockSpec index constant in the KV-walk dim) — the "stream
    Q into the on-chip buffer first" step.
  * K and V have *separate* block specs walking the cache, so Mosaic
    double-buffers two independent HBM->VMEM DMA streams — the 2+2 port
    remap analogue; the HBM roofline term is ~ bytes(KV)/bw.
  * The output (G, D) is accumulated in VMEM scratch and written exactly
    once, after the last KV block ("write back after KV transfers complete").
  * GQA: the grid iterates KV heads; all G = H/Hkv query heads of a group
    ride the same KV stream (KV bytes read once per group, not per head).

Variable sequence lengths (continuous batching) come in via scalar prefetch:
``lengths[b]`` masks tail positions and skips fully-inactive KV blocks.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common.compat import tpu_compiler_params
from repro.quant.kv_quant import unpack_int4

NEG_INF = -1e30


def _dequant_tile(q_tile, s_tile, kv_dtype):
    """In-VMEM dequant of one (bk, Dp) payload tile + (bk,) scale row -> f32
    (bk, D).  This is the *fused* step: packed bytes are what the DMA moved;
    the fp tile exists only in registers/VMEM, never in HBM."""
    q = unpack_int4(q_tile) if kv_dtype == "int4" else q_tile
    return q.astype(jnp.float32) * s_tile.astype(jnp.float32)[:, None]


def _decode_kernel(
    start_ref,  # scalar-prefetch: (B,) int32 — window start (0 for full attn)
    len_ref,  # scalar-prefetch: (B,) int32
    q_ref,  # (1, 1, G, D)
    k_ref,  # (1, 1, bk, D)
    v_ref,  # (1, 1, bk, D)
    out_ref,  # (1, 1, G, D)
    out_l_ref,  # (1, 1, G, 128) — softmax denominator (stats output)
    out_m_ref,  # (1, 1, G, 128) — running max (stats output)
    m_ref,
    l_ref,
    acc_ref,
    *,
    bk: int,
    n_steps: int,
    sm_scale: float,
):
    b = pl.program_id(0)
    t = pl.program_id(2)
    length = len_ref[b]
    start = start_ref[b]

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Skip KV blocks entirely outside [start, length) — sliding windows skip
    # the dead prefix, full attention (start=0) streams everything live.
    @pl.when(jnp.logical_and(t * bk < length, (t + 1) * bk > start))
    def _step():
        q = q_ref[...].astype(jnp.float32)[0, 0]  # (G, D)
        k = k_ref[...].astype(jnp.float32)[0, 0]  # (bk, D)
        v = v_ref[...].astype(jnp.float32)[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale  # (G, bk)
        pos = t * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(jnp.logical_and(pos >= start, pos < length), s, NEG_INF)

        m_prev = m_ref[...][:, :1]
        l_prev = l_ref[...][:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = jnp.broadcast_to(alpha * l_prev + jnp.sum(p, axis=1, keepdims=True), l_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ()))
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(t == n_steps - 1)  # single writeback after the KV walk
    def _finalize():
        l = l_ref[...][:, :1]
        out_ref[...] = (acc_ref[...] / jnp.maximum(l, 1e-30))[None, None].astype(out_ref.dtype)
        out_l_ref[...] = l_ref[...][None, None]
        out_m_ref[...] = m_ref[...][None, None]


@functools.partial(jax.jit, static_argnames=("bk", "sm_scale", "interpret"))
def decode_attention_pallas(
    q: jax.Array,  # (B, Hkv, G, D) — query heads grouped by KV head
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,  # (B, Hkv, S, D)
    lengths: jax.Array,  # (B,) int32 — per-sequence valid cache length
    starts: jax.Array | None = None,  # (B,) int32 — window start (default 0)
    *,
    bk: int = 512,
    sm_scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    b, hkv, g, d = q.shape
    s = k.shape[2]
    # Partial final block: clamp the KV block to the cache and right-pad the
    # cache to a whole number of blocks — padded positions sit at pos >=
    # length, so the existing length mask already zeroes them.  Small
    # reduced-config caches need no caller-side padding.
    bk = min(bk, s)
    pad = (-s) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    n_steps = (s + pad) // bk

    if starts is None:
        starts = jnp.zeros_like(lengths)
    kernel = functools.partial(_decode_kernel, bk=bk, n_steps=n_steps, sm_scale=sm_scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_steps),
        # NB: with scalar prefetch, index maps receive the scalar refs as
        # trailing arguments (absorbed by *_).
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, ti, *_: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, ti, *_: (bi, hi, ti, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, ti, *_: (bi, hi, ti, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, ti, *_: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 128), lambda bi, hi, ti, *_: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 128), lambda bi, hi, ti, *_: (bi, hi, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),  # normalized out
            jax.ShapeDtypeStruct((b, hkv, g, 128), jnp.float32),  # l
            jax.ShapeDtypeStruct((b, hkv, g, 128), jnp.float32),  # m
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(starts.astype(jnp.int32), lengths.astype(jnp.int32), q, k, v)


def _decode_quant_kernel(
    start_ref,  # scalar-prefetch: (B,) int32
    len_ref,  # scalar-prefetch: (B,) int32
    q_ref,  # (1, 1, G, D)
    kq_ref,  # (1, 1, bk, Dp) int8 / uint8 packed payload
    ks_ref,  # (1, 1, bk) f32 scale rows
    vq_ref,  # (1, 1, bk, Dp)
    vs_ref,  # (1, 1, bk)
    out_ref,  # (1, 1, G, D)
    out_l_ref,
    out_m_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    bk: int,
    n_steps: int,
    sm_scale: float,
    kv_dtype: str,
):
    """Fused-dequant decode RM: identical online-softmax walk to
    ``_decode_kernel`` but the K/V streams are the *packed* cache — the DMA
    moves 1/2 (int8) or 1/4 (int4) of the fp bytes plus a 4-byte scale per
    row, and dequant happens on the VMEM tile right before the dot."""
    b = pl.program_id(0)
    t = pl.program_id(2)
    length = len_ref[b]
    start = start_ref[b]

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(jnp.logical_and(t * bk < length, (t + 1) * bk > start))
    def _step():
        q = q_ref[...].astype(jnp.float32)[0, 0]  # (G, D)
        k = _dequant_tile(kq_ref[...][0, 0], ks_ref[...][0, 0], kv_dtype)  # (bk, D)
        v = _dequant_tile(vq_ref[...][0, 0], vs_ref[...][0, 0], kv_dtype)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale
        pos = t * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(jnp.logical_and(pos >= start, pos < length), s, NEG_INF)

        m_prev = m_ref[...][:, :1]
        l_prev = l_ref[...][:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = jnp.broadcast_to(alpha * l_prev + jnp.sum(p, axis=1, keepdims=True), l_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ()))
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(t == n_steps - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        out_ref[...] = (acc_ref[...] / jnp.maximum(l, 1e-30))[None, None].astype(out_ref.dtype)
        out_l_ref[...] = l_ref[...][None, None]
        out_m_ref[...] = m_ref[...][None, None]


@functools.partial(jax.jit, static_argnames=("bk", "sm_scale", "kv_dtype", "interpret"))
def decode_attention_quant_pallas(
    q: jax.Array,  # (B, Hkv, G, D)
    k_q: jax.Array,  # (B, Hkv, S, Dp) packed payload (int8 / uint8)
    k_scale: jax.Array,  # (B, Hkv, S) f32
    v_q: jax.Array,
    v_scale: jax.Array,
    lengths: jax.Array,  # (B,) int32
    starts: jax.Array | None = None,
    *,
    kv_dtype: str,
    bk: int = 512,
    sm_scale: float | None = None,
    interpret: bool = False,
):
    """Fused-dequant variant of ``decode_attention_pallas`` over a quantized
    contiguous cache.  Same outputs (normalized out + l/m stats)."""
    b, hkv, g, d = q.shape
    s = k_q.shape[2]
    bk = min(bk, s)
    pad = (-s) % bk
    if pad:
        pad4 = ((0, 0), (0, 0), (0, pad), (0, 0))
        k_q = jnp.pad(k_q, pad4)
        v_q = jnp.pad(v_q, pad4)
        pad3 = ((0, 0), (0, 0), (0, pad))
        k_scale = jnp.pad(k_scale, pad3)
        v_scale = jnp.pad(v_scale, pad3)
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    n_steps = (s + pad) // bk
    dp = k_q.shape[3]

    if starts is None:
        starts = jnp.zeros_like(lengths)
    kernel = functools.partial(
        _decode_quant_kernel, bk=bk, n_steps=n_steps, sm_scale=sm_scale, kv_dtype=kv_dtype
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_steps),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, ti, *_: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, bk, dp), lambda bi, hi, ti, *_: (bi, hi, ti, 0)),
            pl.BlockSpec((1, 1, bk), lambda bi, hi, ti, *_: (bi, hi, ti)),
            pl.BlockSpec((1, 1, bk, dp), lambda bi, hi, ti, *_: (bi, hi, ti, 0)),
            pl.BlockSpec((1, 1, bk), lambda bi, hi, ti, *_: (bi, hi, ti)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, ti, *_: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 128), lambda bi, hi, ti, *_: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 128), lambda bi, hi, ti, *_: (bi, hi, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, 128), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, 128), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(starts.astype(jnp.int32), lengths.astype(jnp.int32), q, k_q, k_scale, v_q, v_scale)
