"""Jitted wrapper for the decode attention kernel.

Accepts flat (B, H, D) queries, regroups to (B, Hkv, G, D), pads the cache
length to the KV block, and dispatches kernel vs oracle.

``_decode_attention_streaming`` is the compiled jnp path (kernel-shaped
dataflow): K/V stay in their storage dtype and the dots accumulate in f32
via ``preferred_element_type`` — the MXU semantics of the Pallas kernel.
The f32-upcast ``decode_attention_reference`` stays the max-precision
oracle for the kernel tests.  [§Perf iteration D1: the upcast version made
XLA hoist a full-cache f32 convert out of the layer scan — a whole-cache
HBM copy (2x KV bytes write + read) and a 2x peak-memory spike.]
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import (
    decode_attention_pallas,
    decode_attention_quant_pallas,
)
from repro.kernels.decode_attention.ref import decode_attention_reference
from repro.quant.kv_quant import dequantize_kv

# Aliasing contract, audited by the `program` analysis pass
# (repro.analysis.progcheck): these operands alias the persistent KV cache,
# and the op never writes or returns them — cache mutation belongs to the
# DONATED program-level buffers (layers/attention.py scatter writers), never
# to kernel entry points.
CACHE_OPERANDS = {
    "decode_attention": {"args": ("k", "v"), "writes": False},
}


def _decode_attention_streaming(
    q: jax.Array,  # (B, Hkv, G, D)
    k: jax.Array,  # (B, Hkv, S, D) — storage dtype (bf16/f32), never upcast
    v: jax.Array,
    lengths: jax.Array,
    starts: Optional[jax.Array],
    *,
    sm_scale: Optional[float] = None,
    return_stats: bool = False,
):
    b, hkv, g, d = q.shape
    s = k.shape[2]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    if starts is None:
        starts = jnp.zeros_like(lengths)
    scores = jnp.einsum(
        "bhgd,bhsd->bhgs", q.astype(k.dtype), k, preferred_element_type=jnp.float32
    ) * sm_scale
    pos = jnp.arange(s)[None, :]
    mask = (pos < lengths[:, None]) & (pos >= starts[:, None])  # (B, S)
    mask4 = mask[:, None, None, :]
    scores = jnp.where(mask4, scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)  # (B,Hkv,G,1); -1e30 if empty
    p = jnp.where(mask4, jnp.exp(scores - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum(
        "bhgs,bhsd->bhgd", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    ) / jnp.maximum(l, 1e-30)
    if return_stats:
        return out, l, m
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,  # (B, H, D)
    k: jax.Array,  # (B, Hkv, S, D) — or packed payload (B, Hkv, S, Dp) when quantized
    v: jax.Array,
    lengths: jax.Array,  # (B,) int32
    starts: Optional[jax.Array] = None,  # (B,) int32 — sliding-window start
    *,
    bk: int = 512,
    use_kernel: bool = False,
    interpret: bool = True,
    sm_scale: Optional[float] = None,
    return_stats: bool = False,
    k_scales: Optional[jax.Array] = None,  # (B, Hkv, S) f32 — quantized cache
    v_scales: Optional[jax.Array] = None,
    kv_dtype: str = "fp",
):
    """Attention of one query token per sequence over a masked KV cache.

    ``kv_dtype`` in {"int8", "int4"} (with ``k_scales``/``v_scales``) reads a
    *quantized* cache: the kernel path streams the packed payload and fuses
    dequant into the KV walk; the jnp path dequantizes then delegates (the
    oracle dataflow — it materializes the fp cache the kernel avoids).

    ``return_stats=True`` additionally returns the online-softmax stats
    (l, m) of shape (B, H, 1) — in f32, with the output UN-astype'd — so the
    caller can merge further blocks (e.g. the freshly-projected token)."""
    b, h, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    if kv_dtype != "fp":
        assert k_scales is not None and v_scales is not None, "quantized cache needs scales"
        if use_kernel:
            g = h // hkv
            out, l, m = decode_attention_quant_pallas(
                q.reshape(b, hkv, g, d), k, k_scales, v, v_scales,
                lengths.astype(jnp.int32),
                None if starts is None else starts.astype(jnp.int32),
                kv_dtype=kv_dtype, bk=bk, interpret=interpret, sm_scale=sm_scale,
            )
            if return_stats:
                return (out.reshape(b, h, d),
                        l[:, :, :, :1].reshape(b, h, 1), m[:, :, :, :1].reshape(b, h, 1))
            return out.reshape(b, h, d).astype(q.dtype)
        k = dequantize_kv(k, k_scales, kv_dtype)
        v = dequantize_kv(v, v_scales, kv_dtype)
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    if not use_kernel:
        if return_stats:
            out, l, m = _decode_attention_streaming(
                qg, k, v, lengths, starts, sm_scale=sm_scale, return_stats=True
            )
            return out.reshape(b, h, d), l.reshape(b, h, 1), m.reshape(b, h, 1)
        out = _decode_attention_streaming(qg, k, v, lengths, starts, sm_scale=sm_scale)
        return out.reshape(b, h, d)
    # the kernel clamps bk to the cache and pads any partial final block
    out, l, m = decode_attention_pallas(
        qg, k, v, lengths.astype(jnp.int32), None if starts is None else starts.astype(jnp.int32),
        bk=bk, interpret=interpret, sm_scale=sm_scale
    )
    if return_stats:
        return (out.reshape(b, h, d),
                l[:, :, :, :1].reshape(b, h, 1), m[:, :, :, :1].reshape(b, h, 1))
    return out.reshape(b, h, d).astype(q.dtype)
