"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def decode_attention_reference(
    q: jax.Array,  # (B, Hkv, G, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,
    lengths: jax.Array,  # (B,) int32
    starts: Optional[jax.Array] = None,  # (B,) int32 window start
    *,
    sm_scale: Optional[float] = None,
) -> jax.Array:
    b, hkv, g, d = q.shape
    s = k.shape[2]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    if starts is None:
        starts = jnp.zeros_like(lengths)
    scores = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32), k.astype(jnp.float32)) * sm_scale
    pos = jnp.arange(s)[None, :]
    mask = (pos < lengths[:, None]) & (pos >= starts[:, None])  # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_quant_reference(
    q: jax.Array,  # (B, Hkv, G, D)
    k_q: jax.Array,  # (B, Hkv, S, Dp) packed payload
    k_scale: jax.Array,  # (B, Hkv, S) f32
    v_q: jax.Array,
    v_scale: jax.Array,
    lengths: jax.Array,
    starts: Optional[jax.Array] = None,
    *,
    kv_dtype: str,
    sm_scale: Optional[float] = None,
) -> jax.Array:
    """Dequantize-then-attend oracle for the fused-dequant decode kernel."""
    from repro.quant.kv_quant import dequantize_kv

    k = dequantize_kv(k_q, k_scale, kv_dtype)
    v = dequantize_kv(v_q, v_scale, kv_dtype)
    return decode_attention_reference(q, k, v, lengths, starts, sm_scale=sm_scale)
