"""Pallas TPU kernel: compute-optimized prefill attention (the prefill RM).

Paper (C3, §3.2.2, Fig. 3b): FlashAttention-style blocked online-softmax with
*reverse scheduling* — for query block i the K/V blocks are visited
j = i, i-1, ..., 0, so the first block processed is the (causally masked)
diagonal and every later block is mask-free.  On the FPGA this balances
pipeline trip counts; here it means exactly one masked block per Q row-block
and the running max m starts at the true row max for typical causal data
(the diagonal carries the largest logits), which stabilizes the exp rescale
chain.  ``schedule="forward"`` is kept for the ablation benchmark.

Tiling: grid (batch, q_heads, S/blk, S/blk) with the last (KV) dim
sequential.  Per step the kernel holds q (blk, d), k (blk, d), v (blk, d)
in VMEM plus f32 scratch m/l (blk, 128) and acc (blk, d) persisting across
the KV walk.  GQA: KV block specs index head h -> h // q_group, so a group
of q heads shares each streamed KV block.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common.compat import tpu_compiler_params

NEG_INF = -1e30


def _prefill_kernel(
    q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *, blk: int, sm_scale: float, reverse: bool
):
    i = pl.program_id(2)  # q block
    t = pl.program_id(3)  # walk step over kv blocks

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Causal: q block i needs kv blocks 0..i -> active for the first i+1 steps.
    @pl.when(t <= i)
    def _step():
        # reverse schedule: step t visits block j = i - t (diagonal first)
        j = i - t if reverse else t
        q = q_ref[...].astype(jnp.float32)[0, 0]  # (blk, d)
        k = k_ref[...].astype(jnp.float32)[0, 0]
        v = v_ref[...].astype(jnp.float32)[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale  # (blk, blk)

        # Only the diagonal block needs the causal mask (bq == bk == blk).
        diag = jnp.equal(j, i)
        rows = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
        s = jnp.where(jnp.logical_or(jnp.logical_not(diag), rows >= cols), s, NEG_INF)

        m_prev = m_ref[...][:, :1]  # (blk, 1)
        l_prev = l_ref[...][:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)  # rmax(L^{(j)})
        m_new = jnp.maximum(m_prev, m_cur)  # Eq. (1) line 1
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # e^{L - m}
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)  # Eq. (1) line 2
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ()))
        )  # Eq. (1) line 3
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(t == i)  # last active step -> write the normalized output
    def _finalize():
        l = l_ref[...][:, :1]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)
        out_ref[...] = out[None, None].astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("blk", "sm_scale", "schedule", "interpret")
)
def prefill_attention_pallas(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,  # (B, Hkv, S, D)
    *,
    blk: int = 256,
    sm_scale: float | None = None,
    schedule: str = "reverse",
    interpret: bool = False,
) -> jax.Array:
    b, h, s, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    assert s % blk == 0, (s, blk)
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    nblk = s // blk
    reverse = schedule == "reverse"

    kernel = functools.partial(_prefill_kernel, blk=blk, sm_scale=sm_scale, reverse=reverse)

    def kv_index(bi, hi, ii, ti):
        ji = ii - ti if reverse else ti
        # clamp: masked-off steps (t > i) still produce an index; the body is
        # skipped by pl.when so the loaded block is unused.
        ji = jnp.clip(ji, 0, nblk - 1)
        return (bi, hi // g, ji, 0)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nblk, nblk),
        in_specs=[
            pl.BlockSpec((1, 1, blk, d), lambda bi, hi, ii, ti: (bi, hi, ii, 0)),
            pl.BlockSpec((1, 1, blk, d), kv_index),
            pl.BlockSpec((1, 1, blk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, blk, d), lambda bi, hi, ii, ti: (bi, hi, ii, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk, 128), jnp.float32),
            pltpu.VMEM((blk, 128), jnp.float32),
            pltpu.VMEM((blk, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
