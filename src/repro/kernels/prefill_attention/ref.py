"""Pure-jnp oracle for causal (optionally windowed) prefill attention."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def prefill_attention_reference(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,
    *,
    sm_scale: Optional[float] = None,
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    b, h, s, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)) * sm_scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qi >= ki
    if window is not None:
        mask &= qi - ki < window
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
