"""Jitted wrapper for the prefill attention kernel (padding + dispatch)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.prefill_attention.kernel import prefill_attention_pallas
from repro.kernels.prefill_attention.ref import prefill_attention_reference

# Aliasing contract, audited by the `program` analysis pass: prefill K/V
# arrive as the prompt's freshly-projected (not yet cache-resident) tensors,
# but the same read-only rule applies — the op never writes or returns its
# K/V operands; installs happen in the donated program-level cache buffers.
CACHE_OPERANDS = {
    "prefill_attention": {"args": ("k", "v"), "writes": False},
}


def prefill_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    blk: int = 256,
    schedule: str = "reverse",
    use_kernel: bool = False,
    interpret: bool = True,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
) -> jax.Array:
    """Causal self-attention over a full prompt, (B,H,S,D) layout.

    use_kernel=False runs the jnp oracle (CPU-fast path used inside jitted
    model code); use_kernel=True runs the Pallas prefill RM (TPU target,
    interpret=True on CPU).  Sliding windows fall back to the oracle — the
    hymba SWA layers are never the prefill bottleneck.
    """
    if not use_kernel or window is not None:
        return prefill_attention_reference(q, k, v, window=window, sm_scale=sm_scale)
    b, h, s, d = q.shape
    blk = min(blk, s)
    pad = (-s) % blk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = prefill_attention_pallas(
        q, k, v, blk=blk, schedule=schedule, interpret=interpret, sm_scale=sm_scale
    )
    return out[:, :, :s] if pad else out
