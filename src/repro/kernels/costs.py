"""Analytic cost models of the Pallas kernels, derived from their BlockSpecs.

The dry-run runs on a CPU host, where ``pallas_call`` cannot compile for the
real target (Mosaic is TPU-only) and the interpret-mode inlining pollutes the
HLO with materialized intermediates the TPU kernel never creates (f32 score
tensors, hoisted dtype converts, loop-feed layout copies).  A Pallas kernel's
dataflow is *fully determined* by its grid + BlockSpecs, so its HBM traffic,
FLOPs and VMEM working set can be written down exactly.  The dry-run lowers
the model with the attention core stubbed (``cfg.attn_impl='stub'``) and adds
these terms — that pair (XLA-generic vs kernel-substituted) is also exactly
the paper's static-baseline vs phase-specialized-RM comparison, measured on
the TPU roofline.

All functions return per-DEVICE costs given the per-device (post-sharding)
shapes the caller derives from the mesh.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class KernelCost:
    flops: float  # MXU flops
    hbm_bytes: float  # HBM<->VMEM DMA traffic
    vmem_bytes: int  # peak VMEM working set (double-buffered tiles + scratch)

    def __add__(self, other: "KernelCost") -> "KernelCost":
        return KernelCost(
            self.flops + other.flops,
            self.hbm_bytes + other.hbm_bytes,
            max(self.vmem_bytes, other.vmem_bytes),
        )


ZERO = KernelCost(0.0, 0.0, 0)


def prefill_attention_cost(
    b: int, h: int, hkv: int, s: int, d: int, *, blk: int = 256, elt: int = 2,
    causal: bool = True, window: int | None = None, skv: int | None = None,
) -> KernelCost:
    """Reverse-scheduled causal flash attention (kernels/prefill_attention).

    Grid (b, h, nblk_q, nblk_kv) with the KV walk innermost; per (q-head,
    q-block i) the kernel streams K/V blocks j<=i (causal): K/V HBM traffic
    is sum_i (i+1) * blk = nblk(nblk+1)/2 * blk elements per head — each q
    head re-streams its group's KV (VMEM cannot hold S*D at 32k).  A sliding
    window caps the walk at ceil(window/blk)+1 blocks.  ``skv`` covers the
    rectangular cross-attention case (q length s against skv keys).
    """
    skv = s if skv is None else skv
    nblk = max(s // blk, 1)
    nblk_kv = max(skv // blk, 1)
    if causal and skv == s:
        blocks_per_q = (nblk + 1) / 2  # average of i+1
    else:
        blocks_per_q = nblk_kv
    if window is not None and window < skv:
        blocks_per_q = min(blocks_per_q, window / blk + 1)
    kv_elems_streamed = b * h * nblk * blocks_per_q * blk * d * 2  # K and V
    q_o_elems = 2 * b * h * s * d
    # score + PV matmuls: 2 * (blk x d x blk) each per (q-block, kv-block) pair
    flops = b * h * nblk * blocks_per_q * (2 * blk * blk * d) * 2
    vmem = (
        2 * blk * d * elt  # q tile
        + 2 * (2 * blk * d * elt)  # double-buffered k, v streams
        + 2 * (blk * 128 * 4)  # m, l scratch
        + blk * d * 4  # acc
        + blk * blk * 4  # score tile
    )
    return KernelCost(flops, (kv_elems_streamed + q_o_elems) * elt, vmem)


def decode_attention_cost(
    b: int, h: int, hkv: int, s: int, d: int, *, bk: int = 512, elt: int = 2,
    window: int | None = None,
) -> KernelCost:
    """KV-streaming flash-decode (kernels/decode_attention).

    Grid (b, hkv, s/bk): K and V are read ONCE per kv-head group (the
    2xK+2xV port-remap analogue — all G query heads of a group ride one KV
    stream); Q/O/stats are O(b*h*d).  A sliding window skips dead blocks.
    """
    eff_s = min(window, s) if window is not None else s
    kv_bytes = b * hkv * eff_s * d * 2 * elt
    qo_bytes = (2 * b * h * d + 2 * b * h * 128) * 4
    g = max(h // hkv, 1)
    flops = b * hkv * eff_s * (2 * g * d) * 2  # QK^T + PV
    vmem = (
        2 * g * d * elt  # pinned q group
        + 2 * (2 * bk * d * elt)  # double-buffered k and v streams (2 DMAs)
        + 2 * (g * 128 * 4)  # m, l
        + g * d * 4  # acc
        + g * bk * 4  # score tile
    )
    return KernelCost(flops, kv_bytes + qo_bytes, vmem)


def mlstm_chunk_cost(b: int, h: int, s: int, hd: int, *, chunk: int = 64, elt: int = 2) -> KernelCost:
    """Chunkwise-parallel mLSTM kernel (the xlstm prefill RM; [§Perf X2]).

    Flash-linear-attention dataflow: grid (b, h, s/chunk) sequential over
    chunks; q/k/v chunk tiles stream HBM->VMEM, the (hd, hd) matrix memory
    and (hd,) normalizer stay VMEM-resident across the walk, h streams out.
    Per chunk: qk (c x c x hd), inner-weighted PV (c x c x hd), state update
    (c x hd x hd) and query-state (c x hd x hd) contractions."""
    nc = max(s // chunk, 1)
    io = 4 * b * h * s * hd * elt  # q, k, v in; h out
    flops = b * h * nc * (4 * chunk * chunk * hd + 4 * chunk * hd * hd)
    vmem = (
        hd * hd * 4 + 2 * hd * 4  # resident state c, n (+m)
        + 3 * 2 * chunk * hd * elt  # double-buffered q/k/v tiles
        + chunk * chunk * 4  # decay/score tile
        + chunk * hd * 4  # h accumulator
    )
    return KernelCost(flops, float(io), vmem)


def slstm_scan_cost(b: int, s: int, d: int, h: int, *, elt: int = 2) -> KernelCost:
    """Sequential sLSTM kernel: reads the (B,S,4d) pre-activations once,
    carries the per-head recurrent state in VMEM, writes (B,S,d) h out.
    Recurrence flops: R h (hd x 4hd per head) + gate elementwise."""
    hd = d // h
    io = b * s * (4 * d + d) * elt
    flops = b * s * h * (2 * hd * 4 * hd) + 10.0 * b * s * d
    vmem = h * hd * 4 * 4 + 4 * d * elt * 2 + h * hd * 4 * hd * elt
    return KernelCost(flops, float(io), vmem)


def tlmm_cost(m: int, k: int, n: int, *, bm: int = 128, bn: int = 128, bk: int = 512) -> KernelCost:
    """Ternary table-lookup matmul (kernels/tlmm): x int8 (m,k) @ w 2-bit
    (k,n).  Weights stream at 0.25 B/weight; x re-streams once per N tile
    (grid (m/bm, n/bn, k/bk), K innermost)."""
    n_tiles_n = max(n // bn, 1)
    x_bytes = m * k * n_tiles_n  # int8, re-read per n tile
    w_bytes = (k // 4) * n  # packed 2-bit, read once per m sweep
    m_tiles = max(m // bm, 1)
    w_bytes *= m_tiles  # re-read per m tile
    out_bytes = m * n * 2 + m * 4
    flops = 2.0 * m * k * n
    vmem = 2 * (bm * bk) + 2 * (bk // 4 * bn) + 4 * bm * bn + bm * bn * 2
    return KernelCost(flops, float(x_bytes + w_bytes + out_bytes), vmem)
