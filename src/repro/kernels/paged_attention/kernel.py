"""Pallas TPU kernel: paged decode attention (decode RM over a block pool).

Same dataflow as ``repro.kernels.decode_attention.kernel`` — pinned Q tile,
two independent K/V HBM->VMEM streams, single output writeback after the KV
walk — but the cache walked is a *page pool* ``(num_blocks, Hkv, bs, D)``
instead of a dense per-sequence buffer.  The per-sequence block table is a
scalar-prefetch operand, so the K/V BlockSpec index maps resolve
``pages[table[b, t]]`` *before* each grid step's DMA is issued: the kernel
streams exactly the pages a sequence owns, in table order, and never touches
the rest of the pool.

Pages past a sequence's length are skipped entirely (``pl.when`` guard —
their table entries are 0/garbage and their DMA result is never read), which
is what makes ragged continuous batching pay O(actual length), not
O(max_len), in both bandwidth and pool capacity — the paper's Eq. (5)
decode bound with ``context = actual`` rather than ``context = max``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common.compat import tpu_compiler_params
from repro.kernels.decode_attention.kernel import _dequant_tile

NEG_INF = -1e30


def _paged_decode_kernel(
    tables_ref,  # scalar-prefetch: (B, P) int32 — per-sequence page table
    start_ref,  # scalar-prefetch: (B,) int32 — window start (0 for full attn)
    len_ref,  # scalar-prefetch: (B,) int32
    q_ref,  # (1, 1, G, D)
    k_ref,  # (1, 1, bs, D) — page tables_ref[b, t] of this (layer-sliced) pool
    v_ref,  # (1, 1, bs, D)
    out_ref,  # (1, 1, G, D)
    out_l_ref,  # (1, 1, G, 128) — softmax denominator (stats output)
    out_m_ref,  # (1, 1, G, 128) — running max (stats output)
    m_ref,
    l_ref,
    acc_ref,
    *,
    bs: int,
    n_pages: int,
    sm_scale: float,
):
    b = pl.program_id(0)
    t = pl.program_id(2)
    length = len_ref[b]
    start = start_ref[b]

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Pages wholly outside [start, length) are unallocated (or dead window
    # prefix): their table entries are meaningless and their block is never
    # read — the walk skips them.
    @pl.when(jnp.logical_and(t * bs < length, (t + 1) * bs > start))
    def _step():
        q = q_ref[...].astype(jnp.float32)[0, 0]  # (G, D)
        k = k_ref[...].astype(jnp.float32)[0, 0]  # (bs, D)
        v = v_ref[...].astype(jnp.float32)[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale  # (G, bs)
        pos = t * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        s = jnp.where(jnp.logical_and(pos >= start, pos < length), s, NEG_INF)

        m_prev = m_ref[...][:, :1]
        l_prev = l_ref[...][:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = jnp.broadcast_to(alpha * l_prev + jnp.sum(p, axis=1, keepdims=True), l_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ()))
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(t == n_pages - 1)  # single writeback after the page walk
    def _finalize():
        l = l_ref[...][:, :1]
        out_ref[...] = (acc_ref[...] / jnp.maximum(l, 1e-30))[None, None].astype(out_ref.dtype)
        out_l_ref[...] = l_ref[...][None, None]
        out_m_ref[...] = m_ref[...][None, None]


@functools.partial(jax.jit, static_argnames=("sm_scale", "interpret"))
def paged_decode_attention_pallas(
    q: jax.Array,  # (B, Hkv, G, D) — query heads grouped by KV head
    k_pages: jax.Array,  # (N, Hkv, bs, D) — one layer's page pool
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, P) int32 — page ids per sequence
    lengths: jax.Array,  # (B,) int32 — per-sequence valid cache length
    starts: jax.Array | None = None,  # (B,) int32 — window start (default 0)
    *,
    sm_scale: float | None = None,
    interpret: bool = False,
):
    b, hkv, g, d = q.shape
    n, hkv_p, bs, d_p = k_pages.shape
    assert (hkv_p, d_p) == (hkv, d), (k_pages.shape, q.shape)
    n_pages = block_tables.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)

    if starts is None:
        starts = jnp.zeros_like(lengths)
    kernel = functools.partial(_paged_decode_kernel, bs=bs, n_pages=n_pages, sm_scale=sm_scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, n_pages),
        # K/V index maps dereference the prefetched block table: grid step
        # (bi, hi, ti) DMAs page tables[bi, ti] of head hi's pool slice.
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, ti, tbl, *_: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda bi, hi, ti, tbl, *_: (tbl[bi, ti], hi, 0, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda bi, hi, ti, tbl, *_: (tbl[bi, ti], hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, ti, *_: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 128), lambda bi, hi, ti, *_: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 128), lambda bi, hi, ti, *_: (bi, hi, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out, out_l, out_m = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),  # normalized out
            jax.ShapeDtypeStruct((b, hkv, g, 128), jnp.float32),  # l
            jax.ShapeDtypeStruct((b, hkv, g, 128), jnp.float32),  # m
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(
        jnp.clip(block_tables, 0, n - 1).astype(jnp.int32),
        starts.astype(jnp.int32),
        lengths.astype(jnp.int32),
        q,
        k_pages,
        v_pages,
    )
    return out, out_l, out_m


def _paged_decode_quant_kernel(
    tables_ref,  # scalar-prefetch: (B, P) int32
    start_ref,  # scalar-prefetch: (B,) int32
    len_ref,  # scalar-prefetch: (B,) int32
    q_ref,  # (1, 1, G, D)
    kq_ref,  # (1, 1, bs, Dp) packed payload of page tables_ref[b, t]
    ks_ref,  # (1, 1, bs) f32 scale rows of the same page
    vq_ref,  # (1, 1, bs, Dp)
    vs_ref,  # (1, 1, bs)
    out_ref,  # (1, 1, G, D)
    out_l_ref,
    out_m_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    bs: int,
    n_pages: int,
    sm_scale: float,
    kv_dtype: str,
):
    """Fused-dequant paged decode: the same block-table walk as
    ``_paged_decode_kernel``, but each grid step DMAs the page's *packed*
    payload (1/2 or 1/4 of the fp bytes) plus its fp32 scale plane, and the
    fp page exists only as the VMEM tile feeding the dot — decode reads
    packed pages directly, never materializing an fp cache in HBM."""
    b = pl.program_id(0)
    t = pl.program_id(2)
    length = len_ref[b]
    start = start_ref[b]

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(jnp.logical_and(t * bs < length, (t + 1) * bs > start))
    def _step():
        q = q_ref[...].astype(jnp.float32)[0, 0]  # (G, D)
        k = _dequant_tile(kq_ref[...][0, 0], ks_ref[...][0, 0], kv_dtype)  # (bs, D)
        v = _dequant_tile(vq_ref[...][0, 0], vs_ref[...][0, 0], kv_dtype)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale  # (G, bs)
        pos = t * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        s = jnp.where(jnp.logical_and(pos >= start, pos < length), s, NEG_INF)

        m_prev = m_ref[...][:, :1]
        l_prev = l_ref[...][:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = jnp.broadcast_to(alpha * l_prev + jnp.sum(p, axis=1, keepdims=True), l_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ()))
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(t == n_pages - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        out_ref[...] = (acc_ref[...] / jnp.maximum(l, 1e-30))[None, None].astype(out_ref.dtype)
        out_l_ref[...] = l_ref[...][None, None]
        out_m_ref[...] = m_ref[...][None, None]


@functools.partial(jax.jit, static_argnames=("sm_scale", "kv_dtype", "interpret"))
def paged_decode_attention_quant_pallas(
    q: jax.Array,  # (B, Hkv, G, D)
    k_pages_q: jax.Array,  # (N, Hkv, bs, Dp) packed payload pool (one layer)
    k_scales: jax.Array,  # (N, Hkv, bs) f32 scale planes
    v_pages_q: jax.Array,
    v_scales: jax.Array,
    block_tables: jax.Array,  # (B, P) int32
    lengths: jax.Array,  # (B,) int32
    starts: jax.Array | None = None,
    *,
    kv_dtype: str,
    sm_scale: float | None = None,
    interpret: bool = False,
):
    """Fused-dequant variant of ``paged_decode_attention_pallas``: walks the
    block table over the *packed* page pool."""
    b, hkv, g, d = q.shape
    n, hkv_p, bs, dp = k_pages_q.shape
    assert hkv_p == hkv, (k_pages_q.shape, q.shape)
    n_pages = block_tables.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)

    if starts is None:
        starts = jnp.zeros_like(lengths)
    kernel = functools.partial(
        _paged_decode_quant_kernel, bs=bs, n_pages=n_pages, sm_scale=sm_scale, kv_dtype=kv_dtype
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, ti, tbl, *_: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, bs, dp), lambda bi, hi, ti, tbl, *_: (tbl[bi, ti], hi, 0, 0)),
            pl.BlockSpec((1, 1, bs), lambda bi, hi, ti, tbl, *_: (tbl[bi, ti], hi, 0)),
            pl.BlockSpec((1, 1, bs, dp), lambda bi, hi, ti, tbl, *_: (tbl[bi, ti], hi, 0, 0)),
            pl.BlockSpec((1, 1, bs), lambda bi, hi, ti, tbl, *_: (tbl[bi, ti], hi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, ti, *_: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 128), lambda bi, hi, ti, *_: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 128), lambda bi, hi, ti, *_: (bi, hi, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out, out_l, out_m = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, 128), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, 128), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(
        jnp.clip(block_tables, 0, n - 1).astype(jnp.int32),
        starts.astype(jnp.int32),
        lengths.astype(jnp.int32),
        q,
        k_pages_q,
        k_scales,
        v_pages_q,
        v_scales,
    )
    return out, out_l, out_m
