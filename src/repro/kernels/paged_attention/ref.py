"""Pure-jnp oracle for paged single-token decode attention.

Gathers each sequence's pages into a dense (B, Hkv, P*bs, D) view via its
block table, then runs exactly the masked-softmax math of
``repro.kernels.decode_attention.ref`` — token position ``p`` of sequence
``b`` lives at gathered index ``p`` because page ``i`` of the table covers
positions ``[i*bs, (i+1)*bs)``.  The gather materializes a full per-slot
cache (O(B * P * bs) bytes); the Pallas kernel exists to avoid that.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def gather_pages(pages: jax.Array, block_tables: jax.Array) -> jax.Array:
    """(N, Hkv, bs, D) pages + (B, P) tables -> dense (B, Hkv, P*bs, D)."""
    b, p = block_tables.shape
    n, hkv, bs, d = pages.shape
    g = pages[block_tables]  # (B, P, Hkv, bs, D)
    return jnp.moveaxis(g, 2, 1).reshape(b, hkv, p * bs, d)


def paged_decode_attention_reference(
    q: jax.Array,  # (B, Hkv, G, D)
    k_pages: jax.Array,  # (N, Hkv, bs, D) — one layer's page pool
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, P) int32
    lengths: jax.Array,  # (B,) int32 valid cache length per sequence
    starts: Optional[jax.Array] = None,  # (B,) int32 window start
    *,
    sm_scale: Optional[float] = None,
) -> jax.Array:
    b, hkv, g, d = q.shape
    k = gather_pages(k_pages, block_tables)
    v = gather_pages(v_pages, block_tables)
    s = k.shape[2]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    if starts is None:
        starts = jnp.zeros_like(lengths)
    scores = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32), k.astype(jnp.float32)) * sm_scale
    pos = jnp.arange(s)[None, :]
    mask = (pos < lengths[:, None]) & (pos >= starts[:, None])  # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_decode_attention_quant_reference(
    q: jax.Array,  # (B, Hkv, G, D)
    k_pages_q: jax.Array,  # (N, Hkv, bs, Dp) packed payload pool
    k_scales: jax.Array,  # (N, Hkv, bs) f32 scale planes
    v_pages_q: jax.Array,
    v_scales: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    starts: Optional[jax.Array] = None,
    *,
    kv_dtype: str,
    sm_scale: Optional[float] = None,
) -> jax.Array:
    """Dequantize-the-pool-then-attend oracle for the fused-dequant paged
    kernel (materializes the fp pool; the kernel never does)."""
    from repro.quant.kv_quant import dequantize_kv

    k_pages = dequantize_kv(k_pages_q, k_scales, kv_dtype)
    v_pages = dequantize_kv(v_pages_q, v_scales, kv_dtype)
    return paged_decode_attention_reference(
        q, k_pages, v_pages, block_tables, lengths, starts, sm_scale=sm_scale
    )
