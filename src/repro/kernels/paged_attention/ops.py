"""Jitted wrapper for the paged decode attention kernel.

Accepts flat (B, H, D) queries, regroups to (B, Hkv, G, D), and dispatches
kernel vs the compiled jnp path.  Mirrors ``decode_attention.ops``:

``_paged_attention_streaming`` is the kernel-shaped jnp path — K/V pages
stay in their storage dtype and the dots accumulate in f32 via
``preferred_element_type``.  It gathers each sequence's pages into a dense
view first, so its HBM traffic is O(B * P * bs) like the contiguous
engine's; the Pallas kernel is the one that walks the block table directly
(scalar prefetch) and skips unallocated pages.  Because page ``i`` covers
positions ``[i*bs, (i+1)*bs)``, the gathered view places every valid token
at the same index the contiguous cache would — the two layouts are
numerically *identical* under the same mask, which the serving tests
exploit (paged vs contiguous token-for-token parity).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ops import _decode_attention_streaming
from repro.kernels.paged_attention.kernel import (
    paged_decode_attention_pallas,
    paged_decode_attention_quant_pallas,
)
from repro.kernels.paged_attention.ref import gather_pages
from repro.quant.kv_quant import dequantize_kv

# Aliasing contract, audited by the `program` analysis pass: the page pool
# (and its scale planes) alias the persistent paged KV storage; the ops
# gather/stream but never write or return the pool — page installs happen in
# the donated program-level pool buffers (page_write / chunk programs).
CACHE_OPERANDS = {
    "paged_decode_attention": {"args": ("k_pages", "v_pages"), "writes": False},
    "gather_scales": {"args": ("scales",), "writes": False},
}


def gather_scales(scales: jax.Array, block_tables: jax.Array) -> jax.Array:
    """(N, Hkv, bs) scale planes + (B, P) tables -> dense (B, Hkv, P*bs)."""
    b, p = block_tables.shape
    n, hkv, bs = scales.shape
    g = scales[block_tables]  # (B, P, Hkv, bs)
    return jnp.moveaxis(g, 2, 1).reshape(b, hkv, p * bs)


def _paged_attention_streaming(
    q: jax.Array,  # (B, Hkv, G, D)
    k_pages: jax.Array,  # (N, Hkv, bs, D) — storage dtype, never upcast
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, P) int32
    lengths: jax.Array,
    starts: Optional[jax.Array],
    *,
    sm_scale: Optional[float] = None,
    return_stats: bool = False,
):
    # Gather the pages dense, then delegate to the contiguous streaming path
    # — ONE implementation of the masked-softmax/stats math, so the engine's
    # paged-vs-contiguous token parity cannot drift.
    k = gather_pages(k_pages, block_tables)  # (B, Hkv, P*bs, D)
    v = gather_pages(v_pages, block_tables)
    return _decode_attention_streaming(
        q, k, v, lengths, starts, sm_scale=sm_scale, return_stats=return_stats
    )


def paged_decode_attention(
    q: jax.Array,  # (B, H, D)
    k_pages: jax.Array,  # (N, Hkv, bs, D) — packed (N, Hkv, bs, Dp) when quantized
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, P) int32
    lengths: jax.Array,  # (B,) int32
    starts: Optional[jax.Array] = None,  # (B,) int32 — sliding-window start
    *,
    use_kernel: bool = False,
    interpret: bool = True,
    sm_scale: Optional[float] = None,
    return_stats: bool = False,
    k_scales: Optional[jax.Array] = None,  # (N, Hkv, bs) f32 — quantized pool
    v_scales: Optional[jax.Array] = None,
    kv_dtype: str = "fp",
):
    """Attention of one query token per sequence over its paged KV.

    ``kv_dtype`` in {"int8", "int4"} (with ``k_scales``/``v_scales``) walks a
    *quantized* page pool: the kernel path DMAs packed pages and fuses
    dequant into the walk; the jnp path gathers the packed pages (cheap —
    1/2 or 1/4 the bytes of an fp gather), dequantizes the dense view, and
    delegates to the shared streaming math.

    ``return_stats=True`` additionally returns the online-softmax stats
    (l, m) of shape (B, H, 1) — in f32, with the output UN-astype'd — so the
    caller can merge further blocks (the freshly-projected token)."""
    b, h, d = q.shape
    hkv = k_pages.shape[1]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    if kv_dtype != "fp":
        assert k_scales is not None and v_scales is not None, "quantized pool needs scales"
        if use_kernel:
            out, l, m = paged_decode_attention_quant_pallas(
                qg, k_pages, k_scales, v_pages, v_scales,
                block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
                None if starts is None else starts.astype(jnp.int32),
                kv_dtype=kv_dtype, interpret=interpret, sm_scale=sm_scale,
            )
            if return_stats:
                return (out.reshape(b, h, d),
                        l[:, :, :, :1].reshape(b, h, 1), m[:, :, :, :1].reshape(b, h, 1))
            return out.reshape(b, h, d).astype(q.dtype)
        k = dequantize_kv(gather_pages(k_pages, block_tables),
                          gather_scales(k_scales, block_tables), kv_dtype)
        v = dequantize_kv(gather_pages(v_pages, block_tables),
                          gather_scales(v_scales, block_tables), kv_dtype)
        ret = _decode_attention_streaming(
            qg, k, v, lengths, starts, sm_scale=sm_scale, return_stats=return_stats
        )
        if return_stats:
            out, l, m = ret
            return out.reshape(b, h, d), l.reshape(b, h, 1), m.reshape(b, h, 1)
        return ret.reshape(b, h, d)
    if not use_kernel:
        if return_stats:
            out, l, m = _paged_attention_streaming(
                qg, k_pages, v_pages, block_tables, lengths, starts,
                sm_scale=sm_scale, return_stats=True,
            )
            return out.reshape(b, h, d), l.reshape(b, h, 1), m.reshape(b, h, 1)
        out = _paged_attention_streaming(
            qg, k_pages, v_pages, block_tables, lengths, starts, sm_scale=sm_scale
        )
        return out.reshape(b, h, d)
    out, l, m = paged_decode_attention_pallas(
        qg, k_pages, v_pages, block_tables.astype(jnp.int32),
        lengths.astype(jnp.int32),
        None if starts is None else starts.astype(jnp.int32),
        interpret=interpret, sm_scale=sm_scale,
    )
    if return_stats:
        return (out.reshape(b, h, d),
                l[:, :, :, :1].reshape(b, h, 1), m[:, :, :, :1].reshape(b, h, 1))
    return out.reshape(b, h, d).astype(q.dtype)
