"""Jitted user-facing wrapper for the TLMM kernel.

``tlmm_matmul`` is what :class:`repro.layers.linear.TernaryLinear` calls: it
quantizes activations per-token to int8 (A8), folds the BitNet weight scale
into the per-row activation scale, pads M to the sublane tile, and dispatches
to the Pallas kernel (interpret=True on CPU) or the jnp reference (the
default under jit on CPU — identical numerics, faster to compile; the Pallas
path is exercised by the kernel tests and is the TPU target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.quant.act_quant import quantize_activations_int8
from repro.quant.ternary import TernaryWeight
from repro.kernels.tlmm.kernel import tlmm_pallas
from repro.kernels.tlmm.ref import tlmm_reference

# Aliasing contract, audited by the `program` analysis pass: the packed
# ternary weight is a persistent (resident) buffer the op streams but never
# writes or returns.
CACHE_OPERANDS = {
    "tlmm_matmul": {"args": ("w",), "writes": False},
}


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def tlmm_matmul(
    x: jax.Array,  # (..., K) float
    w: TernaryWeight,
    *,
    out_dtype=jnp.bfloat16,
    use_kernel: bool = False,
    interpret: bool = True,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
) -> jax.Array:
    """y = (quantize_int8(x) @ unpack(w)) * act_scale * w_scale."""
    *lead, k = x.shape
    n = w.n
    x2 = x.reshape(-1, k)
    m = x2.shape[0]

    x_q, act_scale = quantize_activations_int8(x2)
    scale = act_scale * w.scale  # (M, 1) f32 — weight absmean folded in

    if not use_kernel:
        y = tlmm_reference(x_q, w.packed, scale, out_dtype=out_dtype)
        return y.reshape(*lead, n)

    bm = min(block_m, _round_up(m, 8))
    bn = min(block_n, n)
    bk = min(block_k, k)
    while n % bn:
        bn //= 2
    while k % bk or bk % 4:
        bk //= 2
    mp = _round_up(m, bm)
    if mp != m:
        x_q = jnp.pad(x_q, ((0, mp - m), (0, 0)))
        scale = jnp.pad(scale, ((0, mp - m), (0, 0)))
    y = tlmm_pallas(
        x_q, w.packed, scale, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype, interpret=interpret
    )[:m]
    return y.reshape(*lead, n)
