"""Pure-jnp oracles for the TLMM kernel.

Two references:

* ``tlmm_reference`` — unpack + int32 matmul; numerically *exact* integer
  arithmetic, the ground truth the Pallas kernel must match bit-for-bit
  (before the final float scale).
* ``tlmm_lut_reference`` — the paper's actual FPGA algorithm (C2): group
  activations in groups of 4, precompute the 3^4 = 81 add/sub combinations
  of each group, re-encode each weight group as a base-3 index, and gather.
  Exactly equal to the direct matmul in integer arithmetic; kept as the
  algorithmic fidelity witness (property-tested in tests/test_tlmm.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.ternary import unpack_ternary

TL_GROUP = 4
_POW3 = 3 ** np.arange(TL_GROUP)  # [1, 3, 9, 27]


def tlmm_reference(x_q: jax.Array, w_packed: jax.Array, scale: jax.Array, out_dtype=jnp.bfloat16) -> jax.Array:
    """(M,K) int8 @ unpack(w_packed) -> (M,N), scaled per-row."""
    w = unpack_ternary(w_packed)  # (K, N) int8
    acc = jax.lax.dot_general(
        x_q, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    return (acc.astype(jnp.float32) * scale).astype(out_dtype)


def _ternary_group_codes(w_q: np.ndarray) -> np.ndarray:
    """int8 ternary (K, N) -> base-3 group codes (K//4, N) in [0, 81)."""
    k, n = w_q.shape
    digits = (w_q.astype(np.int32) + 1).reshape(k // TL_GROUP, TL_GROUP, n)  # {-1,0,1}->{0,1,2}
    return np.einsum("gin,i->gn", digits, _POW3).astype(np.int32)


def _group_lut(x_group: np.ndarray) -> np.ndarray:
    """All 81 ternary combinations of a 4-activation group.

    x_group: (4,) int32 -> lut (81,) int32 with
    lut[code] = sum_i (digit_i(code) - 1) * x[i].
    This is the table the FPGA precomputes once per group per token and then
    indexes with URAM-resident weight codes.
    """
    codes = np.arange(3**TL_GROUP)
    digits = (codes[:, None] // _POW3[None, :]) % 3 - 1  # (81, 4) in {-1,0,1}
    return digits @ x_group.astype(np.int64)


def tlmm_lut_reference(x_q, w_packed, scale, out_dtype=jnp.bfloat16):
    """The paper's index->lookup->accumulate algorithm, bit-exact vs matmul."""
    x = np.asarray(x_q, dtype=np.int32)  # (M, K)
    w = np.asarray(unpack_ternary(w_packed), dtype=np.int8)  # (K, N)
    m, k = x.shape
    n = w.shape[1]
    codes = _ternary_group_codes(w)  # (K//4, N)
    out = np.zeros((m, n), dtype=np.int64)
    for row in range(m):
        xg = x[row].reshape(k // TL_GROUP, TL_GROUP)
        # one 81-entry table per activation group (precomputed add/sub sums)
        luts = np.stack([_group_lut(g) for g in xg])  # (K//4, 81)
        # index–lookup–accumulate: weights are indices into the tables
        out[row] = np.take_along_axis(luts, codes, axis=1).sum(axis=0)
    res = out.astype(np.float32) * np.asarray(scale, dtype=np.float32)
    return jnp.asarray(res).astype(out_dtype)
