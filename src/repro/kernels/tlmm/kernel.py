"""Pallas TPU kernel: ternary table-lookup matmul (TLMM), adapted to the MXU.

Paper (C2, §3.2.2): ternary weights live on-chip as base-3 group indices; a
per-activation-group lookup table of precomputed add/sub partial sums turns
matmul into index->lookup->accumulate, eliminating DDR weight streaming.

TPU adaptation (DESIGN.md §2): the *memory-system* property is what matters —
1.58-bit weights resident in fast memory so the linear layers stop being
weight-bandwidth-bound.  Here the packed 2-bit weights (uint8, 4 weights/byte,
see repro.quant.ternary) are streamed HBM->VMEM at 0.25 B/weight, decoded to
int8 *inside* the kernel, and multiplied on the MXU (int8 x int8 -> int32),
which is the roofline-correct compute engine on TPU — a LUT-gather
realization would run on the VPU at ~1/50th the throughput.  The faithful
LUT algorithm is kept as an oracle in ref.py (tlmm_lut_reference) and the
property tests assert all three agree exactly in integer arithmetic.

VMEM tiling: grid (M/bm, N/bn, K/bk); per step the kernel holds
  x tile   (bm, bk)   int8
  w tile   (bk/4, bn) uint8   <- 4x smaller than an int8 weight tile
  acc      (bm, bn)   int32 scratch (persistent across the K dimension)
K is the innermost, sequential ("arbitrary") grid dim; M/N are parallel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common.compat import tpu_compiler_params


def _decode_ternary_tile(wp: jax.Array) -> jax.Array:
    """uint8 (bk/4, bn) -> int8 (bk, bn) inside the kernel.

    Value k = 4j + i sits in bits [2i, 2i+2) of byte j (codes 0/+1/-1 =
    0b00/0b01/0b10).  The stack+reshape is a sublane interleave; an
    alternative that avoids it is four strided dots
    acc += sum_i dot(x[:, i::4], part_i) — measured equivalent in interpret
    mode, kept simple here.
    """
    parts = []
    for i in range(4):
        bits = (wp >> (2 * i)) & 0x3
        val = jnp.where(bits == 1, jnp.int8(1), jnp.where(bits == 2, jnp.int8(-1), jnp.int8(0)))
        parts.append(val)
    kq, bn = wp.shape
    return jnp.stack(parts, axis=1).reshape(kq * 4, bn)


def _tlmm_kernel(x_ref, wp_ref, scale_ref, out_ref, acc_ref, *, n_k_steps: int, out_dtype):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]  # (bm, bk) int8
    w = _decode_ternary_tile(wp_ref[...])  # (bk, bn) int8
    acc_ref[...] += jax.lax.dot_general(
        x,
        w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k_step == n_k_steps - 1)
    def _finalize():
        # scale_ref: (bm, 1) f32 = act_scale * weight_scale (folded in ops.py)
        out_ref[...] = (acc_ref[...].astype(jnp.float32) * scale_ref[...]).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"),
)
def tlmm_pallas(
    x_q: jax.Array,  # (M, K) int8
    w_packed: jax.Array,  # (K//4, N) uint8
    scale: jax.Array,  # (M, 1) f32 — combined act*weight scale
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
) -> jax.Array:
    m, k = x_q.shape
    kq, n = w_packed.shape
    assert kq * 4 == k, (k, kq)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    assert bk % 4 == 0
    n_k_steps = k // bk

    grid = (m // bm, n // bn, n_k_steps)
    kernel = functools.partial(_tlmm_kernel, n_k_steps=n_k_steps, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk // 4, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((bm, 1), lambda i, j, s: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_q, w_packed, scale)
