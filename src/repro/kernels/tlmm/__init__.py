from repro.kernels.tlmm.ops import tlmm_matmul
from repro.kernels.tlmm.kernel import tlmm_pallas
from repro.kernels.tlmm.ref import tlmm_reference, tlmm_lut_reference
