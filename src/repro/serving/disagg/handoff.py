"""The KV handoff channel: prefill-pool KV into the decode pool's sharding.

This is the disaggregated analogue of the paper's bitstream load: where the
temporal engine pays a relayout "swap" to flip one fabric between phases, the
two-pool engine pays a cross-pool KV transfer.  ``ship()`` moves one finished
KV segment — a monolithic prompt's relayed (possibly quantized
payload+scales) pytree, or one chunk's fp KV — onto the decode mesh via
``core.disagg.kv_transfer_program`` (a ``device_put`` resharding; on real
hardware XLA emits the DCN collective, on forced host meshes a host copy).
Dispatch is asynchronous, so chunks shipped EAGERLY as prefill progresses
overlap their transfer with the remaining prefill compute — the same
"reconfiguration latency hidden by computation" trick as the temporal swap.

The channel also owns the decode-side install queue.  Installing a segment
means scattering it into the decode pool's cache, and because an XLA cache
buffer is one value, any install makes the NEXT decode round's execution
depend on that segment's whole producer chain (prefill compute + transfer).
Deferring installs until the request actually joins the decode set keeps
in-between decode rounds free of cross-pool dependencies — the interference
elimination the disagg benchmark measures — while leaving the installed
bytes (and therefore the emitted tokens) exactly what the colocated engine's
fused install order produces: a request's pages/rows are exclusively its own
until its first token is sampled, so its installs commute with other slots'
decode writes.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.disagg import kv_transfer_program
from repro.obs.trace import TRACER

# Trace lane for cross-pool transfers: ship dispatches happen on whichever
# thread runs the prefill (the pool's dispatch thread for chunks, the
# engine thread for monolithic swaps), but they are one logical resource —
# pinning the lane renders every transfer on a single track, visually
# interleaved with the engine-step and prefill-pool thread lanes.
TRACE_LANE = "kv-handoff"


class KVHandoffChannel:
    """Cross-pool KV transfer + deferred decode-side installs.

    Threading: ``ship()`` runs on BOTH the engine-step thread (monolithic
    swaps) and the prefill pool's dispatch thread (eager chunks), so its
    metering counters are lock-protected.  The install queue is engine-step
    state only — ``defer_install``/``drain``/``discard`` all run between
    quanta on the engine thread — and is annotated (and statically checked,
    see ``repro.analysis``) as such.
    """

    def __init__(self, decode_mesh: Optional[Mesh] = None,
                 spec: Optional[P] = None):
        self.decode_mesh = decode_mesh
        # default: replicate over the decode pool (rank-agnostic, correct
        # for every payload/scale rank); callers with a wide decode mesh
        # can pin a sharded spec instead
        self.spec = P() if spec is None else spec
        self._transfer = (kv_transfer_program(decode_mesh, self.spec)
                          if decode_mesh is not None else None)
        # (slot, install thunk) queue — install order is ship order, and a
        # preempted/aborted slot's segments are discarded before its pages
        # can be reused (DisaggRunner.release)
        self._pending: List[Tuple[int, Callable[[], None]]] = []  # owned-by: engine-step
        # ship() metering: incremented from the engine thread (monolithic
        # swaps) AND the prefill-pool dispatch thread (eager chunks) — the
        # unsynchronized += these started as dropped increments under load
        self._lock = threading.Lock()
        self.segments = 0  # guarded-by: self._lock
        self.eager_segments = 0  # guarded-by: self._lock
        self.bytes_shipped = 0  # guarded-by: self._lock
        self.installs = 0  # guarded-by: self._lock
        self.discarded = 0  # guarded-by: self._lock
        self.t_dispatch = 0.0  # guarded-by: self._lock

    # ------------------------------------------------------------ transfer --

    def ship(self, kv, *, eager: bool = False):
        """Move one KV segment onto the decode mesh (no mesh: same-device
        passthrough, still metered).  Returns the decode-resident pytree;
        the dispatch is async, so an ``eager`` mid-prefill chunk's transfer
        overlaps the chunks still computing on the prefill pool."""
        t0 = time.perf_counter()
        if self._transfer is not None:
            kv = self._transfer(kv)
        t1 = time.perf_counter()
        nbytes = sum(x.nbytes for x in jax.tree.leaves(kv))
        with self._lock:
            self.t_dispatch += t1 - t0
            self.segments += 1
            if eager:
                self.eager_segments += 1
            self.bytes_shipped += nbytes
        if TRACER.enabled:
            TRACER.complete("handoff.ship", t0, t1, lane=TRACE_LANE,
                            bytes=nbytes, eager=eager)
        return kv

    def ship_aux(self, tree):
        """Move a small non-KV pytree (the prompt's first-token logits)
        across the boundary without counting it as a KV segment."""
        if self._transfer is not None:
            tree = self._transfer(tree)
        return tree

    # ------------------------------------------------------------ installs --

    def defer_install(self, slot: int, install: Callable[[], None]) -> None:  # thread: engine-step
        """Queue one shipped segment's decode-side install (a cache-scatter
        thunk reading the runner's CURRENT cache when run)."""
        self._pending.append((slot, install))

    def drain(self, slot: Optional[int] = None) -> int:  # thread: engine-step
        """Run queued installs (one slot's, or all) in ship order — called
        when a request's prefill completes, before its first token is
        sampled.  Returns the number installed."""
        if slot is None:
            run, self._pending = self._pending, []
        else:
            run = [(s, f) for s, f in self._pending if s == slot]
            self._pending = [(s, f) for s, f in self._pending if s != slot]
        # installs record on the CALLER's lane (the engine thread), not the
        # transfer lane: an install blocks on its segment's future, so it
        # can overlap a still-dispatching ship — same-lane events must nest
        with TRACER.span("handoff.install", slot=slot, segments=len(run)):
            for _, install in run:
                install()
        with self._lock:
            self.installs += len(run)
        return len(run)

    def discard(self, slot: int) -> int:  # thread: engine-step
        """Drop a slot's queued installs (preemption/abort: its pages are
        about to be released and may be reallocated — a late install would
        corrupt the new owner)."""
        keep = [(s, f) for s, f in self._pending if s != slot]
        n = len(self._pending) - len(keep)
        self._pending = keep
        with self._lock:
            self.discarded += n
        return n

    @property
    def pending(self) -> int:  # thread: engine-step
        return len(self._pending)

    # ------------------------------------------------------------- metrics --

    def snapshot(self) -> dict:  # thread: engine-step
        with self._lock:
            return {
                "segments": self.segments,
                "eager_segments": self.eager_segments,
                "bytes_shipped": self.bytes_shipped,
                "installs": self.installs,
                "discarded": self.discarded,
                "pending": self.pending,
                "t_dispatch_s": self.t_dispatch,
            }
