"""The decode pool: a ``ModelRunner`` whose prefill computes elsewhere.

``DisaggRunner`` inherits every decode-phase responsibility unchanged — the
decode/verify programs, the paged pool or contiguous cache, slot and
sampling state, preemption replay — all resident on the DECODE mesh (the
``mesh`` the base constructor received).  What it overrides is exactly the
prefill seam:

* ``prefill``: the monolithic body/tail/full programs run on the attached
  ``PrefillPool``; the swap payload (contiguous: the relayouted — possibly
  quantized payload+scales — decode-layout tree, built prefill-side; paged:
  the raw fp prefill-layout KV) crosses the ``KVHandoffChannel`` inside
  ``swap_write``, whose dispatch the SwapController still hides behind the
  prefill tail, and is installed by the SAME jitted install programs the
  colocated engine uses (``insert_prefill_kv`` / ``page_write_program``).

* ``run_prefill_chunk``: chunks compute on the pool via the compute-only
  ``prefill_chunk_kv_program`` and SHIP EAGERLY — each chunk's transfer
  dispatches as it completes, overlapping the remaining chunks' compute —
  while the decode-side installs (``page_write_program`` /
  ``chunk_write_program``, the fused programs' exact scatters) are DEFERRED
  on the channel until the final chunk, so decode rounds in between never
  acquire a data dependency on the in-flight prefill.  Non-final chunks
  also skip the host sync the colocated runner pays for timing: blocking
  would serialize the engine's single step loop against prefill-pool work
  and forfeit the overlap (so disagg ``t_prefill`` records dispatch time
  plus the final chunk's sync, and the true prefill wall time runs
  concurrently on the other pool).

Because every install runs the colocated engine's own quantize-on-write
programs on the same fp values, and installs land before the request's
first token is sampled, greedy outputs are bit-identical to the
single-engine ``EngineCore`` across layouts x kv dtypes, chunked included.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kv_cache import insert_prefill_kv
from repro.core.swap import SwapController
from repro.obs.trace import TRACER
from repro.serving.core import EngineStats, ModelRunner, Request
from repro.serving.disagg.handoff import KVHandoffChannel
from repro.serving.disagg.prefill_pool import PrefillPool
from repro.serving.paging import PrefixMatch

# Cross-object lock discipline (checked by repro.analysis): accesses
# through a local named `pool` are held to PrefillPool's annotations — in
# particular chunk_prefix, which only the pool's dispatch thread may touch.
# analysis: bind(pool=PrefillPool)


class DisaggRunner(ModelRunner):
    """ModelRunner with prefill outsourced to an attached PrefillPool."""

    prefill_pool: Optional[PrefillPool] = None
    handoff: Optional[KVHandoffChannel] = None

    def attach(self, prefill_pool: PrefillPool, handoff: KVHandoffChannel) -> None:
        """Wire the pools together (DisaggEngine calls this right after
        construction, before any request can prefill)."""
        assert prefill_pool.mode == self.mode
        assert prefill_pool.cache_layout == self.cache_layout
        assert prefill_pool.kv_dtype == self.kv_dtype
        assert prefill_pool.prefill_chunk == self.prefill_chunk
        self.prefill_pool = prefill_pool
        self.handoff = handoff
        # the fp chunk-prefix mirror lives on the prefill pool; drop the
        # decode-side buffer the base constructor allocated (prefix_width
        # reads chunk_cap, not the buffer)
        self.chunk_prefix = None

    # ------------------------------------------------------------- prefill --

    def prefill(self, req: Request, slot: int, resuming: bool, stats: EngineStats):
        """Monolithic prefill on the prefill pool + handoff + decode-side
        install — the two-pool mirror of ``ModelRunner.prefill`` (same
        allocation order, same install programs, same stats accounting)."""
        pool, handoff = self.prefill_pool, self.handoff
        tokens_np = np.asarray(req.prompt, np.int32)
        n = len(tokens_np)
        bucket = self.bucket(n)
        pprogs = pool.progs(bucket)

        match = None
        if self.cache_layout == "paged":
            match = self.paged.allocate_prompt(slot, tokens_np)  # may raise
            if not resuming:
                n_full = n // self.block_size
                stats.prefix_hits += match.cached_pages
                stats.prefix_misses += n_full - match.cached_pages
                stats.prefix_hit_tokens += match.cached_pages * self.block_size

        padded = np.zeros((bucket,), np.int32)
        padded[:n] = tokens_np
        tokens = jnp.asarray(padded[None])
        last_pos = jnp.int32(n - 1)

        def swap_write(kv):
            """The swap payload crosses the pool boundary here.  Dispatched
            before the prefill tail (SwapController), so transfer + install
            hide behind the tail's compute exactly like the colocated
            relayout does."""
            if self.cache_layout == "paged":
                kv = handoff.ship(kv)  # fp prefill-layout pages; the decode-
                # side page_write quantizes on write, as colocated
                ids = self.paged.page_ids_for_write(match, bucket // self.block_size)
                self.paged.kv = self.engine.page_write_program(
                    bucket, self.block_size).fn(self.paged.kv, kv, ids)
                return self.paged.kv
            if self.mode == "pdswap":
                relayed = pprogs["relayout"].fn(kv)
            else:
                relayed = pool.relay_static(kv)
            # decode-layout (quantized payload+scales when kv_dtype != fp)
            relayed = handoff.ship(relayed)
            self.cache = insert_prefill_kv(self.cache, relayed, slot, n)
            return self.cache

        t0 = time.perf_counter()
        if self.mode == "pdswap":
            ctl = SwapController(
                pprogs["body"].fn,
                lambda p, x: pprogs["tail"].fn(p, x, last_pos),
                swap_write,
            )
            logits, _, timing = ctl.prefill_and_swap(
                pool.params, tokens, overlap=self.overlap
            )
            if not resuming:
                stats.record_swap(timing)
        else:
            logits, kv = pprogs["full"].fn(pool.params, tokens, last_pos)
            swap_write(kv)
        # first-token logits cross to the decode pool too: the sampler (and
        # any program mixing them with decode-resident operands) must never
        # see prefill-mesh arrays
        logits = handoff.ship_aux(logits)
        t1 = time.perf_counter()
        if resuming:
            stats.t_replay += t1 - t0
        else:
            stats.t_prefill += t1 - t0
            stats.prefill_tokens += n
        if TRACER.enabled:
            TRACER.complete("prefill", t0, t1, request_id=req.request_id,
                            tokens=n, resuming=resuming)

        if self.cache_layout == "paged":
            self.paged.register_prompt_pages(match)
        return logits

    # ------------------------------------------------------ chunked prefill --

    def run_prefill_chunk(
        self,
        req: Request,
        slot: int,
        start: int,
        size: int,
        match: Optional[PrefixMatch],
        restarted: bool,
        stats: EngineStats,
    ):
        """One chunk computed on the prefill pool, shipped eagerly, install
        deferred (see the module docstring for why deferral is what
        actually eliminates cross-phase interference)."""
        pool, handoff = self.prefill_pool, self.handoff
        padded = self.chunk_bucket(size, start)
        prog = pool.chunk_kv_prog(padded, self.prefix_width(start))
        buf = np.zeros((padded,), np.int32)
        buf[:size] = np.asarray(req.prompt[start : start + size], np.int32)
        final = start + size == len(req.prompt)
        t0 = time.perf_counter()

        def compute(buf=buf, prog=prog, start=start, size=size,
                    rid=req.request_id):  # thread: prefill-pool
            """Runs on the pool's dispatch thread (see PrefillPool.submit):
            the engine thread never dispatches chunk work itself — not even
            the token upload — so its next decode dispatch is not queued
            behind any piece of the chunk."""
            tc0 = time.perf_counter()
            tokens = jnp.asarray(buf[None])
            logits, chunk_kv, pool.chunk_prefix = prog.fn(
                pool.params, tokens, pool.chunk_prefix, start, size - 1)
            shipped = handoff.ship(chunk_kv, eager=not final)
            if TRACER.enabled:
                # recorded from the pool thread: this is the lane whose
                # overlap with decode quanta the trace is meant to show
                TRACER.complete("prefill.chunk.compute", tc0,
                                time.perf_counter(), request_id=rid,
                                start=start, size=size)
            return logits, shipped

        fut = pool.submit(compute)
        if self.cache_layout == "paged":
            bs = self.block_size
            ids = self.paged.page_ids_for_write(
                match, padded // bs, first_page=start // bs)
            wprog = self.engine.page_write_program(padded, bs)

            def install(fut=fut, ids=ids, wprog=wprog):
                self.paged.kv = wprog.fn(self.paged.kv, fut.result()[1], ids)
        else:
            wprog = self.engine.chunk_write_program(padded)

            def install(fut=fut, slot=slot, start=start, wprog=wprog):
                self.cache = wprog.fn(self.cache, fut.result()[1], slot, start)

        handoff.defer_install(slot, install)
        logits = None
        if final:
            # the request is about to join the decode set: land every
            # queued segment (ship order), then sync the logits the first
            # token is sampled from
            handoff.drain(slot)
            logits = handoff.ship_aux(fut.result()[0])
            jax.block_until_ready(logits)
        t1 = time.perf_counter()
        if restarted:  # restart re-prefill is recompute overhead, not load
            stats.t_replay += t1 - t0
        else:
            stats.t_prefill += t1 - t0
        stats.prefill_chunks += 1
        if TRACER.enabled:
            # the ENGINE-side window (dispatch + final-chunk drain/sync),
            # distinct from the pool thread's prefill.chunk.compute span
            TRACER.complete("prefill.chunk.dispatch", t0, t1,
                            request_id=req.request_id, start=start,
                            size=size, final=final)
        return logits

    # ------------------------------------------------------------- release --

    def release(self, slot: int) -> None:
        """Slot release (finish / preempt / abort): discard the slot's
        queued installs FIRST — its pages are about to return to the pool,
        and a late install would scribble on their next owner."""
        if self.handoff is not None:
            self.handoff.discard(slot)
        super().release(slot)
