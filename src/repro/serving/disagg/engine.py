"""``DisaggEngine``: the two-pool serving engine / cross-pool router.

Subclasses ``EngineCore`` so admission (WFQ lanes, SLO shedding, swap
policies), chunked-prefill quanta, speculative decode, preemption, aborts,
and the async/HTTP front-ends all work unchanged — the engine IS the router:
``step()`` admits from the same fair queue, drives prefill on the prefill
pool through ``DisaggRunner``, and tracks each request across the pool
boundary (mid-prefill it holds a decode-pool slot + preallocated pages but
sits out decode rounds; its KV streams over the ``KVHandoffChannel``; once
the final segment lands it joins the decode set).

Meshes: pass an explicit ``prefill_mesh`` + ``decode_mesh`` pair, or a
single mesh with a leading ``"pod"`` axis to split via
``core.disagg.split_pod_meshes``, or neither — both pools then share the
default device, which keeps the full engine (channel included) runnable on
one CPU for tests.  Forced host platforms
(``XLA_FLAGS=--xla_force_host_platform_device_count=N``) give real
multi-device pools in CI; ``make_disagg_meshes`` builds the standard
two-pod split from the local devices.

Greedy outputs are bit-identical to the colocated ``EngineCore`` across
{contiguous, paged} x {fp, int8, int4}, chunked prefill included — pinned
by tests/test_disagg_serving.py; ``benchmarks/disagg_interference.py``
shows the payoff (decode ITL under concurrent long-prompt prefill).
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.disagg import split_pod_meshes
from repro.serving.core import EngineCore
from repro.serving.disagg.decode_pool import DisaggRunner
from repro.serving.disagg.handoff import KVHandoffChannel
from repro.serving.disagg.prefill_pool import PrefillPool


def make_disagg_meshes(devices=None, *, tp: int = 1):
    """(prefill_mesh, decode_mesh): the first ``2 * tp`` local devices split
    pod-major into two ``tp``-wide tensor-parallel pools."""
    if devices is None:
        devices = jax.devices()
    need = 2 * tp
    if len(devices) < need:
        raise ValueError(
            f"need {need} devices for two {tp}-wide pools, have {len(devices)} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count on CPU)")
    devs = np.array(devices[:need]).reshape(2, tp)
    return split_pod_meshes(Mesh(devs, ("pod", "model")))


def _mesh_info(mesh: Optional[Mesh]) -> dict:
    if mesh is None:
        return {"devices": 1, "axes": None}  # default-device pool
    return {"devices": int(mesh.devices.size),
            "axes": {n: int(s) for n, s in
                     zip(mesh.axis_names, mesh.devices.shape)}}


class DisaggEngine(EngineCore):
    """EngineCore over a prefill pool + decode pool + handoff channel."""

    runner_cls = DisaggRunner

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        prefill_mesh: Optional[Mesh] = None,
        decode_mesh: Optional[Mesh] = None,
        mesh: Optional[Mesh] = None,  # a ("pod", ...) mesh to split instead
        handoff_spec: Optional[P] = None,  # decode-pool sharding of shipped KV
        **engine_kwargs,
    ):
        if mesh is not None:
            if prefill_mesh is not None or decode_mesh is not None:
                raise ValueError(
                    "pass either mesh (a pod mesh to split) or an explicit "
                    "prefill_mesh/decode_mesh pair, not both")
            prefill_mesh, decode_mesh = split_pod_meshes(mesh)
        if (prefill_mesh is None) != (decode_mesh is None):
            raise ValueError("prefill_mesh and decode_mesh go together")
        # the base engine IS the decode pool: runner caches, decode/verify
        # programs, slots, replay all land on decode_mesh
        super().__init__(cfg, params, mesh=decode_mesh, **engine_kwargs)
        r = self.runner
        self.handoff = KVHandoffChannel(decode_mesh, spec=handoff_spec)
        self.prefill_pool = PrefillPool(
            cfg, params, mesh=prefill_mesh, max_len=r.max_len, mode=r.mode,
            cache_layout=r.cache_layout, block_size=r.block_size,
            kv_dtype=r.kv_dtype, prefill_chunk=r.prefill_chunk)
        r.attach(self.prefill_pool, self.handoff)

    def snapshot_sections(self) -> dict:
        # the shared snapshot builder (obs.engine.engine_snapshot) merges
        # this in — the disagg engine never overrides snapshot() itself,
        # so the block shape cannot drift from the other front-ends
        return {"disagg": {
            "handoff": self.handoff.snapshot(),
            "prefill_pool": _mesh_info(self.prefill_pool.mesh),
            "decode_pool": _mesh_info(self.runner.engine.mesh),
        }}
