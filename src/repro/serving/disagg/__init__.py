"""Disaggregated prefill/decode serving: two phase-specialized pools.

The paper time-multiplexes one edge fabric between a compute-bound prefill
engine and a bandwidth-bound decode engine; at pod scale the same asymmetry
supports SPATIAL disaggregation (Splitwise-style).  This package is that
runtime: a ``PrefillPool`` (compute-phase programs on their own mesh), a
``DisaggRunner``-powered decode pool (the base ``ModelRunner`` machinery on
the decode mesh), a ``KVHandoffChannel`` streaming finished prefill KV
across the boundary (eager per-chunk shipping + deferred installs), and
``DisaggEngine``, the ``EngineCore`` subclass routing requests across the
pools while keeping greedy outputs bit-identical to the single engine.
"""
from repro.serving.disagg.decode_pool import DisaggRunner
from repro.serving.disagg.engine import DisaggEngine, make_disagg_meshes
from repro.serving.disagg.handoff import KVHandoffChannel
from repro.serving.disagg.prefill_pool import PrefillPool

__all__ = [
    "DisaggEngine",
    "DisaggRunner",
    "KVHandoffChannel",
    "PrefillPool",
    "make_disagg_meshes",
]
