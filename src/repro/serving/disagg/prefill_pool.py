"""The prefill pool: compute-phase programs resident on their own mesh.

One ``PrefillPool`` owns everything the prefill phase needs and nothing the
decode phase does: a ``PhaseEngine`` over the prefill mesh (tensor-parallel
via the ``launch.sharding_rules`` inference specs when meshed), a second
committed copy of the params (the "static region" is replicated across
pools — weights never cross the handoff channel), the per-bucket
body/tail/full/relayout programs, and the fp chunk-prefix mirror for chunked
prefill.  The decode pool (``DisaggRunner``) calls in here for every prefill
forward and receives KV to ship through the ``KVHandoffChannel``.

Bit-identity with the colocated engine comes from running the SAME program
bodies on the same inputs: ``prefill_split_programs_varlen`` /
``prefill_program_varlen`` / ``prefill_chunk_kv_program`` share their math
with the fused colocated programs, and the contiguous relayout (pad +
layer-major->batch-major + quantize-on-write) runs prefill-side with the
exact ops ``ModelRunner`` uses, so the shipped pytree holds the bytes the
colocated install would have written.
"""
from __future__ import annotations

import os
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.phase_engine import PhaseEngine, PhaseProgram
from repro.launch.sharding_rules import params_shardings
from repro.serving.paging import cdiv


def _deprioritize() -> None:
    """Drop the pool's dispatch thread to the lowest scheduling priority.
    Decode is the latency-critical phase: on hosts where both pools'
    programs end up competing for the same CPU cycles (the forced-device
    simulation, or a real mesh whose host runtime threads share cores),
    prefill work should only ever consume cycles decode leaves idle.
    On real two-pool hardware the prefill devices are dedicated, so this
    costs nothing there; no-op where the host forbids it.

    ``SCHED_IDLE`` beats plain nice 19: a nice-19 thread still holds the
    core for a wakeup-granularity slice (~ms) after a decode thread
    unblocks, which is exactly the tail this pool must not add, while an
    idle-class thread is preempted immediately by any normal-class wakeup.

    Best-effort by construction: this runs as the pool executor's
    *initializer*, and an initializer that raises poisons the executor —
    every later ``submit()`` fails with BrokenThreadPool and the pool is
    dead.  So every path degrades silently: missing APIs (non-Linux,
    no ``os.sched_setscheduler`` / ``os.setpriority`` /
    ``threading.get_native_id``), ``PermissionError`` (RLIMIT_NICE,
    containers dropping CAP_SYS_NICE), or any other host quirk just leaves
    the thread at normal priority — strictly a performance matter.
    """
    try:
        os.sched_setscheduler(0, os.SCHED_IDLE, os.sched_param(0))
        return
    except (AttributeError, OSError, ValueError):  # non-Linux / forbidden
        pass
    try:
        # PermissionError (an OSError) covers RLIMIT_NICE denials; the
        # getattr covers platforms where get_native_id does not exist at
        # all (threading exposes it only where the OS can name threads)
        get_native_id = getattr(threading, "get_native_id", None)
        if get_native_id is not None:
            os.setpriority(os.PRIO_PROCESS, get_native_id(), 19)
    except (AttributeError, OSError, ValueError):
        pass


class PrefillPool:
    """Phase-specialized prefill engine for one pool of devices."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        mesh=None,
        max_len: int,
        mode: str = "pdswap",  # "pdswap" | "static"
        cache_layout: str = "contiguous",
        block_size: int = 16,
        kv_dtype: str = "fp",
        prefill_chunk: Optional[int] = None,
    ):
        from repro.quant.kv_quant import quantize_kv_tree

        assert mode in ("pdswap", "static"), mode
        self.cfg = cfg
        self.mesh = mesh
        self.mode = mode
        self.cache_layout = cache_layout
        self.kv_dtype = kv_dtype
        self.max_len = max_len
        self.block_size = block_size
        self.prefill_chunk = prefill_chunk
        self.engine = PhaseEngine(
            cfg, mesh, max_len=max_len, cache_layout=cache_layout,
            kv_dtype=kv_dtype)
        # this pool's dispatch thread: JAX's CPU client admits ONE inflight
        # computation per dispatching thread, so a chunk program launched
        # from the engine thread would stall that thread's next decode
        # dispatch behind the whole chunk — the overlap the split exists for
        # only becomes real when prefill work enters from its own thread
        self._exec = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="prefill-pool",
            initializer=_deprioritize)
        if mesh is not None:
            # commit this pool's copy of the static region to its own mesh;
            # the decode pool keeps its own committed copy — weights never
            # ride the handoff channel
            params = jax.device_put(
                params,
                params_shardings(jax.eval_shape(lambda: params), cfg, mesh,
                                 train=False))
        self.params = params
        self._pa = jax.eval_shape(lambda: params)

        if cache_layout != "paged":
            def relay_static(kv):  # same ops as ModelRunner.relay_static, so
                # the shipped decode-layout tree is byte-identical to what
                # the colocated static engine installs
                def pad(x):
                    p = [(0, 0)] * x.ndim
                    p[-2] = (0, max_len - x.shape[-2])
                    return jnp.moveaxis(jnp.pad(x, p), 0, 1)

                return quantize_kv_tree(jax.tree.map(pad, kv), kv_dtype)

            self.relay_static = jax.jit(relay_static)

        # fp chunk-prefix mirror, prefill-pool-resident: chunked prefill's
        # attention context lives where the chunks compute, and the decode
        # pool never holds it (DisaggRunner frees its own); only the
        # dispatch thread may touch it after construction (the single
        # worker serializes chunk order through the donated buffer)
        self.chunk_prefix = None  # owned-by: prefill-pool
        if prefill_chunk is not None:
            from repro.layers.attention import KVCache

            cap = (cdiv(max_len, block_size) * block_size
                   if cache_layout == "paged" else max_len)
            shape = (cfg.num_layers, 1, cfg.num_kv_heads, cap, cfg.head_dim)
            self.chunk_prefix = KVCache(jnp.zeros(shape, jnp.float32),
                                        jnp.zeros(shape, jnp.float32))

    # ------------------------------------------------------------ dispatch --

    def submit(self, fn: Callable) -> Future:
        """Run ``fn`` (a chunk compute + ship closure) on the pool's
        dedicated dispatch thread.  The single worker keeps chunk order —
        the donated chunk-prefix buffer threads sequentially through it —
        while the engine thread stays free to dispatch decode rounds that
        execute concurrently on the decode pool."""
        return self._exec.submit(fn)

    # ------------------------------------------------------------ programs --

    def progs(self, bucket: int) -> dict:
        """Prefill-phase programs for one prompt bucket (PhaseEngine caches
        by key, so this is build-once like ``ModelRunner.progs``).  The
        contiguous relayout runs HERE — the swap payload crosses the pool
        boundary already in decode layout (quantized payload+scales
        included), so the transfer moves the packed bytes, not fp."""
        p: dict = {}
        if self.mode == "pdswap":
            p["body"], p["tail"] = self.engine.prefill_split_programs_varlen(
                self._pa, 1, bucket)
        else:
            p["full"] = self.engine.prefill_program_varlen(self._pa, 1, bucket)
        if self.cache_layout != "paged" and self.mode == "pdswap":
            p["relayout"] = self.engine.relayout_program(1, bucket, self.max_len)
        return p

    def chunk_kv_prog(self, padded: int, prefix_width: int) -> PhaseProgram:
        """The compute-only chunk program (``prefill_chunk_kv_program``) for
        one (padded chunk length, prefix width) pair."""
        return self.engine.prefill_chunk_kv_program(padded, prefix_width)
