"""Pluggable prefill<->decode transition policies (paper §3.4 scheduling).

On the FPGA, flipping the fabric between the prefill and decode engines costs
a ~45 ms partial-bitstream load; on this stack the analogue is the exposed
(decode-visible) latency of the KV-relayout swap program.  *When* to pay that
cost was hardcoded in the PR-1 engine as drain-queue-then-decode.  The
``EngineCore`` scheduler now delegates the decision to a ``SwapPolicy``:

* ``DrainPolicy`` — the paper's behavior, and the default: enter the prefill
  phase whenever a request is queued and a slot is free.  With greedy
  sampling this reproduces the PR-1 engine token-for-token.

* ``SwapCostAwarePolicy`` — consults the measured ``SwapTiming`` history
  (``EngineStats.swap_agg``, the running aggregates over the bounded
  timing window) and defers the swap while the queue is shallow relative to
  the modeled reconfiguration cost: if one swap costs as much decode-visible
  time as ``r`` decode rounds, admitting for a single queued request stalls
  every active slot for ``r`` rounds — better to keep decoding until enough
  requests accumulate to amortize the flip.  A ``swap_cost_override`` lets a
  roofline-modeled figure (e.g. the paper's 45 ms PCAP load on target
  hardware) stand in for measured host timings, and ``min_queue`` pins the
  threshold outright (deterministic tests).  A defer cap bounds queueing
  delay, and an empty active set always admits, so progress is guaranteed.

Policies see only an immutable ``SchedulerView`` snapshot — they decide the
phase, never mutate engine state.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class SchedulerView:
    """Snapshot the scheduler hands a policy once per step (only when at
    least one request is queued AND a slot is free)."""

    queue_depth: int
    free_slots: int
    active_slots: int  # slots currently DECODING (mid-prefill slots excluded)
    swap_cost: float  # mean exposed swap latency, seconds (0 until measured)
    decode_round_cost: float  # mean decode-round latency, seconds
    # Chunked prefill: chunks still owed to partially-prefilled requests
    # (0 under monolithic prefill, and when no prefill is in flight).  A
    # partially-prefilled request already paid admission and holds its slot
    # (and, paged, its pages), so policies should weigh finishing it against
    # deferring — see SwapCostAwarePolicy.
    pending_chunks: int = 0
    # Age of the queue head, seconds since its arrival (0.0 when the queue
    # is empty).  Every defer stretches exactly this wait — it is the term
    # an SLO-aware policy weighs against the TTFT target.
    oldest_wait_s: float = 0.0


class SwapPolicy:
    """Decides, once per step, whether to flip into the prefill phase."""

    name = "base"

    def should_prefill(self, view: SchedulerView) -> bool:
        raise NotImplementedError

    def reset(self) -> None:
        """Called when the engine goes idle (no queue, no active slots)."""


class DrainPolicy(SwapPolicy):
    """Paper scheduling: always prefill when work is queued and a slot is
    free (the engine drains the queue, then decodes)."""

    name = "drain"

    def should_prefill(self, view: SchedulerView) -> bool:
        return True


class SwapCostAwarePolicy(SwapPolicy):
    """Defer the swap while the queue is shallow relative to its cost.

    Threshold: admit when ``queue_depth >= swap_cost / decode_round_cost``
    (scaled by ``cost_ratio``) — i.e. when the queued work is at least as
    deep as the number of decode rounds one flip would stall.  Admits
    unconditionally when nothing is decoding (the flip has no opportunity
    cost) and after ``max_defer_rounds`` consecutive deferrals (bounds the
    queueing delay added to any single request).
    """

    name = "swap-aware"

    def __init__(
        self,
        *,
        cost_ratio: float = 1.0,
        max_defer_rounds: int = 8,
        min_queue: Optional[int] = None,
        swap_cost_override: Optional[float] = None,
    ):
        if max_defer_rounds < 1:
            raise ValueError("max_defer_rounds must be >= 1")
        self.cost_ratio = cost_ratio
        self.max_defer_rounds = max_defer_rounds
        self.min_queue = min_queue
        self.swap_cost_override = swap_cost_override
        self._deferred = 0

    def threshold(self, view: SchedulerView) -> int:
        if self.min_queue is not None:
            return self.min_queue
        cost = self.swap_cost_override if self.swap_cost_override is not None else view.swap_cost
        if view.decode_round_cost <= 0.0:
            return 1  # no history yet: behave like DrainPolicy while warming up
        return max(1, math.ceil(self.cost_ratio * cost / view.decode_round_cost))

    def should_prefill(self, view: SchedulerView) -> bool:
        if view.pending_chunks > 0:
            # A partially-prefilled request holds a slot (and its pages)
            # while producing nothing; each remaining chunk is a bounded
            # quantum whose cost the per-step decode round already
            # amortizes.  Deferring it only stretches that occupancy, so
            # in-flight chunked prefill always continues.
            self._deferred = 0
            return True
        if view.active_slots == 0 or self._deferred >= self.max_defer_rounds:
            self._deferred = 0
            return True
        if view.queue_depth >= self.threshold(view):
            self._deferred = 0
            return True
        self._deferred += 1
        return False

    def reset(self) -> None:
        self._deferred = 0


POLICIES = {
    DrainPolicy.name: DrainPolicy,
    SwapCostAwarePolicy.name: SwapCostAwarePolicy,
}


def make_policy(name: str, **kwargs) -> SwapPolicy:
    if name not in POLICIES:
        # slo.py registers SLOAwareSwapPolicy on import; import lazily so
        # the registry is complete without a circular import at load time
        import repro.serving.slo  # noqa: F401
    if name not in POLICIES:
        raise ValueError(f"unknown swap policy {name!r}; choose from {sorted(POLICIES)}")
    return POLICIES[name](**kwargs)
