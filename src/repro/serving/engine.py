"""End-to-end serving engine: PD-Swap over a continuous-batching runtime.

Faithful mode (``mode="pdswap"``, the paper's single-RP temporal multiplex):
the engine alternates between a prefill phase (batching queued prompts) and a
decode phase (stepping all active slots), performing the logic swap at each
transition with the latency-overlapped mechanism of §3.4.

Baseline mode (``mode="static"``, the TeLLMe-style comparison): ONE program
configuration serves both phases — decode runs against the prefill-layout KV
(no relayout, no phase-specialized sharding/blocking), which is exactly the
compromise the paper's Fig. 6 quantifies.

The engine runs real tokens through the real model on this host (functional
validation) and accumulates modeled-v5e phase latencies from roofline reports
when provided (performance reporting; this container has no TPU).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.kv_cache import KVSlotManager, insert_prefill_kv
from repro.core.swap import SwapController, SwapTiming
from repro.models import get_model


@dataclasses.dataclass
class Request:
    request_id: str
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    enqueue_t: float = 0.0
    first_token_t: float = 0.0
    done_t: float = 0.0


@dataclasses.dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    swaps: int = 0
    swap_timings: List[SwapTiming] = dataclasses.field(default_factory=list)
    t_prefill: float = 0.0
    t_decode: float = 0.0

    def decode_tput(self) -> float:
        return self.decode_tokens / self.t_decode if self.t_decode else 0.0


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        n_slots: int = 4,
        max_len: int = 256,
        prompt_len: int = 32,
        mode: str = "pdswap",  # "pdswap" | "static"
        mesh=None,
        overlap: bool = True,
    ):
        assert cfg.family == "transformer", "serving engine drives the transformer family"
        self.cfg = cfg
        self.params = params
        self.api = get_model(cfg)
        self.mode = mode
        self.overlap = overlap and mode == "pdswap"
        self.max_len = max_len
        self.prompt_len = prompt_len
        self.slots = KVSlotManager(n_slots)
        self.queue: deque[Request] = deque()
        self.finished: Dict[str, Request] = {}
        self.stats = EngineStats()

        from repro.core.phase_engine import PhaseEngine
        from repro.models import transformer as T

        self.engine = PhaseEngine(cfg, mesh, max_len=max_len)
        pa = jax.eval_shape(lambda: params)
        if mode == "pdswap":
            body, tail = self.engine.prefill_split_programs(pa, 1, prompt_len)
            relayout = self.engine.relayout_program(1, prompt_len, max_len)
            self.swap = SwapController(body.fn, tail.fn, relayout.fn)
        else:
            self.prefill_prog = self.engine.prefill_program(pa, 1, prompt_len)

            def relay_static(kv):  # static engine: pad + layout only, no
                # phase-specialized resharding / program swap
                def pad(x):
                    p = [(0, 0)] * x.ndim
                    p[-2] = (0, max_len - x.shape[-2])
                    return jnp.moveaxis(jnp.pad(x, p), 0, 1)  # -> (B, L, ...)

                return jax.tree.map(pad, kv)

            self.relay_static = jax.jit(relay_static)
        self.decode_prog = self.engine.decode_program(pa, n_slots, max_len)
        self.cache = self.api.init_cache(cfg, n_slots, max_len)
        self.last_tokens = jnp.zeros((n_slots,), jnp.int32)

    # ------------------------------------------------------------- client --

    def submit(self, request: Request):
        request.enqueue_t = time.perf_counter()
        self.queue.append(request)

    # -------------------------------------------------------------- phases --

    def _prefill_one(self, req: Request) -> None:
        tokens = jnp.asarray(req.prompt[None, : self.prompt_len], jnp.int32)
        t0 = time.perf_counter()
        if self.mode == "pdswap":
            logits, kv_relayed, timing = self.swap.prefill_and_swap(
                self.params, tokens, overlap=self.overlap
            )
            self.stats.swap_timings.append(timing)
            self.stats.swaps += 1
        else:
            logits, kv = self.prefill_prog.fn(self.params, tokens)
            kv_relayed = self.relay_static(kv)
        self.stats.t_prefill += time.perf_counter() - t0
        self.stats.prefill_tokens += int(tokens.size)

        slot = self.slots.assign(req.request_id, self.prompt_len, req.max_new)
        self.cache = insert_prefill_kv(self.cache, kv_relayed, slot, self.prompt_len)
        tok = int(jnp.argmax(logits[0]))
        req.out_tokens.append(tok)
        req.first_token_t = time.perf_counter()
        self._inflight: Dict[int, Request] = getattr(self, "_inflight", {})
        # the prefill already produced the first new token
        self.slots.slots[slot].generated = 1
        if req.max_new <= 1:
            req.done_t = time.perf_counter()
            self.finished[req.request_id] = req
            self.slots.slots[slot] = type(self.slots.slots[slot])()
            return
        self.last_tokens = self.last_tokens.at[slot].set(tok)
        self._inflight[slot] = req

    def _decode_round(self) -> None:
        lengths = self.slots.lengths_array()
        t0 = time.perf_counter()
        logits, self.cache = self.decode_prog.fn(self.params, self.last_tokens, self.cache, lengths)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(next_tokens)
        self.stats.t_decode += time.perf_counter() - t0

        active = self.slots.active_slots()
        self.stats.decode_tokens += len(active)
        next_np = np.asarray(next_tokens)
        for i in active:
            self._inflight[i].out_tokens.append(int(next_np[i]))
        self.last_tokens = next_tokens

        def finish(i, s):
            req = self._inflight.pop(i)
            req.done_t = time.perf_counter()
            self.finished[req.request_id] = req

        self.slots.step(finished_cb=finish)

    # ---------------------------------------------------------------- run --

    def run(self, max_rounds: int = 10_000) -> EngineStats:
        """Paper scheduling: drain queue with prefill (one swap per batch of
        prompts), then decode until slots empty or new work arrives."""
        rounds = 0
        while (self.queue or self.slots.active_slots()) and rounds < max_rounds:
            rounds += 1
            while self.queue and self.slots.free_slots():
                self._prefill_one(self.queue.popleft())
            if self.slots.active_slots():
                self._decode_round()
        return self.stats
