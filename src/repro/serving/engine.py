"""PR-1 compatibility surface over the step-driven serving core.

The monolithic ``ServingEngine`` was split into Scheduler / ModelRunner /
OutputProcessor around ``EngineCore.step()`` (``repro.serving.core``).  This
module keeps the original import surface — ``ServingEngine``, ``Request``,
``EngineStats`` — with identical constructor signature and ``run()``
semantics: with greedy sampling (the ``SamplingParams`` default) and the
default ``DrainPolicy``, ``run()`` reproduces the PR-1 engine's outputs
token-for-token (pinned by tests/test_serving_api.py).

Mode and layout semantics, unchanged from PR-1:

Faithful mode (``mode="pdswap"``, the paper's single-RP temporal multiplex):
the engine alternates between a prefill phase (batching queued prompts) and a
decode phase (stepping all active slots), performing the logic swap at each
transition with the latency-overlapped mechanism of §3.4.

Baseline mode (``mode="static"``, the TeLLMe-style comparison): ONE program
configuration serves both phases — decode runs against the prefill-layout KV
(no relayout, no phase-specialized sharding/blocking), which is exactly the
compromise the paper's Fig. 6 quantifies.

Cache layouts (orthogonal to the mode):

* ``cache_layout="contiguous"`` — one ``(B_slots, L, Hkv, max_len, D)``
  decode buffer; every slot pays for ``max_len`` positions.
* ``cache_layout="paged"`` — a fixed pool of ``block_size``-token pages
  (``repro.serving.paging``), per-request page tables walked by the
  scalar-prefetched paged decode kernel, hash-based prefix caching,
  admission control when the pool is exhausted, and preemption-by-eviction
  of the lowest-priority request when decode growth cannot be served.

Prompts are variable-length in both layouts: right-padded to a compile
bucket and the true last token's logits read via ``last_pos`` — nothing is
ever silently truncated; prompts that cannot fit are rejected at submit.

The engine runs real tokens through the real model on this host (functional
validation) and accumulates modeled-v5e phase latencies from roofline
reports when provided (performance reporting; this container has no TPU).
"""
from __future__ import annotations

from repro.serving.core import EngineCore, EngineStats, Request


class ServingEngine(EngineCore):
    """The PR-1 engine name; now a thin alias of the step-driven core."""


__all__ = ["EngineCore", "EngineStats", "Request", "ServingEngine"]
