"""End-to-end serving engine: PD-Swap over a continuous-batching runtime.

Faithful mode (``mode="pdswap"``, the paper's single-RP temporal multiplex):
the engine alternates between a prefill phase (batching queued prompts) and a
decode phase (stepping all active slots), performing the logic swap at each
transition with the latency-overlapped mechanism of §3.4.

Baseline mode (``mode="static"``, the TeLLMe-style comparison): ONE program
configuration serves both phases — decode runs against the prefill-layout KV
(no relayout, no phase-specialized sharding/blocking), which is exactly the
compromise the paper's Fig. 6 quantifies.

Cache layouts (orthogonal to the mode):

* ``cache_layout="contiguous"`` — the seed design: one
  ``(B_slots, L, Hkv, max_len, D)`` decode buffer; every slot pays for
  ``max_len`` positions.
* ``cache_layout="paged"`` — the KV-cache-centric design the paper's decode
  engine calls for at serving scale: a fixed pool of ``block_size``-token
  pages (``repro.serving.paging``), per-request page tables walked by the
  scalar-prefetched paged decode kernel, hash-based prefix caching
  (requests sharing a page-aligned prompt prefix share pages), admission
  control when the pool is exhausted, and preemption-by-eviction of the
  lowest-priority request when decode growth cannot be served.

Prompts are variable-length in both layouts: they are right-padded to a
compile bucket (``block_size`` granularity when paged, ``prompt_len`` when
contiguous) and the true last token's logits are read via ``last_pos`` —
nothing is ever silently truncated.  Prompts that cannot fit
(``len + max_new > max_len``) are rejected at submit with a ValueError.

The engine runs real tokens through the real model on this host (functional
validation) and accumulates modeled-v5e phase latencies from roofline reports
when provided (performance reporting; this container has no TPU).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.kv_cache import KVSlotManager, insert_prefill_kv
from repro.core.swap import SwapController, SwapTiming
from repro.models import get_model
from repro.serving.paging import PagedKVCache, PoolExhausted, cdiv


@dataclasses.dataclass
class Request:
    request_id: str
    prompt: np.ndarray  # (S,) int32 — any length with S + max_new <= max_len
    max_new: int
    priority: int = 0  # larger = more important; lowest goes first on preemption
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    enqueue_t: float = 0.0
    first_token_t: float = 0.0
    done_t: float = 0.0
    # Set on preemption.  The restart re-prefills the prompt, then REPLAYS
    # the recorded out_tokens through the decode program (teacher-forcing),
    # reproducing the exact pre-eviction cache state — the same kernels run
    # on the same inputs, so the continuation is bit-identical to a run that
    # was never preempted.
    preempted: bool = False


@dataclasses.dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    swaps: int = 0
    swap_timings: List[SwapTiming] = dataclasses.field(default_factory=list)
    t_prefill: float = 0.0
    t_decode: float = 0.0
    # paged-layout counters
    prefix_hits: int = 0  # prompt pages served from the prefix cache
    prefix_misses: int = 0  # full prompt pages that had to be written
    prefix_hit_tokens: int = 0  # tokens covered by cache-hit pages
    preemptions: int = 0  # requests evicted to free pool capacity
    admission_blocks: int = 0  # prefill attempts deferred on pool pressure
    replayed_tokens: int = 0  # recompute overhead paid by preemption restarts
    t_replay: float = 0.0  # wall time of restart replays (kept out of t_decode)

    def decode_tput(self) -> float:
        return self.decode_tokens / self.t_decode if self.t_decode else 0.0


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        n_slots: int = 4,
        max_len: int = 256,
        prompt_len: int = 32,
        mode: str = "pdswap",  # "pdswap" | "static"
        cache_layout: str = "contiguous",  # "contiguous" | "paged"
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        mesh=None,
        overlap: bool = True,
    ):
        assert cfg.family == "transformer", "serving engine drives the transformer family"
        assert mode in ("pdswap", "static"), mode
        assert cache_layout in ("contiguous", "paged"), cache_layout
        self.cfg = cfg
        self.params = params
        self.api = get_model(cfg)
        self.mode = mode
        self.cache_layout = cache_layout
        self.overlap = overlap and mode == "pdswap"
        self.max_len = max_len
        self.prompt_len = prompt_len
        self.block_size = block_size
        self.slots = KVSlotManager(n_slots)
        self.queue: deque[Request] = deque()
        self.finished: Dict[str, Request] = {}
        self.stats = EngineStats()
        self._inflight: Dict[int, Request] = {}

        from repro.core.phase_engine import PhaseEngine
        from repro.models import transformer as T

        self.engine = PhaseEngine(cfg, mesh, max_len=max_len, cache_layout=cache_layout)
        self._pa = jax.eval_shape(lambda: params)
        self._bucket_progs: Dict[int, dict] = {}  # bucket len -> phase programs

        if cache_layout == "paged":
            if num_blocks is None:
                # full provisioning: every slot can grow to max_len
                num_blocks = n_slots * cdiv(max_len, block_size)
            pool_kv = T.init_paged_pool(cfg, num_blocks, block_size)
            self.paged = PagedKVCache(
                pool_kv, n_slots=n_slots, max_len=max_len, block_size=block_size
            )
            self.decode_prog = self.engine.paged_decode_program(
                self._pa, n_slots, self.paged.max_pages
            )
            self.cache = None
        else:
            self.paged = None

            def relay_static(kv):  # static engine: pad + layout only, no
                # phase-specialized resharding / program swap
                def pad(x):
                    p = [(0, 0)] * x.ndim
                    p[-2] = (0, max_len - x.shape[-2])
                    return jnp.moveaxis(jnp.pad(x, p), 0, 1)  # -> (B, L, ...)

                return jax.tree.map(pad, kv)

            self.relay_static = jax.jit(relay_static)
            self.decode_prog = self.engine.decode_program(self._pa, n_slots, max_len)
            self.cache = self.api.init_cache(cfg, n_slots, max_len)
        self.last_tokens = jnp.zeros((n_slots,), jnp.int32)

    # ------------------------------------------------------------- client --

    def submit(self, request: Request):
        n = int(len(request.prompt))
        if n < 1:
            raise ValueError(f"{request.request_id}: empty prompt")
        if n + request.max_new > self.max_len:
            raise ValueError(
                f"{request.request_id}: prompt ({n} tokens) + max_new "
                f"({request.max_new}) exceeds max_len={self.max_len}; "
                "prompts are never truncated — raise max_len or split the request"
            )
        if self.cache_layout == "paged":
            traj = cdiv(n + request.max_new - 1, self.block_size)
            if traj > self.paged.num_blocks:
                raise ValueError(
                    f"{request.request_id}: needs {traj} KV pages over its "
                    f"lifetime but the pool holds {self.paged.num_blocks}; "
                    "raise num_blocks or lower max_new (a request that can "
                    "never fit would self-preempt forever)"
                )
        request.enqueue_t = time.perf_counter()
        self.queue.append(request)

    # -------------------------------------------------------------- phases --

    def _bucket(self, n: int) -> int:
        """Compile-bucket length for an n-token prompt (right-padded).

        Fine-grained (one quantum) up to 4 quanta, then geometric (quantum x
        power of two) — bounds distinct XLA prefill compilations at
        O(log(max_len / quantum)) instead of max_len / quantum for ragged
        workloads, at the cost of some padding compute."""
        q = self.block_size if self.cache_layout == "paged" else self.prompt_len
        b = cdiv(n, q) * q
        if b > 4 * q:
            g = 4 * q
            while g < b:
                g *= 2
            b = g
        # clamp to max_len: the paged bound stays a multiple of the quantum
        # (page-write reshape needs it, and never pads to max_len); the
        # contiguous bound is exact (relayout pads bucket -> max_len)
        if self.cache_layout == "paged":
            b = min(b, cdiv(self.max_len, q) * q)
        else:
            b = min(b, self.max_len)
        return max(b, q)

    def _progs(self, bucket: int) -> dict:
        """Phase programs for one prompt bucket, built once and cached."""
        if bucket in self._bucket_progs:
            return self._bucket_progs[bucket]
        p: dict = {}
        if self.mode == "pdswap":
            p["body"], p["tail"] = self.engine.prefill_split_programs_varlen(self._pa, 1, bucket)
        else:
            p["full"] = self.engine.prefill_program_varlen(self._pa, 1, bucket)
        if self.cache_layout == "paged":
            p["write"] = self.engine.page_write_program(bucket, self.block_size)
        elif self.mode == "pdswap":
            p["relayout"] = self.engine.relayout_program(1, bucket, self.max_len)
        self._bucket_progs[bucket] = p
        return p

    def _prefill_one(self, req: Request) -> bool:
        """Prefill one request into a slot.  Returns False when admission is
        blocked (paged pool exhausted) — the request goes back to the queue
        head and the engine decodes to drain capacity first."""
        resuming = req.preempted and bool(req.out_tokens)
        tokens_np = np.asarray(req.prompt, np.int32)
        n = len(tokens_np)
        bucket = self._bucket(n)
        progs = self._progs(bucket)

        if self.cache_layout == "paged" and resuming:
            # Admit a restart only when the pool can hold its FULL replayed
            # state (prompt + already-generated tokens).  Without this, two
            # restarts admitted back to back each preempt the other during
            # replay and the admission loop livelocks with zero decode
            # progress.  (Conservative: prefix hits on live pages would
            # reduce the true need.)
            need = cdiv(n + len(req.out_tokens) - 1, self.block_size)
            if self.paged.pool.num_free < need:
                self.stats.admission_blocks += 1
                self.queue.appendleft(req)
                return False

        slot = self.slots.assign(req.request_id, n, req.max_new)
        match = None
        if self.cache_layout == "paged":
            try:
                match = self.paged.allocate_prompt(slot, tokens_np)
            except PoolExhausted:
                self.slots.release(slot)
                self.stats.admission_blocks += 1
                self.queue.appendleft(req)
                return False
            if not resuming:
                # engine-level counters reflect the OFFERED load; a restart's
                # self-hits on its own just-evicted pages would inflate them
                # (pool.stats keeps the raw counts)
                n_full = n // self.block_size
                self.stats.prefix_hits += match.cached_pages
                self.stats.prefix_misses += n_full - match.cached_pages
                self.stats.prefix_hit_tokens += match.cached_pages * self.block_size

        padded = np.zeros((bucket,), np.int32)
        padded[:n] = tokens_np
        tokens = jnp.asarray(padded[None])
        last_pos = jnp.int32(n - 1)

        def swap_write(kv):
            """Install prefilled KV into the decode cache — the swap payload
            whose dispatch the overlap hides behind the prefill tail."""
            if self.cache_layout == "paged":
                ids = self.paged.page_ids_for_write(match, bucket // self.block_size)
                self.paged.kv = progs["write"].fn(self.paged.kv, kv, ids)
                return self.paged.kv
            if self.mode == "pdswap":
                relayed = progs["relayout"].fn(kv)
            else:
                relayed = self.relay_static(kv)
            self.cache = insert_prefill_kv(self.cache, relayed, slot, n)
            return self.cache

        t0 = time.perf_counter()
        if self.mode == "pdswap":
            # SwapController owns the overlap protocol (dispatch the swap
            # first, decode waits for both — paper §3.4); swap_write is this
            # request's relayout payload.
            ctl = SwapController(
                progs["body"].fn,
                lambda p, x: progs["tail"].fn(p, x, last_pos),
                swap_write,
            )
            logits, _, timing = ctl.prefill_and_swap(
                self.params, tokens, overlap=self.overlap
            )
            if not resuming:
                self.stats.swap_timings.append(timing)
                self.stats.swaps += 1
        else:
            logits, kv = progs["full"].fn(self.params, tokens, last_pos)
            swap_write(kv)
        # restarts are recompute overhead, not offered load: their prefill
        # time joins t_replay and they never re-count prefill_tokens/swaps
        if resuming:
            self.stats.t_replay += time.perf_counter() - t0
        else:
            self.stats.t_prefill += time.perf_counter() - t0
            self.stats.prefill_tokens += n

        if self.cache_layout == "paged":
            self.paged.register_prompt_pages(match)

        tok = int(jnp.argmax(logits[0]))
        if resuming:
            # Re-feed the already-generated tokens through the decode program
            # (other slots masked out): the cache comes back bit-identical to
            # its pre-eviction state, so the greedy continuation is too.
            if not self._replay(slot, req):
                # pool raced away mid-replay: back off, stay preempted
                self._release(slot)
                self.stats.admission_blocks += 1
                self.queue.appendleft(req)
                return False
            req.preempted = False
            tok = req.out_tokens[-1]
            self.slots.slots[slot].length = n + len(req.out_tokens) - 1
            self.slots.slots[slot].generated = len(req.out_tokens)
        else:
            req.out_tokens.append(tok)
            req.first_token_t = time.perf_counter()
            # the prefill already produced the first new token
            self.slots.slots[slot].generated = 1
        if self.slots.slots[slot].generated >= req.max_new:
            req.done_t = time.perf_counter()
            self.finished[req.request_id] = req
            self._release(slot)
            return True
        self.last_tokens = self.last_tokens.at[slot].set(tok)
        self._inflight[slot] = req
        return True

    def _release(self, slot: int) -> None:
        self.slots.release(slot)
        if self.cache_layout == "paged":
            self.paged.release_slot(slot)

    # --------------------------------------------------- paged bookkeeping --

    def _pick_victim(self) -> Optional[int]:
        """Lowest-priority inflight slot; ties broken youngest-first."""
        if not self._inflight:
            return None
        return min(
            self._inflight,
            key=lambda s: (self._inflight[s].priority, -self._inflight[s].enqueue_t),
        )

    def _preempt(self, slot: int) -> None:
        """Evict one request: free its pages, requeue it for a deterministic
        restart (re-prefill the prompt, replay the generated tokens)."""
        req = self._inflight.pop(slot)
        req.preempted = True
        self._release(slot)
        self.stats.preemptions += 1
        self.queue.appendleft(req)

    def _grow_slot_page(self, slot: int, length: int) -> None:
        """Make position ``length`` writable, preempting under pool pressure."""
        while True:
            try:
                copy = self.paged.ensure_append_page(slot, length)
                if copy is not None:
                    dst, src = copy
                    kv = self.paged.kv
                    self.paged.kv = type(kv)(
                        kv.k.at[dst].set(kv.k[src]), kv.v.at[dst].set(kv.v[src])
                    )
                return
            except PoolExhausted:
                victim = self._pick_victim()
                if victim is None:
                    raise RuntimeError(
                        "paged KV pool exhausted with nothing left to preempt; "
                        f"raise num_blocks (have {self.paged.num_blocks})"
                    )
                self._preempt(victim)
                if victim == slot:
                    return  # this very slot was evicted; caller skips it

    def _replay(self, slot: int, req: Request) -> bool:
        """Teacher-force the recorded tokens of a preemption restart through
        the decode program.  All other slots are masked (length 0): the paged
        scatter drops them, their pages and outputs are untouched.

        Replay never preempts — the admission headroom check reserved its
        pages; only decode-time growth (which generates NEW tokens every
        round, so it always makes progress) may evict.  Returns False if the
        pool is unexpectedly short anyway; the caller backs off.

        Replay wall time lands in ``stats.t_replay`` — blocking here keeps
        the async-dispatched replay compute from leaking into the next
        decode round's ``t_decode`` (it would skew decode_tput)."""
        p = len(req.prompt)
        n_slots = self.slots.n_slots
        t0 = time.perf_counter()
        for j, tok in enumerate(req.out_tokens[:-1]):
            pos = p + j
            try:
                copy = self.paged.ensure_append_page(slot, pos)
            except PoolExhausted:
                return False
            assert copy is None  # replay appends past the prompt: no CoW
            tokens = np.zeros((n_slots,), np.int32)
            tokens[slot] = tok
            lengths = np.zeros((n_slots,), np.int32)
            lengths[slot] = pos
            tables = self.paged.block_tables_array()
            _, self.paged.kv = self.decode_prog.fn(
                self.params, jnp.asarray(tokens), self.paged.kv, tables,
                jnp.asarray(lengths),
            )
            self.stats.replayed_tokens += 1
        jax.block_until_ready(self.paged.kv.k)
        self.stats.t_replay += time.perf_counter() - t0
        return True

    def _ensure_append_pages(self) -> None:
        """Before a decode round, make every active slot's next position
        writable — growing tables at page boundaries and forking shared
        (copy-on-write) pages — preempting the lowest-priority request when
        the pool cannot serve the growth."""
        for slot in self.slots.active_slots():
            s = self.slots.slots[slot]
            if s.request_id is None:  # preempted earlier in this loop
                continue
            self._grow_slot_page(slot, s.length)

    # --------------------------------------------------------------- decode --

    def _decode_round(self) -> None:
        if self.cache_layout == "paged":
            self._ensure_append_pages()
        active = self.slots.active_slots()
        if not active:
            return
        lengths = self.slots.lengths_array()
        t0 = time.perf_counter()
        if self.cache_layout == "paged":
            tables = self.paged.block_tables_array()
            logits, self.paged.kv = self.decode_prog.fn(
                self.params, self.last_tokens, self.paged.kv, tables, lengths
            )
        else:
            logits, self.cache = self.decode_prog.fn(
                self.params, self.last_tokens, self.cache, lengths
            )
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(next_tokens)
        self.stats.t_decode += time.perf_counter() - t0

        self.stats.decode_tokens += len(active)
        next_np = np.asarray(next_tokens)
        for i in active:
            self._inflight[i].out_tokens.append(int(next_np[i]))
        self.last_tokens = next_tokens

        def finish(i, s):
            req = self._inflight.pop(i)
            req.done_t = time.perf_counter()
            self.finished[req.request_id] = req
            if self.cache_layout == "paged":
                self.paged.release_slot(i)

        self.slots.step(finished_cb=finish)

    # ----------------------------------------------------------------- run --

    def run(self, max_rounds: int = 10_000) -> EngineStats:
        """Paper scheduling: drain queue with prefill (one swap per batch of
        prompts), then decode until slots empty or new work arrives."""
        rounds = 0
        while (self.queue or self.slots.active_slots()) and rounds < max_rounds:
            rounds += 1
            while self.queue and self.slots.free_slots():
                if not self._prefill_one(self.queue.popleft()):
                    if not self.slots.active_slots():
                        head = self.queue[0]
                        raise RuntimeError(
                            f"{head.request_id} can never be admitted: needs more "
                            f"pages than the pool holds ({self.paged.num_blocks} "
                            f"blocks x {self.block_size} tokens)"
                        )
                    break  # decode to drain capacity, then retry admission
            if self.slots.active_slots():
                self._decode_round()
        return self.stats

    # -------------------------------------------------------------- metrics --

    def kv_bytes(self) -> dict:
        """KV memory accounting for the benchmark: bytes reserved up front vs
        the peak actually backing live tokens."""
        if self.cache_layout == "paged":
            return {
                "allocated": self.paged.pool_bytes(),
                "peak_in_use": self.paged.peak_live_pages * self.paged.page_bytes(),
                "page_bytes": self.paged.page_bytes(),
            }
        nbytes = int(self.cache.k.nbytes + self.cache.v.nbytes)
        return {"allocated": nbytes, "peak_in_use": nbytes, "page_bytes": 0}
