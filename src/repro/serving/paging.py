"""Paged KV-cache subsystem: block-pool allocator + prefix caching.

The paper's decode engine is KV-cache-centric: decode throughput is bounded
by KV bytes streamed per token (Eq. 5), so KV *capacity* is the resource that
caps concurrency.  The seed runtime allocated one contiguous
``(B_slots, L, Hkv, max_len, D)`` buffer — every slot pays for ``max_len``
positions regardless of its actual length, and no KV is ever shared between
requests.  This module replaces that with vLLM-style paging:

* ``BlockPool`` — a fixed pool of ``num_blocks`` pages, each covering
  ``block_size`` token positions *across all layers*.  Pure host-side
  metadata: free list, per-page reference counts, copy-on-write forking,
  and an LRU of evictable (refcount-0 but content-cached) pages.
* prefix caching — full pages are registered under a chain hash of their
  token content (``h_i = hash((h_{i-1}, tokens_i))``); a request whose
  prompt shares a page-aligned prefix with an earlier request re-uses the
  cached pages (refcount bump, no write) instead of allocating new ones.
  Pages freed by finished requests stay cached (evictable) until capacity
  pressure reclaims them, so hit rates survive request churn.
* ``PagedKVCache`` — marries the pool metadata to the device page arrays
  (``(num_blocks, L, Hkv, block_size, D)`` K/V, see
  ``repro.models.transformer.init_paged_pool``) and the per-slot page
  tables that the scalar-prefetched paged decode kernel walks
  (``repro.kernels.paged_attention``).

A page is deliberately layer-complete (all ``L`` layers' K/V for its token
span): one allocation covers one token span end-to-end, the page table is
per-request rather than per-(request, layer), and the decode kernel slices
the layer axis exactly like the contiguous cache did.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.kv_quant import infer_kv_dtype, is_quantized, payload_bytes, total_nbytes


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


class PoolExhausted(RuntimeError):
    """No free or evictable page available — caller must free or preempt."""


@dataclasses.dataclass
class PageMeta:
    refcount: int = 0
    hash: Optional[int] = None  # prefix-cache registration, if any
    tokens: Optional[Tuple[int, ...]] = None  # registered page's exact tokens


@dataclasses.dataclass
class PoolStats:
    allocs: int = 0
    frees: int = 0
    prefix_hits: int = 0
    prefix_misses: int = 0
    cache_evictions: int = 0
    cow_copies: int = 0


class BlockPool:
    """Fixed pool of KV pages with refcounts, COW and prefix caching.

    Invariants (asserted by tests/test_paging.py):
      * every page is in exactly one of {free list, evictable LRU, live
        (refcount > 0)};
      * ``num_free + num_evictable + num_live == num_blocks``;
      * a page in the evictable LRU always has refcount 0 and a registered
        hash (it is kept alive only for future prefix hits);
      * ``decref`` of a live unregistered page returns it to the free list.
    """

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.meta: List[PageMeta] = [PageMeta() for _ in range(num_blocks)]
        self.free_list: deque[int] = deque(range(num_blocks))
        self.hash_to_page: Dict[int, int] = {}
        self.evictable: "OrderedDict[int, None]" = OrderedDict()  # LRU order
        self.stats = PoolStats()

    # ------------------------------------------------------------ queries --

    @property
    def num_free(self) -> int:
        """Pages immediately allocatable (fresh + cache-evictable)."""
        return len(self.free_list) + len(self.evictable)

    @property
    def num_live(self) -> int:
        return sum(1 for m in self.meta if m.refcount > 0)

    def refcount(self, pid: int) -> int:
        return self.meta[pid].refcount

    # ------------------------------------------------------- alloc / free --

    def alloc(self) -> int:
        """Allocate one page (refcount 1), evicting a cached page if needed."""
        if self.free_list:
            pid = self.free_list.popleft()
        elif self.evictable:
            pid, _ = self.evictable.popitem(last=False)  # LRU victim
            self._unregister(pid)
            self.stats.cache_evictions += 1
        else:
            raise PoolExhausted(
                f"block pool exhausted: {self.num_blocks} pages all live"
            )
        m = self.meta[pid]
        assert m.refcount == 0
        m.refcount = 1
        self.stats.allocs += 1
        return pid

    def incref(self, pid: int) -> None:
        assert self.meta[pid].refcount > 0, "incref on a dead page"
        self.meta[pid].refcount += 1

    def decref(self, pid: int) -> int:
        """Drop one reference; a refcount-0 page becomes evictable (if it is
        prefix-registered — its contents may serve future hits) or free."""
        m = self.meta[pid]
        assert m.refcount > 0, "decref on a dead page"
        m.refcount -= 1
        if m.refcount == 0:
            self.stats.frees += 1
            if m.hash is not None:
                self.evictable[pid] = None  # most-recently-freed = MRU
            else:
                self.free_list.append(pid)
        return m.refcount

    def evict_all_cached(self) -> int:
        """Reclaim EVERY evictable (refcount-0, prefix-registered) page into
        the free list; returns how many were reclaimed.  The admission
        livelock breaker's last resort: pages held purely for future
        prefix hits are pressure the engine may always shed.  (``alloc()``
        already falls back to the LRU page by page, so this is a
        defensive guarantee — after it runs, a still-failing admission
        provably needs more pages than the pool holds, whatever path the
        admission took.)"""
        n = 0
        while self.evictable:
            pid, _ = self.evictable.popitem(last=False)  # LRU first
            self._unregister(pid)
            self.free_list.append(pid)
            self.stats.cache_evictions += 1
            n += 1
        return n

    def copy_on_write(self, pid: int) -> Tuple[int, bool]:
        """Prepare ``pid`` for writing.  A uniquely-held page is returned
        as-is; a shared one is forked: the caller gets a fresh page (and must
        copy the device contents across) while other holders keep ``pid``."""
        if self.meta[pid].refcount == 1:
            return pid, False
        new = self.alloc()
        self.decref(pid)
        self.stats.cow_copies += 1
        return new, True

    # ------------------------------------------------------ prefix caching --

    @staticmethod
    def chain_hash(prev_hash: Optional[int], tokens: Sequence[int]) -> int:
        """Hash of one full page's tokens chained on its prefix's hash.

        Python's tuple-of-ints hash is deterministic across processes
        (PYTHONHASHSEED only salts str/bytes), so tests can hand-compute it.
        """
        return hash((prev_hash, tuple(int(t) for t in tokens)))

    def lookup(self, h: int, tokens: Optional[Sequence[int]] = None) -> Optional[int]:
        """Prefix-cache probe.  On a hit the page is revived/increffed and
        the caller owns one reference; on a miss returns None.

        ``tokens`` (the probing page's exact token chunk) guards against
        chain-hash collisions: a false hit now needs BOTH a 64-bit hash
        collision AND an identical final chunk (the prefix itself is only
        covered by the hash), instead of the hash alone."""
        pid = self.hash_to_page.get(h)
        if pid is None:
            self.stats.prefix_misses += 1
            return None
        m = self.meta[pid]
        if tokens is not None and m.tokens != tuple(int(t) for t in tokens):
            self.stats.prefix_misses += 1  # hash collision: content mismatch
            return None
        if m.refcount == 0:
            del self.evictable[pid]
            m.refcount = 1
        else:
            m.refcount += 1
        self.stats.prefix_hits += 1
        return pid

    def register(self, h: int, pid: int, tokens: Optional[Sequence[int]] = None) -> None:
        """Publish a fully-written page under its chain hash."""
        if h in self.hash_to_page:
            return  # identical content already cached; keep the older page
        self.meta[pid].hash = h
        self.meta[pid].tokens = None if tokens is None else tuple(int(t) for t in tokens)
        self.hash_to_page[h] = pid

    def _unregister(self, pid: int) -> None:
        h = self.meta[pid].hash
        if h is not None and self.hash_to_page.get(h) == pid:
            del self.hash_to_page[h]
        self.meta[pid].hash = None
        self.meta[pid].tokens = None


@dataclasses.dataclass
class PrefixMatch:
    """Result of allocating a prompt's pages against the prefix cache."""

    pages: List[int]
    cached_pages: int  # leading pages served from the prefix cache
    # (hash, pid, tokens) of newly-written full pages, registered post-write
    new_full_hashes: List[Tuple[int, int, Tuple[int, ...]]]


class PagedKVCache:
    """Device page arrays + per-slot page tables over a ``BlockPool``.

    The K/V page arrays mirror the contiguous decode-cache layout with the
    slot axis replaced by the page axis:

        contiguous:  (B_slots,    L, Hkv, max_len,    D)
        paged:       (num_blocks, L, Hkv, block_size, D)

    so position ``p`` of slot ``b`` lives at
    ``pages[table[b][p // block_size], :, :, p % block_size, :]`` and the
    paged decode kernel walks ``table`` via scalar prefetch.
    """

    def __init__(
        self,
        pool_kv,  # KVCache of (num_blocks, L, Hkv, block_size, D) arrays —
        # or of QuantKV leaves (packed payload + fp32 scale planes)
        *,
        n_slots: int,
        max_len: int,
        block_size: int,
    ):
        self.kv = pool_kv
        self.block_size = block_size
        self.max_len = max_len
        self.max_pages = cdiv(max_len, block_size)
        self.kv_dtype = (
            infer_kv_dtype(pool_kv.k.q) if is_quantized(pool_kv.k) else "fp"
        )
        self.pool = BlockPool(jax.tree.leaves(pool_kv)[0].shape[0], block_size)
        self.tables: List[List[int]] = [[] for _ in range(n_slots)]
        self.peak_live_pages = 0
        self._tables_dirty = True
        self._tables_dev: Optional[jnp.ndarray] = None

    # ------------------------------------------------------------ metrics --

    @property
    def num_blocks(self) -> int:
        return self.pool.num_blocks

    def page_bytes(self) -> int:
        """Total bytes of one page: K + V payload plus (quantized) the fp32
        scale planes — the real footprint the pool reserves per page."""
        return total_nbytes(self.kv) // self.num_blocks

    def page_payload_bytes(self) -> int:
        """Packed K/V payload bytes of one page, scales excluded — the
        quantity the kv_dtype lever shrinks (2x int8, 4x int4 vs bf16)."""
        return payload_bytes(self.kv) // self.num_blocks

    def pool_bytes(self) -> int:
        return self.num_blocks * self.page_bytes()

    def live_bytes(self) -> int:
        return self.pool.num_live * self.page_bytes()

    def _note_usage(self) -> None:
        self.peak_live_pages = max(self.peak_live_pages, self.pool.num_live)

    # ----------------------------------------------------------- prompts --

    def allocate_prompt(self, slot: int, tokens: np.ndarray) -> PrefixMatch:
        """Allocate pages for a prompt, serving page-aligned prefixes from
        the cache.  On ``PoolExhausted`` every page acquired so far is rolled
        back, so a rejected admission leaves the pool untouched."""
        assert not self.tables[slot], f"slot {slot} already holds pages"
        bs = self.block_size
        n = len(tokens)
        n_pages = cdiv(n, bs)
        n_full = n // bs

        pages: List[int] = []
        new_full: List[Tuple[int, int, Tuple[int, ...]]] = []
        cached = 0
        h: Optional[int] = None
        try:
            matching = True
            for i in range(n_pages):
                if i < n_full:
                    chunk = tuple(int(t) for t in tokens[i * bs : (i + 1) * bs])
                    h = BlockPool.chain_hash(h, chunk)
                    if matching:
                        pid = self.pool.lookup(h, chunk)
                        if pid is not None:
                            pages.append(pid)
                            cached += 1
                            continue
                        matching = False  # past the shared prefix: all miss
                    else:
                        self.pool.stats.prefix_misses += 1
                    pid = self.pool.alloc()
                    new_full.append((h, pid, chunk))
                else:
                    pid = self.pool.alloc()  # trailing partial page: never cached
                pages.append(pid)
        except PoolExhausted:
            for pid in pages:
                self.pool.decref(pid)
            raise
        self.tables[slot] = pages
        self._tables_dirty = True
        self._note_usage()
        # snapshot: the live table may diverge later (growth, copy-on-write)
        return PrefixMatch(list(pages), cached, new_full)

    def register_prompt_pages(self, match: PrefixMatch) -> None:
        """Publish the freshly *written* full pages to the prefix cache —
        call after the prefill page-write has been dispatched."""
        for h, pid, chunk in match.new_full_hashes:
            self.pool.register(h, pid, chunk)

    # ------------------------------------------------------------ decode --

    def ensure_append_page(self, slot: int, length: int):
        """Make position ``length`` writable for ``slot`` before a decode
        append.  Grows the table by one page at a page boundary; forks a
        shared partial page (copy-on-write).  Returns an optional
        ``(dst_page, src_page)`` device-copy the caller must perform.

        Raises ``PoolExhausted`` when growth is impossible — the engine
        preempts the lowest-priority request and retries.
        """
        bs = self.block_size
        table = self.tables[slot]
        idx = length // bs
        if idx == len(table):
            table.append(self.pool.alloc())
            self._tables_dirty = True
            self._note_usage()
            return None
        assert idx < len(table), (slot, length, table)
        pid = table[idx]
        if self.pool.refcount(pid) > 1:
            new, copied = self.pool.copy_on_write(pid)
            if copied:
                table[idx] = new
                self._tables_dirty = True
                self._note_usage()
                return (new, pid)
        return None

    def truncate_slot(self, slot: int, length: int) -> int:
        """Speculative rollback: shrink ``slot``'s table to exactly the
        pages covering positions ``[0, length)``, releasing the overshoot
        pages a rejected verify block grew.  Returns how many pages were
        released.

        Only ever drops TRAILING pages, so shared prefix-cache pages (all
        at the front of the table) and a copy-on-write fork of the page
        holding the block's first row (always a kept position) are
        untouched — rollback can neither leak a page (each table entry
        holds exactly one reference, dropped here) nor corrupt a shared
        one (rejected rows were only ever written to pages this slot
        exclusively owns; fully-rejected trailing pages go back to the
        pool).  The next decode append re-grows via
        ``ensure_append_page`` as usual.
        """
        keep = cdiv(length, self.block_size)
        table = self.tables[slot]
        released = 0
        while len(table) > keep:
            self.pool.decref(table.pop())
            released += 1
        if released:
            self._tables_dirty = True
        return released

    def release_slot(self, slot: int) -> None:
        for pid in self.tables[slot]:
            self.pool.decref(pid)
        self.tables[slot] = []
        self._tables_dirty = True

    # ------------------------------------------------------------- device --

    def block_tables_array(self) -> jnp.ndarray:
        """(n_slots, max_pages) int32 for scalar prefetch; unused entries 0
        (the kernel skips them via the per-slot length)."""
        if self._tables_dirty or self._tables_dev is None:
            arr = np.zeros((len(self.tables), self.max_pages), np.int32)
            for i, t in enumerate(self.tables):
                arr[i, : len(t)] = t
            self._tables_dev = jnp.asarray(arr)
            self._tables_dirty = False
        return self._tables_dev

    def page_ids_for_write(
        self, match: PrefixMatch, padded_pages: int, first_page: int = 0
    ) -> jnp.ndarray:
        """(padded_pages,) int32 destination pages for the prefill page-write
        covering prompt pages ``[first_page, first_page + padded_pages)`` —
        the whole prompt for the monolithic swap (``first_page=0``), one
        chunk's span for chunked prefill.

        Cache-hit pages already hold identical content and may be shared with
        live requests — they are marked out-of-bounds so the scatter drops
        them (the "reuse" in copy-on-write free/reuse).  Entries beyond the
        prompt's pages are dropped too (prompt padded up to the compile
        bucket).  The skip sentinel is ``num_blocks`` (not -1, which jnp
        scatter would wrap to the last pool page).
        """
        skip = self.num_blocks
        ids = np.full((padded_pages,), skip, np.int32)
        for i in range(padded_pages):
            gi = first_page + i
            if match.cached_pages <= gi < len(match.pages):
                ids[i] = match.pages[gi]
        return jnp.asarray(ids)
