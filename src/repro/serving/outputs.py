"""Output side of the serving core: incremental ``RequestOutput`` deltas and
finish-reason detection.

``OutputProcessor`` is the third layer of the EngineCore split (Scheduler /
ModelRunner / OutputProcessor): every token the runner produces flows through
``process_token``, which appends it to the request, stamps TTFT exactly once
(including on the preemption-restart path, where the pre-PR-2 engine left it
at 0.0), decides whether the request is finished — a stop token
(``finish_reason="stop"``) or the ``max_new``/``max_tokens`` budget
(``finish_reason="length"``) — and emits the streaming delta that
``EngineCore.step()`` returns and ``engine.generate()`` yields.

Preempted requests re-enter through replay (teacher-forced recorded tokens),
which bypasses this module on purpose: those tokens were already emitted to
the client before eviction, and replay reproduces cache state bit-identically,
so the stream simply continues where it left off.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

from repro.obs.trace import TRACER


def _finish(req, reason: str, now: Optional[float] = None) -> None:
    """The ONE terminal-stamp path: set the finish reason, stamp ``done_t``
    idempotently (a request reaching a second finish path — e.g. the
    chunked-prefill handoff after ``process_tokens`` already finished it —
    must keep its first stamp, or e2e latency silently inflates), and emit
    the tracer's finish event, which asserts it fires exactly once per
    request while tracing."""
    req.finish_reason = reason
    if req.done_t == 0.0:
        req.done_t = time.perf_counter() if now is None else now
    TRACER.finish(req.request_id, reason)


@dataclasses.dataclass
class RequestOutput:
    """One streaming increment for one request.

    ``new_token_ids`` is the delta this step produced (one token per decode
    round; the prefill's first token arrives as its own delta).
    ``token_ids`` is the full generated sequence so far — a LIVE view
    aliasing the request's token list (copying it per delta would make
    streaming O(n^2) on the decode hot path); ``list(out.token_ids)`` if a
    snapshot is needed.  Because ``step()`` returns its outputs after the
    whole quantum, a delta produced early in a step (e.g. the prefill's
    first token) can show a ``token_ids`` view that already includes that
    same step's decode token — the view never lags the deltas, but it may
    run ahead.  When ``finished``, ``finish_reason`` is ``"stop"``
    (a stop token was generated — it is kept as the last token) or
    ``"length"`` (the token budget ran out).
    """

    request_id: str
    new_token_ids: List[int]
    token_ids: List[int]
    finished: bool = False
    finish_reason: Optional[str] = None


class OutputProcessor:
    """Turns raw sampled tokens into RequestOutputs; owns finish semantics.

    With ``stats`` (an ``EngineStats``), every emission also feeds the
    engine's client-visible latency aggregates: TTFT (request arrival to
    first token — queueing delay included) on the first delta, ITL (gap
    since the previous delta) on every later one.  These are what the
    SLO-aware swap policy observes.
    """

    def __init__(self, stats=None):
        self._stats = stats

    def _observe(self, req, now: float) -> None:
        if req.first_token_t == 0.0:
            arrival = getattr(req, "arrival_time_s", 0.0)
            if self._stats is not None and arrival:
                self._stats.ttft.record(now - arrival)
        else:
            last = getattr(req, "last_emit_t", 0.0)
            if self._stats is not None and last:
                self._stats.itl.record(now - last)
        req.last_emit_t = now

    def process_token(self, req, tok: int) -> RequestOutput:
        return self.process_tokens(req, [tok])

    def process_tokens(self, req, toks) -> RequestOutput:
        """Append a (possibly multi-token) delta and decide finish state.

        One decode round used to produce exactly one token; a speculative
        verify round produces up to k+1 at once, and a naive per-token loop
        would happily stream tokens PAST a stop token or past the
        ``max_new`` budget (the block was scored before either cut was
        known).  So the delta is truncated here, in one place: first capped
        at the remaining budget headroom, then cut at the FIRST stop token
        within the cap (the stop token itself is kept, matching the
        single-token path).  A stop landing exactly on the budget boundary
        reports ``"stop"`` — stop takes precedence over ``"length"``,
        exactly as ``process_token`` always resolved that tie.
        """
        headroom = req.max_new - len(req.out_tokens)
        kept = []
        reason = None
        for tok in list(toks)[: max(headroom, 0)]:
            kept.append(int(tok))
            if tok in req.params.stop_tokens:
                reason = "stop"
                break
        req.out_tokens.extend(kept)
        now = time.perf_counter()
        if kept:
            self._observe(req, now)
        if kept and req.first_token_t == 0.0:
            # First token for this request — or a restart whose original
            # admission predates TTFT stamping (the PR-1 bug: resumed
            # requests reported TTFT 0.0).  Never overwrite a real stamp.
            req.first_token_t = now
        if reason is None and len(req.out_tokens) >= req.max_new:
            reason = "length"
        if reason is not None:
            _finish(req, reason, now)
        return RequestOutput(
            request_id=req.request_id,
            new_token_ids=kept,
            token_ids=req.out_tokens,
            finished=reason is not None,
            finish_reason=reason,
        )

    @staticmethod
    def finalize_resumed(req) -> RequestOutput:
        """Terminal output for a replayed request that resumes EXACTLY at
        its budget: every token was already streamed before eviction, so
        there is nothing left to generate — but the stream still owes the
        client a ``finished=True`` delta and the request a finish reason
        (the pre-fix path finished it silently with ``finish_reason=None``
        and the stream simply went dark).  The reason is reconstructed
        from the recorded tail: ``"stop"`` if the last recorded token is a
        stop token, else ``"length"`` (the budget ran out)."""
        reason = req.finish_reason or (
            "stop" if req.out_tokens and req.out_tokens[-1] in req.params.stop_tokens
            else "length"
        )
        _finish(req, reason)
        return RequestOutput(
            request_id=req.request_id,
            new_token_ids=[],
            token_ids=req.out_tokens,
            finished=True,
            finish_reason=req.finish_reason,
        )

    @staticmethod
    def finalize_dropped(req, reason: str) -> RequestOutput:
        """Terminal output for a request removed without completing (client
        abort, SLO deadline shed): zero-delta, finished, with the given
        ``finish_reason``.  Whatever was already streamed stands — the drop
        ends the stream, it does not un-emit tokens."""
        req.preempted = False
        _finish(req, reason)
        return RequestOutput(
            request_id=req.request_id,
            new_token_ids=[],
            token_ids=req.out_tokens,
            finished=True,
            finish_reason=reason,
        )

    @staticmethod
    def finalize_aborted(req) -> RequestOutput:
        """Terminal output for a cancelled request (``finish_reason="abort"``)."""
        return OutputProcessor.finalize_dropped(req, "abort")

    @staticmethod
    def resume_output(req) -> Optional[RequestOutput]:
        """Nothing to emit on a restart — the recorded tokens were streamed
        before eviction and replay reproduces state exactly.  Kept as an
        explicit hook so alternative processors can surface resume events."""
        return None
