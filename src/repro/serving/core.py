"""Step-driven serving core: ``EngineCore.step() -> list[RequestOutput]``.

The PR-1 ``ServingEngine`` was a monolith: one ``run()`` method owned
admission, the prefill<->decode transition, phase-program dispatch, greedy
argmax, and finish bookkeeping.  This module splits it into three layers
around an incremental core:

* ``Scheduler`` — the wait queue, admission validation, preemption victim
  selection, and the *swap decision*: a pluggable ``SwapPolicy``
  (``repro.serving.policy``) is consulted once per step to decide whether to
  pay the reconfiguration cost and flip into the prefill phase (paper §3.4).
  ``DrainPolicy`` reproduces the paper's drain-queue-then-decode loop;
  ``SwapCostAwarePolicy`` defers the flip while the queue is shallow
  relative to the measured/modeled swap cost.

* ``ModelRunner`` — owns everything compiled and everything device-resident:
  phase programs and compile buckets (built on ``core.phase_engine``), the
  contiguous or paged KV cache, the slot manager, per-slot sampling state,
  and the vectorized on-device sampler program.  It executes prefill (with
  the latency-overlapped swap), decode rounds, and preemption replay.

* ``OutputProcessor`` — turns raw sampled tokens into streaming
  ``RequestOutput`` deltas and owns finish semantics (stop tokens vs the
  token budget), TTFT stamping included.

``EngineCore.step()`` advances the engine by one scheduling quantum — at
most one prefill burst (policy-gated) followed by at most one decode round —
and returns the outputs produced.  ``run()`` survives as a thin
compatibility loop over ``step()`` and, with greedy sampling and the default
``DrainPolicy``, reproduces the PR-1 engine token-for-token.
``generate()`` streams one request's outputs as an iterator.

With ``prefill_chunk=N`` the prefill burst becomes CHUNKED: ``step()`` runs
at most one N-token chunk of pending prefill per quantum (continue the
partially-prefilled request, else admit the queue head and run its first
chunk), then the decode round — so a long prompt no longer stalls every
active stream for its whole prefill; decode interleaves between chunks.
Greedy streams are bit-identical to monolithic prefill for every layout x
kv_dtype (chunk-size invariance; in the jnp reference regime — past the
reference path's 1024-token cutoff or under the Pallas prefill kernel the
monolithic summation order differs, so agreement is to float rounding),
and chunk boundaries are a pure function of (prompt length, chunk size)
so preemption replay stays bit-identical.
See ``PrefillProgress``, ``ModelRunner.run_prefill_chunk`` and the chunk
phase programs in ``core.phase_engine``.

With ``spec_decode=k`` every decode round becomes a speculative VERIFY
round: each decoding slot proposes up to ``k`` draft tokens by matching its
recent suffix against its own prompt + output history (host-side prompt
lookup, ``serving.spec_decode`` — no draft model, nothing extra resident),
one batched verify program scores all ``k + 1`` positions in a single
forward pass, the longest confirmed draft prefix plus one correction token
is emitted (multi-token ``RequestOutput`` deltas), and rejected rows are
rolled back by truncating the slot length (contiguous) / releasing the
overshoot pages (paged).  Decode is memory-bandwidth-bound (Eq. 5 — each
token streams the whole KV cache + weights), so every accepted draft token
amortizes a stream the round already paid for.  Greedy targets are the
verify logits' argmax and sampled targets reuse the sequential
``fold_in(seed, token_index)`` key stream, so emitted streams match the
non-speculative engine token-for-token and preemption replay is unchanged
(recorded tokens teacher-force through the decode program; drafts are a
pure function of the token history, so no speculation state survives a
restart).  ``EngineStats`` reports ``draft_tokens`` / ``accepted_tokens``
/ ``acceptance_rate()`` / ``tokens_per_round()``.

Faithful mode (``mode="pdswap"``) and the static baseline, and the
contiguous vs paged cache layouts, keep their PR-1 semantics — see
``repro.serving.engine`` for the original mode/layout notes.  Sampling is
per-request (``SamplingParams``): temperature / top-k / top-p with per-slot
PRNG keys derived as ``fold_in(PRNGKey(seed), token_index)``, so preemption
replay (teacher-forced recorded tokens) resumes the key stream exactly and
stays bit-identical under non-greedy sampling.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, Iterator, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.kv_cache import KVSlotManager, insert_prefill_kv
from repro.core.swap import SwapAggregates, SwapController, SwapTiming
from repro.models import get_model
from repro.obs.trace import TRACER
from repro.serving.outputs import OutputProcessor, RequestOutput
from repro.serving.fair_queue import WeightedFairQueue
from repro.serving.paging import PagedKVCache, PoolExhausted, PrefixMatch, cdiv
from repro.serving.policy import DrainPolicy, SchedulerView, SwapPolicy, make_policy
from repro.serving.sampling import SamplingParams
from repro.serving.slo import LatencyStat

# Raw SwapTiming records kept for inspection; older records collapse into
# EngineStats.swap_agg (running aggregates the SwapCostAwarePolicy reads).
SWAP_TIMING_WINDOW = 64


@dataclasses.dataclass
class Request:
    request_id: str
    prompt: np.ndarray  # (S,) int32 — any length with S + max_new <= max_len
    max_new: int
    priority: int = 0  # larger = more important; lowest goes first on preemption
    params: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    # multi-tenant fair queueing: requests are drained from per-tenant FIFO
    # lanes in weighted deficit-round-robin order (serving.fair_queue), so
    # one tenant's burst cannot starve the others
    tenant: str = "default"
    weight: float = 1.0  # fair-queue share relative to other tenants
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    # Arrival (client submit) time — stamped at the FIRST submit and never
    # overwritten, so TTFT = first_token_t - arrival_time_s includes every
    # queueing delay (front-end admission queue + scheduler wait queue).
    arrival_time_s: float = 0.0
    enqueue_t: float = 0.0  # scheduler-queue entry (re-stamped on requeue)
    first_token_t: float = 0.0
    last_emit_t: float = 0.0  # previous delta's emit time (ITL tracking)
    queue_wait_s: Optional[float] = None  # arrival -> first successful admission
    done_t: float = 0.0
    finish_reason: Optional[str] = None  # "stop" | "length" | "abort" once finished
    # Set on preemption.  The restart re-prefills the prompt, then REPLAYS
    # the recorded out_tokens through the decode program (teacher-forcing),
    # reproducing the exact pre-eviction cache state — the same kernels run
    # on the same inputs, and the sampler's key stream is a pure function of
    # (seed, token index), so the continuation is bit-identical to a run
    # that was never preempted, greedy or sampled alike.
    preempted: bool = False


@dataclasses.dataclass
class PrefillProgress:
    """Host-side state of one partially-prefilled request (chunked prefill).

    Chunk boundaries (``sizes``) are a pure function of (prompt length,
    chunk size) — a preemption-restart re-prefills through the exact same
    chunk programs, which is what keeps replay bit-identical under
    chunking.  Paged prompts allocate ALL their pages at admission
    (``match``); each chunk then writes only its own page span.
    """

    req: Request
    slot: int
    resuming: bool  # restart with recorded tokens: replay them after prefill
    restarted: bool  # ANY preemption restart (even mid-prefill, no tokens yet):
    # its re-prefill is recompute overhead (t_replay), never offered load —
    # prefill_tokens / swaps / prefix counters are charged once per request
    sizes: List[int]  # real (unpadded) chunk sizes, in order
    ci: int = 0  # next chunk index
    pos: int = 0  # tokens already prefilled (real, unpadded)
    match: Optional[PrefixMatch] = None

    @property
    def remaining_chunks(self) -> int:
        return len(self.sizes) - self.ci


@dataclasses.dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    decode_rounds: int = 0
    swaps: int = 0
    prefill_bursts: int = 0  # prefill phases entered (fabric flips, not swaps)
    prefill_chunks: int = 0  # chunked-prefill quanta executed (0 = monolithic)
    swap_timings: Deque[SwapTiming] = dataclasses.field(
        default_factory=lambda: deque(maxlen=SWAP_TIMING_WINDOW)
    )
    swap_agg: SwapAggregates = dataclasses.field(default_factory=SwapAggregates)
    t_prefill: float = 0.0
    t_decode: float = 0.0
    # paged-layout counters
    prefix_hits: int = 0  # prompt pages served from the prefix cache
    prefix_misses: int = 0  # full prompt pages that had to be written
    prefix_hit_tokens: int = 0  # tokens covered by cache-hit pages
    preemptions: int = 0  # requests evicted to free pool capacity
    admission_blocks: int = 0  # prefill attempts deferred on pool pressure
    replayed_tokens: int = 0  # recompute overhead paid by preemption restarts
    t_replay: float = 0.0  # wall time of restart replays (kept out of t_decode)
    # speculative-decoding counters (spec_decode=k)
    draft_tokens: int = 0  # prompt-lookup draft tokens proposed to verify
    accepted_tokens: int = 0  # draft tokens the verify pass confirmed
    verify_rounds: int = 0  # decode rounds run through the verify program
    slot_rounds: int = 0  # sum over decode rounds of active slots — the
    # per-slot normalizer (a plain batched round is batch-many slot-rounds)
    decode_ctx_tokens: int = 0  # context tokens streamed per decode pass,
    # summed over slot-rounds — decode_ctx_tokens / slot_rounds is the mean
    # context the Eq. (5) KV-stream bound is evaluated at (obs.drift)
    # client-visible latency aggregates (bounded windows, see serving.slo):
    # queue wait (arrival -> first successful admission), TTFT (arrival ->
    # first token), ITL (gap between consecutive streamed deltas).  The
    # SLOAwareSwapPolicy binds to these.
    queue_wait: LatencyStat = dataclasses.field(default_factory=LatencyStat)
    ttft: LatencyStat = dataclasses.field(default_factory=LatencyStat)
    itl: LatencyStat = dataclasses.field(default_factory=LatencyStat)
    # per-tenant queue-wait aggregates (same bounded windows), keyed by
    # Request.tenant — pairs with the fair queue's lane depths in
    # EngineCore.snapshot()["tenants"] so WFQ behavior is observable
    tenant_queue_wait: Dict[str, LatencyStat] = dataclasses.field(default_factory=dict)
    aborts: int = 0  # requests cancelled mid-flight or while queued
    sheds: int = 0  # queued requests dropped by SLO admission control

    def decode_tput(self) -> float:
        return self.decode_tokens / self.t_decode if self.t_decode else 0.0

    def acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens the verify pass accepted."""
        return self.accepted_tokens / self.draft_tokens if self.draft_tokens else 0.0

    def tokens_per_round(self) -> float:
        """Mean tokens emitted per SLOT per decode round — exactly 1.0
        without speculation regardless of batch size (normalizing by
        ``slot_rounds``, not rounds, keeps batch width out of the
        number); every accepted draft raises it (the per-stream Eq. (5)
        amortization factor)."""
        return self.decode_tokens / self.slot_rounds if self.slot_rounds else 0.0

    def decode_round_cost(self) -> float:
        return self.t_decode / self.decode_rounds if self.decode_rounds else 0.0

    def record_swap(self, timing: SwapTiming) -> None:
        self.swaps += 1
        self.swap_timings.append(timing)
        self.swap_agg.update(timing)

    def snapshot(self) -> dict:
        """One JSON-serializable stats block — the consistent surface every
        benchmark (and the SSE server's /stats endpoint) reports.  Raw
        counters plus the derived rates and the bounded-window latency
        aggregates; the raw ``swap_timings`` window is summarized, not
        dumped."""
        counters = (
            "prefill_tokens", "decode_tokens", "decode_rounds", "swaps",
            "prefill_bursts", "prefill_chunks", "t_prefill", "t_decode",
            "prefix_hits", "prefix_misses", "prefix_hit_tokens",
            "preemptions", "admission_blocks", "replayed_tokens", "t_replay",
            "draft_tokens", "accepted_tokens", "verify_rounds", "slot_rounds",
            "decode_ctx_tokens", "aborts", "sheds",
        )
        snap = {k: getattr(self, k) for k in counters}
        snap.update(
            decode_tput=self.decode_tput(),
            decode_round_cost=self.decode_round_cost(),
            spec_acceptance_rate=self.acceptance_rate(),
            spec_tokens_per_round=self.tokens_per_round(),
            swap_agg={
                "count": self.swap_agg.count,
                "mean_exposed_cost_s": self.swap_agg.mean_cost,
                "mean_hidden_fraction": self.swap_agg.mean_hidden_fraction,
            },
            queue_wait_s=self.queue_wait.snapshot(),
            ttft_s=self.ttft.snapshot(),
            itl_s=self.itl.snapshot(),
        )
        return snap


class ModelRunner:
    """Owns phase programs, compile buckets, caches, and the sampler."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        n_slots: int = 4,
        max_len: int = 256,
        prompt_len: int = 32,
        mode: str = "pdswap",  # "pdswap" | "static"
        cache_layout: str = "contiguous",  # "contiguous" | "paged"
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        kv_dtype: str = "fp",  # "fp" | "int8" | "int4" — quantized KV cache
        mesh=None,
        overlap: bool = True,
        prefill_chunk: Optional[int] = None,  # tokens per prefill quantum (None = monolithic)
        spec_decode: Optional[int] = None,  # draft depth k (None/0 = speculation off)
        spec_ngram: int = 3,  # prompt-lookup n-gram size
    ):
        from repro.quant.kv_quant import assert_kv_dtype, quantize_kv_tree

        assert cfg.family == "transformer", "serving engine drives the transformer family"
        assert mode in ("pdswap", "static"), mode
        assert cache_layout in ("contiguous", "paged"), cache_layout
        assert_kv_dtype(kv_dtype)
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
            if cache_layout == "paged" and prefill_chunk % block_size:
                raise ValueError(
                    f"prefill_chunk ({prefill_chunk}) must be a multiple of "
                    f"block_size ({block_size}) so chunk boundaries align with "
                    "page boundaries (each chunk writes whole pages)")
        if spec_decode is not None and spec_decode < 1:
            if spec_decode == 0:
                spec_decode = None  # 0 = off, the CLI's natural spelling
            else:
                raise ValueError(f"spec_decode must be >= 1 (or 0/None = off), got {spec_decode}")
        if spec_ngram < 1:
            raise ValueError(f"spec_ngram must be >= 1, got {spec_ngram}")
        self.prefill_chunk = prefill_chunk
        self.spec_decode = spec_decode
        self.spec_ngram = spec_ngram
        self.cfg = cfg
        self.params = params
        self.api = get_model(cfg)
        self.mode = mode
        self.cache_layout = cache_layout
        self.kv_dtype = kv_dtype
        self.overlap = overlap and mode == "pdswap"
        self.max_len = max_len
        self.prompt_len = prompt_len
        self.block_size = block_size
        self.slots = KVSlotManager(n_slots)

        from repro.core.phase_engine import PhaseEngine
        from repro.models import transformer as T

        self.engine = PhaseEngine(
            cfg, mesh, max_len=max_len, cache_layout=cache_layout, kv_dtype=kv_dtype
        )
        self._pa = jax.eval_shape(lambda: params)
        self._bucket_progs: Dict[int, dict] = {}  # bucket len -> phase programs
        self._chunk_progs: Dict[tuple, object] = {}  # (padded len, prefix width) -> program

        if cache_layout == "paged":
            if num_blocks is None:
                # full provisioning: every slot can grow to max_len
                num_blocks = n_slots * cdiv(max_len, block_size)
            pool_kv = T.init_paged_pool(cfg, num_blocks, block_size, kv_dtype=kv_dtype)
            self.paged = PagedKVCache(
                pool_kv, n_slots=n_slots, max_len=max_len, block_size=block_size
            )
            self.decode_prog = self.engine.paged_decode_program(
                self._pa, n_slots, self.paged.max_pages
            )
            self.cache = None
        else:
            self.paged = None

            def relay_static(kv):  # static engine: pad + layout only, no
                # phase-specialized resharding / program swap (but the
                # quantized cache still quantizes on write — storage
                # precision is a cache property, not a phase program)
                def pad(x):
                    p = [(0, 0)] * x.ndim
                    p[-2] = (0, max_len - x.shape[-2])
                    return jnp.moveaxis(jnp.pad(x, p), 0, 1)  # -> (B, L, ...)

                return quantize_kv_tree(jax.tree.map(pad, kv), kv_dtype)

            self.relay_static = jax.jit(relay_static)
            self.decode_prog = self.engine.decode_program(self._pa, n_slots, max_len)
            self.cache = T.init_cache(cfg, n_slots, max_len, kv_dtype=kv_dtype)
        self.last_tokens = jnp.zeros((n_slots,), jnp.int32)

        # Speculative decoding: ONE verify program shape (n_slots, k+1)
        # serves every round — per-slot draft depth varies at runtime via
        # the traced n_tokens operand, never by recompilation.
        self.verify_prog = None
        if spec_decode is not None:
            width = spec_decode + 1
            if cache_layout == "paged":
                self.verify_prog = self.engine.paged_verify_program(
                    self._pa, n_slots, self.paged.max_pages, width)
            else:
                self.verify_prog = self.engine.verify_program(
                    self._pa, n_slots, max_len, width)

        # Chunked prefill keeps an fp mirror of the in-flight prompt's KV
        # (prefill layout, bounded at the cache capacity) so every chunk
        # attends the exact values monolithic prefill would — see
        # transformer._prefill_chunk_body.  One buffer suffices: the
        # engine runs at most one chunked prefill at a time.
        self.chunk_prefix = None
        self.chunk_cap = None  # mirror capacity (valid when prefill_chunk set)
        if prefill_chunk is not None:
            from repro.layers.attention import KVCache as _KVCache

            cap = self.chunk_cap = (
                cdiv(max_len, block_size) * block_size
                if cache_layout == "paged" else max_len)
            shape = (cfg.num_layers, 1, cfg.num_kv_heads, cap, cfg.head_dim)
            self.chunk_prefix = _KVCache(jnp.zeros(shape, jnp.float32),
                                         jnp.zeros(shape, jnp.float32))

        # Per-slot sampling state, refreshed on slot assignment.  The fold_in
        # step index is recomputed from each request's out_tokens at sample
        # time, so there is no mutable PRNG state to checkpoint or restore.
        self._seeds = np.zeros(n_slots, np.int32)
        self._temps = np.zeros(n_slots, np.float32)
        self._top_ks = np.zeros(n_slots, np.int32)
        self._top_ps = np.ones(n_slots, np.float32)

    # ------------------------------------------------------------- buckets --

    def bucket(self, n: int) -> int:
        """Compile-bucket length for an n-token prompt (right-padded).

        Fine-grained (one quantum) up to 4 quanta, then geometric (quantum x
        power of two) — bounds distinct XLA prefill compilations at
        O(log(max_len / quantum)) instead of max_len / quantum for ragged
        workloads, at the cost of some padding compute."""
        q = self.block_size if self.cache_layout == "paged" else self.prompt_len
        b = cdiv(n, q) * q
        if b > 4 * q:
            g = 4 * q
            while g < b:
                g *= 2
            b = g
        # clamp to max_len: the paged bound stays a multiple of the quantum
        # (page-write reshape needs it, and never pads to max_len); the
        # contiguous bound clamps to the largest quantum-aligned length
        # <= max_len so bucket shapes stay consistent when max_len is not a
        # multiple of the quantum — only a prompt too long for that aligned
        # cap falls back to the single exact max_len shape (relayout pads
        # bucket -> max_len, so the bound may never exceed max_len)
        if self.cache_layout == "paged":
            b = min(b, cdiv(self.max_len, q) * q)
        else:
            cap = self.max_len - self.max_len % q
            b = min(b, cap) if n <= cap else self.max_len
        return max(b, q)

    def progs(self, bucket: int) -> dict:
        """Phase programs for one prompt bucket, built once and cached."""
        if bucket in self._bucket_progs:
            return self._bucket_progs[bucket]
        p: dict = {}
        if self.mode == "pdswap":
            p["body"], p["tail"] = self.engine.prefill_split_programs_varlen(self._pa, 1, bucket)
        else:
            p["full"] = self.engine.prefill_program_varlen(self._pa, 1, bucket)
        if self.cache_layout == "paged":
            p["write"] = self.engine.page_write_program(bucket, self.block_size)
        elif self.mode == "pdswap":
            p["relayout"] = self.engine.relayout_program(1, bucket, self.max_len)
        self._bucket_progs[bucket] = p
        return p

    # ------------------------------------------------------ chunked prefill --

    def chunk_sizes(self, n: int) -> List[int]:
        """Real (unpadded) chunk sizes for an n-token prompt — a pure
        function of (n, prefill_chunk), so a preemption-restart re-prefills
        through the exact same chunk boundaries and compiled programs
        (replay bit-identity under chunking)."""
        c = self.prefill_chunk
        sizes = [c] * (n // c)
        if n % c:
            sizes.append(n % c)
        return sizes

    def chunk_bucket(self, size: int, start: int) -> int:
        """Compile bucket for one chunk: every full chunk shares the single
        chunk-shaped compilation; the tail rounds up to the layout quantum
        (ONE tail bucket per prompt), replacing the power-of-two bucket
        ladder.  The contiguous tail additionally clamps to ``max_len -
        start`` so the in-place install window never overflows the cache
        (dynamic_update_slice would silently shift an overflowing write)."""
        c = self.prefill_chunk
        if size == c:
            return c
        if self.cache_layout == "paged":
            return cdiv(size, self.block_size) * self.block_size
        q = max(1, min(self.prompt_len, c))
        return max(min(cdiv(size, q) * q, self.max_len - start), size)

    def prefix_width(self, start: int) -> int:
        """Compile-time width of the prefix the chunk's attention sees:
        0 for the first chunk, else the chunk-based geometric ladder bucket
        >= start, clamped to the mirror capacity — O(log(cap / chunk))
        distinct widths, and a short prompt's chunks never attend over the
        mirror's full max_len capacity."""
        cap = self.chunk_cap
        if start == 0:
            return 0
        g = self.prefill_chunk
        while g < start:
            g *= 2
        return min(g, cap)

    def chunk_prog(self, padded: int, prefix_width: int):
        """The chunk-shaped phase program for one (padded chunk length,
        prefix width) pair."""
        key = (padded, prefix_width)
        if key in self._chunk_progs:
            return self._chunk_progs[key]
        if self.cache_layout == "paged":
            prog = self.engine.paged_prefill_chunk_program(
                padded, self.paged.max_pages, self.block_size, prefix_width)
        else:
            prog = self.engine.prefill_chunk_program(
                padded, self.slots.n_slots, self.max_len, prefix_width)
        self._chunk_progs[key] = prog
        return prog

    # ------------------------------ program registry (analysis surface) --
    #
    # The `program` analysis pass (repro.analysis.progcheck) audits the
    # traced phase programs against the roofline contract.  The methods
    # below are its interface: the statically-enumerable shape sets the
    # bucketing functions promise, and the registry with each program's
    # abstract input signature — so the auditor traces EXACTLY the
    # signatures serving dispatches, not a parallel reconstruction.

    def reachable_buckets(self) -> List[int]:
        """Every distinct prefill compile bucket reachable from a prompt of
        1..max_len tokens — the finite shape set ``bucket()`` promises.  A
        ``bucket()`` regression that leaks per-prompt shapes shows up here
        as an unbounded / misaligned set (the coverage gate's input)."""
        return sorted({self.bucket(n) for n in range(1, self.max_len + 1)})

    def reachable_chunk_shapes(self) -> List[tuple]:
        """Every (padded chunk length, prefix width) pair chunked prefill
        can request for prompts of 1..max_len tokens — pure functions of
        (n, prefill_chunk), so enumerable without running anything."""
        if self.prefill_chunk is None:
            return []
        shapes = set()
        for n in range(1, self.max_len + 1):
            start = 0
            for size in self.chunk_sizes(n):
                shapes.add((self.chunk_bucket(size, start),
                            self.prefix_width(start)))
                start += size
        return sorted(shapes)

    def build_serving_grid(self) -> None:
        """Instantiate every program the serving grid can reach — per-bucket
        prefill/swap programs, per-(chunk, prefix) chunk programs, the
        samplers — so ``program_signatures()`` covers the full surface.
        Construction is lazy-jit only: nothing traces or compiles here."""
        for b in self.reachable_buckets():
            self.progs(b)
        for padded, pw in self.reachable_chunk_shapes():
            self.chunk_prog(padded, pw)
        self.engine.sampler_program(self.slots.n_slots)
        self.engine.sampler_program(1)
        if self.spec_decode:
            self.engine.block_sampler_program(
                self.slots.n_slots, self.spec_decode + 1)

    def program_signatures(self) -> Dict[str, object]:
        """The engine's program registry with each program's
        ``abstract_inputs`` filled in (``jax.ShapeDtypeStruct`` trees) —
        the exact traced surface of the `program` analysis pass."""
        out = {}
        for key, prog in self.engine.programs.items():
            if not prog.abstract_inputs:
                sig = self.abstract_signature(key)
                if sig is not None:
                    prog.abstract_inputs = sig
            out[key] = prog
        return out

    def abstract_signature(self, key: str) -> Optional[tuple]:
        """Abstract (ShapeDtypeStruct) inputs for the program registered
        under ``key`` — the same shapes/dtypes ``EngineCore.step()``
        dispatches.  Returns None for programs this runner never
        dispatches (e.g. the disaggregated pools' split programs)."""
        import re as _re

        from repro.layers.attention import KVCache as _KVCache

        sds = jax.ShapeDtypeStruct
        i32, f32 = jnp.int32, jnp.float32
        abstract = lambda tree: jax.tree.map(  # noqa: E731
            lambda x: sds(x.shape, x.dtype), tree)
        cfg = self.cfg
        scalar = sds((), i32)

        def prefill_kv(s):  # one prompt's prefill-layout fp KV
            shape = (cfg.num_layers, 1, cfg.num_kv_heads, s, cfg.head_dim)
            return _KVCache(sds(shape, f32), sds(shape, f32))

        def vec(n, dt=i32):
            return sds((n,), dt)

        m = _re.fullmatch(r"prefill_varlen:(\d+)x(\d+)", key)
        if m:
            b, s = map(int, m.groups())
            return (self._pa, sds((b, s), i32), scalar)
        m = _re.fullmatch(r"prefill_split_varlen:(\d+)x(\d+)(:tail)?", key)
        if m:
            b, s = int(m.group(1)), int(m.group(2))
            tokens = sds((b, s), i32)
            if not m.group(3):
                return (self._pa, tokens)
            body = self.engine.programs[key[: -len(":tail")]]
            x_mid, _ = jax.eval_shape(body.fn, self._pa, tokens)
            return (self._pa, x_mid, scalar)
        m = _re.fullmatch(r"prefill_chunk:(\d+)\+(\d+)@(\d+)x(\d+)", key)
        if m:
            c = int(m.group(1))
            return (self._pa, sds((1, c), i32), abstract(self.cache),
                    abstract(self.chunk_prefix), scalar, scalar, scalar)
        m = _re.fullmatch(r"prefill_chunk_paged:(\d+)\+(\d+)@(\d+)x(\d+)", key)
        if m:
            c, bs = int(m.group(1)), int(m.group(4))
            return (self._pa, sds((1, c), i32), abstract(self.paged.kv),
                    abstract(self.chunk_prefix), vec(c // bs), scalar, scalar)
        m = _re.fullmatch(r"relayout:(\d+)x(\d+)->(\d+)", key)
        if m:
            return (prefill_kv(int(m.group(2))),)
        m = _re.fullmatch(r"page_write:(\d+)@(\d+)", key)
        if m:
            s, bs = map(int, m.groups())
            return (abstract(self.paged.kv), prefill_kv(s), vec(s // bs))
        m = _re.fullmatch(r"decode:(\d+)x(\d+)", key)
        if m:
            b = int(m.group(1))
            return (self._pa, vec(b), abstract(self.cache), vec(b))
        m = _re.fullmatch(r"decode_paged:(\d+)x(\d+)", key)
        if m:
            n, mp = map(int, m.groups())
            return (self._pa, vec(n), abstract(self.paged.kv),
                    sds((n, mp), i32), vec(n))
        m = _re.fullmatch(r"verify:(\d+)x(\d+)@(\d+)", key)
        if m:
            b, w = int(m.group(1)), int(m.group(2))
            return (self._pa, sds((b, w), i32), abstract(self.cache),
                    vec(b), vec(b))
        m = _re.fullmatch(r"verify_paged:(\d+)x(\d+)@(\d+)", key)
        if m:
            n, w, mp = map(int, m.groups())
            return (self._pa, sds((n, w), i32), abstract(self.paged.kv),
                    sds((n, mp), i32), vec(n), vec(n))
        m = _re.fullmatch(r"sampler:(\d+)", key)
        if m:
            b = int(m.group(1))
            return (sds((b, cfg.padded_vocab()), f32), vec(b), vec(b),
                    vec(b, jnp.float32), vec(b), vec(b, jnp.float32))
        m = _re.fullmatch(r"block_sampler:(\d+)x(\d+)", key)
        if m:
            b, w = map(int, m.groups())
            return (sds((b, w, cfg.padded_vocab()), f32), vec(b), vec(b),
                    vec(b, jnp.float32), vec(b), vec(b, jnp.float32))
        return None

    def run_prefill_chunk(
        self,
        req: Request,
        slot: int,
        start: int,
        size: int,
        match: Optional[PrefixMatch],
        restarted: bool,
        stats: EngineStats,
    ):
        """Run ONE chunk ``[start, start + size)`` of a request's prefill
        and install its KV (quantize-on-write) — the bounded prefill
        quantum.  Returns the chunk's last-token logits (meaningful only
        for the final chunk).  The install is fused into the chunk program,
        so there is no separate relayout swap to overlap: the fabric flips
        back to decode right after each chunk."""
        padded = self.chunk_bucket(size, start)
        prog = self.chunk_prog(padded, self.prefix_width(start))
        buf = np.zeros((padded,), np.int32)
        buf[:size] = np.asarray(req.prompt[start : start + size], np.int32)
        tokens = jnp.asarray(buf[None])
        t0 = time.perf_counter()  # analysis: allow(det:wallclock) — chunk wall time feeds t_prefill/t_replay stats and a trace span only
        if self.cache_layout == "paged":
            bs = self.block_size
            # start is page-aligned (chunk % bs == 0); prefix-cache hits and
            # padding pages arrive as the OOB skip sentinel and are dropped
            ids = self.paged.page_ids_for_write(
                match, padded // bs, first_page=start // bs)
            logits, self.paged.kv, self.chunk_prefix = prog.fn(
                self.params, tokens, self.paged.kv, self.chunk_prefix,
                ids, start, size - 1)
        else:
            logits, self.cache, self.chunk_prefix = prog.fn(
                self.params, tokens, self.cache, self.chunk_prefix, slot,
                start, size - 1)
        jax.block_until_ready(logits)
        t1 = time.perf_counter()  # analysis: allow(det:wallclock) — chunk wall time feeds t_prefill/t_replay stats and a trace span only
        if restarted:  # restart re-prefill is recompute overhead, not load
            stats.t_replay += t1 - t0
        else:
            stats.t_prefill += t1 - t0
        stats.prefill_chunks += 1
        if TRACER.enabled:
            TRACER.complete("prefill.chunk", t0, t1,
                            request_id=req.request_id, start=start, size=size)
        return logits

    # ------------------------------------------------------------- prefill --

    def restart_headroom_ok(self, req: Request) -> bool:
        """Admit a restart only when the pool can hold its FULL replayed
        state (prompt + already-generated tokens).  Without this, two
        restarts admitted back to back each preempt the other during replay
        and the admission loop livelocks with zero decode progress.
        (Conservative: prefix hits on live pages would reduce the true
        need.)"""
        need = cdiv(len(req.prompt) + len(req.out_tokens) - 1, self.block_size)
        return self.paged.pool.num_free >= need

    def prefill(self, req: Request, slot: int, resuming: bool, stats: EngineStats):
        """Run the prefill phase for one admitted request and install its KV
        into the decode cache (the swap, latency-overlapped in pdswap mode).
        Returns the prompt's last-token logits, shape (1, V).  Raises
        ``PoolExhausted`` (after full rollback) when the paged pool cannot
        hold the prompt."""
        tokens_np = np.asarray(req.prompt, np.int32)
        n = len(tokens_np)
        bucket = self.bucket(n)
        progs = self.progs(bucket)

        match = None
        if self.cache_layout == "paged":
            match = self.paged.allocate_prompt(slot, tokens_np)  # may raise
            if not resuming:
                # engine-level counters reflect the OFFERED load; a restart's
                # self-hits on its own just-evicted pages would inflate them
                # (pool.stats keeps the raw counts)
                n_full = n // self.block_size
                stats.prefix_hits += match.cached_pages
                stats.prefix_misses += n_full - match.cached_pages
                stats.prefix_hit_tokens += match.cached_pages * self.block_size

        padded = np.zeros((bucket,), np.int32)
        padded[:n] = tokens_np
        tokens = jnp.asarray(padded[None])
        last_pos = jnp.int32(n - 1)

        def swap_write(kv):
            """Install prefilled KV into the decode cache — the swap payload
            whose dispatch the overlap hides behind the prefill tail."""
            if self.cache_layout == "paged":
                ids = self.paged.page_ids_for_write(match, bucket // self.block_size)
                self.paged.kv = progs["write"].fn(self.paged.kv, kv, ids)
                return self.paged.kv
            if self.mode == "pdswap":
                relayed = progs["relayout"].fn(kv)
            else:
                relayed = self.relay_static(kv)
            self.cache = insert_prefill_kv(self.cache, relayed, slot, n)
            return self.cache

        t0 = time.perf_counter()  # analysis: allow(det:wallclock) — prefill wall time feeds t_prefill/t_replay stats only
        if self.mode == "pdswap":
            # SwapController owns the overlap protocol (dispatch the swap
            # first, decode waits for both — paper §3.4); swap_write is this
            # request's relayout payload.
            ctl = SwapController(
                progs["body"].fn,
                lambda p, x: progs["tail"].fn(p, x, last_pos),
                swap_write,
            )
            logits, _, timing = ctl.prefill_and_swap(
                self.params, tokens, overlap=self.overlap
            )
            if not resuming:
                stats.record_swap(timing)
            if TRACER.enabled:
                TRACER.instant("swap", request_id=req.request_id,
                               t_relayout=timing.t_relayout,
                               hidden_fraction=timing.hidden_fraction)
        else:
            logits, kv = progs["full"].fn(self.params, tokens, last_pos)
            swap_write(kv)
        # restarts are recompute overhead, not offered load: their prefill
        # time joins t_replay and they never re-count prefill_tokens/swaps
        t1 = time.perf_counter()  # analysis: allow(det:wallclock) — prefill wall time feeds t_prefill/t_replay stats only
        if resuming:
            stats.t_replay += t1 - t0
        else:
            stats.t_prefill += t1 - t0
            stats.prefill_tokens += n
        if TRACER.enabled:
            TRACER.complete("prefill", t0, t1, request_id=req.request_id,
                            tokens=n, resuming=resuming)

        if self.cache_layout == "paged":
            self.paged.register_prompt_pages(match)
        return logits

    # -------------------------------------------------------------- decode --

    def decode_logits(self, lengths) -> jnp.ndarray:
        """One decode round through the phase program; updates the cache in
        place (donated) and returns the (B, V) logits."""
        if self.cache_layout == "paged":
            tables = self.paged.block_tables_array()
            logits, self.paged.kv = self.decode_prog.fn(
                self.params, self.last_tokens, self.paged.kv, tables, lengths
            )
        else:
            logits, self.cache = self.decode_prog.fn(
                self.params, self.last_tokens, self.cache, lengths
            )
        return logits

    # -------------------------------------------------- speculative decode --

    def draft_for(self, req: Request, slot: int) -> np.ndarray:
        """Clamped prompt-lookup draft for one DECODING slot (host-side).

        The proposal depth is ``spec_decode`` clamped to the slot's real
        headroom, so a verify round can never write live KV where it must
        not land:

        * budget — at most ``max_new - generated - 1`` drafts are useful
          (the round's last emitted token never becomes an input, so its
          KV is never needed — exactly the non-speculative invariant);
        * cache — live verify rows must stay ``<= max_len - 2``: row
          ``max_len - 1`` is the chunked-prefill parked-write row, whose
          whole trick is that live KV NEVER occupies it (a k-token append
          would otherwise break the invariant silently — satellite fix,
          asserted again at round build time).

        The paged trajectory bound needs no extra clamp: with the budget
        clamp the deepest verify write is position ``prompt + max_new - 2``,
        inside the pages the admission trajectory check already reserved.
        """
        s = self.slots.slots[slot]
        k = min(
            self.spec_decode,
            req.max_new - s.generated - 1,
            self.max_len - 2 - s.length,
        )
        if k <= 0:
            return np.zeros((0,), np.int32)
        from repro.serving.spec_decode import find_draft

        ctx = np.concatenate(
            [np.asarray(req.prompt, np.int32),
             np.asarray(req.out_tokens, np.int32)]) if req.out_tokens else (
            np.asarray(req.prompt, np.int32))
        return find_draft(ctx, k, self.spec_ngram)

    def run_verify(self, tokens, lengths, n_tokens) -> jnp.ndarray:
        """One speculative verify round: score every slot's (last token +
        draft) block in one forward, install the block KV in place
        (quantize-on-write; rows past ``n_tokens`` dropped).  Returns the
        (B, W, V) logits — the per-position verify targets."""
        if self.cache_layout == "paged":
            tables = self.paged.block_tables_array()
            logits, self.paged.kv = self.verify_prog.fn(
                self.params, tokens, self.paged.kv, tables, lengths, n_tokens
            )
        else:
            logits, self.cache = self.verify_prog.fn(
                self.params, tokens, self.cache, lengths, n_tokens
            )
        return logits

    def rollback_overshoot(self, slot: int, length: int) -> None:
        """Roll rejected verify rows back.  Contiguous: a no-op — rows past
        the slot length are garbage the per-slot masking never reads, and
        any position is rewritten before the length grows past it.  Paged:
        release the overshoot pages so rejections cannot leak pool
        capacity (or hold COW forks alive) across rounds."""
        if self.cache_layout == "paged":
            self.paged.truncate_slot(slot, length)

    def select_targets(self, logits, inflight: Dict[int, Request]) -> jnp.ndarray:
        """Per-position verify targets, (B, W) int32 — what sequential
        decode would have produced at each block position.  All-greedy
        batches take the direct argmax (the decode hot path); any sampling
        request routes through the vectorized block sampler, whose PRNG
        key for (slot, position i) is ``fold_in(seed, generated + i)`` —
        the sequential stream's exact keys."""
        if all(r.params.greedy for r in inflight.values()):
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        step0s = np.zeros(self.slots.n_slots, np.int32)
        for s, r in inflight.items():
            step0s[s] = len(r.out_tokens)
        prog = self.engine.block_sampler_program(self.slots.n_slots, logits.shape[1])
        return prog.fn(
            logits, jnp.asarray(self._seeds), jnp.asarray(step0s),
            jnp.asarray(self._temps), jnp.asarray(self._top_ks),
            jnp.asarray(self._top_ps),
        )

    # ------------------------------------------------------------- sampler --

    def set_slot_sampling(self, slot: int, req: Request) -> None:
        p = req.params
        self._seeds[slot] = p.seed32
        self._temps[slot] = p.temperature
        self._top_ks[slot] = p.top_k
        self._top_ps[slot] = p.top_p

    def sample_batch(self, logits, inflight: Dict[int, Request]) -> jnp.ndarray:
        """Next token for every slot, (B,) int32.  All-greedy batches take
        the direct argmax path (the PR-1 hot path); any sampling request
        routes the whole batch through the vectorized sampler program
        (greedy slots still resolve to argmax inside it)."""
        if all(r.params.greedy for r in inflight.values()):
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        steps = np.zeros(self.slots.n_slots, np.int32)
        for s, r in inflight.items():
            steps[s] = len(r.out_tokens)
        prog = self.engine.sampler_program(self.slots.n_slots)
        return prog.fn(
            logits, jnp.asarray(self._seeds), jnp.asarray(steps),
            jnp.asarray(self._temps), jnp.asarray(self._top_ks),
            jnp.asarray(self._top_ps),
        )

    def sample_first(self, logits, req: Request) -> int:
        """The prompt's first generated token, from the prefill logits."""
        if req.params.greedy:
            return int(jnp.argmax(logits[0]))
        p = req.params
        prog = self.engine.sampler_program(1)
        tok = prog.fn(
            logits[:1],
            jnp.asarray([p.seed32], jnp.int32),
            jnp.asarray([len(req.out_tokens)], jnp.int32),
            jnp.asarray([p.temperature], jnp.float32),
            jnp.asarray([p.top_k], jnp.int32),
            jnp.asarray([p.top_p], jnp.float32),
        )
        return int(tok[0])

    # -------------------------------------------------- paged bookkeeping --

    def append_page(self, slot: int, length: int) -> None:
        """Make position ``length`` writable, forking shared (copy-on-write)
        pages.  Raises ``PoolExhausted`` when the pool cannot grow — the
        EngineCore preemption loop handles that."""
        copy = self.paged.ensure_append_page(slot, length)
        if copy is not None:
            dst, src = copy
            # device copy of every page plane — payload AND (quantized) the
            # fp32 scale rows travel together, so the fork is exact
            self.paged.kv = jax.tree.map(
                lambda a: a.at[dst].set(a[src]), self.paged.kv
            )

    def replay(self, slot: int, req: Request, stats: EngineStats) -> bool:
        """Teacher-force the recorded tokens of a preemption restart through
        the decode program.  All other slots are masked (length 0): the paged
        scatter drops them, their pages and outputs are untouched.

        Replay never preempts — the admission headroom check reserved its
        pages; only decode-time growth (which generates NEW tokens every
        round, so it always makes progress) may evict.  Returns False if the
        pool is unexpectedly short anyway; the caller backs off.

        Replay wall time lands in ``stats.t_replay`` — blocking here keeps
        the async-dispatched replay compute from leaking into the next
        decode round's ``t_decode`` (it would skew decode_tput)."""
        p = len(req.prompt)
        n_slots = self.slots.n_slots
        t0 = time.perf_counter()  # analysis: allow(det:wallclock) — replay wall time feeds t_replay stats and a trace span only
        for j, tok in enumerate(req.out_tokens[:-1]):
            pos = p + j
            try:
                copy = self.paged.ensure_append_page(slot, pos)
            except PoolExhausted:
                return False
            assert copy is None  # replay appends past the prompt: no CoW
            tokens = np.zeros((n_slots,), np.int32)
            tokens[slot] = tok
            lengths = np.zeros((n_slots,), np.int32)
            lengths[slot] = pos
            tables = self.paged.block_tables_array()
            _, self.paged.kv = self.decode_prog.fn(
                self.params, jnp.asarray(tokens), self.paged.kv, tables,
                jnp.asarray(lengths),
            )
            stats.replayed_tokens += 1
        jax.block_until_ready(jax.tree.leaves(self.paged.kv))
        t1 = time.perf_counter()  # analysis: allow(det:wallclock) — replay wall time feeds t_replay stats and a trace span only
        stats.t_replay += t1 - t0
        if TRACER.enabled:
            TRACER.complete("replay", t0, t1, request_id=req.request_id,
                            tokens=max(len(req.out_tokens) - 1, 0))
        return True

    def release(self, slot: int) -> None:
        self.slots.release(slot)
        if self.cache_layout == "paged":
            self.paged.release_slot(slot)

    # ------------------------------------------------------------- metrics --

    def kv_bytes(self) -> dict:
        """KV memory accounting for the benchmark: bytes reserved up front vs
        the peak actually backing live tokens.  ``payload`` is the packed
        K/V bytes alone (scale planes excluded) — the term ``kv_dtype``
        shrinks 2x (int8) / 4x (int4) against the fp cache."""
        from repro.quant.kv_quant import payload_bytes, total_nbytes

        if self.cache_layout == "paged":
            return {
                "allocated": self.paged.pool_bytes(),
                "peak_in_use": self.paged.peak_live_pages * self.paged.page_bytes(),
                "page_bytes": self.paged.page_bytes(),
                "payload": self.paged.num_blocks * self.paged.page_payload_bytes(),
                "kv_dtype": self.kv_dtype,
            }
        nbytes = total_nbytes(self.cache)
        return {"allocated": nbytes, "peak_in_use": nbytes, "page_bytes": 0,
                "payload": payload_bytes(self.cache), "kv_dtype": self.kv_dtype}


class Scheduler:
    """Admission, preemption, fair queueing, and the swap decision."""

    def __init__(self, runner: ModelRunner, policy: SwapPolicy):
        self.runner = runner
        self.policy = policy
        # per-tenant weighted fair queue (deficit round robin); exact FIFO
        # with a single tenant, so the PR-2 scheduling is unchanged by
        # default — see serving.fair_queue
        self.queue = WeightedFairQueue()
        self.inflight: Dict[int, Request] = {}

    def validate(self, request: Request) -> None:
        """Admission validation, raising ``ValueError`` with the rejection
        reason.  Pure host arithmetic over engine constants — safe to call
        from the async front-end while a step runs."""
        if request.params.max_tokens is not None:
            request.max_new = request.params.max_tokens
        n = int(len(request.prompt))
        if n < 1:
            raise ValueError(f"{request.request_id}: empty prompt")
        if n + request.max_new > self.runner.max_len:
            raise ValueError(
                f"{request.request_id}: prompt ({n} tokens) + max_new "
                f"({request.max_new}) exceeds max_len={self.runner.max_len}; "
                "prompts are never truncated — raise max_len or split the request"
            )
        if self.runner.cache_layout == "paged":
            traj = cdiv(n + request.max_new - 1, self.runner.block_size)
            if traj > self.runner.paged.num_blocks:
                raise ValueError(
                    f"{request.request_id}: needs {traj} KV pages over its "
                    f"lifetime but the pool holds {self.runner.paged.num_blocks}; "
                    "raise num_blocks or lower max_new (a request that can "
                    "never fit would self-preempt forever)"
                )

    def submit(self, request: Request) -> None:
        self.validate(request)
        now = time.perf_counter()  # analysis: allow(det:wallclock) — arrival stamp meters queue wait / TTFT and feeds SLO pacing, never token values
        if request.arrival_time_s == 0.0:
            # the client-visible arrival: stamped ONCE at first submit, so
            # TTFT measured downstream includes all queueing delay (the
            # async front-end stamps even earlier, at its admission queue)
            request.arrival_time_s = now
        request.enqueue_t = now
        self.queue.append(request)
        if TRACER.enabled:
            TRACER.instant("req.submit", request_id=request.request_id,
                           tenant=request.tenant)

    def requeue_head(self, request: Request) -> None:
        self.queue.appendleft(request)

    def remove_queued(self, request_id: str) -> Optional[Request]:
        """Pull a request out of the wait queue (abort path)."""
        return self.queue.remove(request_id)

    def enter_prefill_phase(self, stats: EngineStats, *, pending_chunks: int = 0) -> bool:
        """The swap decision: flip into the prefill phase this step?  Called
        when work is queued and a slot is free, or (chunked prefill) when a
        partially-prefilled request has chunks pending — ``pending_chunks``
        carries that count into the view so a policy can reason about
        in-flight prefill work.  An empty DECODING set bypasses the policy —
        with nothing decoding the flip has no opportunity cost, and this
        guarantees progress under any policy.  (``active_slots`` counts
        decoding slots only; a mid-prefill slot is occupied but produces no
        tokens the flip could stall.)"""
        active = len(self.inflight)
        if active == 0:
            return True
        head = self.queue.peek()
        oldest = (time.perf_counter() - head.arrival_time_s  # analysis: allow(det:wallclock) — queue-age feeds the swap policy's pacing view, not any stream's token values
                  if head is not None and head.arrival_time_s else 0.0)
        view = SchedulerView(
            queue_depth=len(self.queue),
            free_slots=len(self.runner.slots.free_slots()),
            active_slots=active,
            swap_cost=stats.swap_agg.mean_cost,
            decode_round_cost=stats.decode_round_cost(),
            pending_chunks=pending_chunks,
            oldest_wait_s=oldest,
        )
        return self.policy.should_prefill(view)

    def pick_victim(self) -> Optional[int]:
        """Lowest-priority inflight slot; ties broken youngest-first."""
        if not self.inflight:
            return None
        return min(
            self.inflight,
            key=lambda s: (self.inflight[s].priority, -self.inflight[s].enqueue_t),
        )

    def preempt(self, slot: int, stats: EngineStats) -> None:
        """Evict one request: free its pages, requeue it for a deterministic
        restart (re-prefill the prompt, replay the generated tokens)."""
        req = self.inflight.pop(slot)
        req.preempted = True
        self.runner.release(slot)
        stats.preemptions += 1
        self.queue.appendleft(req)
        if TRACER.enabled:
            TRACER.instant("req.preempt", request_id=req.request_id, slot=slot)


class EngineCore:
    """The incremental serving core; one ``step()`` = one scheduling quantum."""

    # The runner to build — the one seam a subclass needs to change what is
    # compiled and device-resident while inheriting every scheduling,
    # preemption, chunking, and speculative-decode path unchanged (the
    # disaggregated engine swaps in a runner whose prefill computes on a
    # separate pool; see serving.disagg).
    runner_cls = ModelRunner

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        n_slots: int = 4,
        max_len: int = 256,
        prompt_len: int = 32,
        mode: str = "pdswap",  # "pdswap" | "static"
        cache_layout: str = "contiguous",  # "contiguous" | "paged"
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        kv_dtype: str = "fp",  # "fp" | "int8" | "int4" — quantized KV cache
        mesh=None,
        overlap: bool = True,
        swap_policy: Union[SwapPolicy, str, None] = None,
        prefill_chunk: Optional[int] = None,  # tokens per prefill quantum (None = monolithic)
        spec_decode: Optional[int] = None,  # speculative draft depth k (None/0 = off)
        spec_ngram: int = 3,  # prompt-lookup n-gram size
    ):
        self.cfg = cfg
        self.runner = self.runner_cls(
            cfg, params, n_slots=n_slots, max_len=max_len, prompt_len=prompt_len,
            mode=mode, cache_layout=cache_layout, block_size=block_size,
            num_blocks=num_blocks, kv_dtype=kv_dtype, mesh=mesh, overlap=overlap,
            prefill_chunk=prefill_chunk, spec_decode=spec_decode,
            spec_ngram=spec_ngram,
        )
        # slot -> partially-prefilled request state (chunked prefill only);
        # insertion order is admission order, so continuation is FIFO
        self._prefilling: Dict[int, PrefillProgress] = {}
        if swap_policy is None:
            swap_policy = DrainPolicy()
        elif isinstance(swap_policy, str):
            swap_policy = make_policy(swap_policy)
        self.scheduler = Scheduler(self.runner, swap_policy)
        self.stats = EngineStats()
        # latency-observing policies (SLOAwareSwapPolicy) read the engine's
        # own aggregates — bind() closes the control loop
        if hasattr(swap_policy, "bind"):
            swap_policy.bind(self.stats)
        self.out_proc = OutputProcessor(stats=self.stats)
        self.finished: Dict[str, Request] = {}
        self._gen_seq = 0

    # ------------------------------------------------------------- client --

    @property
    def mode(self) -> str:
        return self.runner.mode

    @property
    def cache_layout(self) -> str:
        return self.runner.cache_layout

    @property
    def kv_dtype(self) -> str:
        return self.runner.kv_dtype

    @property
    def prefill_chunk(self) -> Optional[int]:
        return self.runner.prefill_chunk

    @property
    def spec_decode(self) -> Optional[int]:
        return self.runner.spec_decode

    def submit(self, request: Request) -> None:
        self.scheduler.submit(request)

    def has_unfinished(self) -> bool:
        return bool(self.scheduler.queue or self.runner.slots.active_slots())

    def abort(self, request_id: str) -> Optional[RequestOutput]:
        """Cancel one request wherever it currently lives — the wait queue,
        mid-(chunked-)prefill, or decoding (plain or speculative) — and
        release everything it holds: the slot and, paged, every page its
        table references (prefix-cache pages it shares merely drop a
        refcount; pages it wrote exclusively return to the pool/evictable
        set, so pool accounting returns to its pre-request baseline).

        Returns the terminal zero-delta output (``finish_reason="abort"``)
        the stream is owed, or ``None`` when the id is unknown or already
        finished (abort after finish is a harmless no-op).  Call between
        ``step()`` calls — the async front-end serializes aborts onto the
        step loop for exactly that reason."""
        req = self.scheduler.remove_queued(request_id)
        if req is None:
            for slot, prog in list(self._prefilling.items()):
                if prog.req.request_id == request_id:
                    del self._prefilling[slot]
                    self.runner.release(slot)
                    req = prog.req
                    break
        if req is None:
            for slot, r in list(self.scheduler.inflight.items()):
                if r.request_id == request_id:
                    self.scheduler.inflight.pop(slot)
                    self.runner.release(slot)
                    req = r
                    break
        if req is None:
            return None
        self.stats.aborts += 1
        if TRACER.enabled:
            TRACER.instant("req.abort", request_id=request_id)
        out = self.out_proc.finalize_aborted(req)
        self.finished[req.request_id] = req
        return out

    def snapshot(self) -> dict:
        """The one stats block benchmarks and the /stats endpoint emit —
        built by ``obs.engine.engine_snapshot`` (the single builder every
        front-end shares): ``EngineStats.snapshot()`` plus KV accounting,
        the per-tenant fair-queue view, roofline drift, and any subclass
        sections (``snapshot_sections``)."""
        from repro.obs.engine import engine_snapshot

        return engine_snapshot(self)

    def snapshot_sections(self) -> dict:
        """Subclass hook: extra top-level sections for ``snapshot()``
        (the disagg engine adds its pool/handoff view here) — override
        THIS, not ``snapshot()``, so the block shape can't drift."""
        return {}

    def metrics_registry(self):
        """The typed metrics registry over this engine (built once; every
        metric is a live callback view, so one registry serves all
        scrapes — see ``obs.engine.engine_registry``)."""
        if getattr(self, "_metrics_registry", None) is None:
            from repro.obs.engine import engine_registry

            self._metrics_registry = engine_registry(self)
        return self._metrics_registry

    def snapshot_v2(self) -> dict:
        """Structured typed export (``{"schema": "v2", counters/gauges/
        histograms}``) of the same numbers ``/metrics`` serves."""
        from repro.obs.engine import snapshot_v2

        return snapshot_v2(self, registry=self.metrics_registry())

    def reset_stats(self) -> None:
        """Swap in a fresh ``EngineStats`` — benchmarks call this after a
        warmup pass so XLA compilation never lands in the measured
        aggregates.  Everything that holds the stats object is re-bound:
        the output processor and (when the policy observes, e.g.
        slo-aware) the swap policy, whose defer state is reset too."""
        self.stats = EngineStats()
        self.out_proc = OutputProcessor(stats=self.stats)
        policy = self.scheduler.policy
        if hasattr(policy, "bind"):
            policy.bind(self.stats)
        if hasattr(policy, "reset"):
            policy.reset()

    # --------------------------------------------------------------- step --

    def step(self) -> List[RequestOutput]:
        """Advance one scheduling quantum.

        Monolithic prefill (``prefill_chunk=None``): a policy-gated prefill
        burst (admitting queued requests into free slots, one swap each),
        then one decode round over the active slots — the PR-2 behavior,
        token-for-token.

        Chunked prefill: at most ONE chunk of pending prefill (continue the
        partially-prefilled request, or admit the queue head and run its
        first chunk), then one decode round over the DECODING slots — so a
        long prompt's prefill is spread over many quanta and active streams
        get a token between every pair of chunks instead of stalling for
        the whole burst.  Returns every streaming output the quantum
        produced."""
        t_step0 = time.perf_counter() if TRACER.enabled else 0.0  # analysis: allow(det:wallclock) — trace-span stamp, recorded only while tracing
        outs: List[RequestOutput] = []
        sched, runner = self.scheduler, self.runner
        # SLO admission control: a policy that knows the TTFT deadline may
        # shed queue heads that can no longer meet it.  A doomed request
        # counts against goodput whether it is served late or dropped —
        # but serving it also queues everyone BEHIND it past their
        # deadlines, so shedding converts one unavoidable miss into
        # capacity for requests that can still hit their targets.  Only
        # policies exposing ``should_shed`` participate; the static
        # policies serve every admitted request, late or not.
        shed = getattr(sched.policy, "should_shed", None)
        if shed is not None:
            now = time.perf_counter()  # analysis: allow(det:wallclock) — shed deadline check paces admission (drop-or-serve), never token values
            while sched.queue:
                head = sched.queue[0]
                if head.out_tokens or getattr(head, "preempted", False):
                    # a preempted / partially-served request is in-flight
                    # state awaiting replay, not a new admission — dropping
                    # it is not admission control
                    break
                wait = (now - head.arrival_time_s) if head.arrival_time_s else 0.0
                if not shed(wait):
                    break
                sched.queue.popleft()
                self.stats.sheds += 1
                if TRACER.enabled:
                    TRACER.instant("req.shed", request_id=head.request_id,
                                   wait_s=wait)
                outs.append(self.out_proc.finalize_dropped(head, "shed"))
                self.finished[head.request_id] = head
        if runner.prefill_chunk is not None:
            # An SLO-aware policy can widen the EFFECTIVE prefill chunk by
            # granting several chunk quanta back to back before the decode
            # round (prefill_quanta > 1 when observed ITL has budget slack,
            # or TTFT is violating).  Greedy outputs are invariant to
            # chunking, so this steers latency only.  Default policies run
            # exactly one quantum — the PR-4 behavior.
            pq = getattr(sched.policy, "prefill_quanta", None)
            ran = 0
            while True:
                before = self.stats.prefill_chunks
                outs.extend(self._chunked_prefill_quantum())
                if self.stats.prefill_chunks == before:
                    break  # deferred, blocked, or no prefill work pending
                ran += 1
                # re-consult AFTER each executed quantum: the policy's view
                # was refreshed by that quantum's should_prefill, so the
                # decision tracks the CURRENT decode set — deciding the
                # whole width up front from last step's state widened into
                # a set that had just started decoding (a one-step-stale
                # "empty set" stalls the new stream for the full width)
                if pq is None or ran >= max(1, int(pq())):
                    break
        elif sched.queue and runner.slots.free_slots() and sched.enter_prefill_phase(self.stats):
            admitted = 0
            while sched.queue and runner.slots.free_slots():
                ok, out = self._admit_one(sched.queue.popleft())
                if out is not None:
                    outs.append(out)
                if not ok:
                    if not runner.slots.active_slots():
                        self._unblock_admission_or_raise()
                    break  # decode to drain capacity, then retry admission
                admitted += 1
            if admitted:
                self.stats.prefill_bursts += 1
        if sched.inflight:
            outs.extend(self._decode_round())
        if not self.has_unfinished():
            sched.policy.reset()
        if TRACER.enabled and t_step0:
            TRACER.complete("engine.step", t_step0, time.perf_counter(),  # analysis: allow(det:wallclock) — trace-span stamp, recorded only while tracing
                            outputs=len(outs))
        return outs

    def _unblock_admission_or_raise(self) -> None:
        """The queue head failed admission with ZERO active slots — nothing
        is decoding, so no capacity will drain on its own.  Before
        declaring livelock, shed every refcount-0 prefix-cache page and
        let the next step retry: the old code raised unconditionally, an
        assertion of impossibility it never verified.  (``alloc()``
        already consumes the evictable LRU page by page, so today the
        retry mostly re-proves the failure — the eviction makes the raise
        correct by construction for ANY admission path, including future
        ones that reserve capacity via ``num_free`` checks rather than
        ``alloc()``.)"""
        runner = self.runner
        if runner.cache_layout == "paged" and runner.paged.pool.evict_all_cached():
            return
        head = self.scheduler.queue[0]
        raise RuntimeError(
            f"{head.request_id} can never be admitted: needs more "
            f"pages than the pool holds ({runner.paged.num_blocks} "
            f"blocks x {runner.block_size} tokens)"
        )

    # ----------------------------------------------------- chunked prefill --

    def _pending_chunks(self) -> int:
        return sum(p.remaining_chunks for p in self._prefilling.values())

    def _chunked_prefill_quantum(self) -> List[RequestOutput]:
        """At most one chunk of pending prefill this quantum: continue the
        oldest partially-prefilled request, or — none pending — admit the
        queue head and run its first chunk.  Both are policy-gated (the
        view carries the pending-chunk count), and each chunk executed is
        one fabric flip (``prefill_bursts``)."""
        sched, runner = self.scheduler, self.runner
        if self._prefilling:
            if not sched.enter_prefill_phase(
                    self.stats, pending_chunks=self._pending_chunks()):
                return []
            slot = next(iter(self._prefilling))
            return self._advance_chunk(self._prefilling[slot])
        if not (sched.queue and runner.slots.free_slots()):
            return []
        if not sched.enter_prefill_phase(self.stats):
            return []
        ok, outs = self._admit_one_chunked(sched.queue.popleft())
        if not ok and not sched.inflight:
            self._unblock_admission_or_raise()
        return outs

    def _admit_one_chunked(self, req: Request):
        """Chunked admission: reserve the slot (and, paged, ALL prompt
        pages — chunk writes then land in a stable page plan), then run the
        first chunk.  Returns ``(ok, outputs)`` with the same blocked-
        admission contract as ``_admit_one``."""
        runner, stats = self.runner, self.stats
        out = self._finish_resumed_at_budget(req)
        if out is not None:
            return True, [out]
        resuming = req.preempted and bool(req.out_tokens)
        restarted = req.preempted  # mid-prefill evictions restart with no tokens

        if runner.cache_layout == "paged" and resuming and not runner.restart_headroom_ok(req):
            self._block_admission(req)
            return False, []

        slot = runner.slots.assign(req.request_id, len(req.prompt), req.max_new)
        runner.set_slot_sampling(slot, req)
        match = None
        if runner.cache_layout == "paged":
            try:
                match = runner.paged.allocate_prompt(slot, np.asarray(req.prompt, np.int32))
            except PoolExhausted:
                self._block_admission(req, slot)
                return False, []
            if not restarted:
                n_full = len(req.prompt) // runner.block_size
                stats.prefix_hits += match.cached_pages
                stats.prefix_misses += n_full - match.cached_pages
                stats.prefix_hit_tokens += match.cached_pages * runner.block_size
        if not restarted:
            # Offered load is charged once, at the FIRST admission — a
            # restart (with or without recorded tokens) re-prefills as
            # recompute overhead (t_replay) and must not re-count.  One
            # logical swap per request, as in the monolithic path; the
            # install is fused into the chunk programs, so there is no
            # separate relayout latency to overlap/record (no SwapTiming).
            stats.prefill_tokens += len(req.prompt)
            stats.swaps += 1

        self._record_admission(req)
        # the shared fp prefix mirror (runner.chunk_prefix) supports exactly
        # one in-flight chunked prefill — _chunked_prefill_quantum only
        # admits when none is pending, and this guards the invariant
        assert not self._prefilling, "one chunked prefill in flight at a time"
        prog = PrefillProgress(req, slot, resuming, restarted,
                               sizes=runner.chunk_sizes(len(req.prompt)), match=match)
        self._prefilling[slot] = prog
        return True, self._advance_chunk(prog)

    def _advance_chunk(self, prog: PrefillProgress) -> List[RequestOutput]:
        """Run one chunk; on the final chunk, finish the prefill (first
        token / replay) and hand the slot to the decode set."""
        runner, stats = self.runner, self.stats
        size = prog.sizes[prog.ci]
        logits = runner.run_prefill_chunk(
            prog.req, prog.slot, prog.pos, size, prog.match, prog.restarted, stats)
        prog.ci += 1
        prog.pos += size
        stats.prefill_bursts += 1
        if prog.ci < len(prog.sizes):
            return []
        del self._prefilling[prog.slot]
        return self._finish_chunked_prefill(prog, logits)

    def _finish_chunked_prefill(self, prog: PrefillProgress, logits) -> List[RequestOutput]:
        """The post-prefill half of ``_admit_one`` for the chunked path:
        publish prefix pages, then the shared ``_finish_prefill`` handoff
        (restart replay or first-token sampling -> decode set)."""
        if self.runner.cache_layout == "paged":
            self.runner.paged.register_prompt_pages(prog.match)
        _, out = self._finish_prefill(prog.req, prog.slot, logits, prog.resuming)
        return [out] if out is not None else []

    def _preempt_prefilling(self, slot: int) -> None:
        """Evict a partially-prefilled request (decode growth exhausted the
        pool and every decoding request is already gone): requeue it for a
        deterministic chunked restart — same chunk boundaries, so the
        replayed trajectory stays bit-identical."""
        prog = self._prefilling.pop(slot)
        prog.req.preempted = True
        self.runner.release(slot)
        self.stats.preemptions += 1
        self.scheduler.queue.appendleft(prog.req)
        if TRACER.enabled:
            TRACER.instant("req.preempt", request_id=prog.req.request_id,
                           slot=slot, mid_prefill=True)

    def run(self, max_rounds: int = 10_000) -> EngineStats:
        """Compatibility loop: the PR-1 ``ServingEngine.run()`` drain-then-
        decode scheduling is ``step()`` under greedy + DrainPolicy."""
        rounds = 0
        while self.has_unfinished() and rounds < max_rounds:
            rounds += 1
            self.step()
        return self.stats

    def generate(
        self,
        prompt,
        params: Optional[SamplingParams] = None,
        *,
        request_id: Optional[str] = None,
        max_new: Optional[int] = None,
        priority: int = 0,
        max_steps: int = 10_000,
    ) -> Iterator[RequestOutput]:
        """Submit one request and stream its outputs as they are produced.

        Other queued/inflight requests keep being served by the same
        ``step()`` calls; their outputs are retained on their Request
        objects (and in ``finished``) as usual.
        """
        if params is None:
            params = SamplingParams()
        prompt = np.asarray(prompt, np.int32)
        if max_new is None:
            if params.max_tokens is not None:
                max_new = params.max_tokens  # submit() applies the override
            else:
                # default to the request's full slot headroom — the old
                # silent cap of 16 truncated any longer generation the
                # caller never asked to limit.  The paged layout further
                # clamps to what the pool can hold over the request's
                # lifetime (submit() rejects trajectories that can never
                # fit; an unbudgeted generate() should degrade, not raise)
                max_new = self.runner.max_len - len(prompt)
                if self.runner.cache_layout == "paged":
                    pool_tokens = (self.runner.paged.num_blocks
                                   * self.runner.block_size)
                    max_new = min(max_new, pool_tokens - len(prompt) + 1)
                max_new = max(1, max_new)
        self._gen_seq += 1
        rid = request_id or f"gen-{self._gen_seq}"
        req = Request(rid, prompt, max_new=max_new,
                      priority=priority, params=params)
        self.submit(req)
        for _ in range(max_steps):
            for out in self.step():
                if out.request_id == rid:
                    yield out
                    if out.finished:
                        return
        raise RuntimeError(f"{rid} did not finish within {max_steps} steps")

    # ---------------------------------------------------------- admission --

    def _finish_resumed_at_budget(self, req: Request) -> Optional[RequestOutput]:
        """A replayed request whose recorded trajectory already fills its
        ``max_new`` budget has nothing left to generate — finish it HERE,
        before admission burns a slot, a full prompt prefill and a
        teacher-forced replay just to discard the rebuilt cache state
        (the finished condition is pure host arithmetic on
        ``(len(out_tokens), max_new)``).  Returns the terminal zero-delta
        output, or None when the request really needs a slot."""
        if not (req.preempted and req.out_tokens
                and len(req.out_tokens) >= req.max_new):
            return None
        req.preempted = False
        if req.first_token_t == 0.0:
            # same safety net as the replay path: recorded tokens normally
            # carry a stamp from their original admission
            req.first_token_t = time.perf_counter()  # analysis: allow(det:wallclock) — TTFT safety-net stamp for pre-seeded resumes; stats only
        out = self.out_proc.finalize_resumed(req)
        self.finished[req.request_id] = req
        return out

    def _admit_one(self, req: Request):
        """Admit one request into a slot (the old ``_prefill_one``).
        Returns ``(ok, output)``: ``ok=False`` means admission is blocked
        (paged pool exhausted) — the request went back to the queue head and
        the engine should decode to drain capacity first."""
        runner, stats = self.runner, self.stats
        out = self._finish_resumed_at_budget(req)
        if out is not None:
            return True, out
        resuming = req.preempted and bool(req.out_tokens)

        if runner.cache_layout == "paged" and resuming and not runner.restart_headroom_ok(req):
            self._block_admission(req)
            return False, None

        slot = runner.slots.assign(req.request_id, len(req.prompt), req.max_new)
        runner.set_slot_sampling(slot, req)
        try:
            logits = runner.prefill(req, slot, resuming, stats)
        except PoolExhausted:
            self._block_admission(req, slot)
            return False, None
        self._record_admission(req)

        return self._finish_prefill(req, slot, logits, resuming)

    def _record_admission(self, req: Request) -> None:
        """Stamp arrival -> first-successful-admission queue wait, exactly
        once per request (a preemption restart keeps its original stamp —
        the client waited once, at the front of the stream)."""
        if req.queue_wait_s is None and req.arrival_time_s:
            req.queue_wait_s = time.perf_counter() - req.arrival_time_s  # analysis: allow(det:wallclock) — queue-wait metering stamp; stats only
            self.stats.queue_wait.record(req.queue_wait_s)
            self.stats.tenant_queue_wait.setdefault(
                req.tenant, LatencyStat()).record(req.queue_wait_s)
            if TRACER.enabled:
                TRACER.instant("req.admit", request_id=req.request_id,
                               queue_wait_s=req.queue_wait_s)

    def _block_admission(self, req: Request, slot: Optional[int] = None) -> None:
        """One admission attempt is blocked on pool pressure: roll the slot
        back (if one was taken), count the block, requeue at the head."""
        if slot is not None:
            self.runner.release(slot)
        self.stats.admission_blocks += 1
        self.scheduler.requeue_head(req)

    def _finish_prefill(self, req: Request, slot: int, logits, resuming: bool):
        """Post-prefill handoff shared by the monolithic and chunked paths.
        Returns ``(ok, output)``; ``ok=False`` means the restart replay lost
        a pool race — the request went back to the queue head, preempted.
        """
        runner, stats, sched = self.runner, self.stats, self.scheduler
        out = None
        if resuming:
            # Re-feed the already-generated tokens through the decode program
            # (other slots masked out): the cache comes back bit-identical to
            # its pre-eviction state, so the continuation is too.
            if not runner.replay(slot, req, stats):
                # pool raced away mid-replay: back off, stay preempted
                self._block_admission(req, slot)
                return False, None
            req.preempted = False
            if req.first_token_t == 0.0:
                # Safety net: a request can only reach here with recorded
                # tokens, which normally carry a TTFT stamp from
                # OutputProcessor at original admission — but a request
                # submitted with pre-seeded out_tokens (external replay,
                # checkpoint restore) would otherwise report TTFT 0.0.
                req.first_token_t = time.perf_counter()  # analysis: allow(det:wallclock) — TTFT safety-net stamp for pre-seeded resumes; stats only
            tok = req.out_tokens[-1]
            runner.slots.slots[slot].length = len(req.prompt) + len(req.out_tokens) - 1
            runner.slots.slots[slot].generated = len(req.out_tokens)
        else:
            req.preempted = False  # a mid-prefill eviction restarts token-free
            tok = runner.sample_first(logits, req)
            out = self.out_proc.process_token(req, tok)
            # the prefill already produced the first new token
            runner.slots.slots[slot].generated = 1

        finished = out.finished if out is not None else (
            runner.slots.slots[slot].generated >= req.max_new
        )
        if finished:
            if out is None:
                # Backstop for a replayed request finishing with nothing
                # left to emit (the common resume-exactly-at-budget case is
                # intercepted before admission by _finish_resumed_at_budget;
                # this guards any future path reaching here): the old code
                # finished it with finish_reason None and never emitted a
                # terminal delta — the stream just went dark.  Reconstruct
                # the reason from the recorded tail and emit the zero-delta
                # finished output the client is owed.
                out = self.out_proc.finalize_resumed(req)
            if req.done_t == 0.0:
                req.done_t = time.perf_counter()  # analysis: allow(det:wallclock) — completion stamp for latency stats only
            self.finished[req.request_id] = req
            runner.release(slot)
            return True, out
        runner.last_tokens = runner.last_tokens.at[slot].set(tok)
        sched.inflight[slot] = req
        return True, out

    # -------------------------------------------------- paged bookkeeping --

    def _grow_slot_page(self, slot: int, length: int) -> None:
        """Make position ``length`` writable, preempting under pool pressure."""
        while True:
            try:
                self.runner.append_page(slot, length)
                return
            except PoolExhausted:
                victim = self.scheduler.pick_victim()
                if victim is None:
                    if self._prefilling:
                        # nothing decoding left to evict, but a partially-
                        # prefilled request still holds pages — preempt the
                        # lowest-priority one (ties youngest-first)
                        pslot = min(self._prefilling, key=lambda s: (
                            self._prefilling[s].req.priority,
                            -self._prefilling[s].req.enqueue_t))
                        self._preempt_prefilling(pslot)
                        continue
                    raise RuntimeError(
                        "paged KV pool exhausted with nothing left to preempt; "
                        f"raise num_blocks (have {self.runner.paged.num_blocks})"
                    )
                self.scheduler.preempt(victim, self.stats)
                if victim == slot:
                    return  # this very slot was evicted; caller skips it

    def _ensure_append_pages(self) -> None:
        """Before a decode round, make every active slot's next position
        writable — growing tables at page boundaries and forking shared
        (copy-on-write) pages — preempting the lowest-priority request when
        the pool cannot serve the growth."""
        for slot in self.runner.slots.active_slots():
            s = self.runner.slots.slots[slot]
            if s.request_id is None:  # preempted earlier in this loop
                continue
            if slot in self._prefilling:  # mid-prefill: pages preallocated,
                continue  # and the slot sits out the decode round
            self._grow_slot_page(slot, s.length)

    # --------------------------------------------------------------- decode --

    def _decode_round(self) -> List[RequestOutput]:
        runner, stats, sched = self.runner, self.stats, self.scheduler
        if runner.spec_decode is not None:
            # host-side prompt lookup first: when at least one slot found a
            # draft the round goes through the k+1-wide verify program;
            # with NO drafts anywhere (incompressible streams, or every
            # slot still too young for its n-gram to repeat) the round
            # falls back to the plain single-token decode program — the
            # verify pass would do k+1x the work to emit the same one
            # token per slot
            drafts = {slot: runner.draft_for(sched.inflight[slot], slot)
                      for slot in sorted(sched.inflight)}
            if any(len(d) for d in drafts.values()):
                return self._verify_round(drafts)
        if runner.cache_layout == "paged":
            self._ensure_append_pages()
        active = sorted(sched.inflight)
        if not active:
            return []
        if self._prefilling:
            # Mid-prefill slots sit the round out, but the batched decode
            # program still computes (and scatters) a row for them — park
            # that garbage write where it can never be read.  Paged: length
            # 0 routes the scatter to an out-of-bounds page id (dropped).
            # Contiguous: length >= max_len clamps the write to the cache's
            # last row, which live data never occupies (the last generated
            # token's KV lands at position n + max_new - 2 <= max_len - 2).
            lengths_np = np.asarray([s.length for s in runner.slots.slots], np.int32)
            park = 0 if runner.cache_layout == "paged" else runner.max_len
            for slot in self._prefilling:
                lengths_np[slot] = park
            lengths = jnp.asarray(lengths_np)
        else:
            lengths = runner.slots.lengths_array()
        t0 = time.perf_counter()  # analysis: allow(det:wallclock) — decode-round wall time feeds t_decode stats only
        logits = runner.decode_logits(lengths)
        next_tokens = runner.sample_batch(logits, sched.inflight)
        jax.block_until_ready(next_tokens)
        t1 = time.perf_counter()  # analysis: allow(det:wallclock) — decode-round wall time feeds t_decode stats only
        stats.t_decode += t1 - t0
        stats.decode_rounds += 1
        stats.decode_tokens += len(active)

        stats.slot_rounds += len(active)
        stats.decode_ctx_tokens += int(
            sum(runner.slots.slots[i].length for i in active))
        if TRACER.enabled:
            TRACER.complete("decode.round", t0, t1, batch=len(active))
        next_np = np.asarray(next_tokens)
        outs: List[RequestOutput] = []
        for i in active:
            req = sched.inflight[i]
            out = self.out_proc.process_token(req, int(next_np[i]))
            s = runner.slots.slots[i]
            s.length += 1
            s.generated += 1
            if out.finished:
                sched.inflight.pop(i)
                self.finished[req.request_id] = req
                runner.release(i)
            outs.append(out)
        runner.last_tokens = next_tokens
        return outs

    # -------------------------------------------------- speculative decode --

    def _grow_slot_span(self, slot: int, start: int, count: int) -> None:
        """Make positions ``[start, start + count)`` writable for one slot
        before a verify round — page growth + copy-on-write forks, with the
        same preempt-under-pressure loop the single-token path uses.  Stops
        early if the slot itself becomes the eviction victim."""
        for pos in range(start, start + count):
            self._grow_slot_page(slot, pos)
            if self.runner.slots.slots[slot].request_id is None:
                return  # this very slot was evicted mid-growth

    def _verify_round(self, drafts: Dict[int, np.ndarray]) -> List[RequestOutput]:
        """One decode quantum under speculative decoding: draft (host-side
        prompt lookup — ``drafts`` arrives from ``_decode_round``, which
        already fell back to plain decode when every slot came up empty),
        verify (one batched k+1-position forward), accept (longest
        confirmed draft prefix + one correction token), roll back
        (truncate slot length / release overshoot pages).

        Every emitted token is the token sequential decode would have
        produced at that position — greedy targets are the verify logits'
        argmax, sampled targets reuse the sequential PRNG key stream — so
        with greedy sampling the stream is bit-identical to the
        non-speculative engine for every layout x kv_dtype (pinned by
        tests/test_spec_decode.py), and preemption replay (which
        teacher-forces the recorded tokens) needs no speculation-specific
        state at all.
        """
        runner, stats, sched = self.runner, self.stats, self.scheduler
        n_slots = runner.slots.n_slots
        w = runner.spec_decode + 1
        # paged: make each slot's verify span writable (growth + COW;
        # may preempt victims — including, under pressure, a drafted slot)
        if runner.cache_layout == "paged":
            for slot in list(drafts):
                if slot not in sched.inflight:
                    continue  # evicted by an earlier slot's growth
                s = runner.slots.slots[slot]
                if s.request_id is None:
                    continue
                self._grow_slot_span(slot, s.length, len(drafts[slot]) + 1)
        active = sorted(sched.inflight)
        if not active:
            return []
        last_np = np.array(runner.last_tokens)  # writable copy (np.asarray of
        # a device array is a read-only view)
        tokens_np = np.zeros((n_slots, w), np.int32)
        n_tok_np = np.zeros((n_slots,), np.int32)
        lengths_np = np.asarray(
            [s.length for s in runner.slots.slots], np.int32)
        for slot in active:
            d = drafts[slot]
            tokens_np[slot, 0] = last_np[slot]
            tokens_np[slot, 1 : 1 + len(d)] = d
            n_tok_np[slot] = 1 + len(d)
            # satellite invariant: live verify rows stay clear of the
            # chunked-prefill parked-write row max_len - 1 (draft_for
            # clamps; this guards any future clamp regression)
            assert lengths_np[slot] + n_tok_np[slot] - 1 <= runner.max_len - 2, (
                slot, int(lengths_np[slot]), int(n_tok_np[slot]), runner.max_len)
        # mid-prefill slots sit the round out: n_tokens 0 routes every one
        # of their rows (KV writes) out of bounds, and nothing reads their
        # logits — no parked-write trick needed on this path
        t0 = time.perf_counter()  # analysis: allow(det:wallclock) — verify-round wall time feeds t_decode stats only
        logits = runner.run_verify(
            jnp.asarray(tokens_np), jnp.asarray(lengths_np), jnp.asarray(n_tok_np))
        targets = runner.select_targets(logits, sched.inflight)
        jax.block_until_ready(targets)
        t1 = time.perf_counter()  # analysis: allow(det:wallclock) — verify-round wall time feeds t_decode stats only
        stats.t_decode += t1 - t0
        stats.decode_rounds += 1
        stats.verify_rounds += 1
        stats.slot_rounds += len(active)
        stats.decode_ctx_tokens += int(sum(lengths_np[i] for i in active))
        if TRACER.enabled:
            TRACER.complete("decode.verify", t0, t1, batch=len(active),
                            drafted=int(sum(len(drafts[s]) for s in active)))

        from repro.core.sampling import accept_length

        targets_np = np.asarray(targets)
        outs: List[RequestOutput] = []
        for slot in active:
            req = sched.inflight[slot]
            d = drafts[slot]
            a = accept_length(d, targets_np[slot, : len(d)])
            stats.draft_tokens += len(d)
            stats.accepted_tokens += a
            # emit the confirmed prefix plus the correction/bonus token;
            # the output processor owns stop/budget truncation, so the
            # ACTUAL delta (and the state advance below) may be shorter
            emitted = [int(t) for t in targets_np[slot, : a + 1]]
            out = self.out_proc.process_tokens(req, emitted)
            e = len(out.new_token_ids)
            s = runner.slots.slots[slot]
            s.length += e
            s.generated += e
            stats.decode_tokens += e
            last_np[slot] = out.new_token_ids[-1]
            if out.finished:
                sched.inflight.pop(slot)
                self.finished[req.request_id] = req
                runner.release(slot)
            else:
                # roll rejected/truncated rows back: overshoot pages go
                # home, so a failed speculation never leaks pool capacity
                runner.rollback_overshoot(slot, s.length)
            outs.append(out)
        runner.last_tokens = jnp.asarray(last_np)
        return outs

    # -------------------------------------------------------------- metrics --

    def kv_bytes(self) -> dict:
        return self.runner.kv_bytes()
