"""Async multi-tenant serving front-end over ``EngineCore``.

``EngineCore.step()`` is a synchronous scheduling quantum; everything that
made the repo "serving" so far drove it from a batch script.  ``AsyncEngine``
turns it into a real front-end:

* a **background step loop** — one task runs ``step()`` (on a single-worker
  thread executor, so the event loop keeps streaming and accepting
  connections while a quantum computes) whenever there is work, and parks on
  an event when idle;
* **per-request async streams** — ``submit()`` returns a ``RequestStream``;
  ``async for out in stream`` yields each ``RequestOutput`` delta as the
  engine produces it, ending at the terminal ``finished`` output.
  ``generate()`` is the one-call convenience wrapper;
* **abort** — ``stream.abort()`` / ``AsyncEngine.abort(request_id)`` cancels
  a request wherever it lives (admission queue, wait queue, mid-prefill,
  mid-decode, mid-spec-verify).  Aborts are serialized onto the step loop
  (never concurrent with a running quantum); the stream receives a terminal
  ``finish_reason="abort"`` delta and the slot + paged KV pages are
  released;
* **backpressure** — admission is bounded: once ``max_queue`` requests are
  waiting (front-end pending + scheduler queue), ``submit()`` raises
  ``AdmissionRejected`` with a machine-readable reason instead of queueing
  unboundedly; structurally impossible requests (prompt + budget over
  ``max_len``, trajectory over the paged pool) are rejected with the
  scheduler's reason at submit time, before they occupy anything.

Thread-safety model: the event loop owns all front-end state; the executor
thread only ever runs ``core.step()``.  Submissions land in ``_pending`` and
are drained into ``core.submit()`` by the loop task *between* quanta, so the
scheduler's queue is never mutated concurrently with a step.  The loop also
never READS core state while a quantum runs: admission decisions consult
loop-owned mirrors (``_core_backlog``, the scheduler-queue length snapshot
refreshed between quanta; ``_ids``, every id ever admitted) instead of
reaching into ``core.scheduler.queue`` / ``core.finished`` mid-step.  This
discipline is not just prose — the ownership annotations below
(``# owned-by: event-loop`` / ``# thread: event-loop``) are enforced by the
lock-discipline pass in ``repro.analysis`` (run ``python -m repro.analysis
--pass lock``), so an access from the wrong thread fails CI.  Because the
engine itself is the same ``EngineCore`` stepped the same way, greedy
outputs through ``AsyncEngine`` are bit-identical to the synchronous engine
(pinned by tests/test_async_serving.py across layouts x kv dtypes, chunked
prefill and speculative decoding included).
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import AsyncIterator, Deque, Dict, Optional

import numpy as np

from repro.obs.trace import TRACER
from repro.serving.core import EngineCore, Request
from repro.serving.outputs import RequestOutput
from repro.serving.sampling import SamplingParams


class AdmissionRejected(RuntimeError):
    """A submit was refused outright (backpressure or impossible request).

    ``reason`` is machine-readable-ish: ``"queue_full: ..."`` for
    backpressure, otherwise the scheduler's validation message.
    """

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


@dataclasses.dataclass
class _Stream:
    queue: "asyncio.Queue[RequestOutput]"
    request: Request


class RequestStream:
    """One request's async output stream: iterate to the terminal delta."""

    def __init__(self, engine: "AsyncEngine", request_id: str,
                 queue: "asyncio.Queue[RequestOutput]"):
        self.engine = engine
        self.request_id = request_id
        self._q = queue
        self._done = False

    def __aiter__(self) -> "RequestStream":
        return self

    async def __anext__(self) -> RequestOutput:
        if self._done:
            raise StopAsyncIteration
        out = await self._q.get()
        if out.finished:
            self._done = True
        return out

    async def abort(self) -> None:
        await self.engine.abort(self.request_id)


class AsyncEngine:
    """Async front-end: background step loop + per-request output streams."""

    def __init__(self, core: EngineCore, *, max_queue: int = 64):
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.core = core
        self.max_queue = max_queue
        self._pending: Deque[Request] = deque()  # owned-by: event-loop
        self._streams: Dict[str, _Stream] = {}  # owned-by: event-loop
        self._aborts: Deque[str] = deque()  # owned-by: event-loop
        self._wake = asyncio.Event()
        self._task: Optional[asyncio.Task] = None
        self._closed = False  # owned-by: event-loop
        self._exec = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="engine-step")
        self._seq = 0  # owned-by: event-loop
        # loop-owned mirrors of core state, so admission never reads the
        # core while a quantum mutates it on the executor thread:
        # scheduler-queue length, refreshed between quanta ...
        self._core_backlog = 0  # owned-by: event-loop
        # ... and every id ever admitted (duplicate suppression without
        # touching core.finished mid-step)
        self._ids: set = set()  # owned-by: event-loop
        # backpressure accounting (snapshot()-style counters)
        self.accepted = 0  # owned-by: event-loop
        self.rejected = 0  # owned-by: event-loop
        self.reject_reasons: Dict[str, int] = {}  # owned-by: event-loop

    # ------------------------------------------------------------ lifecycle --

    def start(self) -> "AsyncEngine":
        """Start the step loop on the running event loop (idempotent)."""
        if self._task is None or self._task.done():
            self._task = asyncio.get_running_loop().create_task(self._run())
        return self

    async def __aenter__(self) -> "AsyncEngine":
        return self.start()

    async def __aexit__(self, *exc) -> None:
        await self.shutdown()

    async def shutdown(self) -> None:  # thread: event-loop
        """Stop the loop.  In-flight requests stop advancing; their streams
        receive a terminal abort delta so no reader hangs."""
        self._closed = True
        self._wake.set()
        if self._task is not None:
            await self._task
            self._task = None
        for rid in list(self._streams):
            out = self.core.abort(rid)
            if out is None:  # still in the front-end pending queue
                stream = self._streams[rid]
                out = self.core.out_proc.finalize_aborted(stream.request)
            self._route(out)
        self._exec.shutdown(wait=True)

    # ------------------------------------------------------------ admission --

    def _reject(self, reason: str) -> None:  # thread: event-loop
        self.rejected += 1
        key = reason.split(":", 1)[0]
        self.reject_reasons[key] = self.reject_reasons.get(key, 0) + 1
        raise AdmissionRejected(reason)

    def _backlog(self) -> int:  # thread: event-loop
        # _core_backlog is the between-quanta snapshot of the scheduler
        # queue: at most one quantum stale, and never a racy read of a
        # deque the executor thread is popping
        return len(self._pending) + self._core_backlog

    async def submit(
        self,
        prompt,
        params: Optional[SamplingParams] = None,
        *,
        request_id: Optional[str] = None,
        max_new: Optional[int] = None,
        tenant: str = "default",
        weight: float = 1.0,
        priority: int = 0,
    ) -> RequestStream:  # thread: event-loop
        """Admit one request and return its output stream.

        Raises ``AdmissionRejected`` instead of queueing when the wait
        backlog is at ``max_queue`` (the bounded-queue backpressure that
        stands in for the saturated paged pool upstream of it) or when the
        request can never be served (scheduler validation).
        """
        if self._closed:
            raise AdmissionRejected("shutdown: engine is closed")
        if self._backlog() >= self.max_queue:
            self._reject(
                f"queue_full: {self._backlog()} requests already waiting "
                f"(max_queue={self.max_queue}); retry with backoff")
        self._seq += 1
        rid = request_id or f"areq-{self._seq}"
        if rid in self._ids:  # loop-owned set of every id ever admitted —
            # covers open streams AND finished requests without reading
            # core.finished concurrently with a running quantum
            self._reject(f"duplicate_id: request id {rid!r} already in use")
        prompt = np.asarray(prompt, np.int32)
        if max_new is None:
            if params is not None and params.max_tokens is not None:
                max_new = params.max_tokens  # validate() applies the override
            else:
                # same unbudgeted default as EngineCore.generate(): the full
                # slot headroom, clamped to what the paged pool can hold
                runner = self.core.runner
                max_new = runner.max_len - len(prompt)
                if runner.cache_layout == "paged":
                    pool_tokens = runner.paged.num_blocks * runner.block_size
                    max_new = min(max_new, pool_tokens - len(prompt) + 1)
                max_new = max(1, max_new)
        req = Request(
            rid, prompt, max_new=max_new,
            priority=priority, params=params or SamplingParams(),
            tenant=tenant, weight=weight,
        )
        req.arrival_time_s = time.perf_counter()  # client-visible arrival:
        # stamped HERE, before any queueing — TTFT includes the wait
        try:
            # pure host arithmetic over engine constants: safe while a step
            # runs, and it rejects impossible requests before they queue
            self.core.scheduler.validate(req)
        except ValueError as e:
            self._reject(f"invalid: {e}")
        q: asyncio.Queue = asyncio.Queue()
        self._ids.add(rid)
        self._streams[rid] = _Stream(q, req)
        self._pending.append(req)  # the loop drains between quanta
        if TRACER.enabled:
            TRACER.instant("req.enqueue", request_id=rid, tenant=tenant)
        self._wake.set()
        return RequestStream(self, rid, q)

    async def generate(
        self,
        prompt,
        params: Optional[SamplingParams] = None,
        **kwargs,
    ) -> AsyncIterator[RequestOutput]:
        """Submit and stream: ``async for out in eng.generate(...)``."""
        stream = await self.submit(prompt, params, **kwargs)
        async for out in stream:
            yield out

    async def abort(self, request_id: str) -> None:  # thread: event-loop
        """Cancel a request.  Serialized onto the step loop, so it never
        races a quantum; the stream receives its terminal abort delta from
        the loop."""
        self._aborts.append(request_id)
        self._wake.set()

    # ------------------------------------------------------------ step loop --

    def _route(self, out: RequestOutput) -> None:  # thread: event-loop
        stream = self._streams.get(out.request_id)
        if stream is not None:
            stream.queue.put_nowait(out)
            if out.finished:
                del self._streams[out.request_id]

    def _drain_control(self) -> None:  # thread: event-loop
        """Apply aborts and admissions queued since the last quantum (the
        loop task runs this between ``step()`` calls, never during one)."""
        while self._aborts:
            rid = self._aborts.popleft()
            stream = self._streams.get(rid)
            if stream is not None and stream.request in self._pending:
                # never reached the core: finish it right here
                self._pending.remove(stream.request)
                self.core.stats.aborts += 1
                self._route(self.core.out_proc.finalize_aborted(stream.request))
                continue
            out = self.core.abort(rid)
            if out is not None:
                self._route(out)
        while self._pending:
            req = self._pending.popleft()
            try:
                self.core.submit(req)
                self.accepted += 1
            except ValueError as e:  # race-window double check; terminal
                self.core.stats.aborts += 1
                out = self.core.out_proc.finalize_aborted(req)
                out.finish_reason = req.finish_reason = f"rejected: {e}"
                self._route(out)
        # between-quanta: no step in flight, so this read cannot race the
        # executor — it is the ONLY place admission state touches the core
        self._core_backlog = len(self.core.scheduler.queue)

    async def _run(self) -> None:  # thread: event-loop
        loop = asyncio.get_running_loop()
        while not self._closed:
            self._drain_control()
            if self.core.has_unfinished():
                outs = await loop.run_in_executor(self._exec, self.core.step)
                for out in outs:
                    self._route(out)
                # quantum done: refresh the admission-visible queue snapshot
                self._core_backlog = len(self.core.scheduler.queue)
                # yield so streams/submits/aborts interleave between quanta
                await asyncio.sleep(0)
            else:
                self._wake.clear()
                if self._aborts or self._pending or self.core.has_unfinished():
                    continue  # raced in while clearing
                await self._wake.wait()
        self._drain_control()  # final aborts so no stream reader hangs

    # -------------------------------------------------------------- metrics --

    def snapshot(self) -> dict:  # thread: event-loop
        """Engine stats block plus front-end admission counters — the same
        shared builder ``EngineCore.snapshot()`` uses (obs.engine), with the
        front-end section passed as the one extra."""
        from repro.obs.engine import engine_snapshot

        return engine_snapshot(self.core, extra={"frontend": {
            "accepted": self.accepted,
            "rejected": self.rejected,
            "reject_reasons": dict(self.reject_reasons),
            "pending": len(self._pending),
            "open_streams": len(self._streams),
            "max_queue": self.max_queue,
        }})

    def metrics_registry(self):
        """The engine registry extended with front-end admission metrics
        (built once; callback views stay live across scrapes)."""
        if getattr(self, "_metrics_registry", None) is None:
            from repro.obs.engine import engine_registry

            self._metrics_registry = engine_registry(self.core, frontend=self)
        return self._metrics_registry

    def snapshot_v2(self) -> dict:
        from repro.obs.engine import snapshot_v2

        return snapshot_v2(self.core, registry=self.metrics_registry())
