"""Per-tenant weighted fair queueing for the Scheduler's wait queue.

The PR-2 scheduler kept one global FIFO deque: a single tenant submitting a
burst of requests starves every other tenant behind it for the burst's whole
service time.  ``WeightedFairQueue`` replaces the deque with per-tenant FIFO
lanes drained in deficit-round-robin (DRR) order: each visit to a tenant adds
its weight to a deficit counter and the tenant is served while the deficit
lasts (one unit per request), so over any busy window tenant ``i`` receives
service proportional to ``weight_i`` regardless of how deep any one lane is.

The interface mirrors the deque the scheduler already used — ``append``,
``appendleft``, ``popleft``, ``len``, truthiness, ``[0]`` — so every existing
call site works unchanged:

* With a single tenant (the default), DRR degenerates to exact FIFO, which
  is what keeps the pre-existing engine tests (and greedy bit-identity
  against the synchronous reference runs) untouched.
* ``appendleft`` is the *requeue-at-head* path (blocked admission,
  preemption): the request goes onto a head lane served before any DRR
  pick, preserving the "retry this exact request next" contract regardless
  of tenant.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional


class WeightedFairQueue:
    """Deficit-round-robin over per-tenant FIFO lanes (cost 1 per request)."""

    def __init__(self):
        self._lanes: Dict[str, Deque] = {}
        self._order: List[str] = []  # tenant visit order (first-seen)
        self._deficit: Dict[str, float] = {}
        self._weights: Dict[str, float] = {}
        self._head: Deque = deque()  # requeued-at-head requests, any tenant
        self._ptr = 0  # DRR cursor into _order
        self._len = 0

    # ------------------------------------------------------------ helpers --

    @staticmethod
    def _tenant(req) -> str:
        return getattr(req, "tenant", "default") or "default"

    def _lane(self, tenant: str, weight: float) -> Deque:
        lane = self._lanes.get(tenant)
        if lane is None:
            lane = self._lanes[tenant] = deque()
            self._order.append(tenant)
            self._deficit[tenant] = 0.0
        if weight > 0.0:
            self._weights[tenant] = weight  # latest request's weight wins
        return lane

    def set_weight(self, tenant: str, weight: float) -> None:
        if weight <= 0.0:
            raise ValueError(f"tenant weight must be > 0, got {weight}")
        self._lane(tenant, weight)

    # ------------------------------------------------------ deque protocol --

    def append(self, req) -> None:
        self._lane(self._tenant(req), float(getattr(req, "weight", 1.0))).append(req)
        self._len += 1

    def appendleft(self, req) -> None:
        """Requeue at the global head: the next ``popleft`` returns it.
        Used for blocked admissions and preemption restarts, which must be
        retried before any fair-share pick (they already won arbitration
        once; fairness was charged then)."""
        self._head.appendleft(req)
        self._len += 1

    def popleft(self):
        if self._head:
            self._len -= 1
            return self._head.popleft()
        if self._len == 0:
            raise IndexError("pop from an empty WeightedFairQueue")
        # DRR: visit tenants in fixed order; a visit grants `weight` deficit;
        # serve while deficit >= 1, then move on.  Empty lanes forfeit their
        # deficit (a tenant cannot bank credit while idle).
        while True:
            if self._ptr >= len(self._order):
                self._ptr = 0
            tenant = self._order[self._ptr]
            lane = self._lanes[tenant]
            if not lane:
                self._deficit[tenant] = 0.0
                self._ptr += 1
                continue
            if self._deficit[tenant] < 1.0:
                self._deficit[tenant] += self._weights.get(tenant, 1.0)
                if self._deficit[tenant] < 1.0:
                    self._ptr += 1  # weight < 1: accrues over multiple cycles
                    continue
            self._deficit[tenant] -= 1.0
            self._len -= 1
            req = lane.popleft()
            if not lane or self._deficit[tenant] < 1.0:
                self._ptr += 1  # lane drained or deficit spent: next tenant
            return req

    def remove(self, request_id: str):
        """Remove and return a queued request by id (abort path); None if
        the id is not queued."""
        for lane in (self._head, *self._lanes.values()):
            for req in lane:
                if req.request_id == request_id:
                    lane.remove(req)
                    self._len -= 1
                    return req
        return None

    def lane_depths(self) -> Dict[str, int]:
        """Queued depth per tenant — DRR lane lengths, with head-lane
        requeues (blocked admissions / preemption restarts) counted under
        their own tenant.  Feeds ``EngineCore.snapshot()["tenants"]`` so
        per-tenant queueing is observable from ``GET /stats``."""
        depths = {t: len(lane) for t, lane in self._lanes.items() if lane}
        for req in self._head:
            t = self._tenant(req)
            depths[t] = depths.get(t, 0) + 1
        return depths

    def peek(self) -> Optional[object]:
        """The request the next ``popleft`` would return (no deficit spent)."""
        if self._head:
            return self._head[0]
        if self._len == 0:
            return None
        n = len(self._order)
        for off in range(n):
            lane = self._lanes[self._order[(self._ptr + off) % n]]
            if lane:
                return lane[0]
        return None

    def __getitem__(self, i: int):
        if i != 0:
            raise IndexError("WeightedFairQueue only exposes the head ([0])")
        head = self.peek()
        if head is None:
            raise IndexError("empty WeightedFairQueue")
        return head

    def __iter__(self):
        yield from self._head
        for tenant in self._order:
            yield from self._lanes[tenant]

    def __len__(self) -> int:
        return self._len

    def __bool__(self) -> bool:
        return self._len > 0
