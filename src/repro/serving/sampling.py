"""Per-request sampling parameters for the serving API.

The paper's runtime (and the PR-1 engine) hardwired greedy argmax into both
the prefill epilogue and the decode round.  Serving-scale traffic needs
per-request generation control, so sampling is a first-class phase program:
one jitted ``sample_tokens`` call per decode round draws every slot's next
token on device — temperature scaling, top-k truncation and top-p (nucleus)
truncation composed per slot, with greedy slots taking the argmax path
inside the same program.  The sampler math itself lives in
``repro.core.sampling`` (the core layer, next to the other phase-program
builders) and is re-exported here.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.sampling import filter_logits, sample_tokens  # noqa: F401 (re-export)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request generation parameters (immutable; validated on build).

    ``temperature == 0`` selects greedy argmax — the PR-1 behavior and the
    default, so existing callers are unchanged.  ``top_k == 0`` and
    ``top_p == 1.0`` disable the respective truncations.  ``stop_tokens``
    end generation early (the stop token is kept in the output, finish
    reason ``"stop"``); ``max_tokens``, when set, overrides the request's
    ``max_new`` budget (finish reason ``"length"``).

    ``seed`` makes generation deterministic: token ``i`` is always drawn
    with ``fold_in(PRNGKey(seed), i)``, so seeded sampling is bit-identical
    across runs and across preemption/restart cycles (see
    ``repro.core.sampling``).
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    stop_tokens: Tuple[int, ...] = ()
    max_tokens: Optional[int] = None

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 disables), got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_tokens is not None and self.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {self.max_tokens}")
        object.__setattr__(self, "stop_tokens", tuple(int(t) for t in self.stop_tokens))

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0

    @property
    def seed32(self) -> int:
        """Seed folded into the non-negative int32 range PRNGKey accepts
        under jit (x64 disabled)."""
        return int(self.seed) & 0x7FFFFFFF
