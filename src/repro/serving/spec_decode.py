"""Prompt-lookup (n-gram) drafting for self-speculative decoding.

The paper's decode engine is memory-bandwidth-bound: every decoded token
streams the whole KV cache and weight set for ONE row of output (Eq. 5),
while the fabric's compute sits idle.  Speculative decoding is the standard
algorithm-side answer (AccLLM): draft ``k`` cheap candidate tokens, then
score all ``k + 1`` positions in ONE verify pass — the KV/weight stream is
paid once per round instead of once per token, so every accepted draft
token is a free ride on bandwidth the round already spent.

On an edge deployment there is no room for a separate draft model, so the
drafter here is *self-speculative prompt lookup*: match the sequence's own
trailing n-gram against its prompt + generated history and propose the
tokens that followed the match.  Pure host-side numpy — zero device work,
zero extra weights — and it shines exactly where decode is most painful:
long repetitive contexts (summarization, code edits, RAG over the prompt),
where the continuation of a repeated n-gram is very often the continuation
the model picks anyway.

The drafter only ever *proposes*; acceptance is decided by the verify
pass against the slot's own ``SamplingParams`` (``repro.core.sampling``),
so a bad draft costs one wasted verify column, never a wrong token.
"""
from __future__ import annotations

import numpy as np


def find_draft(context: np.ndarray, max_k: int, ngram: int) -> np.ndarray:
    """Propose up to ``max_k`` draft tokens by prompt lookup.

    Tries n-gram sizes from ``ngram`` down to 1: for each size, the
    context's trailing n-gram is matched against every earlier position.
    Among the matches, prefer the most recent one whose continuation can
    supply a full ``max_k`` tokens; with no full continuation available,
    fall back to the most recent match (recency tracks the local pattern
    best — a period-p loop's rightmost match predicts the next period).

    Returns an int32 array of length in ``[0, max_k]`` — empty when the
    trailing n-gram never occurred before (the engine then runs the slot as
    plain decode: one real verify column, zero drafts).

    Deterministic and a pure function of ``(context, max_k, ngram)``, so a
    preemption-restart that replays the same history re-derives the same
    drafts — speculation adds no scheduler state that replay would have to
    checkpoint.
    """
    context = np.asarray(context, np.int32)
    n = len(context)
    if max_k <= 0 or n < 2:
        return np.zeros((0,), np.int32)
    for size in range(min(ngram, n - 1), 0, -1):
        suffix = context[n - size:]
        # candidate starts 0 .. n-1-size: the match must end before the last
        # position so at least one continuation token exists
        windows = np.lib.stride_tricks.sliding_window_view(context[: n - 1], size)
        starts = np.flatnonzero((windows == suffix[None, :]).all(axis=1))
        if len(starts) == 0:
            continue
        full = starts[starts + size + max_k <= n]
        start = int(full[-1]) if len(full) else int(starts[-1])
        cont = context[start + size : start + size + max_k]
        return cont.astype(np.int32)
    return np.zeros((0,), np.int32)
