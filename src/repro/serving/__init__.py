from repro.serving.arrivals import Arrival, bursty_times, make_trace, poisson_times
from repro.serving.async_engine import AdmissionRejected, AsyncEngine, RequestStream
from repro.serving.core import EngineCore, EngineStats, Request
from repro.serving.disagg import (
    DisaggEngine,
    DisaggRunner,
    KVHandoffChannel,
    PrefillPool,
    make_disagg_meshes,
)
from repro.serving.engine import ServingEngine
from repro.serving.fair_queue import WeightedFairQueue
from repro.serving.outputs import OutputProcessor, RequestOutput
from repro.serving.paging import BlockPool, PagedKVCache, PoolExhausted
from repro.serving.policy import (
    POLICIES,
    DrainPolicy,
    SchedulerView,
    SwapCostAwarePolicy,
    SwapPolicy,
    make_policy,
)
from repro.serving.sampling import SamplingParams
from repro.serving.slo import LatencyStat, SLOAwareSwapPolicy, SLOConfig
