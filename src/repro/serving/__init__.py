from repro.serving.core import EngineCore, EngineStats, Request
from repro.serving.engine import ServingEngine
from repro.serving.outputs import OutputProcessor, RequestOutput
from repro.serving.paging import BlockPool, PagedKVCache, PoolExhausted
from repro.serving.policy import (
    POLICIES,
    DrainPolicy,
    SchedulerView,
    SwapCostAwarePolicy,
    SwapPolicy,
    make_policy,
)
from repro.serving.sampling import SamplingParams
