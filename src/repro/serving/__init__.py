from repro.serving.engine import Request, ServingEngine, EngineStats
from repro.serving.paging import BlockPool, PagedKVCache, PoolExhausted
