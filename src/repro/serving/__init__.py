from repro.serving.engine import Request, ServingEngine, EngineStats
