"""Seeded arrival-trace generators for serving benchmarks and the launcher.

One small module shared by ``launch.serve`` (``--arrival-rate``) and
``benchmarks.traffic_storm``: every trace is a list of ``Arrival`` records
(arrival time in seconds from trace start, tenant id + fair-queue weight,
prompt length) drawn from a seeded ``numpy`` generator, so a trace is a pure
function of its knobs and identical across runs, hosts, and the policies
being compared on it.

Two arrival processes:

* ``poisson_times`` — homogeneous Poisson at ``rate`` req/s (i.i.d.
  exponential inter-arrivals), the steady-traffic baseline.
* ``bursty_times`` — a diurnal square wave: the rate alternates between
  ``base_rate`` and ``burst_rate`` every half ``period_s``.  Sampled by
  thinning (propose at the max rate, accept with probability
  ``rate(t)/max_rate``), so it is an exact non-homogeneous Poisson process,
  not a per-phase approximation.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One request arrival in a trace."""

    t: float  # seconds from trace start
    prompt_len: int
    tenant: str = "default"
    weight: float = 1.0


def poisson_times(rate: float, n: int, rng: np.random.Generator) -> np.ndarray:
    """Arrival times of ``n`` events of a Poisson process at ``rate``/s."""
    if rate <= 0.0:
        raise ValueError(f"rate must be > 0, got {rate}")
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def bursty_times(
    base_rate: float,
    burst_rate: float,
    period_s: float,
    n: int,
    rng: np.random.Generator,
) -> np.ndarray:
    """Arrival times of ``n`` events of a square-wave-rate Poisson process.

    The instantaneous rate is ``base_rate`` during the first half of every
    ``period_s`` window and ``burst_rate`` during the second half (the
    "diurnal" storm).  Exact via thinning at ``max(base, burst)``.
    """
    if min(base_rate, burst_rate) <= 0.0 or period_s <= 0.0:
        raise ValueError("rates and period_s must be > 0")
    rmax = max(base_rate, burst_rate)
    times = np.empty(n)
    t, i = 0.0, 0
    while i < n:
        t += float(rng.exponential(1.0 / rmax))
        r = burst_rate if (t % period_s) >= period_s / 2.0 else base_rate
        if rng.random() <= r / rmax:
            times[i] = t
            i += 1
    return times


def make_trace(
    n: int,
    *,
    kind: str = "poisson",  # "poisson" | "bursty"
    rate: float = 10.0,
    burst_rate: Optional[float] = None,  # bursty: high-phase rate (default 4x)
    period_s: float = 2.0,  # bursty: square-wave period
    seed: int = 0,
    prompt_lens: Tuple[int, int] = (8, 32),  # uniform [lo, hi] per request
    tenants: Sequence[Tuple[str, float, float]] = (("default", 1.0, 1.0),),
    # (tenant id, fair-queue weight, traffic share); shares are normalized
) -> List[Arrival]:
    """One seeded multi-tenant trace: arrival process x prompt mix x tenants."""
    if kind not in ("poisson", "bursty"):
        raise ValueError(f"unknown trace kind {kind!r}")
    rng = np.random.default_rng(seed)
    if kind == "poisson":
        times = poisson_times(rate, n, rng)
    else:
        times = bursty_times(rate, burst_rate or 4.0 * rate, period_s, n, rng)
    lo, hi = prompt_lens
    if not 1 <= lo <= hi:
        raise ValueError(f"prompt_lens must satisfy 1 <= lo <= hi, got {prompt_lens}")
    lens = rng.integers(lo, hi + 1, size=n)
    shares = np.asarray([s for _, _, s in tenants], np.float64)
    shares = shares / shares.sum()
    picks = rng.choice(len(tenants), size=n, p=shares)
    return [
        Arrival(
            t=float(times[i]),
            prompt_len=int(lens[i]),
            tenant=tenants[picks[i]][0],
            weight=float(tenants[picks[i]][1]),
        )
        for i in range(n)
    ]
