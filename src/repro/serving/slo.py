"""SLO-aware serving: latency targets, per-request tracking, and a swap
policy steered by observed TTFT/ITL percentiles.

Two client-visible latencies define an interactive serving SLO:

* **TTFT** — time to first token, *arrival* to first emitted token.  The
  clock starts when the request is submitted to the front-end (satellite
  fix: ``Request.arrival_time_s`` is stamped at submit, so scheduler
  queueing delay is inside TTFT, not hidden before it).
* **ITL** — inter-token latency, the gap between consecutive streamed
  deltas of one request.

``LatencyStat`` is the aggregate the engine keeps for each of {queue wait,
TTFT, ITL}: running count/sum plus a bounded sample window for p50/p95 (a
long serving run must not grow a per-token list without bound).

``SLOAwareSwapPolicy`` closes the loop the static policies leave open: the
``DrainPolicy`` always swaps (best TTFT, worst ITL under load) and the
``SwapCostAwarePolicy`` amortizes the swap against a *cost* model — neither
looks at the latencies clients actually experience.  This policy reads the
engine's observed p95 TTFT/ITL each step and steers both halves of the
prefill decision:

* ``should_prefill`` — flip into prefill when the queue head's age
  threatens the TTFT target (prioritize pending prefill) or when observed
  ITL has budget slack; defer (bounded) when ITL is violating and TTFT is
  safe — protect the decode streams first.
* ``prefill_quanta`` — under chunked prefill, the *effective* prefill chunk
  per step: with ITL slack (or TTFT already violating) the engine may run
  several chunk quanta back to back before the next decode round,
  ``effective_chunk = prefill_chunk x quanta``.  Greedy outputs are
  invariant to chunking (the PR-4 contract), so this knob moves latency
  only, never tokens.

The policy observes through ``bind(stats)`` — ``EngineCore`` binds its own
``EngineStats`` at construction, so the same policy object works under the
synchronous engine, ``AsyncEngine``, and the benchmarks without extra
plumbing.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Optional

import numpy as np

from repro.serving.policy import SchedulerView, SwapPolicy

LATENCY_WINDOW = 2048  # samples kept for percentile estimates


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Latency targets for one serving deployment (seconds)."""

    ttft_target_s: float = 0.5
    itl_target_s: float = 0.05
    # should_prefill knobs: the queue head is "at risk" once it has waited
    # ttft_risk x target (prefill must start well before the deadline to
    # leave room for the prefill itself); ITL has "slack" below
    # itl_slack x target.
    ttft_risk: float = 0.4
    itl_slack: float = 0.6

    def __post_init__(self):
        if self.ttft_target_s <= 0.0 or self.itl_target_s <= 0.0:
            raise ValueError("SLO targets must be > 0")
        if not 0.0 < self.ttft_risk <= 1.0 or not 0.0 < self.itl_slack <= 1.0:
            raise ValueError("ttft_risk and itl_slack must be in (0, 1]")


class LatencyStat:
    """Bounded-window latency aggregate: count/sum forever, percentiles over
    the last ``LATENCY_WINDOW`` samples."""

    def __init__(self, window: int = LATENCY_WINDOW):
        self.count = 0
        self.total = 0.0
        self._win: Deque[float] = deque(maxlen=window)

    def record(self, v: float) -> None:
        self.count += 1
        self.total += v
        self._win.append(v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float, last: Optional[int] = None) -> float:
        """Percentile over the sample window; ``last`` restricts it to the
        most recent N samples (a latency *controller* must react to current
        conditions — a storm spike an hour ago should not pin p95 high for
        the rest of the run)."""
        if not self._win:
            return 0.0
        data = self._win if last is None else list(self._win)[-last:]
        return float(np.percentile(np.asarray(data), q))

    @property
    def p50(self) -> float:
        return self.percentile(50)

    @property
    def p95(self) -> float:
        return self.percentile(95)

    def snapshot(self) -> dict:
        """JSON-serializable summary (seconds)."""
        return {"count": self.count, "mean": self.mean,
                "p50": self.p50, "p95": self.p95}


def request_latency(req) -> dict:
    """Client-visible latency summary of one finished request, from the
    stamps the engine maintains (seconds; 0.0 where a stamp is missing —
    e.g. TTFT of a request that never produced a token)."""
    arrival = getattr(req, "arrival_time_s", 0.0) or getattr(req, "enqueue_t", 0.0)
    ttft = (req.first_token_t - arrival) if req.first_token_t and arrival else 0.0
    qw = getattr(req, "queue_wait_s", None)
    return {
        "request_id": req.request_id,
        "ttft_s": ttft,
        "queue_wait_s": 0.0 if qw is None else qw,
        "e2e_s": (req.done_t - arrival) if req.done_t and arrival else 0.0,
        "tokens": len(req.out_tokens),
        "finish_reason": req.finish_reason,
    }


class SLOAwareSwapPolicy(SwapPolicy):
    """Steer the prefill<->decode flip (and the effective chunk size) from
    observed p95 TTFT/ITL against an ``SLOConfig``.

    Decision order (progress-safe: an empty decode set and a defer cap both
    force admission, like the other policies):

    1. nothing decoding -> prefill (no opportunity cost);
    2. in-flight chunked prefill -> continue it (its TTFT clock is running
       and each chunk is a bounded quantum);
    3. queue head older than ``ttft_risk x ttft_target`` -> prefill (a
       violated TTFT can never be repaired later; ITL can recover);
    4. observed p95 ITL over target AND the queue still shallow -> defer,
       bounded (protect decode; a deep queue is sustained overload, where
       deferring starves TTFT without recovering ITL);
    5. observed p95 ITL under ``itl_slack x`` target -> prefill (spend the
       slack on pending work);
    6. otherwise (ITL between slack and target): amortize like the
       swap-cost policy — admit once the queue is at least as deep as the
       decode rounds one swap costs.  Batching admissions this way is also
       the paper's phase alternation: a prefill chunk inside a busy decode
       set stalls every stream and slows the slot turnover that drains the
       queue, while the same chunks a few rounds later land in a near-empty
       set and cost almost nothing.

    Progress is guaranteed by the defer bound, the TTFT-risk override, and
    rule 1.
    """

    name = "slo-aware"

    def __init__(
        self,
        slo: Optional[SLOConfig] = None,
        *,
        max_defer_rounds: int = 8,
        max_quanta: int = 4,
        recent: int = 64,
    ):
        if max_defer_rounds < 1 or max_quanta < 1 or recent < 1:
            raise ValueError(
                "max_defer_rounds, max_quanta and recent must be >= 1")
        self.slo = slo or SLOConfig()
        self.max_defer_rounds = max_defer_rounds
        self.max_quanta = max_quanta
        self.recent = recent  # steer from the last N samples, not all-time
        self._stats = None  # EngineStats, bound by the engine
        self._deferred = 0
        self._last_active = 0  # decode-set size at the last should_prefill
        self._last_queue = 0  # queue depth at the last should_prefill

    def bind(self, stats) -> None:
        """Attach the engine's ``EngineStats`` (its ttft/itl ``LatencyStat``
        aggregates are the policy's observations)."""
        self._stats = stats

    # ----------------------------------------------------------- decision --

    def _itl_p95(self) -> float:
        if self._stats is None:
            return 0.0
        return self._stats.itl.percentile(95, last=self.recent)

    def should_prefill(self, view: SchedulerView) -> bool:
        self._last_active = view.active_slots
        self._last_queue = view.queue_depth
        if view.active_slots == 0 or view.pending_chunks > 0:
            self._deferred = 0
            return True
        slo = self.slo
        if view.oldest_wait_s >= slo.ttft_risk * slo.ttft_target_s:
            self._deferred = 0
            return True
        itl = self._itl_p95()
        if (itl > slo.itl_target_s
                and view.queue_depth <= max(1, 2 * view.active_slots)
                and self._deferred < self.max_defer_rounds):
            # ITL violating under light queue pressure: hold admissions
            # (bounded) so the active streams decode in clean windows.  A
            # deep queue means sustained overload — deferring there
            # starves TTFT without ever recovering ITL, so the depth
            # guard falls through instead.
            self._deferred += 1
            return False
        if itl <= slo.itl_slack * slo.itl_target_s:
            self._deferred = 0
            return True
        # between slack and target: amortize like the swap-cost policy —
        # batching admissions until the queue is worth one swap keeps
        # prefill chunks out of busy decode windows (phase alternation)
        if view.decode_round_cost > 0.0 and view.swap_cost > 0.0:
            need = max(1, int(np.ceil(view.swap_cost / view.decode_round_cost)))
        else:
            need = 1
        if view.queue_depth >= need or self._deferred >= self.max_defer_rounds:
            self._deferred = 0
            return True
        self._deferred += 1
        return False

    def prefill_quanta(self) -> int:
        """Chunk quanta the engine may run back to back this step (chunked
        prefill only): 1 when ITL is tight or unobserved; more only while
        observed p95 ITL sits under the slack line.  The width is budgeted
        from the OBSERVED median gap plus the engine's measured per-chunk
        cost — not raw kernel costs, which miss step/streaming overhead
        and systematically overshoot the target.  There is deliberately no
        'TTFT crisis' override to the maximum: each step still runs only
        one decode round, so widening quanta under load slows the slot
        turnover that actually drains the admission queue — it trades a
        broken ITL for no TTFT gain.  The one unconditional widening is a
        near-empty decode set (the admit half of phase alternation):
        chunks run back to back at full width when there is no stream
        left to stall."""
        if self._stats is None:
            return 1
        if self._last_active == 0:
            return self.max_quanta
        if self._last_queue <= self._last_active:
            # no real backlog to drain: a widened quantum would spend ITL
            # headroom (each extra chunk inflates one gap of every active
            # stream) to accelerate a queue the normal cadence absorbs
            return 1
        slo = self.slo
        itl = self._itl_p95()
        if itl <= 0.0 or itl > slo.itl_slack * slo.itl_target_s:
            return 1
        stats = self._stats
        chunk_cost = (stats.t_prefill / stats.prefill_chunks
                      if stats.prefill_chunks else 0.0)
        if chunk_cost <= 0.0:
            return 1
        base_gap = (stats.itl.percentile(50, last=self.recent)
                    or stats.decode_round_cost())
        budget = slo.itl_target_s - base_gap
        return int(max(1, min(self.max_quanta, budget / chunk_cost)))

    def should_shed(self, wait_s: float) -> bool:
        """Deadline-based admission control: drop a queue head that can no
        longer meet its TTFT target.  A doomed request counts against
        goodput whether it is served late or dropped — but *serving* it
        also spends a swap + prefill on work that is already lost, pushing
        everyone queued behind it past THEIR deadlines.  Shedding converts
        one unavoidable miss into capacity for requests that can still be
        served in time.

        "Doomed" is not ``wait >= target``: admission is only the start —
        the first token still needs the prompt's chunked prefill,
        interleaved with everyone else's quanta.  That admission-to-first-
        token time is observable as the gap between the engine's TTFT and
        queue-wait medians, so the head is shed once
        ``wait + observed_serve_time`` crosses the target (falling back to
        the bare deadline before any observations exist).  Only this
        policy exposes the hook; the static policies never shed,
        preserving their run-to-completion semantics (and greedy
        bit-identity)."""
        serve = 0.0
        if self._stats is not None:
            serve = max(0.0, self._stats.ttft.percentile(50, last=self.recent)
                        - self._stats.queue_wait.percentile(50, last=self.recent))
        # the serve estimate is two medians over different request subsets
        # and can spike under churn; never shed before half the deadline,
        # so an inflated estimate cannot drop requests with real headroom
        line = max(0.5 * self.slo.ttft_target_s,
                   self.slo.ttft_target_s - serve)
        return wait_s >= line

    def reset(self) -> None:
        self._deferred = 0
        self._last_active = 0


# register with the name-based factory (POLICIES lives in policy.py;
# importing this module completes the registry — make_policy() does so
# lazily to avoid a circular import at load time)
from repro.serving.policy import POLICIES  # noqa: E402

POLICIES.setdefault(SLOAwareSwapPolicy.name, SLOAwareSwapPolicy)
