"""moonshot-v1-16b-a3b  [moe]  (hf:moonshotai/Moonlight-16B-A3B)

48L d_model=2048 16H (GQA kv=16) per-expert d_ff=1408 vocab=163840,
MoE 64e top-6.  64 experts divide the 16-way model axis -> the EP
(expert-parallel all_to_all) path is exercised by this arch.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="transformer",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    moe=True,
    num_experts=64,
    top_k=6,
    moe_d_ff=1408,
    rope_theta=50000.0,
)
