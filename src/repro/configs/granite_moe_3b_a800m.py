"""granite-moe-3b-a800m  [moe]  (hf:ibm-granite granite-3.0 MoE family)

32L d_model=1536 24H (GQA kv=8) per-expert d_ff=512 vocab=49155, MoE 40e top-8.
The assignment header says "MoE 40e top-8" while its trailing note says "32
experts"; the HF 3b-a800m config is 40 experts top-8, so we use 40 (see
DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="transformer",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    moe=True,
    num_experts=40,
    top_k=8,
    moe_d_ff=512,
    rope_theta=10000.0,
)
