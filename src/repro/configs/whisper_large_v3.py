"""whisper-large-v3  [audio]  (arXiv:2212.04356)

Enc-dec, 32 encoder + 32 decoder layers, d_model=1280 20H d_ff=5120
vocab=51866.  The conv frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (B, 1500, 1280).
LayerNorm + GELU (not RMS/SwiGLU), learned positions (no RoPE).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    encoder_layers=32,
    encoder_seq=1500,
    cross_attention=True,
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
    rope_theta=0.0,  # learned absolute positions, no RoPE
    max_position_embeddings=32768,  # decode_32k cell needs this many slots
)
