"""Model/config dataclasses shared by every architecture in the zoo.

One ``ModelConfig`` describes any member of the four families implemented in
``repro.models``:

* ``transformer`` — decoder-only dense or MoE (llama/granite/moonshot/...)
* ``xlstm``       — sLSTM + mLSTM recurrent blocks (attention-free)
* ``hymba``       — parallel attention + selective-SSM heads hybrid
* ``encdec``      — Whisper-style encoder-decoder with a stubbed frontend

The config is a frozen dataclass so it can be closed over by jitted functions
and hashed into AOT-compile cache keys.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """BitNet-b1.58 style quantization (the paper's W1.58-A8 regime)."""

    mode: str = "bf16"  # "bf16" | "ternary"
    act_bits: int = 8  # activation quant for ternary linears (per-token absmax)
    # Group size for the table-lookup formulation (FPGA LUT groups of 4).
    tl_group: int = 4

    @property
    def ternary(self) -> bool:
        return self.mode == "ternary"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # transformer | xlstm | hymba | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden size (d_ff field is then unused)

    # --- attention details ---
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # Sliding-window attention: None = full attention everywhere.
    sliding_window: Optional[int] = None
    # Layers that keep *full* attention when sliding_window is set (hymba: 3).
    global_attn_layers: Tuple[int, ...] = ()
    causal: bool = True

    # --- SSM / recurrent ---
    ssm_state: int = 0  # N, the per-channel state size (hymba: 16)
    ssm_conv: int = 4  # depthwise conv width in the mamba branch
    # xlstm: one sLSTM block every `slstm_every` layers (7:1 ratio -> 8).
    slstm_every: int = 8

    # --- encoder-decoder ---
    encoder_layers: int = 0
    encoder_seq: int = 0  # whisper: 1500 frames after the (stubbed) conv frontend
    cross_attention: bool = False

    # --- misc ---
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu (SwiGLU) | gelu (plain MLP, whisper)
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    max_position_embeddings: int = 1 << 20

    quant: QuantConfig = dataclasses.field(default_factory=QuantConfig)

    # dropped-token capacity factor for MoE routing
    moe_capacity_factor: float = 1.25

    # --- execution knobs (static: part of the jit cache key) ---
    # Dispatch Pallas kernels (interpret=True on CPU; compiled on TPU).
    use_pallas: bool = False
    # Attention-core implementation for lowering:
    #   "xla"  — generic jnp/XLA attention (the static-baseline program)
    #   "stub" — shape-correct zero-cost stand-in; the dry-run adds the
    #            Pallas kernel's analytic BlockSpec-derived cost instead
    #            (kernels/costs.py) — the phase-specialized RM program.
    attn_impl: str = "xla"
    # Activation checkpointing policy for the layer scan: full | dots | none.
    remat: str = "full"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
            f"{self.name}: num_heads={self.num_heads} not a multiple of "
            f"num_kv_heads={self.num_kv_heads}"
        )

    # ---- derived quantities used by sharding + roofline ----

    @property
    def q_group(self) -> int:
        return self.num_heads // self.num_kv_heads

    def padded_vocab(self, multiple: int = 256) -> int:
        """Megatron-style vocab padding so the vocab dim shards evenly."""
        return _round_up(self.vocab_size, multiple)

    @property
    def attention_free(self) -> bool:
        return self.family == "xlstm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context with bounded per-step cost?"""
        return self.family in ("xlstm", "hymba")

    @property
    def ffn_hidden(self) -> int:
        return self.moe_d_ff if self.moe else self.d_ff

    def param_count(self) -> int:
        """Analytic parameter count (matches models.init within ties/bias noise)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "xlstm":
            per = _xlstm_layer_params(self)
            return emb + L * per + d
        attn = d * (self.num_heads * hd) + d * (2 * self.num_kv_heads * hd) + (self.num_heads * hd) * d
        if self.qkv_bias:
            attn += (self.num_heads + 2 * self.num_kv_heads) * hd
        if self.moe:
            ffn = self.num_experts * (3 * d * self.moe_d_ff) + d * self.num_experts
        elif self.act == "silu":
            ffn = 3 * d * self.d_ff
        else:
            ffn = 2 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        total = emb + L * per_layer + d
        if self.family == "hymba":
            total += L * _ssm_branch_params(self)
        if self.family == "encdec":
            enc_per = attn + (2 * d * self.d_ff) + 2 * d
            cross = attn + d
            total += self.encoder_layers * enc_per + L * cross
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only top_k experts count)."""
        if not self.moe:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        dense = self.param_count() - L * self.num_experts * 3 * d * self.moe_d_ff
        return dense + L * self.top_k * 3 * d * self.moe_d_ff


def _xlstm_layer_params(cfg: ModelConfig) -> int:
    d, H = cfg.d_model, cfg.num_heads
    hd = d // H
    # mLSTM block: q/k/v proj + i/f/o gates + out proj + norm
    m = 3 * d * d + 3 * d * H + d * d + 2 * d
    # sLSTM block: 4 gates input + 4 recurrent (block-diag per head) + out
    s = 4 * d * d + 4 * H * hd * hd + d * d + 2 * d
    n_s = cfg.num_layers // cfg.slstm_every
    n_m = cfg.num_layers - n_s
    return (n_m * m + n_s * s) // cfg.num_layers


def _ssm_branch_params(cfg: ModelConfig) -> int:
    d, N = cfg.d_model, cfg.ssm_state
    d_in = d  # ssm branch inner width == d_model (parallel-heads design)
    return d * 2 * d_in + d_in * (2 * N + 1) + d_in * cfg.ssm_conv + d_in * d + d_in


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell of the assigned matrix."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> list[ShapeCell]:
    """The shape cells that run for this arch (long_500k: sub-quadratic only)."""
    cells = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.sub_quadratic:
        cells.append(SHAPES["long_500k"])
    return cells
