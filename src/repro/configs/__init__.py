"""Architecture registry: ``--arch <id>`` resolves through here."""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import (
    ModelConfig,
    QuantConfig,
    ShapeCell,
    SHAPES,
    applicable_shapes,
)

# arch-id -> module path (one module per assigned architecture + paper's own)
_ARCH_MODULES = {
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "minicpm-2b": "repro.configs.minicpm_2b",
    "smollm-135m": "repro.configs.smollm_135m",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "bitnet-730m": "repro.configs.bitnet_730m",
}

ASSIGNED_ARCHS = [a for a in _ARCH_MODULES if a != "bitnet-730m"]
ALL_ARCHS = list(_ARCH_MODULES)


def get_config(arch: str, *, quant_mode: str | None = None) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    cfg: ModelConfig = importlib.import_module(_ARCH_MODULES[arch]).CONFIG
    if quant_mode is not None:
        cfg = dataclasses.replace(cfg, quant=QuantConfig(mode=quant_mode))
    return cfg


def reduced_config(arch: str, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests (per-arch smoke tests
    instantiate REDUCED configs; full configs are exercised only by the
    dry-run)."""
    cfg = get_config(arch)
    small = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=2 if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=32,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab_size=256,
        max_position_embeddings=2048,
    )
    if cfg.moe:
        small.update(num_experts=4, top_k=2, moe_d_ff=64)
    if cfg.family == "encdec":
        small.update(encoder_layers=2, encoder_seq=16)
    if cfg.family == "hymba":
        small.update(sliding_window=32, global_attn_layers=(0,), ssm_state=8)
    if cfg.family == "xlstm":
        small.update(num_heads=4, num_kv_heads=4, head_dim=32, slstm_every=2)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
