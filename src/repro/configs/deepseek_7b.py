"""deepseek-7b  [dense]  (arXiv:2401.02954) — llama-arch.

30L d_model=4096 32H (MHA kv=32) d_ff=11008 vocab=102400.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="transformer",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
    rope_theta=10000.0,
    tie_embeddings=False,
)
