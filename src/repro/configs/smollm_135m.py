"""smollm-135m  [dense]  (hf:HuggingFaceTB/SmolLM-135M) — small llama-arch.

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.  The ~100M-class model:
the end-to-end training example (examples/train_smollm.py) trains this arch.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="transformer",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    rope_theta=10000.0,
    tie_embeddings=True,
)
