"""minicpm-2b  [dense]  (arXiv:2404.06395) — llama-like; WSD LR schedule.

40L d_model=2304 36H (MHA kv=36) d_ff=5760 vocab=122753.  The WSD
(warmup-stable-decay) schedule it introduces lives in repro.optim.schedules
and is selected by the training example for this arch.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="transformer",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    rope_theta=10000.0,
    tie_embeddings=True,
)
