"""hymba-1.5b  [hybrid]  (arXiv:2411.13676) — parallel attention + mamba heads.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Per Hymba: sliding-window attention everywhere except 3 full-attention
layers (first / middle / last); the SSM branch runs in parallel with the
attention branch in every layer.  SWA + SSM => sub-quadratic, so this arch
runs the long_500k decode cell.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hymba",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    sliding_window=1024,
    global_attn_layers=(0, 15, 31),
    tie_embeddings=True,
)
