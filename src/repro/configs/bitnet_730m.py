"""bitnet-730m — the paper's own model (BitNet b1.58 0.73B, W1.58-A8).

Not part of the assigned 10-arch pool; included so the paper-faithful
experiments (Fig. 5/6, Tables 1/2 analogues) run the same model the paper
ran: ternary weights, int8 activations, table-lookup linear path.
LLaMA-shaped 700M-class config per BitNet b1.58 (arXiv:2402.17764).
"""
from repro.configs.base import ModelConfig, QuantConfig

CONFIG = ModelConfig(
    name="bitnet-730m",
    family="transformer",
    num_layers=24,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=4096,
    vocab_size=32002,
    rope_theta=10000.0,
    tie_embeddings=True,
    quant=QuantConfig(mode="ternary"),
)
