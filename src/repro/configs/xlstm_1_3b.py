"""xlstm-1.3b  [ssm]  (arXiv:2405.04517)

48L d_model=2048 4H d_ff=0 vocab=50304 — sLSTM + mLSTM blocks at the paper's
7:1 ratio (one sLSTM block per 8).  Attention-free: the PD-Swap *attention*
RMs don't apply, but the phase asymmetry does — chunkwise-parallel prefill vs
O(1)-state recurrent decode are the two phase-specialized programs
(DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="xlstm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,
    tie_embeddings=False,
)
