"""chameleon-34b  [vlm]  (arXiv:2405.09818)

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 — early-fusion VLM.
VQ-VAE image tokens share the text vocabulary, so the modality frontend is a
stub: ``input_specs()`` provides plain token ids (image patches are just ids
in [0, vocab)).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="transformer",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    rope_theta=10000.0,
    tie_embeddings=False,
)
