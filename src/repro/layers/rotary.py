"""Rotary position embeddings (llama convention: rotate-half).

Model convention throughout this repo: projected q/k tensors are
(batch, seq, heads, head_dim); positions are (batch, seq).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int -> same shape, rotated."""
    d = x.shape[-1]
    inv = rope_frequencies(d, theta)  # (D/2,)
    ang = positions[:, :, None, None].astype(jnp.float32) * inv  # (B, S, 1, D/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1 = x[..., : d // 2].astype(jnp.float32)
    x2 = x[..., d // 2 :].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
