"""Logical-axis sharding: MaxText-style rules mapping logical dims to mesh axes.

Model code annotates tensors with *logical* axis names; a ``PartitionCtx``
resolves them to mesh axes through a rules table and inserts
``with_sharding_constraint``.  With ``mesh=None`` (unit tests, single host)
every annotation is a no-op, so the same model code runs anywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, tuple]

# Default rule tables.  "dp" is data-parallel (('pod','data') on the multi-pod
# mesh), "tp" is tensor-parallel ('model'), "fsdp" the param-sharding axis.
TRAIN_RULES: dict[str, Axis] = {
    "batch": "__dp__",
    "seq": None,
    "embed": None,  # activations keep embed replicated across tp
    "heads": "__tp__",
    "kv_heads": "__tp__",
    "head_dim": None,
    "ffn": "__tp__",
    "vocab": "__tp__",
    "experts": None,
    "expert_ffn": "__tp__",
    "layers": None,
    # parameter logical axes
    "param_embed": "__fsdp__",  # FSDP: shard the big dim of every weight
    "param_ffn": "__tp__",
    "param_heads": "__tp__",
    "param_vocab": "__tp__",
    "kv_seq": None,
    "state": None,
}

PREFILL_RULES = dict(
    TRAIN_RULES,
    param_embed=None,  # inference: weights replicated over dp, sharded over tp
)

# Decode: batch over data; the KV cache *sequence* dim over the model axis —
# flash-decoding-style KV sharding multiplies effective streaming bandwidth
# (the scaled-out analogue of the paper's 2xK+2xV HP-port remap, §3.2.3) and
# sidesteps uneven kv-head counts (e.g. 8 kv heads on a 16-way axis).
DECODE_RULES = dict(
    PREFILL_RULES,
    batch="__dp__",
    kv_seq="__tp__",
    heads=None,  # q is one token: replicate heads, shard the cache instead
    kv_heads=None,
)

# long-context decode (global_batch=1): batch can't shard, so the KV/state
# sequence dim takes *every* mesh axis and the whole pod streams one cache.
LONG_DECODE_RULES = dict(DECODE_RULES, batch=None, kv_seq="__dp_tp__", state="__tp__")


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    dp: Axis = "data"  # ('pod','data') on the multi-pod mesh
    tp: Axis = "model"
    fsdp: Axis = "data"


@dataclasses.dataclass(frozen=True)
class PartitionCtx:
    mesh: Optional[Mesh] = None
    axes: MeshAxes = dataclasses.field(default_factory=MeshAxes)
    rules: Mapping[str, Axis] = dataclasses.field(default_factory=lambda: dict(TRAIN_RULES))

    def resolve(self, logical: Sequence[Optional[str]]) -> P:
        out = []
        for name in logical:
            ax = self.rules.get(name) if name else None
            if ax == "__dp__":
                ax = self.axes.dp
            elif ax == "__tp__":
                ax = self.axes.tp
            elif ax == "__fsdp__":
                ax = self.axes.fsdp
            elif ax == "__dp_tp__":
                dp = self.axes.dp if isinstance(self.axes.dp, tuple) else (self.axes.dp,)
                tp = self.axes.tp if isinstance(self.axes.tp, tuple) else (self.axes.tp,)
                ax = tuple(a for a in dp + tp if a)
            out.append(ax)
        return P(*out)

    def shard(self, x: jax.Array, *logical: Optional[str]) -> jax.Array:
        """Annotate x with the resolved sharding (no-op without a mesh)."""
        if self.mesh is None:
            return x
        assert len(logical) == x.ndim, (logical, x.shape)
        spec = self.resolve(logical)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def named_sharding(self, *logical: Optional[str]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.resolve(logical))

    @property
    def tp_size(self) -> int:
        if self.mesh is None:
            return 1
        ax = self.axes.tp
        return self.mesh.shape[ax] if isinstance(ax, str) else 1

    def with_rules(self, rules: Mapping[str, Axis]) -> "PartitionCtx":
        return dataclasses.replace(self, rules=rules)


NULL_CTX = PartitionCtx()


def sanitize_spec(spec: P, shape: Sequence[int], mesh: Mesh) -> P:
    """Drop spec axes whose mesh size does not divide the dim.

    ``pjit`` in_shardings require divisibility (unlike
    with_sharding_constraint, which pads); odd dims — 9 heads on a 16-way
    axis, whisper's 1500-frame encoder — fall back to replication on that
    dim rather than erroring."""
    out = []
    for d, size in enumerate(shape):
        ax = spec[d] if d < len(spec) else None
        if ax is not None:
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            if size % n != 0:
                ax = None
        out.append(ax)
    return P(*out)


def sanitize_named_sharding(ns: NamedSharding, shape: Sequence[int]) -> NamedSharding:
    return NamedSharding(ns.mesh, sanitize_spec(ns.spec, shape, ns.mesh))
