"""RMSNorm / LayerNorm (fp32 statistics, cast back to input dtype)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def layernorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def norm_init(kind: str, d: int, dtype=jnp.float32) -> dict:
    return rmsnorm_init(d, dtype) if kind == "rmsnorm" else layernorm_init(d, dtype)


def apply_norm(params: dict, x: jax.Array, kind: str = "rmsnorm", eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    else:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)
