"""Dense FFN: SwiGLU (llama family) or GELU MLP (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.linear import linear_apply, linear_init
from repro.layers.sharding import PartitionCtx


def mlp_init(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.act == "silu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": linear_init(k1, d, f, dtype=dtype),
            "w_up": linear_init(k2, d, f, dtype=dtype),
            "w_down": linear_init(k3, f, d, dtype=dtype, scale=1.0 / f**0.5),
        }
    k1, k2 = jax.random.split(key)
    return {
        "w_in": linear_init(k1, d, f, bias=True, dtype=dtype),
        "w_out": linear_init(k2, f, d, bias=True, dtype=dtype, scale=1.0 / f**0.5),
    }


def mlp_apply(params: dict, x: jax.Array, cfg: ModelConfig, pctx: PartitionCtx, *, training: bool = False) -> jax.Array:
    kw = dict(quant=cfg.quant, training=training, use_pallas=cfg.use_pallas)
    if "w_gate" in params:
        g = linear_apply(params["w_gate"], x, **kw)
        u = linear_apply(params["w_up"], x, **kw)
        g = pctx.shard(g, "batch", "seq", "ffn")
        h = (jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)) * u
        return linear_apply(params["w_down"], h, **kw)
    h = linear_apply(params["w_in"], x, **kw)
    h = pctx.shard(h, "batch", "seq", "ffn")
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return linear_apply(params["w_out"], h, **kw)
