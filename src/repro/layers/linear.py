"""Linear layers: dense bf16 and TLMM-backed ternary (the paper's static region).

Three execution regimes, all sharing one param layout:

* ``bf16``            — plain matmul on latent weights.
* ``ternary`` (train) — BitNet QAT: STE ternary weights + STE int8 acts.
* ``ternary`` (infer) — weights converted once to :class:`TernaryWeight`
                        (2-bit packed) and multiplied by the TLMM op; this is
                        the "static region" engine shared by both phases.

The param dict is {"w": (K, N)} (+"b") for latent weights, or
{"w": TernaryWeight} after ``convert_linear_for_inference``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import QuantConfig
from repro.kernels.tlmm.ops import tlmm_matmul
from repro.quant.act_quant import quantize_activations_int8
from repro.quant.ternary import TernaryWeight, quantize_and_pack, ternary_quantize_ste


def linear_init(key, k: int, n: int, *, bias: bool = False, dtype=jnp.bfloat16, scale: Optional[float] = None) -> dict:
    if scale is None:
        scale = 1.0 / (k**0.5)
    p = {"w": (jax.random.normal(key, (k, n), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((n,), dtype)
    return p


def _act_fake_quant_ste(x: jax.Array) -> jax.Array:
    x_q, scale = quantize_activations_int8(x)
    deq = (x_q.astype(jnp.float32) * scale).astype(x.dtype)
    return x + jax.lax.stop_gradient(deq - x)


def linear_apply(
    params: dict,
    x: jax.Array,
    quant: QuantConfig,
    *,
    training: bool = False,
    use_pallas: bool = False,
    interpret: bool = True,
) -> jax.Array:
    w = params["w"]
    if isinstance(w, TernaryWeight):
        # inference TLMM path (packed 2-bit weights)
        y = tlmm_matmul(x, w, out_dtype=x.dtype, use_kernel=use_pallas, interpret=interpret)
    elif quant.ternary:
        if training:
            # BitNet QAT: STE through both weight and activation quantizers
            w_ste, _ = ternary_quantize_ste(w.astype(jnp.float32))
            y = _act_fake_quant_ste(x).astype(jnp.float32) @ w_ste
            y = y.astype(x.dtype)
        else:
            # unconverted ternary inference: quantize on the fly (slow path)
            x_q, s = quantize_activations_int8(x)
            from repro.quant.ternary import ternary_quantize

            w_q, beta = ternary_quantize(w.astype(jnp.float32))
            acc = jax.lax.dot_general(
                x_q.reshape(-1, x.shape[-1]), w_q, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            y = (acc * s.reshape(-1, 1) * beta).reshape(*x.shape[:-1], w.shape[1]).astype(x.dtype)
    else:
        y = x @ w.astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def convert_linear_for_inference(params: dict, quant: QuantConfig) -> dict:
    """Latent fp weights -> packed TernaryWeight (one-time model conversion)."""
    if not quant.ternary or isinstance(params["w"], TernaryWeight):
        return params
    out = dict(params)
    out["w"] = quantize_and_pack(params["w"].astype(jnp.float32))
    return out
