"""Mixture-of-Experts FFN with sort-based capacity routing.

Two distribution strategies, selected automatically per arch:

* **EP** (expert parallel) — when ``num_experts % tp_size == 0`` (moonshot:
  64e on a 16-way model axis): experts are sharded over the model axis and
  tokens move via ``all_to_all`` inside ``shard_map`` (GShard/Switch
  pattern).
* **TP** (tensor parallel experts) — otherwise (granite: 40e): every shard
  routes its local tokens to *all* experts and computes the expert FFNs on
  its slice of the expert hidden dim, with one ``psum`` over the model axis
  at the end.

Routing is sort-based (argsort + per-expert rank), never materializing the
(T, E, C) one-hot dispatch tensor — at 1M tokens that tensor is the classic
OOM of naive MoE implementations.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.layers.sharding import PartitionCtx
from repro.quant.ternary import ternary_quantize_ste


def moe_init(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in, s_out = 1.0 / d**0.5, 1.0 / f**0.5
    return {
        "router": (jax.random.normal(k1, (d, e), jnp.float32) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (e, d, f), jnp.float32) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k3, (e, d, f), jnp.float32) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k4, (e, f, d), jnp.float32) * s_out).astype(dtype),
    }


def _maybe_ternary(w: jax.Array, cfg: ModelConfig, training: bool) -> jax.Array:
    if not cfg.quant.ternary:
        return w
    if training:
        w_ste, _ = ternary_quantize_ste(w.astype(jnp.float32))
        return w_ste
    from repro.quant.ternary import ternary_quantize

    w_q, beta = ternary_quantize(w.astype(jnp.float32))
    return (w_q.astype(jnp.float32) * beta).astype(w.dtype)


def _route(gate_logits: jax.Array, k: int, capacity: int, num_experts: int):
    """Sort-based top-k routing.  gate_logits: (T, E) f32.

    Returns (token_idx (T*k,), dest (T*k,) into E*C flat buffer or OOB when
    dropped, combine_w (T*k,) f32).
    """
    t = gate_logits.shape[0]
    probs = jax.nn.softmax(gate_logits.astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(probs, k)  # (T, k)
    topv = topv / (jnp.sum(topv, axis=-1, keepdims=True) + 1e-9)
    flat_e = topi.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=num_experts)
    offsets = jnp.cumsum(counts) - counts  # exclusive prefix sum
    ranks_sorted = jnp.arange(t * k) - offsets[sorted_e]
    ranks = jnp.zeros((t * k,), jnp.int32).at[order].set(ranks_sorted.astype(jnp.int32))
    keep = ranks < capacity
    dest = jnp.where(keep, flat_e * capacity + ranks, num_experts * capacity)  # OOB -> dropped
    token_idx = jnp.repeat(jnp.arange(t), k)
    return token_idx, dest, topv.reshape(-1), probs


def _expert_ffn(buf: jax.Array, w_gate, w_up, w_down, act: str) -> jax.Array:
    """buf: (E, C, d) -> (E, C, d) through per-expert SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(buf.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down.astype(buf.dtype))


def _dispatch(x_flat, token_idx, dest, e, c):
    buf = jnp.zeros((e * c + 1, x_flat.shape[-1]), x_flat.dtype)
    buf = buf.at[dest].add(x_flat[token_idx], mode="drop")
    return buf[: e * c].reshape(e, c, -1)


def _combine(y_buf, token_idx, dest, weights, t):
    e_c = y_buf.shape[0] * y_buf.shape[1]
    y_flat = y_buf.reshape(e_c, -1)
    safe = jnp.minimum(dest, e_c - 1)
    contrib = y_flat[safe] * (weights * (dest < e_c))[:, None].astype(y_flat.dtype)
    out = jnp.zeros((t, y_flat.shape[-1]), y_flat.dtype)
    return out.at[token_idx].add(contrib)


# Token-chunk size for the dispatch buffer: bounds the (E, C, d) working set
# to ~hundreds of MB at train_4k scale (65k tokens/shard would need GBs).
MOE_TOKEN_CHUNK = 8192


def _moe_tokens_chunked(x_flat, gate_logits, params, cfg: ModelConfig, *, training,
                        tp_axis, ep, chunk: int = MOE_TOKEN_CHUNK):
    t, d = x_flat.shape
    if t <= chunk:
        return _moe_tokens(x_flat, gate_logits, params, cfg, training=training,
                           tp_axis=tp_axis, ep=ep)
    pad = (-t) % chunk
    if pad:
        x_flat = jnp.pad(x_flat, ((0, pad), (0, 0)))
        gate_logits = jnp.pad(gate_logits, ((0, pad), (0, 0)))
    nc = (t + pad) // chunk

    def body(_, inp):
        xc, gc = inp
        return None, _moe_tokens(xc, gc, params, cfg, training=training,
                                 tp_axis=tp_axis, ep=ep)

    _, ys = jax.lax.scan(
        body, None,
        (x_flat.reshape(nc, chunk, d), gate_logits.reshape(nc, chunk, -1)),
    )
    return ys.reshape(nc * chunk, d)[:t]


def _moe_tokens(x_flat, gate_logits, params, cfg: ModelConfig, *, training: bool,
                tp_axis: Optional[str], ep: bool):
    """Local-view MoE over T tokens.  Runs standalone or inside shard_map."""
    t, d = x_flat.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = max(8, int(t * k / e * cfg.moe_capacity_factor))
    w_gate = _maybe_ternary(params["w_gate"], cfg, training)
    w_up = _maybe_ternary(params["w_up"], cfg, training)
    w_down = _maybe_ternary(params["w_down"], cfg, training)

    token_idx, dest, comb_w, _ = _route(gate_logits, k, cap, e)
    buf = _dispatch(x_flat, token_idx, dest, e, cap)  # (E, C, d)

    if ep and tp_axis is not None:
        # expert-major send buffers to their owner shards (GShard pattern):
        # (E, C, d) --a2a--> (E_loc, n_sh*C, d): local experts, candidate
        # tokens from every source shard (concatenated in shard order).
        recv = jax.lax.all_to_all(buf, tp_axis, split_axis=0, concat_axis=1, tiled=True)
        y_loc = _expert_ffn(recv, w_gate, w_up, w_down, cfg.act)
        # inverse exchange: back to (E, C, d) holding this shard's own tokens
        y_buf = jax.lax.all_to_all(y_loc, tp_axis, split_axis=1, concat_axis=0, tiled=True)
        return _combine(y_buf, token_idx, dest, comb_w, t)

    # TP path: full expert set, hidden dim already sliced by the caller
    y_buf = _expert_ffn(buf, w_gate, w_up, w_down, cfg.act)
    out = _combine(y_buf, token_idx, dest, comb_w, t)
    if tp_axis is not None:
        out = jax.lax.psum(out, tp_axis)
    return out


def load_balance_loss(gate_logits: jax.Array, k: int, num_experts: int) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * p_e (f: token fraction, p: prob mass)."""
    probs = jax.nn.softmax(gate_logits.astype(jnp.float32), axis=-1).reshape(-1, num_experts)
    _, topi = jax.lax.top_k(probs, k)
    f = jnp.mean(
        jnp.sum(jax.nn.one_hot(topi, num_experts, dtype=jnp.float32), axis=-2), axis=0
    ) / k
    p = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(f * p)


def moe_apply(
    params: dict,
    x: jax.Array,  # (B, S, d)
    cfg: ModelConfig,
    pctx: PartitionCtx,
    *,
    training: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,d), aux_loss scalar)."""
    b, s, d = x.shape
    gate_logits = (x.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    aux = load_balance_loss(gate_logits, cfg.top_k, cfg.num_experts)

    tp = pctx.axes.tp if (pctx.mesh is not None and isinstance(pctx.axes.tp, str)) else None
    if tp is None:
        y = _moe_tokens_chunked(
            x.reshape(b * s, d), gate_logits.reshape(b * s, -1), params, cfg,
            training=training, tp_axis=None, ep=False,
        ).reshape(b, s, d)
        return y.astype(x.dtype), aux

    ep = cfg.num_experts % pctx.tp_size == 0
    dp = pctx.rules.get("batch")
    dp = pctx.axes.dp if dp == "__dp__" else None
    # [§Perf iteration M1] Tokens are SHARDED over the model axis inside the
    # MoE block whenever the sequence divides it.  The earlier P(dp, None,
    # None) spec replicated every token to all tp shards — routing, dispatch
    # and the expert FFNs ran tp_size x redundantly (useful_frac 1/19 on
    # moonshot train) and the all_to_all carried tp_size x the volume.  With
    # seq-sharded tokens: EP archs keep experts sharded + a2a (GShard); the
    # non-divisible-experts archs (granite 40e/16) replicate the (small)
    # expert weights and need NO collective at all inside the block — the
    # output all-gather back to replicated activations is the only cost.
    seq_sharded = s % max(pctx.tp_size, 1) == 0 and pctx.tp_size > 1
    x_spec = P(dp, tp, None) if seq_sharded else P(dp, None, None)
    if ep:
        w_specs = {"router": P(), "w_gate": P(tp, None, None), "w_up": P(tp, None, None), "w_down": P(tp, None, None)}
        inner_tp, inner_ep = tp, True
    elif seq_sharded:
        w_specs = {"router": P(), "w_gate": P(None, None, None), "w_up": P(None, None, None), "w_down": P(None, None, None)}
        inner_tp, inner_ep = None, False  # local experts, no collective
    else:
        w_specs = {"router": P(), "w_gate": P(None, None, tp), "w_up": P(None, None, tp), "w_down": P(None, tp, None)}
        inner_tp, inner_ep = tp, False  # hidden-dim split + psum

    def shard_fn(p, xs, gl):
        bl, sl, _ = xs.shape
        y = _moe_tokens_chunked(
            xs.reshape(bl * sl, d), gl.reshape(bl * sl, -1), p, cfg,
            training=training, tp_axis=inner_tp, ep=inner_ep,
        )
        return y.reshape(bl, sl, d)

    y = shard_map(
        shard_fn,
        mesh=pctx.mesh,
        in_specs=(w_specs, x_spec, x_spec),
        out_specs=x_spec,
        check_vma=False,
    )(params, x, gate_logits)
    return y.astype(x.dtype), aux
