"""Attention layer: the *dynamic region* of PD-Swap.

One parameter set, two phase-specialized execution paths (the two RMs):

* ``attention_prefill``  — token-parallel blocked attention (compute-bound
  engine).  Dispatches to the Pallas reverse-scheduled flash kernel
  (``cfg.use_pallas``) or to a memory-bounded chunked-scan jnp path whose
  peak live set is O(S·chunk) instead of O(S²) — required for the 32k/500k
  dry-run cells.
* ``attention_decode``   — single-token KV-cache-streaming attention
  (bandwidth-bound engine), Pallas flash-decode kernel or jnp oracle, with
  per-sequence lengths for continuous batching and ring-buffer caches for
  sliding-window layers.

Projections (Q/K/V/O) are TLMM/dense linears — the paper's *static region* —
and are shared verbatim by both phases.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.paged_attention.ops import paged_decode_attention
from repro.kernels.prefill_attention.ops import prefill_attention
from repro.layers.linear import linear_apply, linear_init
from repro.layers.rotary import apply_rope
from repro.layers.sharding import PartitionCtx
from repro.quant.kv_quant import QuantKV, infer_kv_dtype, quantize_kv


class KVCache(NamedTuple):
    k: jax.Array  # (B, Hkv, Smax, D) — or a QuantKV (payload + scale plane)
    v: jax.Array  # (B, Hkv, Smax, D)


def _kv_leaf_args(k_leaf, v_leaf):
    """Split a (possibly quantized) K/V cache leaf pair into the positional
    payload arrays + the keyword scale/dtype arguments the kernel ops take.
    The cache pytree itself carries the precision — no dtype plumbing."""
    if isinstance(k_leaf, QuantKV):
        return k_leaf.q, v_leaf.q, dict(
            k_scales=k_leaf.scale, v_scales=v_leaf.scale,
            kv_dtype=infer_kv_dtype(k_leaf.q),
        )
    return k_leaf, v_leaf, {}


def attention_init(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": linear_init(k1, d, h * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": linear_init(k2, d, hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": linear_init(k3, d, hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": linear_init(k4, h * hd, d, dtype=dtype, scale=1.0 / (h * hd) ** 0.5),
    }


def _project_qkv(params, x, cfg: ModelConfig, positions, *, training, rope=True):
    b, s, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kw = dict(quant=cfg.quant, training=training, use_pallas=cfg.use_pallas)
    q = linear_apply(params["wq"], x, **kw).reshape(b, s, h, hd)
    k = linear_apply(params["wk"], x, **kw).reshape(b, s, hkv, hd)
    v = linear_apply(params["wv"], x, **kw).reshape(b, s, hkv, hd)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _chunked_attention(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,
    *,
    causal: bool,
    window: Optional[int],
    chunk: int = 512,
    q_offset: int = 0,
) -> jax.Array:
    """Exact attention with O(S·chunk) live memory: scan over query chunks.

    GQA is handled grouped — KV is never expanded to H heads (that expansion
    is the hidden memory bug of naive GQA at 32k).
    """
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = h // hkv
    sm = 1.0 / math.sqrt(d)
    chunk = min(chunk, sq)
    pad = (-sq) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = (sq + pad) // chunk
    qg = q.reshape(b, hkv, g, nc, chunk, d)
    qg = jnp.moveaxis(qg, 3, 0)  # (nc, B, Hkv, G, chunk, D)
    kpos = jnp.arange(skv)

    def body(_, args):
        ci, qc = args  # qc: (B, Hkv, G, chunk, D)
        qpos = q_offset + ci * chunk + jnp.arange(chunk)
        # bf16 operands + f32 accumulation (preferred_element_type) — the
        # MXU semantics; never materialize f32 copies of K/V [§Perf T1]
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qc.astype(k.dtype), k,
                       preferred_element_type=jnp.float32) * sm
        mask = jnp.ones((chunk, skv), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return None, o.astype(q.dtype)

    # checkpoint: without it, backward saves every chunk's (.., chunk, Skv)
    # score tensor — the full S^2 matrix in aggregate.
    body = jax.checkpoint(body)
    _, out = jax.lax.scan(body, None, (jnp.arange(nc), qg))
    out = jnp.moveaxis(out, 0, 3).reshape(b, hkv, g, sq + pad, d)
    out = out.reshape(b, h, sq + pad, d)
    return out[:, :, :sq]


def attention_prefill(
    params: dict,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,  # (B, S)
    cfg: ModelConfig,
    pctx: PartitionCtx,
    *,
    window: Optional[int] = None,
    causal: bool = True,
    training: bool = False,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """The prefill RM.  Returns (y, (k, v)) with k/v in (B, Hkv, S, D) cache layout."""
    b, s, _ = x.shape
    rope = cross_kv is None and cfg.rope_theta > 0
    q, k, v = _project_qkv(params, x, cfg, positions, training=training, rope=rope)
    q = pctx.shard(q, "batch", "seq", "heads", "head_dim")
    qt = q.transpose(0, 2, 1, 3)  # (B, H, S, D)
    if cross_kv is not None:
        kt, vt = cross_kv  # encoder KV, (B, Hkv, Senc, D)
        causal = False
    else:
        kt = pctx.shard(k, "batch", "seq", "kv_heads", "head_dim").transpose(0, 2, 1, 3)
        vt = pctx.shard(v, "batch", "seq", "kv_heads", "head_dim").transpose(0, 2, 1, 3)

    if cfg.attn_impl == "stub":
        # Kernel-substituted lowering (dry-run): the attention core is a
        # shape-correct identity; kernels/costs.py supplies the Pallas
        # kernel's exact analytic cost.  Projections/KV collection stay real.
        out = qt
    elif cfg.use_pallas and window is None and causal and qt.shape[2] == kt.shape[2]:
        out = prefill_attention(qt, kt, vt, use_kernel=True, interpret=True)
    elif s <= 1024 and kt.shape[2] <= 1024:
        from repro.kernels.prefill_attention.ref import prefill_attention_reference

        g = cfg.num_heads // kt.shape[1]
        kk = jnp.repeat(kt, g, axis=1) if g > 1 else kt
        vv = jnp.repeat(vt, g, axis=1) if g > 1 else vt
        sm = 1.0 / math.sqrt(cfg.head_dim)
        scores = jnp.einsum("bhqd,bhkd->bhqk", qt.astype(jnp.float32), kk.astype(jnp.float32)) * sm
        qi, ki = jnp.arange(s)[:, None], jnp.arange(kt.shape[2])[None, :]
        mask = jnp.ones((s, kt.shape[2]), bool)
        if causal:
            mask &= qi >= ki
        if window is not None:
            mask &= qi - ki < window
        scores = jnp.where(mask[None, None], scores, -1e30)
        out = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(scores, -1), vv.astype(jnp.float32)).astype(x.dtype)
    else:
        out = _chunked_attention(qt, kt, vt, causal=causal, window=window)

    out = pctx.shard(out, "batch", "heads", "seq", "head_dim")
    y = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.num_heads * cfg.head_dim)
    y = linear_apply(params["wo"], y, quant=cfg.quant, training=training, use_pallas=cfg.use_pallas)
    return y, (kt, vt)


def attention_prefill_chunk(
    params: dict,
    x: jax.Array,  # (B, C, d) — one chunk of the prompt
    k_prefix: jax.Array,  # (B, Hkv, Cap, D) fp — the installed cache prefix,
    v_prefix: jax.Array,  # valid in [0, prefix_len), garbage beyond
    prefix_len: jax.Array,  # traced scalar — tokens already prefilled
    cfg: ModelConfig,
    pctx: PartitionCtx,
    *,
    window: Optional[int] = None,
    positions: Optional[jax.Array] = None,  # (B, C), default prefix_len + arange(C)
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Chunked-prefill attention: the chunk's queries attend over the
    already-installed KV-cache prefix PLUS the chunk itself, with a
    position-offset causal mask.

    This is the third execution path of the dynamic region (prefill RM run
    one bounded quantum at a time): query position ``q`` sits at global
    position ``prefix_len + q`` and may attend key ``k`` iff ``k`` is a
    valid prefix position (``k < prefix_len``) or a chunk position at or
    before it.  ``k_prefix``/``v_prefix`` are the prefill-resident fp
    mirror of the already-installed prefix (see
    ``transformer._prefill_chunk_body`` for why the fp values, not the
    possibly-quantized cache bytes, are what keep chunked == monolithic).

    Returns (y, (k, v)) with the CHUNK's new K/V in (B, Hkv, C, D) cache
    layout; the caller installs them at ``[prefix_len, prefix_len + C)``
    (quantize-on-write under ``kv_dtype``).  A Pallas chunk kernel is a
    future optimization — this jnp path matches the reference prefill's
    f32 einsum numerics, so chunked == monolithic bitwise in the reference
    regime (monolithic prompts past the 1024-token reference cutoff, or
    under the Pallas kernel, accumulate in a different order and agree to
    float rounding instead).
    """
    b, c, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    cap = k_prefix.shape[2]
    if positions is None:
        positions = jnp.broadcast_to(prefix_len + jnp.arange(c), (b, c))
    q, k, v = _project_qkv(params, x, cfg, positions, training=False,
                           rope=cfg.rope_theta > 0)
    qt = q.transpose(0, 2, 1, 3)  # (B, H, C, D)
    kt = k.transpose(0, 2, 1, 3)  # (B, Hkv, C, D)
    vt = v.transpose(0, 2, 1, 3)

    if cfg.attn_impl == "stub":
        out = qt  # kernel-substituted lowering; see kernels/costs.py
    else:
        kk = jnp.concatenate([k_prefix.astype(jnp.float32), kt.astype(jnp.float32)], axis=2)
        vv = jnp.concatenate([v_prefix.astype(jnp.float32), vt.astype(jnp.float32)], axis=2)
        g = h // hkv
        if g > 1:
            kk = jnp.repeat(kk, g, axis=1)
            vv = jnp.repeat(vv, g, axis=1)
        sm = 1.0 / math.sqrt(hd)
        scores = jnp.einsum("bhqd,bhkd->bhqk", qt.astype(jnp.float32), kk) * sm
        # global key positions: prefix buffer slot i holds position i (valid
        # iff i < prefix_len); chunk key j sits at prefix_len + j
        qpos = prefix_len + jnp.arange(c)[:, None]  # (C, 1)
        kpos = jnp.concatenate([jnp.arange(cap), prefix_len + jnp.arange(c)])
        valid = jnp.concatenate(
            [jnp.arange(cap) < prefix_len, jnp.ones((c,), bool)])
        mask = valid[None, :] & (qpos >= kpos[None, :])
        if window is not None:
            mask &= qpos - kpos[None, :] < window
        scores = jnp.where(mask[None, None], scores, -1e30)
        out = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(scores, -1), vv).astype(x.dtype)

    out = pctx.shard(out, "batch", "heads", "seq", "head_dim")
    y = out.transpose(0, 2, 1, 3).reshape(b, c, h * hd)
    y = linear_apply(params["wo"], y, quant=cfg.quant, training=False, use_pallas=cfg.use_pallas)
    return y, (kt, vt)


def attention_verify(
    params: dict,
    x: jax.Array,  # (B, W, d) — per slot: [last sampled token, draft_1..draft_k]
    k_cache: jax.Array,  # (B, Hkv, Cap, D) dense cache view (fp/bf16, already
    v_cache: jax.Array,  # dequantized/gathered by the caller), valid [0, len)
    lengths: jax.Array,  # (B,) tokens already installed in the cache
    cfg: ModelConfig,
    pctx: PartitionCtx,
    *,
    window: Optional[int] = None,
    positions: Optional[jax.Array] = None,  # (B, W), default lengths + arange(W)
    store_roundtrip=None,  # fn: fresh K/V -> the values a cache read-back yields
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Speculative-verify attention: score a W = k+1 token block per slot.

    The fourth execution path of the dynamic region — the decode RM run
    ``k + 1`` positions at a time.  Block position ``i`` of slot ``b`` sits
    at global position ``lengths[b] + i`` and attends the installed cache
    prefix (``j < lengths[b]``) plus block positions ``<= i`` — the k-token
    variant of ``attention_prefill_chunk``'s position-offset causal mask,
    but batched over slots with per-slot traced prefix lengths.  Rows past
    a slot's real token count compute garbage that later rows never see
    (causality runs forward only); the caller drops their logits and
    routes their KV writes out of bounds.

    Numerics REPLICATE the decode RM step for step, which is what lets
    greedy speculative streams match plain decode bit-for-bit: sequential
    decode at position ``lengths + i`` (1) streams the cache — where block
    rows ``< i`` would by then sit in STORAGE precision, having been
    written (bf16 cast, or quantize-on-write) and read back — with the
    storage-dtype dot / f32-accumulate / P-cast-to-V-dtype math of
    ``_decode_attention_streaming``, then (2) folds its OWN fresh
    full-precision K/V via ``_merge_new_token``.  So here the streamed
    part extends the cache view with ``store_roundtrip``-rounded block
    rows under a strict mask (``j < i``), and each row's own token enters
    through the same online-softmax merge, elementwise-identical to the
    decode epilogue.

    Returns (y (B, W, d_model), (k, v)) with the BLOCK's new K/V in
    (B, Hkv, W, D) cache layout; the caller installs rows ``< n_tokens``
    at ``[lengths, lengths + n_tokens)`` (quantize-on-write under
    ``kv_dtype``) and the engine rolls rejected rows back by truncating
    the slot length / releasing overshoot pages.
    """
    b, w, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    cap = k_cache.shape[2]
    if positions is None:
        positions = lengths[:, None] + jnp.arange(w)[None, :]
    q, k, v = _project_qkv(params, x, cfg, positions, training=False,
                           rope=cfg.rope_theta > 0)
    qt = q.transpose(0, 2, 1, 3)  # (B, H, W, D)
    kt = k.transpose(0, 2, 1, 3)  # (B, Hkv, W, D)
    vt = v.transpose(0, 2, 1, 3)

    if cfg.attn_impl == "stub":
        out = qt  # kernel-substituted lowering; see kernels/costs.py
    else:
        g = h // hkv
        sm = 1.0 / math.sqrt(hd)
        # block rows as a LATER cache read would see them: storage-rounded
        kt_st = store_roundtrip(kt) if store_roundtrip is not None else kt
        vt_st = store_roundtrip(vt) if store_roundtrip is not None else vt
        ext_k = jnp.concatenate([k_cache, kt_st.astype(k_cache.dtype)], axis=2)
        ext_v = jnp.concatenate([v_cache, vt_st.astype(v_cache.dtype)], axis=2)
        kk = jnp.repeat(ext_k, g, axis=1) if g > 1 else ext_k
        vv = jnp.repeat(ext_v, g, axis=1) if g > 1 else ext_v
        # --- stage 1: the streaming pass (_decode_attention_streaming) ---
        scores = jnp.einsum("bhqd,bhkd->bhqk", qt.astype(kk.dtype), kk,
                            preferred_element_type=jnp.float32) * sm
        iq = jnp.arange(w)
        qpos = lengths[:, None] + iq[None, :]  # (B, W) global query positions
        kpos_c = jnp.arange(cap)[None, :]  # cache key j holds position j
        mask_c = jnp.broadcast_to((kpos_c < lengths[:, None])[:, None, :], (b, w, cap))
        mask_b = jnp.broadcast_to((iq[:, None] > iq[None, :])[None], (b, w, w))  # strict:
        # a row's own token enters via the merge, exactly as in decode
        if window is not None:
            starts = jnp.maximum(0, qpos + 1 - window)  # (B, W), decode's window start
            mask_c &= kpos_c[:, None, :] >= starts[:, :, None]
            mask_b &= (lengths[:, None, None] + iq[None, None, :]) >= starts[:, :, None]
        mask = jnp.concatenate([mask_c, mask_b], axis=-1)[:, None]  # (B,1,W,cap+W)
        scores = jnp.where(mask, scores, -1e30)
        m = jnp.max(scores, axis=-1, keepdims=True)  # (B, H, W, 1)
        p = jnp.where(mask, jnp.exp(scores - m), 0.0)
        l = jnp.sum(p, axis=-1, keepdims=True)
        out_c = jnp.einsum("bhqk,bhkd->bhqd", p.astype(vv.dtype), vv,
                           preferred_element_type=jnp.float32)
        out_c = out_c / jnp.maximum(l, 1e-30)
        # --- stage 2: fold each row's own fresh K/V (_merge_new_token) ---
        kn = jnp.repeat(kt, g, axis=1) if g > 1 else kt  # (B, H, W, D), full precision
        vn = jnp.repeat(vt, g, axis=1) if g > 1 else vt
        s_new = jnp.sum(qt.astype(jnp.float32) * kn.astype(jnp.float32),
                        axis=-1, keepdims=True) * sm
        m2 = jnp.maximum(m, s_new)
        alpha = jnp.exp(m - m2)
        p_new = jnp.exp(s_new - m2)
        l2 = alpha * l + p_new
        out = (out_c * (alpha * l) + p_new * vn.astype(jnp.float32)) / jnp.maximum(l2, 1e-30)
        out = out.astype(x.dtype)

    out = pctx.shard(out, "batch", "heads", "seq", "head_dim")
    y = out.transpose(0, 2, 1, 3).reshape(b, w, h * hd)
    y = linear_apply(params["wo"], y, quant=cfg.quant, training=False, use_pallas=cfg.use_pallas)
    return y, (kt, vt)


def update_cache(cache: KVCache, k_new: jax.Array, v_new: jax.Array, lengths: jax.Array) -> KVCache:
    """Insert one token's K/V per sequence at its current length."""
    smax = cache.k.shape[2]
    idx = jnp.minimum(lengths, smax - 1)

    def upd(c, new, i):  # c: (Hkv, Smax, D); new: (Hkv, 1, D)
        return jax.lax.dynamic_update_slice(c, new.astype(c.dtype), (0, i, 0))

    k = jax.vmap(upd)(cache.k, k_new, idx)
    v = jax.vmap(upd)(cache.v, v_new, idx)
    return KVCache(k, v)


def scatter_new_tokens(buf: jax.Array, new: jax.Array, lengths: jax.Array) -> jax.Array:
    """Write every layer's new token into the decode cache in ONE update.

    buf: (B, L, Hkv, Smax, D) — the decode cache is BATCH-LEADING; new:
    (L, B, Hkv, 1, D), the per-layer tokens collected as scan ys.

    [§Perf iteration D2] During the decode scan the cache is READ-ONLY (the
    online-softmax merge folds each layer's fresh token into its attention
    output); afterwards, per batch element, all L layers' tokens land at ONE
    sequence position — with batch leading that is a single contiguous
    (L, Hkv, 1, D)-window dynamic_update_slice under a single-level leading-
    axis vmap.  Write traffic O(L*B*Hkv*D); the donated buffer aliases in
    place.

    (Earlier formulations all made XLA materialize/transpose the full cache:
    cache-as-carry + vmap-over-batch-axis-1 DUS — vmap moved the batch axis
    to the front, full transpose copies EVERY layer, 3.5x WORSE than
    baseline; jnp advanced indexing with non-adjacent indices — whole-buffer
    transpose to index-leading order and back; nested vmap over (L, B) —
    transposed f32 full-buffer scatters; reshape-flattening (L, B) — merged
    an unsharded dim into the batch-sharded dim and REPLICATED the cache on
    every device.  Lesson: batch-leading layout + one leading vmap axis is
    the only shape XLA updates in place.)
    """
    b, l, hkv, smax, d = buf.shape
    idx = jnp.minimum(lengths, smax - 1)  # (B,)
    newb = jnp.moveaxis(new[:, :, :, 0, :], 1, 0).astype(buf.dtype)  # (B, L, Hkv, D)

    def upd_one(c, n, i):  # c: (L, Hkv, Smax, D); n: (L, Hkv, D); i scalar
        return jax.lax.dynamic_update_slice(c, n[:, :, None, :], (0, 0, i, 0))

    return jax.vmap(upd_one)(buf, newb, idx)


def scatter_new_tokens_paged(
    pages: jax.Array, new: jax.Array, block_tables: jax.Array, lengths: jax.Array
) -> jax.Array:
    """Paged analogue of ``scatter_new_tokens``: write every layer's new
    token into its sequence's *current page* in one scatter.

    pages: (N, L, Hkv, bs, D) — the layer-complete page pool; new:
    (L, B, Hkv, 1, D) per-layer tokens collected as scan ys; block_tables:
    (B, P) int32; lengths: (B,).

    Sequence ``b``'s token lands at page ``tables[b, len//bs]``, in-page
    offset ``len % bs``.  Inactive slots (length 0) are routed to an
    out-of-bounds page id and dropped by the scatter, so they never corrupt
    live pages (NB: -1 would WRAP to the last pool page — jnp scatter
    normalizes negative indices; only ids >= N are dropped).  Distinct
    active slots always own distinct pages, so the scatter indices never
    collide.  Write traffic is O(L*B*Hkv*D), matching the contiguous path.
    """
    n, l, hkv, bs, d = pages.shape
    bsz = lengths.shape[0]
    page_idx = jnp.minimum(lengths // bs, block_tables.shape[1] - 1)
    page = jnp.take_along_axis(block_tables, page_idx[:, None], axis=1)[:, 0]
    page = jnp.where(lengths > 0, page, n)  # inactive slots: OOB -> dropped
    off = lengths % bs
    newb = jnp.moveaxis(new[:, :, :, 0, :], 1, 0).astype(pages.dtype)  # (B, L, Hkv, D)
    return pages.at[page, :, :, off, :].set(newb, mode="drop")


def write_prefill_pages(
    pages: jax.Array, kv: jax.Array, page_ids: jax.Array, *, block_size: int
) -> jax.Array:
    """Scatter a prefilled request's KV into its allocated pages.

    pages: (N, L, Hkv, bs, D); kv: prefill layout (L, 1, Hkv, S, D) with S a
    multiple of ``block_size`` (the compile bucket; the tail past the real
    prompt length is garbage masked by the per-slot length); page_ids:
    (S/bs,) int32 destinations, out-of-bounds entries dropped — prefix-cache
    hits keep their (identical, possibly shared) cached contents instead of
    being rewritten.  (Skip ids must be >= N, never -1: jnp scatter wraps
    negative indices to the end of the pool.)
    """
    l, b, hkv, s, d = kv.shape
    bs = block_size
    kb = kv[:, 0].reshape(l, hkv, s // bs, bs, d)
    kb = jnp.moveaxis(kb, 2, 0)  # (P, L, Hkv, bs, D)
    return pages.at[page_ids].set(kb.astype(pages.dtype), mode="drop")


def scatter_new_scales(buf: jax.Array, new: jax.Array, lengths: jax.Array) -> jax.Array:
    """Scale-plane analogue of ``scatter_new_tokens``.

    buf: (B, L, Hkv, Smax) fp32 per-token scale plane of the quantized
    contiguous cache; new: (L, B, Hkv, 1) fresh-token scales.  Same batch-
    leading single-DUS shape as the payload write.
    """
    b, l, hkv, smax = buf.shape
    idx = jnp.minimum(lengths, smax - 1)
    newb = jnp.moveaxis(new[:, :, :, 0], 1, 0).astype(buf.dtype)  # (B, L, Hkv)

    def upd_one(c, n, i):  # c: (L, Hkv, Smax); n: (L, Hkv); i scalar
        return jax.lax.dynamic_update_slice(c, n[:, :, None], (0, 0, i))

    return jax.vmap(upd_one)(buf, newb, idx)


def scatter_new_tokens_q(buf, new: jax.Array, lengths: jax.Array):
    """``scatter_new_tokens`` generalized to a possibly-quantized cache leaf:
    quantize-on-write of the fresh token rows (payload + scale plane), so
    the fp cache is never materialized.  ``new`` is always fp (L, B, Hkv, 1,
    D); requantizing the same values reproduces the same bytes, which keeps
    preemption replay bit-identical under quantization."""
    if not isinstance(buf, QuantKV):
        return scatter_new_tokens(buf, new, lengths)
    payload, scale = quantize_kv(new, infer_kv_dtype(buf.q))
    return QuantKV(
        scatter_new_tokens(buf.q, payload, lengths),
        scatter_new_scales(buf.scale, scale, lengths),
    )


def scatter_new_scales_paged(
    pages: jax.Array, new: jax.Array, block_tables: jax.Array, lengths: jax.Array
) -> jax.Array:
    """Scale-plane analogue of ``scatter_new_tokens_paged``.

    pages: (N, L, Hkv, bs) fp32 scale planes; new: (L, B, Hkv, 1).  Inactive
    slots route to an out-of-bounds page id and are dropped, exactly like
    the payload scatter.
    """
    n, l, hkv, bs = pages.shape
    page_idx = jnp.minimum(lengths // bs, block_tables.shape[1] - 1)
    page = jnp.take_along_axis(block_tables, page_idx[:, None], axis=1)[:, 0]
    page = jnp.where(lengths > 0, page, n)
    off = lengths % bs
    newb = jnp.moveaxis(new[:, :, :, 0], 1, 0).astype(pages.dtype)  # (B, L, Hkv)
    return pages.at[page, :, :, off].set(newb, mode="drop")


def scatter_new_tokens_paged_q(pages, new: jax.Array, block_tables: jax.Array, lengths: jax.Array):
    """``scatter_new_tokens_paged`` generalized to a possibly-quantized page
    pool leaf — quantize-on-write into the current page (see
    ``scatter_new_tokens_q`` for the determinism contract)."""
    if not isinstance(pages, QuantKV):
        return scatter_new_tokens_paged(pages, new, block_tables, lengths)
    payload, scale = quantize_kv(new, infer_kv_dtype(pages.q))
    return QuantKV(
        scatter_new_tokens_paged(pages.q, payload, block_tables, lengths),
        scatter_new_scales_paged(pages.scale, scale, block_tables, lengths),
    )


def write_prefill_scales(
    pages: jax.Array, scales: jax.Array, page_ids: jax.Array, *, block_size: int
) -> jax.Array:
    """Scale-plane analogue of ``write_prefill_pages``: pages (N, L, Hkv,
    bs), scales (L, 1, Hkv, S) with S a multiple of ``block_size``; same
    out-of-bounds skip semantics for prefix-cache hits."""
    l, b, hkv, s = scales.shape
    bs = block_size
    sb = scales[:, 0].reshape(l, hkv, s // bs, bs)
    sb = jnp.moveaxis(sb, 2, 0)  # (P, L, Hkv, bs)
    return pages.at[page_ids].set(sb.astype(pages.dtype), mode="drop")


def write_prefill_pages_q(pages, kv: jax.Array, page_ids: jax.Array, *, block_size: int):
    """``write_prefill_pages`` generalized to a possibly-quantized pool leaf:
    the paged swap becomes quantize-on-write (per-token-per-head scales),
    so prefilled KV lands in the pool already packed."""
    if not isinstance(pages, QuantKV):
        return write_prefill_pages(pages, kv, page_ids, block_size=block_size)
    payload, scale = quantize_kv(kv, infer_kv_dtype(pages.q))
    return QuantKV(
        write_prefill_pages(pages.q, payload, page_ids, block_size=block_size),
        write_prefill_scales(pages.scale, scale, page_ids, block_size=block_size),
    )


def write_chunk_kv(buf: jax.Array, new: jax.Array, slot, start) -> jax.Array:
    """Install one prefill chunk's KV into the contiguous decode cache.

    buf: (B_slots, L, Hkv, Smax, D) batch-leading decode cache; new:
    (L, 1, Hkv, C, D) — the chunk's per-layer K or V collected as scan ys;
    ``slot``/``start`` are traced scalars.  All L layers' C tokens land in
    one contiguous window, so the write is a single dynamic_update_slice
    (the donated buffer aliases in place — same shape discipline as
    ``scatter_new_tokens``).  ``start + C <= Smax`` is the caller's
    contract (the chunk tail bucket is clamped to the cache bound;
    dynamic_update_slice would silently shift a write that overflows).
    """
    newb = jnp.moveaxis(new, 1, 0).astype(buf.dtype)  # (1, L, Hkv, C, D)
    return jax.lax.dynamic_update_slice(buf, newb, (slot, 0, 0, start, 0))


def write_chunk_scales(buf: jax.Array, new: jax.Array, slot, start) -> jax.Array:
    """Scale-plane analogue of ``write_chunk_kv``: buf (B, L, Hkv, Smax)
    fp32, new (L, 1, Hkv, C)."""
    newb = jnp.moveaxis(new, 1, 0).astype(buf.dtype)  # (1, L, Hkv, C)
    return jax.lax.dynamic_update_slice(buf, newb, (slot, 0, 0, start))


def write_chunk_kv_q(buf, new: jax.Array, slot, start):
    """``write_chunk_kv`` generalized to a possibly-quantized cache leaf:
    quantize-on-write of the chunk rows (payload + scale plane).  Per-token
    scales mean chunk-at-a-time quantization writes exactly the bytes
    whole-prompt quantization would — the chunked/monolithic cache-state
    equivalence and preemption-replay bit-identity rest on that."""
    if not isinstance(buf, QuantKV):
        return write_chunk_kv(buf, new, slot, start)
    payload, scale = quantize_kv(new, infer_kv_dtype(buf.q))
    return QuantKV(
        write_chunk_kv(buf.q, payload, slot, start),
        write_chunk_scales(buf.scale, scale, slot, start),
    )


def scatter_verify_tokens(
    buf: jax.Array, new: jax.Array, lengths: jax.Array, n_tokens: jax.Array
) -> jax.Array:
    """Write a speculative verify block's KV into the contiguous cache.

    buf: (B, L, Hkv, Smax, D) batch-leading decode cache; new:
    (L, B, Hkv, W, D) per-layer block K or V collected as scan ys; row
    ``i`` of slot ``b`` lands at position ``lengths[b] + i`` iff
    ``i < n_tokens[b]`` — rows past a slot's real token count (draft
    padding, parked mid-prefill slots, free slots) route out of bounds and
    are dropped by the scatter, so they can never corrupt live KV or the
    chunked-prefill parked-write row ``Smax - 1`` (the engine additionally
    clamps draft depth so LIVE rows stay ``<= Smax - 2``).  Distinct live
    (slot, position) pairs never collide.
    """
    b, l, hkv, smax, d = buf.shape
    w = new.shape[3]
    iq = jnp.arange(w)[None, :]
    pos = lengths[:, None] + iq  # (B, W)
    pos = jnp.where(iq < n_tokens[:, None], pos, smax)  # OOB -> dropped
    bidx = jnp.broadcast_to(jnp.arange(b)[:, None], (b, w))
    newb = jnp.moveaxis(jnp.moveaxis(new, 1, 0), 3, 1).astype(buf.dtype)  # (B, W, L, Hkv, D)
    return buf.at[bidx, :, :, pos, :].set(newb, mode="drop")


def scatter_verify_scales(
    buf: jax.Array, new: jax.Array, lengths: jax.Array, n_tokens: jax.Array
) -> jax.Array:
    """Scale-plane analogue of ``scatter_verify_tokens``: buf (B, L, Hkv,
    Smax) fp32, new (L, B, Hkv, W); same out-of-bounds drop routing."""
    b, l, hkv, smax = buf.shape
    w = new.shape[3]
    iq = jnp.arange(w)[None, :]
    pos = lengths[:, None] + iq
    pos = jnp.where(iq < n_tokens[:, None], pos, smax)
    bidx = jnp.broadcast_to(jnp.arange(b)[:, None], (b, w))
    newb = jnp.moveaxis(jnp.moveaxis(new, 1, 0), 3, 1).astype(buf.dtype)  # (B, W, L, Hkv)
    return buf.at[bidx, :, :, pos].set(newb, mode="drop")


def scatter_verify_tokens_q(buf, new: jax.Array, lengths: jax.Array, n_tokens: jax.Array):
    """``scatter_verify_tokens`` generalized to a possibly-quantized cache
    leaf: quantize-on-write of the block rows (payload + per-(layer, head,
    token) scale), the same granularity every other write path uses — so a
    verify-round append lands exactly the bytes sequential decode appends
    would, which is what keeps speculative streams and preemption replay
    bit-identical under quantization."""
    if not isinstance(buf, QuantKV):
        return scatter_verify_tokens(buf, new, lengths, n_tokens)
    payload, scale = quantize_kv(new, infer_kv_dtype(buf.q))
    return QuantKV(
        scatter_verify_tokens(buf.q, payload, lengths, n_tokens),
        scatter_verify_scales(buf.scale, scale, lengths, n_tokens),
    )


def scatter_verify_tokens_paged(
    pages: jax.Array, new: jax.Array, block_tables: jax.Array,
    lengths: jax.Array, n_tokens: jax.Array
) -> jax.Array:
    """Paged analogue of ``scatter_verify_tokens``: row ``i`` of slot ``b``
    lands in page ``tables[b, (lengths[b]+i) // bs]`` at in-page offset
    ``(lengths[b]+i) % bs``.  Rows with ``i >= n_tokens[b]`` (and inactive
    slots, ``lengths == 0``) route to the out-of-bounds page id and are
    dropped — the engine only grows the table to cover a slot's REAL rows,
    so padding rows must never consult it.  Live slots own distinct pages,
    so the (page, offset) scatter indices never collide.
    """
    n, l, hkv, bs, d = pages.shape
    w = new.shape[3]
    iq = jnp.arange(w)[None, :]
    pos = lengths[:, None] + iq  # (B, W) global positions
    page_idx = jnp.minimum(pos // bs, block_tables.shape[1] - 1)
    page = jnp.take_along_axis(block_tables, page_idx, axis=1)  # (B, W)
    valid = (iq < n_tokens[:, None]) & (lengths[:, None] > 0)
    page = jnp.where(valid, page, n)  # OOB -> dropped
    off = pos % bs
    newb = jnp.moveaxis(jnp.moveaxis(new, 1, 0), 3, 1).astype(pages.dtype)  # (B, W, L, Hkv, D)
    return pages.at[page, :, :, off, :].set(newb, mode="drop")


def scatter_verify_scales_paged(
    pages: jax.Array, new: jax.Array, block_tables: jax.Array,
    lengths: jax.Array, n_tokens: jax.Array
) -> jax.Array:
    """Scale-plane analogue of ``scatter_verify_tokens_paged``: pages
    (N, L, Hkv, bs) fp32, new (L, B, Hkv, W)."""
    n, l, hkv, bs = pages.shape
    w = new.shape[3]
    iq = jnp.arange(w)[None, :]
    pos = lengths[:, None] + iq
    page_idx = jnp.minimum(pos // bs, block_tables.shape[1] - 1)
    page = jnp.take_along_axis(block_tables, page_idx, axis=1)
    valid = (iq < n_tokens[:, None]) & (lengths[:, None] > 0)
    page = jnp.where(valid, page, n)
    off = pos % bs
    newb = jnp.moveaxis(jnp.moveaxis(new, 1, 0), 3, 1).astype(pages.dtype)  # (B, W, L, Hkv)
    return pages.at[page, :, :, off].set(newb, mode="drop")


def scatter_verify_tokens_paged_q(
    pages, new: jax.Array, block_tables: jax.Array,
    lengths: jax.Array, n_tokens: jax.Array
):
    """``scatter_verify_tokens_paged`` generalized to a possibly-quantized
    page pool leaf — quantize-on-write of the verify block (see
    ``scatter_verify_tokens_q`` for the determinism contract)."""
    if not isinstance(pages, QuantKV):
        return scatter_verify_tokens_paged(pages, new, block_tables, lengths, n_tokens)
    payload, scale = quantize_kv(new, infer_kv_dtype(pages.q))
    return QuantKV(
        scatter_verify_tokens_paged(pages.q, payload, block_tables, lengths, n_tokens),
        scatter_verify_scales_paged(pages.scale, scale, block_tables, lengths, n_tokens),
    )


def _merge_new_token(
    out_cache: jax.Array,  # (B, H, D) — attention over cache, f32-normalized
    l_cache: jax.Array,  # (B, H, 1) — softmax denominator over cache
    m_cache: jax.Array,  # (B, H, 1) — running max over cache
    q: jax.Array,  # (B, H, D)
    k_new: jax.Array,  # (B, Hkv, 1, D)
    v_new: jax.Array,
    sm_scale: float,
) -> jax.Array:
    """Fold the freshly-projected token's K/V into cache attention output.

    [§Perf iteration D2] The classic online-softmax merge: the new token is
    one extra 'block', so the decode step never materializes an updated
    cache slice (update-then-attend would write+read O(cache) bytes; the
    merge is O(tokens)).
    """
    b, h, d = q.shape
    g = h // k_new.shape[1]
    kn = jnp.repeat(k_new[:, :, 0, :], g, axis=1) if g > 1 else k_new[:, :, 0, :]
    vn = jnp.repeat(v_new[:, :, 0, :], g, axis=1) if g > 1 else v_new[:, :, 0, :]
    s_new = jnp.sum(q.astype(jnp.float32) * kn.astype(jnp.float32), axis=-1, keepdims=True) * sm_scale
    m = jnp.maximum(m_cache, s_new)
    alpha = jnp.exp(m_cache - m)
    p_new = jnp.exp(s_new - m)
    l = alpha * l_cache + p_new
    out = (out_cache * (alpha * l_cache) + p_new * vn.astype(jnp.float32)) / jnp.maximum(l, 1e-30)
    return out


def attention_decode(
    params: dict,
    x: jax.Array,  # (B, 1, d)
    cache: KVCache,
    lengths: jax.Array,  # (B,) tokens already in cache
    cfg: ModelConfig,
    pctx: PartitionCtx,
    *,
    window: Optional[int] = None,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    cross_len: Optional[int] = None,
) -> Tuple[jax.Array, KVCache]:
    """The decode RM: one token against the streamed KV cache.

    Returns (y, (k_new, v_new)) — the NEW token's K/V only, shape
    (B, Hkv, 1, D); the caller scatters it into its carried cache buffer
    (``scatter_token``).  The attention output already includes the new
    token via the online-softmax merge, so the updated cache slice is never
    materialized.  Cross-attention (read-only KV) returns ``cache``
    unchanged.
    """
    b = x.shape[0]
    h, hd = cfg.num_heads, cfg.head_dim

    if cross_kv is not None:
        q, k, v = _project_qkv(params, x, cfg, lengths[:, None], training=False, rope=False)
        qd = q.reshape(b, h, hd)
        kt, vt = cross_kv
        if cfg.attn_impl == "stub":
            out = qd
        else:
            eff_len = jnp.full((b,), cross_len if cross_len is not None else kt.shape[2], jnp.int32)
            out = decode_attention(qd, kt, vt, eff_len, use_kernel=cfg.use_pallas, interpret=True)
        y = out.reshape(b, 1, h * hd)
        y = linear_apply(params["wo"], y, quant=cfg.quant, training=False, use_pallas=cfg.use_pallas)
        return y, cache

    def attend(qd, starts):
        k_arr, v_arr, qkw = _kv_leaf_args(cache.k, cache.v)
        return decode_attention(
            qd, k_arr, v_arr, lengths.astype(jnp.int32), starts,
            use_kernel=cfg.use_pallas, interpret=True, return_stats=True, **qkw,
        )

    return _decode_new_token(params, x, lengths, cfg, window, attend)


def _decode_new_token(params, x, lengths, cfg, window, attend_cache):
    """Shared decode-RM body for both cache layouts: project the one new
    token's Q/K/V, attend over the EXISTING cache ([start, len) valid) via
    ``attend_cache(qd, starts) -> (out, l, m)``, merge the fresh token
    analytically, and output-project.  Window start accounts for the
    appended token: valid range becomes [max(0, len+1-window), len+1).
    Returns (y, new-token K/V (B, Hkv, 1, D))."""
    b = x.shape[0]
    h, hd = cfg.num_heads, cfg.head_dim
    q, k, v = _project_qkv(params, x, cfg, lengths[:, None], training=False,
                           rope=cfg.rope_theta > 0)
    qd = q.reshape(b, h, hd)
    k_new = k.transpose(0, 2, 1, 3)  # (B, Hkv, 1, D)
    v_new = v.transpose(0, 2, 1, 3)
    if cfg.attn_impl == "stub":
        out = qd  # kernel-substituted lowering; see kernels/costs.py
    else:
        starts = None if window is None else jnp.maximum(0, lengths + 1 - window).astype(jnp.int32)
        sm_scale = 1.0 / math.sqrt(hd)
        out_c, l_c, m_c = attend_cache(qd, starts)
        out = _merge_new_token(out_c, l_c, m_c, qd, k_new, v_new, sm_scale).astype(x.dtype)

    y = out.reshape(b, 1, h * hd)
    y = linear_apply(params["wo"], y, quant=cfg.quant, training=False, use_pallas=cfg.use_pallas)
    return y, KVCache(k_new, v_new)


def attention_decode_paged(
    params: dict,
    x: jax.Array,  # (B, 1, d)
    k_pages: jax.Array,  # (N, Hkv, bs, D) — this layer's slice of the pool
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, P) int32
    lengths: jax.Array,  # (B,) tokens already in cache
    cfg: ModelConfig,
    pctx: PartitionCtx,
    *,
    window: Optional[int] = None,
) -> Tuple[jax.Array, KVCache]:
    """The decode RM over the paged cache: one token against the block-table
    -walked KV.  Same contract as ``attention_decode``'s cache branch (both
    share ``_decode_new_token``, so the two layouts cannot drift) — the
    caller scatters the returned new-token K/V into the pool
    (``scatter_new_tokens_paged``); the attention output already folds it in
    via the online-softmax merge.
    """

    def attend(qd, starts):
        k_arr, v_arr, qkw = _kv_leaf_args(k_pages, v_pages)
        return paged_decode_attention(
            qd, k_arr, v_arr, block_tables, lengths.astype(jnp.int32), starts,
            use_kernel=cfg.use_pallas, interpret=True, return_stats=True, **qkw,
        )

    return _decode_new_token(params, x, lengths, cfg, window, attend)
