from repro.layers.sharding import PartitionCtx, MeshAxes, NULL_CTX, TRAIN_RULES, PREFILL_RULES, DECODE_RULES, LONG_DECODE_RULES
from repro.layers.norm import norm_init, apply_norm
from repro.layers.rotary import apply_rope
from repro.layers.linear import linear_init, linear_apply, convert_linear_for_inference
from repro.layers.attention import attention_init, attention_prefill, attention_decode, KVCache, update_cache
from repro.layers.mlp import mlp_init, mlp_apply
from repro.layers.moe import moe_init, moe_apply
