"""KV-cache quantization: symmetric int8/int4 storage with fp32 scale planes.

The paper's decode bound (Eq. 5) is KV bytes streamed per token; the most
direct software lever on it is shrinking bytes per cached token.  This module
is the numeric core of the ``kv_dtype`` subsystem (``"fp"`` | ``"int8"`` |
``"int4"``):

* **Granularity** — one symmetric absmax scale per (layer, kv-head, token)
  row, stored as an fp32 *scale plane* alongside each block's packed payload
  (``payload.shape[:-1]``).  Scales at token granularity (rather than one
  scale per whole block) are what keep decode appends and preemption replay
  exact: writing token ``t`` into a page never rescales tokens ``< t``, so
  requantizing the same values always reproduces the same page bytes.
* **int4** — values in [-7, 7] nibble-packed in pairs along the head_dim
  axis (lo nibble = even index), so one token's row is ``D/2`` bytes and a
  single-token append touches only its own packed bytes.
* **Determinism** — ``quantize_kv`` is a pure function and a fixed point of
  ``quantize ∘ dequantize`` on the payload (the scale of a dequantized row
  round-trips to within 1 ulp and the integer payload exactly), which is the
  property the serving engine's bit-identical preemption replay rests on.

``QuantKV`` is a pytree (payload + scale), so quantized caches flow through
``jax.tree.map``-based plumbing (relayout, slot insert, copy-on-write,
donation) unchanged.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

KV_DTYPES = ("fp", "int8", "int4")

# symmetric range per dtype: int4 uses [-7, 7] (not -8) so negation is exact
QMAX = {"int8": 127, "int4": 7}

# storage bits per payload element (fp = the bf16 cache default)
KV_DTYPE_BITS = {"fp": 16, "int8": 8, "int4": 4}
SCALE_BITS = 32  # fp32 scale per (layer, head, token) row


class QuantKV(NamedTuple):
    """One quantized K or V tensor: packed payload + its fp32 scale plane.

    ``q``:     int8 (int8 mode) or uint8 nibble-packed (int4 mode); the
               trailing axis is head_dim (int8) or head_dim // 2 (int4).
    ``scale``: fp32 with shape ``q.shape[:-1]`` — one symmetric absmax scale
               per (…, token) row.
    """

    q: jax.Array
    scale: jax.Array


def assert_kv_dtype(kv_dtype: str) -> str:
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, got {kv_dtype!r}")
    return kv_dtype


def is_quantized(leaf) -> bool:
    return isinstance(leaf, QuantKV)


def infer_kv_dtype(payload: jax.Array) -> str:
    """Payload dtype encodes the mode: int8 -> "int8", uint8 -> "int4"."""
    if payload.dtype == jnp.int8:
        return "int8"
    if payload.dtype == jnp.uint8:
        return "int4"
    return "fp"


# ------------------------------------------------------------- int4 packing --


def pack_int4(q: jax.Array) -> jax.Array:
    """(… , D) int8 values in [-8, 7] -> (…, D//2) uint8 nibble pairs.

    Even indices land in the low nibble, odd in the high nibble, so one
    packed byte holds two adjacent head_dim elements of the SAME token —
    tokens never share bytes and single-token appends stay independent.
    """
    assert q.shape[-1] % 2 == 0, f"head_dim must be even to nibble-pack, got {q.shape}"
    lo = q[..., 0::2] & 0x0F
    hi = q[..., 1::2] & 0x0F
    return ((hi << 4) | lo).astype(jnp.uint8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """(…, D//2) uint8 -> (…, D) int8, sign-extending each nibble."""
    pi = packed.astype(jnp.int8)
    lo = jnp.right_shift(jnp.left_shift(pi, 4), 4)  # arithmetic shift sign-extends
    hi = jnp.right_shift(pi, 4)
    both = jnp.stack([lo, hi], axis=-1)  # (..., D//2, 2)
    return both.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


# --------------------------------------------------------- quant / dequant --


def quantize_kv(x: jax.Array, kv_dtype: str) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Symmetric per-row absmax quantization of a (…, D) K/V tensor.

    Returns ``(payload, scale)`` with ``scale.shape == x.shape[:-1]`` (fp32)
    and ``x ≈ unpack(payload) * scale[..., None]``.  ``kv_dtype="fp"``
    returns ``(x, None)`` so callers can treat fp as the degenerate case.
    All-zero rows get scale 1.0 (payload 0), avoiding 0/0.
    """
    assert_kv_dtype(kv_dtype)
    if kv_dtype == "fp":
        return x, None
    qmax = QMAX[kv_dtype]
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -qmax, qmax).astype(jnp.int8)
    if kv_dtype == "int4":
        q = pack_int4(q)
    return q, scale


def dequantize_kv(payload: jax.Array, scale: jax.Array, kv_dtype: Optional[str] = None) -> jax.Array:
    """Inverse of :func:`quantize_kv` -> fp32 (…, D)."""
    if kv_dtype is None:
        kv_dtype = infer_kv_dtype(payload)
    if kv_dtype == "fp":
        return payload
    q = unpack_int4(payload) if kv_dtype == "int4" else payload
    return q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)


def quantize_kv_tree(kv, kv_dtype: str):
    """Map a KVCache-shaped pytree of fp arrays to QuantKV leaves (identity
    for "fp").  Used by the relayout / static-relay swap programs."""
    assert_kv_dtype(kv_dtype)
    if kv_dtype == "fp":
        return kv

    def q(x):
        payload, scale = quantize_kv(x, kv_dtype)
        return QuantKV(payload, scale)

    return jax.tree.map(q, kv, is_leaf=lambda l: isinstance(l, jax.Array))


# ----------------------------------------------------------- byte accounting --


def payload_nbytes(leaf) -> int:
    """Bytes of actual KV payload in one cache leaf (scales excluded)."""
    return int(leaf.q.nbytes) if is_quantized(leaf) else int(leaf.nbytes)


def total_nbytes(tree) -> int:
    return sum(int(a.nbytes) for a in jax.tree.leaves(tree))


def payload_bytes(tree) -> int:
    """Payload bytes across a KVCache pytree whose k/v leaves may be QuantKV."""
    total = 0
    for leaf in jax.tree.leaves(tree, is_leaf=is_quantized):
        total += payload_nbytes(leaf)
    return total
