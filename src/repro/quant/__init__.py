from repro.quant.ternary import (
    ternary_quantize,
    ternary_quantize_ste,
    pack_ternary,
    unpack_ternary,
    TernaryWeight,
)
from repro.quant.act_quant import quantize_activations_int8
from repro.quant.kv_quant import (
    KV_DTYPES,
    QuantKV,
    assert_kv_dtype,
    dequantize_kv,
    infer_kv_dtype,
    pack_int4,
    quantize_kv,
    quantize_kv_tree,
    unpack_int4,
)
