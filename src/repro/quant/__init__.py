from repro.quant.ternary import (
    ternary_quantize,
    ternary_quantize_ste,
    pack_ternary,
    unpack_ternary,
    TernaryWeight,
)
from repro.quant.act_quant import quantize_activations_int8
