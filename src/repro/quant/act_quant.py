"""Per-token int8 activation quantization (the A8 side of W1.58-A8)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_activations_int8(x: jax.Array, eps: float = 1e-5) -> Tuple[jax.Array, jax.Array]:
    """Per-token absmax int8 quantization.

    x: (..., K) float -> (x_q int8 (..., K), scale f32 (..., 1)) with
    x ~= x_q * scale.  BitNet uses symmetric absmax per token.
    """
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = absmax / 127.0 + eps
    x_q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return x_q, scale
