"""BitNet b1.58 ternary weight quantization + 2-bit packing.

The paper's linear workload is W1.58-A8: weights in {-1, 0, +1} with one
per-tensor scale (absmean), activations per-token int8.  On the FPGA the
ternary weights live in URAM as base-3 group indices feeding a lookup table;
on TPU we keep the *memory* property (2 bits/weight resident in HBM, decoded
on the fly in VMEM inside the Pallas TLMM kernel) and use the MXU for the
arithmetic (DESIGN.md §2).

Packing format (shared by kernel, ops and ref):
  4 ternary values -> 1 uint8 along the *input* (K) dimension.
  2-bit codes: 0b00 -> 0, 0b01 -> +1, 0b10 -> -1  (0b11 unused).
  value k = 4*j + i  lives in bits [2i, 2i+2) of packed[j].
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TernaryWeight:
    """A packed ternary weight: the on-device format of a TLMM linear."""

    packed: jax.Array  # uint8, (K // 4, N)
    scale: jax.Array  # f32 scalar — BitNet absmean beta

    @property
    def k(self) -> int:
        return self.packed.shape[0] * 4

    @property
    def n(self) -> int:
        return self.packed.shape[1]


def ternary_quantize(w: jax.Array, eps: float = 1e-5) -> Tuple[jax.Array, jax.Array]:
    """BitNet b1.58 absmean quantizer.

    W_q = RoundClip(W / (mean|W| + eps), -1, 1),  beta = mean|W|.
    Returns (w_q int8 in {-1,0,1}, beta f32 scalar).
    """
    beta = jnp.mean(jnp.abs(w.astype(jnp.float32)))
    w_q = jnp.clip(jnp.round(w.astype(jnp.float32) / (beta + eps)), -1, 1)
    return w_q.astype(jnp.int8), beta


def ternary_quantize_ste(w: jax.Array, eps: float = 1e-5) -> Tuple[jax.Array, jax.Array]:
    """Straight-through-estimator version for QAT training.

    Forward: dequantized ternary weights (w_q * beta).  Backward: identity
    w.r.t. the latent fp weights (BitNet training recipe).
    """
    w_q, beta = ternary_quantize(w, eps)
    deq = w_q.astype(w.dtype) * beta.astype(w.dtype)
    return w + jax.lax.stop_gradient(deq - w), beta


def pack_ternary(w_q: jax.Array) -> jax.Array:
    """Pack int8 ternary (K, N) -> uint8 (K//4, N); K must be a multiple of 4."""
    k, n = w_q.shape
    assert k % 4 == 0, f"K={k} not a multiple of 4"
    # {-1,0,1} -> codes {2,0,1}
    codes = jnp.where(w_q < 0, jnp.uint8(2), w_q.astype(jnp.uint8))
    codes = codes.reshape(k // 4, 4, n)
    packed = (
        codes[:, 0, :]
        | (codes[:, 1, :] << 2)
        | (codes[:, 2, :] << 4)
        | (codes[:, 3, :] << 6)
    )
    return packed.astype(jnp.uint8)


def unpack_ternary(packed: jax.Array) -> jax.Array:
    """uint8 (K//4, N) -> int8 ternary (K, N).  Used by ref.py and the kernel."""
    kq, n = packed.shape
    parts = []
    for i in range(4):
        bits = (packed >> (2 * i)) & 0x3
        val = jnp.where(bits == 1, jnp.int8(1), jnp.where(bits == 2, jnp.int8(-1), jnp.int8(0)))
        parts.append(val)
    # (K//4, 4, N) -> (K, N)
    return jnp.stack(parts, axis=1).reshape(kq * 4, n)


def quantize_and_pack(w: jax.Array) -> TernaryWeight:
    w_q, beta = ternary_quantize(w)
    return TernaryWeight(packed=pack_ternary(w_q), scale=beta)


def packed_bytes(k: int, n: int) -> int:
    return (k // 4) * n
