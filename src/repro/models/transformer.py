"""Decoder-only transformer (dense + MoE): granite, moonshot, chameleon,
deepseek, qwen, minicpm, smollm, bitnet.

Layer-stacked parameters (leading dim = num_layers) consumed by
``jax.lax.scan`` so the HLO stays one-layer-sized — essential for compiling
the 512-device dry-run of 48-layer models on a single CPU host.

Three entry points = the PD-Swap phase programs:
  * ``forward_train``  — full causal pass -> per-token loss (train_4k cells)
  * ``forward_prefill``— full causal pass -> logits + per-layer KV (prefill RM)
  * ``decode_step``    — one token against the cache (decode RM)
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.attention import (
    KVCache,
    attention_decode,
    attention_init,
    attention_prefill,
)
from repro.layers.mlp import mlp_apply, mlp_init
from repro.layers.moe import moe_apply, moe_init
from repro.layers.norm import apply_norm, norm_init
from repro.layers.sharding import NULL_CTX, PartitionCtx


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "full": save nothing


def init(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> dict:
    vp = cfg.padded_vocab()
    k_emb, k_layers, k_head = jax.random.split(key, 3)

    def layer_init(k):
        ka, kf = jax.random.split(k)
        p = {
            "attn": attention_init(cfg, ka, dtype),
            "ln1": norm_init(cfg.norm, cfg.d_model),
            "ln2": norm_init(cfg.norm, cfg.d_model),
        }
        if cfg.moe:
            p["moe"] = moe_init(cfg, kf, dtype)
        else:
            p["mlp"] = mlp_init(cfg, kf, dtype)
        return p

    params = {
        "emb": (jax.random.normal(k_emb, (vp, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
        "layers": jax.vmap(layer_init)(jax.random.split(k_layers, cfg.num_layers)),
        "ln_f": norm_init(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_head, (cfg.d_model, vp), jnp.float32) * 0.02
        ).astype(dtype)
    return params


def _logits(params, x, cfg: ModelConfig, pctx: PartitionCtx) -> jax.Array:
    x = apply_norm(params["ln_f"], x, cfg.norm, cfg.norm_eps)
    head = params["emb"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x.astype(jnp.float32) @ head.astype(jnp.float32)
    return pctx.shard(logits, "batch", "seq", "vocab")


def _embed(params, tokens, cfg, pctx):
    x = params["emb"][tokens]
    return pctx.shard(x, "batch", "seq", "embed")


def _block_prefill(x, lp, positions, cfg, pctx, *, training, collect_kv):
    h = apply_norm(lp["ln1"], x, cfg.norm, cfg.norm_eps)
    attn_out, kv = attention_prefill(
        lp["attn"], h, positions, cfg, pctx,
        window=cfg.sliding_window, training=training,
    )
    x = x + attn_out
    h = apply_norm(lp["ln2"], x, cfg.norm, cfg.norm_eps)
    if cfg.moe:
        ffn_out, aux = moe_apply(lp["moe"], h, cfg, pctx, training=training)
    else:
        ffn_out, aux = mlp_apply(lp["mlp"], h, cfg, pctx, training=training), jnp.float32(0)
    x = pctx.shard(x + ffn_out, "batch", "seq", "embed")
    return x, aux, (kv if collect_kv else None)


def forward_hidden(
    params: dict,
    tokens: jax.Array,  # (B, S)
    cfg: ModelConfig,
    pctx: PartitionCtx = NULL_CTX,
    *,
    training: bool = True,
):
    """Returns (final normed hidden (B,S,d), aux loss)."""
    b, s = tokens.shape
    x = _embed(params, tokens, cfg, pctx)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(carry, lp):
        x, aux = carry
        x, aux_l, _ = _block_prefill(x, lp, positions, cfg, pctx, training=training, collect_kv=False)
        return (x, aux + aux_l), None

    body = _remat(body, cfg)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0)), params["layers"])
    return apply_norm(params["ln_f"], x, cfg.norm, cfg.norm_eps), aux


def _head(params, cfg: ModelConfig):
    return params["emb"].T if cfg.tie_embeddings else params["lm_head"]


def forward_train(params, tokens, cfg: ModelConfig, pctx: PartitionCtx = NULL_CTX):
    """Full logits (B, S, Vp) — small-model/test path; training uses the
    chunked loss below to avoid materializing this tensor."""
    x, aux = forward_hidden(params, tokens, cfg, pctx, training=True)
    logits = x.astype(jnp.float32) @ _head(params, cfg).astype(jnp.float32)
    return pctx.shard(logits, "batch", "seq", "vocab"), aux


def loss_fn(params, batch: dict, cfg: ModelConfig, pctx: PartitionCtx = NULL_CTX,
            aux_weight: float = 0.01):
    """batch: tokens (B,S), targets (B,S), mask (B,S)."""
    from repro.train.losses import chunked_ce_loss

    x, aux = forward_hidden(params, batch["tokens"], cfg, pctx, training=True)
    loss = chunked_ce_loss(x, _head(params, cfg), batch["targets"], batch["mask"], pctx)
    return loss + aux_weight * aux / max(cfg.num_layers, 1), {"nll": loss, "aux": aux}


def forward_prefill(
    params: dict,
    tokens: jax.Array,  # (B, S)
    cfg: ModelConfig,
    pctx: PartitionCtx = NULL_CTX,
    *,
    split_tail: bool = False,
    last_pos: Optional[jax.Array] = None,
):
    """The prefill RM.  Returns (logits_last (B, Vp), kv_caches (L-pytree)).

    ``split_tail=True`` returns after the *last layer's attention* with a
    continuation closure — the hook the latency-overlapped swap (paper §3.4,
    Fig. 5) uses: KV is complete at that point, so the controller can launch
    the decode-engine relayout while the tail (last FFN + norm + logits)
    still runs.  See repro.core.swap.

    ``last_pos`` (traced scalar, default S-1) selects which position's
    logits are returned — variable-length prompts right-pad to a compile
    bucket and read the logits of their true last token; causality keeps
    positions <= last_pos independent of the padding tail.
    """
    b, s = tokens.shape
    x = _embed(params, tokens, cfg, pctx)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    n_scan = cfg.num_layers - 1 if split_tail else cfg.num_layers
    scan_layers = jax.tree.map(lambda a: a[:n_scan], params["layers"])

    def body(x, lp):
        x, _, kv = _block_prefill(x, lp, positions, cfg, pctx, training=False, collect_kv=True)
        return x, kv

    x, kvs = jax.lax.scan(body, x, scan_layers)

    if not split_tail:
        # logits only for the last (or requested) position — never (B, S, V)
        x_last = x[:, -1:, :] if last_pos is None else jax.lax.dynamic_slice_in_dim(
            x, last_pos, 1, axis=1)
        logits = _logits(params, x_last, cfg, pctx)
        return logits[:, -1, :], KVCache(kvs[0], kvs[1])

    # --- split point: run the last layer only through its attention ---
    last = jax.tree.map(lambda a: a[-1], params["layers"])
    h = apply_norm(last["ln1"], x, cfg.norm, cfg.norm_eps)
    attn_out, kv_last = attention_prefill(
        last["attn"], h, positions, cfg, pctx, window=cfg.sliding_window, training=False
    )
    x_mid = x + attn_out
    k_all = jnp.concatenate([kvs[0], kv_last[0][None]], axis=0)
    v_all = jnp.concatenate([kvs[1], kv_last[1][None]], axis=0)
    # The caller jits `prefill_tail` as its own program and dispatches the KV
    # relayout in between — that dispatch gap is the paper's overlap window.
    return x_mid, KVCache(k_all, v_all)


def prefill_tail(params, x_mid, cfg: ModelConfig, pctx: PartitionCtx = NULL_CTX,
                 last_pos: Optional[jax.Array] = None):
    """Standalone jittable tail (last FFN + logits) for the overlapped swap."""
    last = jax.tree.map(lambda a: a[-1], params["layers"])
    h2 = apply_norm(last["ln2"], x_mid, cfg.norm, cfg.norm_eps)
    if cfg.moe:
        ffn_out, _ = moe_apply(last["moe"], h2, cfg, pctx, training=False)
    else:
        ffn_out = mlp_apply(last["mlp"], h2, cfg, pctx, training=False)
    x_out = x_mid + ffn_out
    x_last = x_out[:, -1:, :] if last_pos is None else jax.lax.dynamic_slice_in_dim(
        x_out, last_pos, 1, axis=1)
    logits = _logits(params, x_last, cfg, pctx)
    return logits[:, -1, :]


def _prefill_chunk_body(params, tokens, prefix, prefix_len, cfg, pctx,
                        prefix_width=None):
    """Shared chunk forward for both cache layouts: run one prompt chunk
    through the layer stack, each layer attending over the prefill-resident
    fp KV ``prefix`` (valid in ``[0, prefix_len)``) plus the chunk itself.
    Returns (hidden (1, C, d), chunk KV ys (L, 1, Hkv, C, D), new prefix
    with the chunk inserted at ``[prefix_len, prefix_len + C)``).

    ``prefix_width`` (compile-time) truncates the prefix the attention
    SEES to its leading ``prefix_width`` positions — the caller picks a
    ladder bucket >= prefix_len, so a short prompt's chunks never pay
    attention over the buffer's full max_len capacity.  The running
    update still lands in the full-capacity buffer.

    Why an fp prefix mirror rather than re-reading the decode cache: the
    cache may be quantized (``kv_dtype``), and a chunk attending over a
    dequantized prefix would compute hidden states — and therefore KV —
    that drift from the monolithic prefill (which attends its own fp KV).
    The mirror keeps chunked prefill numerically equal to monolithic for
    EVERY kv_dtype; per-token quantize-on-write of the same fp values then
    lands the exact bytes whole-prompt quantization would, so the decode
    trajectory is invariant to chunking.  The mirror is one (L, 1, Hkv,
    Cap, D) fp32 buffer — the same transient footprint the monolithic
    prefill's KV held, bounded by max_len, and shared across requests
    because only one request prefills at a time.
    """
    from repro.layers.attention import attention_prefill_chunk

    b, c = tokens.shape
    x = _embed(params, tokens, cfg, pctx)
    positions = jnp.broadcast_to(prefix_len + jnp.arange(c), (b, c))
    pk, pv = prefix.k, prefix.v
    if prefix_width is not None and prefix_width < pk.shape[3]:
        pk = pk[:, :, :, :prefix_width, :]  # static slice: attention-visible
        pv = pv[:, :, :, :prefix_width, :]  # window of the running prefix

    def body(x, scanned):
        lp, li = scanned
        kp = jax.lax.dynamic_index_in_dim(pk, li, axis=0, keepdims=False)
        vp = jax.lax.dynamic_index_in_dim(pv, li, axis=0, keepdims=False)
        h = apply_norm(lp["ln1"], x, cfg.norm, cfg.norm_eps)
        attn_out, (k_new, v_new) = attention_prefill_chunk(
            lp["attn"], h, kp, vp, prefix_len, cfg, pctx,
            window=cfg.sliding_window, positions=positions,
        )
        x = x + attn_out
        h = apply_norm(lp["ln2"], x, cfg.norm, cfg.norm_eps)
        if cfg.moe:
            ffn_out, _ = moe_apply(lp["moe"], h, cfg, pctx, training=False)
        else:
            ffn_out = mlp_apply(lp["mlp"], h, cfg, pctx, training=False)
        return x + ffn_out, (k_new, v_new)

    x, (tok_k, tok_v) = jax.lax.scan(body, x, (params["layers"], jnp.arange(cfg.num_layers)))
    start = (0, 0, 0, prefix_len, 0)
    new_prefix = KVCache(
        jax.lax.dynamic_update_slice(prefix.k, tok_k.astype(prefix.k.dtype), start),
        jax.lax.dynamic_update_slice(prefix.v, tok_v.astype(prefix.v.dtype), start),
    )
    return x, tok_k, tok_v, new_prefix


def prefill_chunk(
    params: dict,
    tokens: jax.Array,  # (1, C) int32 — one right-padded chunk of the prompt
    cache: KVCache,  # (B_slots, L, Hkv, Smax, D) decode cache (donated)
    prefix: KVCache,  # (L, 1, Hkv, Cap, D) fp32 running prefix (donated)
    slot: jax.Array,  # traced scalar — destination slot
    prefix_len: jax.Array,  # traced scalar — tokens already installed
    last_pos: jax.Array,  # traced scalar — chunk-local position of the last real token
    cfg: ModelConfig,
    pctx: PartitionCtx = NULL_CTX,
    prefix_width=None,  # compile-time attention-visible prefix width
):
    """One chunk of prefill installed into the CONTIGUOUS decode cache.

    The chunk's queries attend over the already-prefilled prefix plus the
    chunk itself with a position-offset causal mask (see
    ``_prefill_chunk_body`` for why the prefix is an fp mirror); the
    chunk's KV is installed at ``[prefix_len, prefix_len + C)`` of slot
    ``slot`` by one post-scan ``write_chunk_kv_q`` (quantize-on-write
    under ``kv_dtype``).  Returns (logits (1, Vp) of ``last_pos``,
    new_cache, new_prefix) — intermediate chunks simply ignore the logits
    (the head is one tiny matmul at these chunk sizes).

    Chunk boundaries are a pure function of (prompt length, chunk size), so
    a preemption-restart re-prefills through the exact same programs and
    replay stays bit-identical.
    """
    from repro.layers.attention import write_chunk_kv_q

    x, tok_k, tok_v, new_prefix = _prefill_chunk_body(
        params, tokens, prefix, prefix_len, cfg, pctx, prefix_width=prefix_width)
    new_k = write_chunk_kv_q(cache.k, tok_k, slot, prefix_len)
    new_v = write_chunk_kv_q(cache.v, tok_v, slot, prefix_len)
    x_last = jax.lax.dynamic_slice_in_dim(x, last_pos, 1, axis=1)
    logits = _logits(params, x_last, cfg, pctx)
    return logits[:, -1, :], KVCache(new_k, new_v), new_prefix


def prefill_chunk_paged(
    params: dict,
    tokens: jax.Array,  # (1, C) int32 — one right-padded chunk, C % bs == 0
    pages: KVCache,  # (N, L, Hkv, bs, D) page pool (donated)
    prefix: KVCache,  # (L, 1, Hkv, Cap, D) fp32 running prefix (donated)
    page_ids: jax.Array,  # (C // bs,) int32 — destinations; OOB entries dropped
    prefix_len: jax.Array,  # traced scalar
    last_pos: jax.Array,  # traced scalar, chunk-local
    cfg: ModelConfig,
    pctx: PartitionCtx = NULL_CTX,
    prefix_width=None,  # compile-time attention-visible prefix width
):
    """One chunk of prefill installed into the PAGED pool —
    ``prefill_chunk`` with the chunk's KV scattered into its own pages by
    ``write_prefill_pages_q`` (quantize-on-write; prefix-cache-hit pages
    arrive as out-of-bounds ids and keep their shared contents).  The
    chunk start is page-aligned (``prefill_chunk % block_size == 0``), so
    every chunk writes whole pages.
    """
    from repro.layers.attention import write_prefill_pages_q

    bs = pages.k.q.shape[3] if hasattr(pages.k, "q") else pages.k.shape[3]
    x, tok_k, tok_v, new_prefix = _prefill_chunk_body(
        params, tokens, prefix, prefix_len, cfg, pctx, prefix_width=prefix_width)
    new_k = write_prefill_pages_q(pages.k, tok_k, page_ids, block_size=bs)
    new_v = write_prefill_pages_q(pages.v, tok_v, page_ids, block_size=bs)
    x_last = jax.lax.dynamic_slice_in_dim(x, last_pos, 1, axis=1)
    logits = _logits(params, x_last, cfg, pctx)
    return logits[:, -1, :], KVCache(new_k, new_v), new_prefix


def prefill_chunk_kv(
    params: dict,
    tokens: jax.Array,  # (1, C) int32 — one right-padded chunk of the prompt
    prefix: KVCache,  # (L, 1, Hkv, Cap, D) fp32 running prefix (donated)
    prefix_len: jax.Array,  # traced scalar — tokens already prefilled
    last_pos: jax.Array,  # traced scalar, chunk-local
    cfg: ModelConfig,
    pctx: PartitionCtx = NULL_CTX,
    prefix_width=None,  # compile-time attention-visible prefix width
):
    """One chunk of prefill computed WITHOUT an install — the disaggregated
    prefill pool's chunk program.  Identical math to ``prefill_chunk`` /
    ``prefill_chunk_paged`` (same ``_prefill_chunk_body``, same logits
    epilogue); the chunk's fp KV is RETURNED instead of written, so the
    caller can ship it across the pool boundary and install it decode-side
    with the very same quantize-on-write scatter the colocated engine fuses
    in here — which is what keeps the two-pool engine bit-identical.
    Returns (logits (1, Vp) of ``last_pos``, chunk KV (L, 1, Hkv, C, D) fp,
    new_prefix)."""
    x, tok_k, tok_v, new_prefix = _prefill_chunk_body(
        params, tokens, prefix, prefix_len, cfg, pctx, prefix_width=prefix_width)
    x_last = jax.lax.dynamic_slice_in_dim(x, last_pos, 1, axis=1)
    logits = _logits(params, x_last, cfg, pctx)
    return logits[:, -1, :], KVCache(tok_k, tok_v), new_prefix


def _kv_buffer(shape, dtype, kv_dtype: str):
    """One K or V cache buffer: a plain fp array, or a QuantKV holding the
    packed payload (int8, or uint8 nibble pairs for int4) plus the fp32
    per-(layer, head, token) scale plane."""
    from repro.quant.kv_quant import QuantKV, assert_kv_dtype

    assert_kv_dtype(kv_dtype)
    if kv_dtype == "fp":
        return jnp.zeros(shape, dtype)
    d = shape[-1]
    if kv_dtype == "int4":
        assert d % 2 == 0, f"head_dim must be even for int4 nibble packing, got {d}"
        payload = jnp.zeros(shape[:-1] + (d // 2,), jnp.uint8)
    else:
        payload = jnp.zeros(shape, jnp.int8)
    return QuantKV(payload, jnp.ones(shape[:-1], jnp.float32))


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               kv_dtype: str = "fp") -> KVCache:
    # Decode cache is BATCH-LEADING (B, L, Hkv, S, D): all layers' new
    # tokens for one sequence land in one contiguous DUS window, and the
    # leading dim is the vmap/sharding axis (see attention.scatter_new_tokens).
    # kv_dtype != "fp" stores packed payload + scale planes instead.
    shape = (batch, cfg.num_layers, cfg.num_kv_heads, max_len, cfg.head_dim)
    return KVCache(_kv_buffer(shape, dtype, kv_dtype), _kv_buffer(shape, dtype, kv_dtype))


def init_paged_pool(cfg: ModelConfig, num_blocks: int, block_size: int,
                    dtype=jnp.bfloat16, kv_dtype: str = "fp") -> KVCache:
    # Paged decode cache: the slot axis of init_cache becomes the PAGE axis
    # — (N, L, Hkv, bs, D), each page layer-complete for block_size token
    # positions.  Ownership/refcounts live in serving.paging.PagedKVCache.
    # kv_dtype != "fp" makes each page a packed payload + fp32 scale plane.
    shape = (num_blocks, cfg.num_layers, cfg.num_kv_heads, block_size, cfg.head_dim)
    return KVCache(_kv_buffer(shape, dtype, kv_dtype), _kv_buffer(shape, dtype, kv_dtype))


def _slice_layer(leaf, li):
    """Slice layer ``li`` (axis 1) from a decode-cache leaf; quantized leaves
    are QuantKV pytrees (payload + scale plane) — slice both together."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, li, axis=1, keepdims=False), leaf
    )


def decode_step(
    params: dict,
    token: jax.Array,  # (B,) int32 — current input token
    cache: KVCache,  # (L, B, Hkv, Smax, D)
    lengths: jax.Array,  # (B,)
    cfg: ModelConfig,
    pctx: PartitionCtx = NULL_CTX,
):
    """The decode RM: one step.  Returns (logits (B, Vp), new_cache).

    [§Perf iteration D2] The (batch-leading) cache is closed over and
    READ-ONLY during the scan: each layer dynamic-slices its K/V, the
    online-softmax merge folds the fresh token into the attention output,
    and the scan emits only the tiny (L,B,Hkv,1,D) new-token ys.  One
    post-scan ``scatter_new_tokens`` writes all layers' tokens into the
    (donated, aliased-in-place) cache — per-step cache write traffic is
    O(L*B*Hkv*D), not O(cache).
    """
    from repro.layers.attention import scatter_new_tokens_q

    b = token.shape[0]
    x = _embed(params, token[:, None], cfg, pctx)

    def body(x, scanned):
        lp, li = scanned
        ck = _slice_layer(cache.k, li)
        cv = _slice_layer(cache.v, li)
        h = apply_norm(lp["ln1"], x, cfg.norm, cfg.norm_eps)
        attn_out, new_kv = attention_decode(
            lp["attn"], h, KVCache(ck, cv), lengths, cfg, pctx, window=cfg.sliding_window
        )
        x = x + attn_out
        h = apply_norm(lp["ln2"], x, cfg.norm, cfg.norm_eps)
        if cfg.moe:
            ffn_out, _ = moe_apply(lp["moe"], h, cfg, pctx, training=False)
        else:
            ffn_out = mlp_apply(lp["mlp"], h, cfg, pctx, training=False)
        return x + ffn_out, (new_kv.k, new_kv.v)

    x, (tok_k, tok_v) = jax.lax.scan(body, x, (params["layers"], jnp.arange(cfg.num_layers)))
    new_k = scatter_new_tokens_q(cache.k, tok_k, lengths)
    new_v = scatter_new_tokens_q(cache.v, tok_v, lengths)
    logits = _logits(params, x, cfg, pctx)
    return logits[:, 0, :], KVCache(new_k, new_v)


def _store_roundtrip(cache_leaf):
    """How a cache read-back rounds freshly-written K/V — the function
    ``attention_verify`` applies to block rows so a verify pass sees
    earlier block tokens EXACTLY as sequential decode would after writing
    then re-reading them: a quantize/dequantize round trip for quantized
    caches, None (the concat's storage-dtype cast) for fp."""
    from repro.quant.kv_quant import QuantKV, dequantize_kv, infer_kv_dtype, quantize_kv

    if not isinstance(cache_leaf, QuantKV):
        return None
    dt = infer_kv_dtype(cache_leaf.q)

    def roundtrip(x):
        payload, scale = quantize_kv(x, dt)
        return dequantize_kv(payload, scale, dt)

    return roundtrip


def verify(
    params: dict,
    tokens: jax.Array,  # (B, W) int32 — per slot [last token, draft_1..draft_k]
    cache: KVCache,  # (B, L, Hkv, Smax, D) decode cache (donated)
    lengths: jax.Array,  # (B,) tokens already installed per slot
    n_tokens: jax.Array,  # (B,) real rows per slot (draft_len + 1; 0 = sit out)
    cfg: ModelConfig,
    pctx: PartitionCtx = NULL_CTX,
):
    """The speculative VERIFY pass over the contiguous cache: score a
    W = k+1 token block per slot in one forward.  Returns (logits
    (B, W, Vp), new_cache).

    Structure mirrors ``decode_step``: the cache is READ-ONLY during the
    layer scan (each layer slices its K/V; ``attention_verify`` applies the
    position-offset causal mask over prefix + block), and ONE post-scan
    ``scatter_verify_tokens_q`` writes all layers' block rows in place
    (quantize-on-write) — per-round cache write traffic O(L*B*Hkv*W*D).
    Rows past ``n_tokens`` are dropped by the scatter and their logits are
    garbage the host ignores; the engine truncates slot length / releases
    overshoot pages to roll back rejected rows.  Quantized caches are
    dequantized with the same math the decode jnp path uses, so verify
    reads exactly the fp values plain decode reads.
    """
    from repro.layers.attention import attention_verify, scatter_verify_tokens_q
    from repro.quant.kv_quant import QuantKV, dequantize_kv, infer_kv_dtype

    x = _embed(params, tokens, cfg, pctx)
    positions = lengths[:, None] + jnp.arange(tokens.shape[1])[None, :]
    roundtrip = _store_roundtrip(cache.k)

    def dense(leaf):  # (B, Hkv, Smax, D) fp view of one layer's cache slice
        if isinstance(leaf, QuantKV):
            return dequantize_kv(leaf.q, leaf.scale, infer_kv_dtype(leaf.q))
        return leaf

    def body(x, scanned):
        lp, li = scanned
        ck = dense(_slice_layer(cache.k, li))
        cv = dense(_slice_layer(cache.v, li))
        h = apply_norm(lp["ln1"], x, cfg.norm, cfg.norm_eps)
        attn_out, (k_new, v_new) = attention_verify(
            lp["attn"], h, ck, cv, lengths, cfg, pctx,
            window=cfg.sliding_window, positions=positions,
            store_roundtrip=roundtrip,
        )
        x = x + attn_out
        h = apply_norm(lp["ln2"], x, cfg.norm, cfg.norm_eps)
        if cfg.moe:
            ffn_out, _ = moe_apply(lp["moe"], h, cfg, pctx, training=False)
        else:
            ffn_out = mlp_apply(lp["mlp"], h, cfg, pctx, training=False)
        return x + ffn_out, (k_new, v_new)

    x, (tok_k, tok_v) = jax.lax.scan(body, x, (params["layers"], jnp.arange(cfg.num_layers)))
    new_k = scatter_verify_tokens_q(cache.k, tok_k, lengths, n_tokens)
    new_v = scatter_verify_tokens_q(cache.v, tok_v, lengths, n_tokens)
    logits = _logits(params, x, cfg, pctx)  # ALL W positions — the verify targets
    return logits, KVCache(new_k, new_v)


def verify_paged(
    params: dict,
    tokens: jax.Array,  # (B, W) int32
    pages: KVCache,  # (N, L, Hkv, bs, D) page pool (donated)
    block_tables: jax.Array,  # (B, P) int32
    lengths: jax.Array,  # (B,)
    n_tokens: jax.Array,  # (B,) real rows per slot
    cfg: ModelConfig,
    pctx: PartitionCtx = NULL_CTX,
):
    """The speculative VERIFY pass over the paged pool — ``verify`` with
    each layer's K/V gathered dense through the block table first (the
    paged jnp decode path's move: page ``i`` covers positions ``[i*bs,
    (i+1)*bs)``, so the gathered view places every token at the index the
    contiguous cache would, and paged vs contiguous verify cannot drift).
    The block's KV is scattered into each slot's pages by
    ``scatter_verify_tokens_paged_q`` (quantize-on-write; rows past
    ``n_tokens`` route out of bounds).
    """
    from repro.kernels.paged_attention.ops import gather_scales
    from repro.kernels.paged_attention.ref import gather_pages
    from repro.layers.attention import attention_verify, scatter_verify_tokens_paged_q
    from repro.quant.kv_quant import QuantKV, dequantize_kv, infer_kv_dtype

    x = _embed(params, tokens, cfg, pctx)
    positions = lengths[:, None] + jnp.arange(tokens.shape[1])[None, :]
    roundtrip = _store_roundtrip(pages.k)

    def dense(leaf):  # (B, Hkv, P*bs, D) fp gather of one layer's pages
        if isinstance(leaf, QuantKV):
            return dequantize_kv(gather_pages(leaf.q, block_tables),
                                 gather_scales(leaf.scale, block_tables),
                                 infer_kv_dtype(leaf.q))
        return gather_pages(leaf, block_tables)

    def body(x, scanned):
        lp, li = scanned
        ck = dense(_slice_layer(pages.k, li))
        cv = dense(_slice_layer(pages.v, li))
        h = apply_norm(lp["ln1"], x, cfg.norm, cfg.norm_eps)
        attn_out, (k_new, v_new) = attention_verify(
            lp["attn"], h, ck, cv, lengths, cfg, pctx,
            window=cfg.sliding_window, positions=positions,
            store_roundtrip=roundtrip,
        )
        x = x + attn_out
        h = apply_norm(lp["ln2"], x, cfg.norm, cfg.norm_eps)
        if cfg.moe:
            ffn_out, _ = moe_apply(lp["moe"], h, cfg, pctx, training=False)
        else:
            ffn_out = mlp_apply(lp["mlp"], h, cfg, pctx, training=False)
        return x + ffn_out, (k_new, v_new)

    x, (tok_k, tok_v) = jax.lax.scan(body, x, (params["layers"], jnp.arange(cfg.num_layers)))
    new_k = scatter_verify_tokens_paged_q(pages.k, tok_k, block_tables, lengths, n_tokens)
    new_v = scatter_verify_tokens_paged_q(pages.v, tok_v, block_tables, lengths, n_tokens)
    logits = _logits(params, x, cfg, pctx)
    return logits, KVCache(new_k, new_v)


def decode_step_paged(
    params: dict,
    token: jax.Array,  # (B,) int32 — current input token
    pages: KVCache,  # (N, L, Hkv, bs, D) page pool
    block_tables: jax.Array,  # (B, P) int32
    lengths: jax.Array,  # (B,)
    cfg: ModelConfig,
    pctx: PartitionCtx = NULL_CTX,
):
    """The decode RM over the paged KV cache: one step.

    Structure mirrors ``decode_step``: the pool is closed over and READ-ONLY
    during the layer scan (each layer slices its (N, Hkv, bs, D) plane; the
    online-softmax merge folds the fresh token in), and one post-scan
    ``scatter_new_tokens_paged`` writes all layers' tokens into each
    sequence's current page — per-step write traffic O(L*B*Hkv*D).  Returns
    (logits (B, Vp), new_pages).
    """
    from repro.layers.attention import attention_decode_paged, scatter_new_tokens_paged_q

    x = _embed(params, token[:, None], cfg, pctx)

    def body(x, scanned):
        lp, li = scanned
        pk = _slice_layer(pages.k, li)
        pv = _slice_layer(pages.v, li)
        h = apply_norm(lp["ln1"], x, cfg.norm, cfg.norm_eps)
        attn_out, new_kv = attention_decode_paged(
            lp["attn"], h, pk, pv, block_tables, lengths, cfg, pctx,
            window=cfg.sliding_window,
        )
        x = x + attn_out
        h = apply_norm(lp["ln2"], x, cfg.norm, cfg.norm_eps)
        if cfg.moe:
            ffn_out, _ = moe_apply(lp["moe"], h, cfg, pctx, training=False)
        else:
            ffn_out = mlp_apply(lp["mlp"], h, cfg, pctx, training=False)
        return x + ffn_out, (new_kv.k, new_kv.v)

    x, (tok_k, tok_v) = jax.lax.scan(body, x, (params["layers"], jnp.arange(cfg.num_layers)))
    new_k = scatter_new_tokens_paged_q(pages.k, tok_k, block_tables, lengths)
    new_v = scatter_new_tokens_paged_q(pages.v, tok_v, block_tables, lengths)
    logits = _logits(params, x, cfg, pctx)
    return logits[:, 0, :], KVCache(new_k, new_v)
