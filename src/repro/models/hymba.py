"""Hymba (arXiv:2411.13676): parallel attention + SSM heads per layer.

Every layer runs an attention branch and a selective-SSM branch on the same
input and fuses them (per-branch RMSNorm, learned scalar gates, mean).
Attention is sliding-window everywhere except ``global_attn_layers`` — under
scan-over-layers the per-layer window is a *traced* scalar (full-attention
layers get a huge sentinel window), keeping the scanned computation uniform.

PD-Swap applicability: the attention sub-heads swap prefill/decode RMs like
any transformer; the SSM sub-heads use the xlstm-style O(1) recurrent decode.
SWA + SSM ⇒ sub-quadratic: this arch runs the long_500k cell.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.attention import KVCache, attention_decode, attention_init, attention_prefill
from repro.layers.mlp import mlp_apply, mlp_init
from repro.layers.norm import apply_norm, norm_init
from repro.layers.sharding import NULL_CTX, PartitionCtx
from repro.models.ssm import ssm_decode, ssm_init, ssm_prefill

_FULL_WINDOW = 1 << 30


class HymbaCache(NamedTuple):
    kv: KVCache  # (L, B, Hkv, Smax, D)
    ssm_h: jax.Array  # (L, B, d_in, N)
    conv: jax.Array  # (L, B, ssm_conv-1, d_in)


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer attention window; full-attention layers get the sentinel."""
    w = jnp.full((cfg.num_layers,), cfg.sliding_window or _FULL_WINDOW, jnp.int32)
    for l in cfg.global_attn_layers:
        w = w.at[l].set(_FULL_WINDOW)
    return w


def init(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> dict:
    vp = cfg.padded_vocab()
    ke, kl = jax.random.split(key)

    def layer_init(k):
        ka, ks, kf = jax.random.split(k, 3)
        return {
            "attn": attention_init(cfg, ka, dtype),
            "ssm": ssm_init(cfg, ks, dtype),
            "ln1": norm_init("rmsnorm", cfg.d_model),
            "ln2": norm_init("rmsnorm", cfg.d_model),
            "attn_norm": norm_init("rmsnorm", cfg.d_model),
            "ssm_norm": norm_init("rmsnorm", cfg.d_model),
            "gate_a": jnp.ones((), jnp.float32),
            "gate_s": jnp.ones((), jnp.float32),
            "mlp": mlp_init(cfg, kf, dtype),
        }

    return {
        "emb": (jax.random.normal(ke, (vp, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
        "layers": jax.vmap(layer_init)(jax.random.split(kl, cfg.num_layers)),
        "ln_f": norm_init("rmsnorm", cfg.d_model),
    }


def _fuse(lp, attn_out, ssm_out, cfg):
    a = apply_norm(lp["attn_norm"], attn_out, "rmsnorm", cfg.norm_eps)
    s = apply_norm(lp["ssm_norm"], ssm_out, "rmsnorm", cfg.norm_eps)
    return 0.5 * (lp["gate_a"] * a.astype(jnp.float32) + lp["gate_s"] * s.astype(jnp.float32)).astype(attn_out.dtype)


def _block_prefill(x, lp, window, positions, cfg, pctx, *, training):
    h = apply_norm(lp["ln1"], x, "rmsnorm", cfg.norm_eps)
    attn_out, kv = attention_prefill(lp["attn"], h, positions, cfg, pctx, window=window, training=training)
    ssm_out, (ssm_h, conv) = ssm_prefill(lp["ssm"], h, cfg)
    x = x + _fuse(lp, attn_out, ssm_out, cfg)
    h2 = apply_norm(lp["ln2"], x, "rmsnorm", cfg.norm_eps)
    x = x + mlp_apply(lp["mlp"], h2, cfg, pctx, training=training)
    x = pctx.shard(x, "batch", "seq", "embed")
    return x, (kv, ssm_h, conv)


def forward_hidden(params, tokens, cfg: ModelConfig, pctx: PartitionCtx = NULL_CTX, *, training=True):
    b, s = tokens.shape
    x = params["emb"][tokens]
    x = pctx.shard(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    windows = layer_windows(cfg)

    def body(x, scanned):
        lp, w = scanned
        x, _ = _block_prefill(x, lp, w, positions, cfg, pctx, training=training)
        return x, None

    if cfg.remat != "none" and training:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, (params["layers"], windows))
    return apply_norm(params["ln_f"], x, "rmsnorm", cfg.norm_eps)


def forward_train(params, tokens, cfg: ModelConfig, pctx: PartitionCtx = NULL_CTX):
    x = forward_hidden(params, tokens, cfg, pctx, training=True)
    logits = x.astype(jnp.float32) @ params["emb"].astype(jnp.float32).T
    return pctx.shard(logits, "batch", "seq", "vocab"), jnp.float32(0)


def loss_fn(params, batch, cfg, pctx: PartitionCtx = NULL_CTX, aux_weight: float = 0.0):
    from repro.train.losses import chunked_ce_loss

    x = forward_hidden(params, batch["tokens"], cfg, pctx, training=True)
    loss = chunked_ce_loss(x, params["emb"].T, batch["targets"], batch["mask"], pctx)
    return loss, {"nll": loss, "aux": jnp.float32(0)}


def forward_prefill(params, tokens, cfg: ModelConfig, pctx: PartitionCtx = NULL_CTX):
    b, s = tokens.shape
    x = params["emb"][tokens]
    x = pctx.shard(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    windows = layer_windows(cfg)

    def body(x, scanned):
        lp, w = scanned
        x, (kv, ssm_h, conv) = _block_prefill(x, lp, w, positions, cfg, pctx, training=False)
        return x, (kv[0], kv[1], ssm_h, conv)

    x, (ks, vs, hs, convs) = jax.lax.scan(body, x, (params["layers"], windows))
    x = apply_norm(params["ln_f"], x[:, -1:, :], "rmsnorm", cfg.norm_eps)
    logits = x.astype(jnp.float32) @ params["emb"].astype(jnp.float32).T
    return logits[:, -1, :], HymbaCache(KVCache(ks, vs), hs, convs)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> HymbaCache:
    l = cfg.num_layers
    # KV batch-leading (B, L, Hkv, S, D) — see attention.scatter_new_tokens.
    kv = KVCache(
        jnp.zeros((batch, l, cfg.num_kv_heads, max_len, cfg.head_dim), dtype),
        jnp.zeros((batch, l, cfg.num_kv_heads, max_len, cfg.head_dim), dtype),
    )
    return HymbaCache(
        kv=kv,
        ssm_h=jnp.zeros((l, batch, cfg.d_model, cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((l, batch, cfg.ssm_conv - 1, cfg.d_model), jnp.float32),
    )


def decode_step(params, token, cache: HymbaCache, lengths, cfg: ModelConfig, pctx: PartitionCtx = NULL_CTX):
    """[§Perf iteration D2] Batch-leading KV cache, read-only through the
    scan; one post-scan scatter writes all layers' new tokens.  The small
    SSM/conv states still ride xs/ys — their re-stack is O(B·d·N)."""
    from repro.layers.attention import scatter_new_tokens

    b = token.shape[0]
    x = params["emb"][token[:, None]]
    windows = layer_windows(cfg)

    def body(x, scanned):
        lp, w, li, sh, cs = scanned
        ck = jax.lax.dynamic_index_in_dim(cache.kv.k, li, axis=1, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(cache.kv.v, li, axis=1, keepdims=False)
        h = apply_norm(lp["ln1"], x, "rmsnorm", cfg.norm_eps)
        attn_out, new_kv = attention_decode(lp["attn"], h, KVCache(ck, cv), lengths, cfg, pctx, window=w)
        ssm_out, (new_h, new_cs) = ssm_decode(lp["ssm"], h, cfg, sh, cs)
        x = x + _fuse(lp, attn_out, ssm_out, cfg)
        h2 = apply_norm(lp["ln2"], x, "rmsnorm", cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h2, cfg, pctx, training=False)
        return x, (new_kv.k, new_kv.v, new_h, new_cs)

    x, (tok_k, tok_v, hs, convs) = jax.lax.scan(
        body, x, (params["layers"], windows, jnp.arange(cfg.num_layers), cache.ssm_h, cache.conv)
    )
    ks = scatter_new_tokens(cache.kv.k, tok_k, lengths)
    vs = scatter_new_tokens(cache.kv.v, tok_v, lengths)
    x = apply_norm(params["ln_f"], x, "rmsnorm", cfg.norm_eps)
    logits = x.astype(jnp.float32) @ params["emb"].astype(jnp.float32).T
    return logits[:, 0, :], HymbaCache(KVCache(ks, vs), hs, convs)
