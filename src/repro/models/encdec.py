"""Whisper-style encoder-decoder with a stubbed conv frontend.

Per the assignment, the modality frontend is a STUB: ``input_specs()`` feeds
precomputed frame embeddings (B, encoder_seq, d_model).  LayerNorm + GELU,
learned absolute positions (decoder) / sinusoidal (encoder), no RoPE.

PD-Swap mapping (DESIGN.md §4): the encoder is prefill-only; decoder
self-attention swaps prefill/decode RMs; cross-attention KV is computed once
after encoding and then consumed in pure decode-style streaming.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.attention import (
    KVCache,
    attention_decode,
    attention_init,
    attention_prefill,
)
from repro.layers.linear import linear_apply
from repro.layers.mlp import mlp_apply, mlp_init
from repro.layers.norm import apply_norm, norm_init
from repro.layers.sharding import NULL_CTX, PartitionCtx


class EncDecCache(NamedTuple):
    self_kv: KVCache  # (L, B, Hkv, Smax, D)
    cross_kv: KVCache  # (L, B, Hkv, Senc, D)


def _sinusoids(length: int, channels: int) -> jnp.ndarray:
    log_timescale = jnp.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2))
    ang = jnp.arange(length)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


def init(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> dict:
    vp = cfg.padded_vocab()
    ke, kenc, kdec, kp = jax.random.split(key, 4)

    def enc_layer(k):
        ka, kf = jax.random.split(k)
        return {
            "attn": attention_init(cfg, ka, dtype),
            "ln1": norm_init("layernorm", cfg.d_model),
            "mlp": mlp_init(cfg, kf, dtype),
            "ln2": norm_init("layernorm", cfg.d_model),
        }

    def dec_layer(k):
        ka, kx, kf = jax.random.split(k, 3)
        return {
            "attn": attention_init(cfg, ka, dtype),
            "cross": attention_init(cfg, kx, dtype),
            "ln1": norm_init("layernorm", cfg.d_model),
            "lnx": norm_init("layernorm", cfg.d_model),
            "ln2": norm_init("layernorm", cfg.d_model),
            "mlp": mlp_init(cfg, kf, dtype),
        }

    return {
        "emb": (jax.random.normal(ke, (vp, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
        "pos_dec": (jax.random.normal(kp, (cfg.max_position_embeddings, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
        "enc_layers": jax.vmap(enc_layer)(jax.random.split(kenc, cfg.encoder_layers)),
        "dec_layers": jax.vmap(dec_layer)(jax.random.split(kdec, cfg.num_layers)),
        "ln_enc": norm_init("layernorm", cfg.d_model),
        "ln_f": norm_init("layernorm", cfg.d_model),
    }


def encode(params, frames: jax.Array, cfg: ModelConfig, pctx: PartitionCtx = NULL_CTX) -> jax.Array:
    """frames: (B, Senc, d) precomputed embeddings (conv frontend stub)."""
    b, s, d = frames.shape
    x = frames + _sinusoids(s, d).astype(frames.dtype)[None]
    x = pctx.shard(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(x, lp):
        h = apply_norm(lp["ln1"], x, "layernorm", cfg.norm_eps)
        attn_out, _ = attention_prefill(lp["attn"], h, positions, cfg, pctx, causal=False, training=False)
        x = x + attn_out
        h = apply_norm(lp["ln2"], x, "layernorm", cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h, cfg, pctx, training=False)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(params["ln_enc"], x, "layernorm", cfg.norm_eps)


def compute_cross_kv(params, enc_out: jax.Array, cfg: ModelConfig, pctx: PartitionCtx = NULL_CTX) -> KVCache:
    """Project encoder output into per-decoder-layer cross K/V (done once)."""
    b, s, _ = enc_out.shape
    hkv, hd = cfg.num_kv_heads, cfg.head_dim

    def body(_, lp):
        kw = dict(quant=cfg.quant, training=False, use_pallas=cfg.use_pallas)
        k = linear_apply(lp["cross"]["wk"], enc_out, **kw).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
        v = linear_apply(lp["cross"]["wv"], enc_out, **kw).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(body, None, params["dec_layers"])
    return KVCache(ks, vs)


def _dec_block_prefill(x, lp, positions, cross_k, cross_v, cfg, pctx, *, training):
    h = apply_norm(lp["ln1"], x, "layernorm", cfg.norm_eps)
    attn_out, kv = attention_prefill(lp["attn"], h, positions, cfg, pctx, training=training)
    x = x + attn_out
    h = apply_norm(lp["lnx"], x, "layernorm", cfg.norm_eps)
    cross_out, _ = attention_prefill(
        lp["cross"], h, positions, cfg, pctx, causal=False, training=training,
        cross_kv=(cross_k, cross_v),
    )
    x = x + cross_out
    h = apply_norm(lp["ln2"], x, "layernorm", cfg.norm_eps)
    x = x + mlp_apply(lp["mlp"], h, cfg, pctx, training=training)
    return pctx.shard(x, "batch", "seq", "embed"), kv


def _decoder_hidden(params, tokens, cross: KVCache, cfg, pctx, *, training, collect_kv, pos_offset=0):
    b, s = tokens.shape
    x = params["emb"][tokens] + params["pos_dec"][pos_offset : pos_offset + s][None]
    x = pctx.shard(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(pos_offset, pos_offset + s), (b, s))

    def body(x, scanned):
        lp, ck, cv = scanned
        x, kv = _dec_block_prefill(x, lp, positions, ck, cv, cfg, pctx, training=training)
        return x, (kv if collect_kv else None)

    if cfg.remat != "none" and training:
        body = jax.checkpoint(body)
    x, kvs = jax.lax.scan(body, x, (params["dec_layers"], cross.k, cross.v))
    return apply_norm(params["ln_f"], x, "layernorm", cfg.norm_eps), kvs


def forward_train(params, batch_inputs, cfg: ModelConfig, pctx: PartitionCtx = NULL_CTX):
    """batch_inputs: dict with 'frames' (B,Senc,d) and 'tokens' (B,S)."""
    enc_out = encode(params, batch_inputs["frames"], cfg, pctx)
    cross = compute_cross_kv(params, enc_out, cfg, pctx)
    x, _ = _decoder_hidden(params, batch_inputs["tokens"], cross, cfg, pctx, training=True, collect_kv=False)
    logits = x.astype(jnp.float32) @ params["emb"].astype(jnp.float32).T
    return pctx.shard(logits, "batch", "seq", "vocab"), jnp.float32(0)


def loss_fn(params, batch, cfg, pctx: PartitionCtx = NULL_CTX, aux_weight: float = 0.0):
    from repro.train.losses import chunked_ce_loss

    enc_out = encode(params, batch["frames"], cfg, pctx)
    cross = compute_cross_kv(params, enc_out, cfg, pctx)
    x, _ = _decoder_hidden(params, batch["tokens"], cross, cfg, pctx, training=True, collect_kv=False)
    loss = chunked_ce_loss(x, params["emb"].T, batch["targets"], batch["mask"], pctx)
    return loss, {"nll": loss, "aux": jnp.float32(0)}


def _padded_enc_seq(cfg: ModelConfig) -> int:
    """Cross-KV cache seq padded to a 128 multiple (1500 -> 1536) so the
    decode cache shards evenly over a 16-way axis; the padded tail is masked
    via ``cross_len``."""
    return ((cfg.encoder_seq + 127) // 128) * 128


def forward_prefill(params, tokens, cfg: ModelConfig, pctx: PartitionCtx = NULL_CTX, *, frames=None):
    """Encode + decoder prefill.  Returns (last logits, EncDecCache)."""
    enc_out = encode(params, frames, cfg, pctx)
    cross = compute_cross_kv(params, enc_out, cfg, pctx)
    x, kvs = _decoder_hidden(params, tokens, cross, cfg, pctx, training=False, collect_kv=True)
    logits = x[:, -1:, :].astype(jnp.float32) @ params["emb"].astype(jnp.float32).T
    pad = _padded_enc_seq(cfg) - cfg.encoder_seq
    cross_padded = KVCache(
        jnp.pad(cross.k, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))),
        jnp.pad(cross.v, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))),
    )
    return logits[:, -1, :], EncDecCache(KVCache(kvs[0], kvs[1]), cross_padded)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> EncDecCache:
    l = cfg.num_layers
    # batch-leading (B, L, Hkv, S, D) — see attention.scatter_new_tokens
    mk = lambda s: jnp.zeros((batch, l, cfg.num_kv_heads, s, cfg.head_dim), dtype)
    se = _padded_enc_seq(cfg)
    return EncDecCache(KVCache(mk(max_len), mk(max_len)), KVCache(mk(se), mk(se)))


def decode_step(params, token, cache: EncDecCache, lengths, cfg: ModelConfig, pctx: PartitionCtx = NULL_CTX):
    """[§Perf iteration D2] Self-attention KV read-only through the scan
    (merge handles the fresh token); one post-scan scatter writes all
    layers' tokens.  Cross-KV never updates."""
    from repro.layers.attention import scatter_new_tokens

    b = token.shape[0]
    x = params["emb"][token[:, None]]
    pos = params["pos_dec"][lengths][:, None, :]  # (B,1,d) gather per-sequence position
    x = x + pos

    def body(x, scanned):
        lp, li = scanned
        ck = jax.lax.dynamic_index_in_dim(cache.self_kv.k, li, axis=1, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(cache.self_kv.v, li, axis=1, keepdims=False)
        xk = jax.lax.dynamic_index_in_dim(cache.cross_kv.k, li, axis=1, keepdims=False)
        xv = jax.lax.dynamic_index_in_dim(cache.cross_kv.v, li, axis=1, keepdims=False)
        h = apply_norm(lp["ln1"], x, "layernorm", cfg.norm_eps)
        attn_out, new_kv = attention_decode(lp["attn"], h, KVCache(ck, cv), lengths, cfg, pctx)
        x = x + attn_out
        h = apply_norm(lp["lnx"], x, "layernorm", cfg.norm_eps)
        cross_out, _ = attention_decode(
            lp["cross"], h, KVCache(xk, xv), lengths, cfg, pctx, cross_kv=(xk, xv),
            cross_len=cfg.encoder_seq,  # mask the 1500->1536 sharding pad
        )
        x = x + cross_out
        h = apply_norm(lp["ln2"], x, "layernorm", cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h, cfg, pctx, training=False)
        return x, (new_kv.k, new_kv.v)

    x, (tok_k, tok_v) = jax.lax.scan(
        body, x, (params["dec_layers"], jnp.arange(cfg.num_layers)),
    )
    ks = scatter_new_tokens(cache.self_kv.k, tok_k, lengths)
    vs = scatter_new_tokens(cache.self_kv.v, tok_v, lengths)
    x = apply_norm(params["ln_f"], x, "layernorm", cfg.norm_eps)
    logits = x.astype(jnp.float32) @ params["emb"].astype(jnp.float32).T
    return logits[:, 0, :], EncDecCache(KVCache(ks, vs), cache.cross_kv)
