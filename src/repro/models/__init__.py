from repro.models.registry import get_model, ModelAPI
