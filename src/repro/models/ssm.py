"""Selective SSM (Mamba-style) branch used by hymba's parallel heads.

Prefill: chunk-parallel linear recurrence — ``associative_scan`` within a
chunk (so the (B, c, d, N) working set stays bounded), sequential carry
across chunks.  Decode: O(1) state update.  tests/test_hymba.py asserts the
two agree step-by-step.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.norm import apply_norm, norm_init


def ssm_init(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> dict:
    d, n = cfg.d_model, cfg.ssm_state
    d_in = d  # parallel-heads design: branch width == d_model
    ks = jax.random.split(key, 6)
    s = 1.0 / d**0.5
    return {
        "w_in": (jax.random.normal(ks[0], (d, 2 * d_in), jnp.float32) * s).astype(dtype),
        "conv": (jax.random.normal(ks[1], (cfg.ssm_conv, d_in), jnp.float32) * 0.2).astype(dtype),
        "w_bc": (jax.random.normal(ks[2], (d_in, 2 * n), jnp.float32) * s).astype(dtype),
        "w_dt": (jax.random.normal(ks[3], (d_in, 1), jnp.float32) * s).astype(dtype),
        "dt_bias": jnp.full((d_in,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (d_in, 1))),
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "w_out": (jax.random.normal(ks[4], (d_in, d), jnp.float32) * s).astype(dtype),
    }


def _ssm_inputs(p, x, cfg: ModelConfig, conv_state=None):
    """x: (B, S, d) -> gates and per-step scan elements.

    Returns (xc, z, dt, b_mat, c_mat, new_conv_state); conv_state is the last
    (ssm_conv-1) inputs for streaming decode.
    """
    b, s, d = x.shape
    xz = x.astype(jnp.float32) @ p["w_in"].astype(jnp.float32)
    x_in, z = jnp.split(xz, 2, axis=-1)  # (B,S,d_in)
    w = cfg.ssm_conv
    if conv_state is None:
        ctx = jnp.pad(x_in, ((0, 0), (w - 1, 0), (0, 0)))
    else:
        ctx = jnp.concatenate([conv_state.astype(x_in.dtype), x_in], axis=1)
    # depthwise causal conv via stacked shifts (w is tiny: 4)
    xc = sum(ctx[:, i : i + s, :] * p["conv"].astype(jnp.float32)[i] for i in range(w))
    new_conv_state = ctx[:, -(w - 1) :, :] if w > 1 else None
    xc = jax.nn.silu(xc)
    dt = jax.nn.softplus(xc @ p["w_dt"].astype(jnp.float32) + p["dt_bias"])  # (B,S,d_in)? w_dt (d,1)->(B,S,1)
    dt = jnp.broadcast_to(dt, xc.shape)
    bc = xc @ p["w_bc"].astype(jnp.float32)
    b_mat, c_mat = jnp.split(bc, 2, axis=-1)  # (B,S,N)
    return xc, z, dt, b_mat, c_mat, new_conv_state


def _scan_chunk(a, u):
    """Associative scan of h_t = a_t * h_{t-1} + u_t within axis 1."""

    def combine(x, y):
        a1, u1 = x
        a2, u2 = y
        return a1 * a2, a2 * u1 + u2

    return jax.lax.associative_scan(combine, (a, u), axis=1)


def ssm_prefill(p, x, cfg: ModelConfig, h0=None, conv_state=None, chunk: int = 128):
    """Returns (y (B,S,d), (h_last (B,d_in,N), conv_state))."""
    b, s, d = x.shape
    n = cfg.ssm_state
    xc, z, dt, b_mat, c_mat, conv_state = _ssm_inputs(p, x, cfg, conv_state)
    a_cont = -jnp.exp(p["a_log"])  # (d_in, N)
    if h0 is None:
        h0 = jnp.zeros((b, xc.shape[-1], n), jnp.float32)

    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        xc_p = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_p = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_p = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    else:
        xc_p, dt_p, b_p, c_p = xc, dt, b_mat, c_mat
    nc = (s + pad) // c
    resh = lambda t: jnp.moveaxis(t.reshape(b, nc, c, t.shape[-1]), 1, 0)
    xcs, dts, bs, cs = resh(xc_p), resh(dt_p), resh(b_p), resh(c_p)

    def body(h_prev, inp):
        xci, dti, bi, ci = inp  # (B,c,d_in)/(B,c,N)
        a = jnp.exp(dti[..., None] * a_cont)  # (B,c,d_in,N)
        u = (dti * xci)[..., None] * bi[:, :, None, :]  # (B,c,d_in,N)
        a_s, u_s = _scan_chunk(a, u)
        h_all = a_s * h_prev[:, None] + u_s  # (B,c,d_in,N)
        y = jnp.einsum("bcdn,bcn->bcd", h_all, ci)
        return h_all[:, -1], y

    # checkpoint: keeps backward from saving (B, c, d_in, N) per chunk
    body = jax.checkpoint(body)
    h_last, ys = jax.lax.scan(body, h0, (xcs, dts, bs, cs))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * c, -1)[:, :s]
    y = y + p["d_skip"] * xc
    y = y * jax.nn.silu(z)
    out = y @ p["w_out"].astype(jnp.float32)
    return out.astype(x.dtype), (h_last, conv_state)


def ssm_decode(p, x, cfg: ModelConfig, h_prev, conv_state):
    """x: (B,1,d); h_prev: (B,d_in,N); conv_state: (B,ssm_conv-1,d_in)."""
    xc, z, dt, b_mat, c_mat, conv_state = _ssm_inputs(p, x, cfg, conv_state)
    a_cont = -jnp.exp(p["a_log"])
    a = jnp.exp(dt[:, 0, :, None] * a_cont)  # (B,d_in,N)
    u = (dt[:, 0] * xc[:, 0])[..., None] * b_mat[:, 0, None, :]
    h_new = a * h_prev + u
    y = jnp.einsum("bdn,bn->bd", h_new, c_mat[:, 0])[:, None, :]
    y = y + p["d_skip"] * xc
    y = y * jax.nn.silu(z)
    out = y @ p["w_out"].astype(jnp.float32)
    return out.astype(x.dtype), (h_new, conv_state)
