"""Uniform model API: family -> module functions used by train/serve/dryrun."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from repro.configs.base import ModelConfig
from repro.models import encdec, hymba, transformer, xlstm

_FAMILIES = {
    "transformer": transformer,
    "xlstm": xlstm,
    "hymba": hymba,
    "encdec": encdec,
}


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    init: Callable
    loss_fn: Callable
    forward_prefill: Callable
    decode_step: Callable
    init_cache: Optional[Callable]
    module: Any


def get_model(cfg: ModelConfig) -> ModelAPI:
    mod = _FAMILIES[cfg.family]
    return ModelAPI(
        init=mod.init,
        loss_fn=mod.loss_fn,
        forward_prefill=mod.forward_prefill,
        decode_step=mod.decode_step,
        init_cache=getattr(mod, "init_cache", None),
        module=mod,
    )
