"""xLSTM (arXiv:2405.04517): mLSTM + sLSTM blocks at a 7:1 ratio.

Attention-free — the PD-Swap *attention* RMs don't apply, but the
prefill/decode asymmetry does and maps onto the same phase-engine machinery
(DESIGN.md §4):

* prefill RM  = **chunkwise-parallel** mLSTM (matrix-memory linear recurrence
  evaluated block-parallel within chunks, sequential across chunks — the
  compute-bound form), sLSTM via sequential scan.
* decode RM   = **O(1) recurrent state update** per token (the
  bandwidth-bound form: state + weights streaming, no KV cache at all).

The chunkwise and recurrent forms are the same math; tests/test_xlstm.py
asserts step-by-step decode equals chunkwise prefill to fp tolerance.

Layer grouping for scan: layers come in groups of ``slstm_every`` =
(slstm_every-1) mLSTM + 1 sLSTM, so the group is the scanned unit and both
param stacks stay uniform.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.norm import apply_norm, norm_init
from repro.layers.sharding import NULL_CTX, PartitionCtx


class MLSTMState(NamedTuple):
    c: jax.Array  # (B, H, dk, dv) matrix memory
    n: jax.Array  # (B, H, dk) normalizer
    m: jax.Array  # (B, H) stabilizer


class SLSTMState(NamedTuple):
    c: jax.Array  # (B, H, hd)
    n: jax.Array  # (B, H, hd)
    h: jax.Array  # (B, H, hd)
    m: jax.Array  # (B, H, hd)


class XLSTMCache(NamedTuple):
    """Grouped states: leaves have leading dim = n_groups (scan axis)."""

    mlstm: MLSTMState  # (G, n_m, B, H, dk, dv) etc.
    slstm: SLSTMState  # (G, B, H, hd)


# ---------------------------------------------------------------- mLSTM ----


def _mlstm_init(cfg: ModelConfig, key, dtype):
    d, h = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 5)
    s = 1.0 / d**0.5
    mk = lambda k, shape: (jax.random.normal(k, shape, jnp.float32) * s).astype(dtype)
    return {
        "ln": norm_init("rmsnorm", d),
        "w_qkv": mk(ks[0], (d, 3 * d)),
        "w_if": mk(ks[1], (d, 2 * h)),
        "w_og": mk(ks[2], (d, d)),
        "w_out": mk(ks[3], (d, d)),
        "hnorm": norm_init("rmsnorm", d),
    }


def _mlstm_chunk(q, k, v, it, ft, state: MLSTMState):
    """One chunk, batch-parallel.  q/k/v: (B,H,c,hd); it/ft: (B,H,c).

    [§Perf iteration X1] q/k/v arrive bf16 and are upcast HERE, on the
    (B,H,c,hd) chunk — materializing f32 only at chunk granularity keeps the
    (B,S,d)-sized streams bf16 (the memory term of the prefill program
    halves); gate/stabilizer math stays f32 throughout.
    """
    q, k, v = (t.astype(jnp.float32) for t in (q, k, v))
    b, h, c, dk = q.shape
    f_cum = jnp.cumsum(ft, axis=-1)  # F_t
    a = f_cum + state.m[..., None]  # (B,H,c) init-state branch
    # D[t,s] = F_t - F_s + i_s for s<=t
    dmat = f_cum[..., :, None] - f_cum[..., None, :] + it[..., None, :]
    tri = jnp.tril(jnp.ones((c, c), bool))
    dmat = jnp.where(tri, dmat, -jnp.inf)
    m_t = jnp.maximum(a, jnp.max(dmat, axis=-1))  # (B,H,c)
    init_w = jnp.exp(a - m_t)  # (B,H,c)
    inner_w = jnp.exp(dmat - m_t[..., None])  # (B,H,c,c)

    qk = jnp.einsum("bhtd,bhsd->bhts", q, k)  # (B,H,c,c)
    num = init_w[..., None] * jnp.einsum("bhtd,bhdv->bhtv", q, state.c) + jnp.einsum(
        "bhts,bhts,bhsv->bhtv", inner_w, qk, v
    )
    den = init_w * jnp.einsum("bhtd,bhd->bht", q, state.n) + jnp.einsum(
        "bhts,bhts->bht", inner_w, qk
    )
    h_out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

    # end-of-chunk state
    f_tot = f_cum[..., -1]  # (B,H)
    m_new = jnp.maximum(f_tot + state.m, jnp.max(f_tot[..., None] - f_cum + it, axis=-1))
    w_init = jnp.exp(f_tot + state.m - m_new)  # (B,H)
    w_s = jnp.exp(f_tot[..., None] - f_cum + it - m_new[..., None])  # (B,H,c)
    c_new = w_init[..., None, None] * state.c + jnp.einsum("bhs,bhsd,bhsv->bhdv", w_s, k, v)
    n_new = w_init[..., None] * state.n + jnp.einsum("bhs,bhsd->bhd", w_s, k)
    return h_out, MLSTMState(c_new, n_new, m_new)


def _mlstm_step(q, k, v, it, ft, state: MLSTMState):
    """Single-token recurrent update.  q/k/v: (B,H,hd); it/ft: (B,H)."""
    m_new = jnp.maximum(ft + state.m, it)
    w_f = jnp.exp(ft + state.m - m_new)[..., None]
    w_i = jnp.exp(it - m_new)[..., None]
    c_new = w_f[..., None] * state.c + w_i[..., None] * (k[..., :, None] * v[..., None, :])
    n_new = w_f * state.n + w_i * k
    num = jnp.einsum("bhd,bhdv->bhv", q, c_new)
    den = jnp.einsum("bhd,bhd->bh", q, n_new)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h, MLSTMState(c_new, n_new, m_new)


def _mlstm_project(p, x, cfg):
    """[§Perf iteration X1] Projections run in the weight dtype (bf16) with
    f32 accumulation — (B,S,d)-sized q/k/v/og streams stay bf16; only the
    (B,H,S) gate pre-activations (d/hd-times smaller) are f32."""
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.d_model // cfg.num_heads
    xn = apply_norm(p["ln"], x, "rmsnorm", cfg.norm_eps).astype(p["w_qkv"].dtype)
    qkv = xn @ p["w_qkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    shp = (b, s, h, hd)
    q = q.reshape(shp).transpose(0, 2, 1, 3)
    k = (k / hd**0.5).reshape(shp).transpose(0, 2, 1, 3)
    v = v.reshape(shp).transpose(0, 2, 1, 3)
    gates = (xn @ p["w_if"]).astype(jnp.float32)  # (B,S,2H) — small, f32 math
    it = gates[..., :h].transpose(0, 2, 1)  # (B,H,S) input gate (exp)
    ft = jax.nn.log_sigmoid(gates[..., h:]).transpose(0, 2, 1)  # log f in (-inf,0)
    og = jax.nn.sigmoid((xn @ p["w_og"]).astype(jnp.float32)).astype(xn.dtype)  # (B,S,d)
    return q, k, v, it, ft, og, xn


def _mlstm_finish(p, x, h_seq, og, cfg):
    """h_seq: (B,H,S,hd) -> residual output (bf16 streams, f32 accum)."""
    b, _, s, _ = h_seq.shape
    h_flat = h_seq.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
    h_flat = apply_norm(p["hnorm"], h_flat.astype(x.dtype), "rmsnorm", cfg.norm_eps)
    out = (og.astype(h_flat.dtype) * h_flat) @ p["w_out"]
    return x + out.astype(x.dtype)


def mlstm_prefill(p, x, state: MLSTMState, cfg: ModelConfig, chunk: int = 64):
    b, s, d = x.shape
    q, k, v, it, ft, og, _ = _mlstm_project(p, x, cfg)
    if cfg.attn_impl == "stub":
        # Kernel-substituted lowering: the chunkwise recurrence core is the
        # Pallas mlstm kernel (kernels/costs.mlstm_chunk_cost); projections
        # and the output path stay real.  [§Perf X2]
        return _mlstm_finish(p, x, q, og, cfg), state
    c = min(chunk, s)
    pad = (-s) % c
    if pad:  # pad with f=0(log f=-inf would kill state; use f=1 -> log 0? pad i with -inf so padded steps are no-ops)
        q, k, v = (jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0))) for t in (q, k, v))
        it = jnp.pad(it, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
        ft = jnp.pad(ft, ((0, 0), (0, 0), (0, pad)))
    nc = (s + pad) // c
    resh = lambda t: jnp.moveaxis(t.reshape(b, cfg.num_heads, nc, c, -1), 2, 0)
    qs, ks, vs = resh(q), resh(k), resh(v)
    its = jnp.moveaxis(it.reshape(b, cfg.num_heads, nc, c), 2, 0)
    fts = jnp.moveaxis(ft.reshape(b, cfg.num_heads, nc, c), 2, 0)

    def body(st, inp):
        qc, kc, vc, ic, fc = inp
        h_out, st = _mlstm_chunk(qc, kc, vc, ic, fc, st)
        return st, h_out.astype(x.dtype)  # stack the output stream in bf16

    body = jax.checkpoint(body)
    state, hs = jax.lax.scan(body, state, (qs, ks, vs, its, fts))
    h_seq = jnp.moveaxis(hs, 0, 2).reshape(b, cfg.num_heads, nc * c, -1)[:, :, :s]
    return _mlstm_finish(p, x, h_seq, og, cfg), state


def mlstm_decode(p, x, state: MLSTMState, cfg: ModelConfig):
    q, k, v, it, ft, og, _ = _mlstm_project(p, x, cfg)  # S=1
    h, state = _mlstm_step(q[:, :, 0], k[:, :, 0], v[:, :, 0], it[:, :, 0], ft[:, :, 0], state)
    return _mlstm_finish(p, x, h[:, :, None, :], og, cfg), state


# ---------------------------------------------------------------- sLSTM ----


def _slstm_init(cfg: ModelConfig, key, dtype):
    d, h = cfg.d_model, cfg.num_heads
    hd = d // h
    ks = jax.random.split(key, 3)
    s = 1.0 / d**0.5
    return {
        "ln": norm_init("rmsnorm", d),
        "w": (jax.random.normal(ks[0], (d, 4 * d), jnp.float32) * s).astype(dtype),
        "r": (jax.random.normal(ks[1], (h, hd, 4 * hd), jnp.float32) * (1.0 / hd**0.5)).astype(dtype),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "w_out": (jax.random.normal(ks[2], (d, d), jnp.float32) * s).astype(dtype),
        "hnorm": norm_init("rmsnorm", d),
    }


def _slstm_step(p, wx_t, state: SLSTMState, cfg: ModelConfig):
    """wx_t: precomputed W x_t (B, 4d).  Recurrent R h_{t-1} added here."""
    b = wx_t.shape[0]
    h_, hd = cfg.num_heads, cfg.d_model // cfg.num_heads
    rh = jnp.einsum("bhd,hde->bhe", state.h.astype(jnp.float32), p["r"].astype(jnp.float32))
    pre = wx_t.reshape(b, h_, 4 * hd) + rh + p["b"].reshape(h_, 4 * hd)
    zt, it, ft, ot = jnp.split(pre, 4, axis=-1)  # (B,H,hd) each
    z = jnp.tanh(zt)
    o = jax.nn.sigmoid(ot)
    ft = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(ft + state.m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(ft + state.m - m_new)
    c_new = f_p * state.c + i_p * z
    n_new = f_p * state.n + i_p
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMState(c_new, n_new, h_new, m_new)


def slstm_forward(p, x, state: SLSTMState, cfg: ModelConfig):
    """Sequential over S (sLSTM has no parallel form — by design).

    [§Perf iteration X1] The (B,S,4d) pre-activation stream and the stacked
    h outputs stay bf16; the per-step gate/state math upcasts the (B,4d)
    step slice to f32 inside the scan body."""
    b, s, d = x.shape
    xn = apply_norm(p["ln"], x, "rmsnorm", cfg.norm_eps).astype(p["w"].dtype)
    wx = xn @ p["w"]  # (B,S,4d) bf16 stream
    if cfg.attn_impl == "stub":
        # sLSTM recurrence core as a Pallas kernel (slstm_scan_cost) [§Perf X2]
        # (wx sliced so the W projection — real, non-kernel work — stays live)
        h_seq = apply_norm(p["hnorm"], wx[..., :d].astype(x.dtype), "rmsnorm", cfg.norm_eps)
        out = h_seq @ p["w_out"].astype(h_seq.dtype)
        return x + out.astype(x.dtype), state

    def body(st, wx_t):
        st = _slstm_step(p, wx_t.astype(jnp.float32), st, cfg)
        return st, st.h.astype(x.dtype)

    state, hs = jax.lax.scan(body, state, jnp.moveaxis(wx, 1, 0))
    h_seq = jnp.moveaxis(hs, 0, 1).reshape(b, s, d)
    h_seq = apply_norm(p["hnorm"], h_seq.astype(x.dtype), "rmsnorm", cfg.norm_eps)
    out = h_seq @ p["w_out"].astype(h_seq.dtype)
    return x + out.astype(x.dtype), state


# ---------------------------------------------------------------- model ----


def _group_counts(cfg: ModelConfig) -> Tuple[int, int]:
    g = cfg.slstm_every
    assert cfg.num_layers % g == 0, (cfg.num_layers, g)
    return cfg.num_layers // g, g - 1  # (n_groups, mlstm per group)


def init(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> dict:
    ng, nm = _group_counts(cfg)
    vp = cfg.padded_vocab()
    ke, km, ks, kh = jax.random.split(key, 4)

    def group_init(k):
        k1, k2 = jax.random.split(k)
        return {
            "mlstm": jax.vmap(lambda kk: _mlstm_init(cfg, kk, dtype))(jax.random.split(k1, nm)),
            "slstm": _slstm_init(cfg, k2, dtype),
        }

    return {
        "emb": (jax.random.normal(ke, (vp, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
        "groups": jax.vmap(group_init)(jax.random.split(km, ng)),
        "ln_f": norm_init("rmsnorm", cfg.d_model),
        "lm_head": (jax.random.normal(kh, (cfg.d_model, vp), jnp.float32) * 0.02).astype(dtype),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0, dtype=jnp.float32) -> XLSTMCache:
    ng, nm = _group_counts(cfg)
    h = cfg.num_heads
    hd = cfg.d_model // h
    m = MLSTMState(
        c=jnp.zeros((ng, nm, batch, h, hd, hd), dtype),
        n=jnp.zeros((ng, nm, batch, h, hd), dtype),
        m=jnp.full((ng, nm, batch, h), -1e30, dtype),
    )
    s = SLSTMState(
        c=jnp.zeros((ng, batch, h, hd), dtype),
        n=jnp.zeros((ng, batch, h, hd), dtype),
        h=jnp.zeros((ng, batch, h, hd), dtype),
        m=jnp.full((ng, batch, h, hd), -1e30, dtype),
    )
    return XLSTMCache(m, s)


def _forward(params, tokens, cfg, pctx, cache: XLSTMCache, *, mode: str, last_only: bool = False):
    b, s = tokens.shape
    x = params["emb"][tokens]
    x = pctx.shard(x, "batch", "seq", "embed")

    def group_body(x, scanned):
        gp, mstate, sstate = scanned

        def m_body(x, inner):
            mp, mst = inner
            if mode == "decode":
                x, mst = mlstm_decode(mp, x, mst, cfg)
            else:
                x, mst = mlstm_prefill(mp, x, mst, cfg)
            return x, mst

        x, new_m = jax.lax.scan(m_body, x, (gp["mlstm"], mstate))
        if mode == "decode":
            new_s = _slstm_decode_block(gp["slstm"], x, sstate, cfg)
            x, new_s = new_s
        else:
            x, new_s = slstm_forward(gp["slstm"], x, sstate, cfg)
        return x, (new_m, new_s)

    if cfg.remat != "none" and mode == "train":
        from repro.models.transformer import _remat

        group_body = _remat(group_body, cfg)
    x, (new_m, new_s) = jax.lax.scan(group_body, x, (params["groups"], cache.mlstm, cache.slstm))
    x = apply_norm(params["ln_f"], x, "rmsnorm", cfg.norm_eps)
    if last_only:
        x = x[:, -1:, :]
    logits = x.astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
    return pctx.shard(logits, "batch", "seq", "vocab"), XLSTMCache(new_m, new_s)


def forward_hidden(params, tokens, cfg, pctx: PartitionCtx = NULL_CTX):
    """Final normed hidden states (B,S,d) for the chunked loss."""
    b, s = tokens.shape
    x = params["emb"][tokens]
    x = pctx.shard(x, "batch", "seq", "embed")
    cache = init_cache(cfg, b)

    def group_body(x, scanned):
        gp, mstate, sstate = scanned

        def m_body(x, inner):
            mp, mst = inner
            x, mst = mlstm_prefill(mp, x, mst, cfg)
            return x, mst

        x, _ = jax.lax.scan(m_body, x, (gp["mlstm"], mstate))
        x, _ = slstm_forward(gp["slstm"], x, sstate, cfg)
        return x, None

    if cfg.remat != "none":
        from repro.models.transformer import _remat

        group_body = _remat(group_body, cfg)
    x, _ = jax.lax.scan(group_body, x, (params["groups"], cache.mlstm, cache.slstm))
    return apply_norm(params["ln_f"], x, "rmsnorm", cfg.norm_eps)


def _slstm_decode_block(p, x, state, cfg):
    return slstm_forward(p, x, state, cfg)


def forward_train(params, tokens, cfg, pctx: PartitionCtx = NULL_CTX):
    logits, _ = _forward(params, tokens, cfg, pctx, init_cache(cfg, tokens.shape[0]), mode="train")
    return logits, jnp.float32(0)


def loss_fn(params, batch, cfg, pctx: PartitionCtx = NULL_CTX, aux_weight: float = 0.0):
    from repro.train.losses import chunked_ce_loss

    x = forward_hidden(params, batch["tokens"], cfg, pctx)
    loss = chunked_ce_loss(x, params["lm_head"], batch["targets"], batch["mask"], pctx)
    return loss, {"nll": loss, "aux": jnp.float32(0)}


def forward_prefill(params, tokens, cfg, pctx: PartitionCtx = NULL_CTX):
    cache = init_cache(cfg, tokens.shape[0])
    logits, cache = _forward(params, tokens, cfg, pctx, cache, mode="prefill", last_only=True)
    return logits[:, -1, :], cache


def decode_step(params, token, cache: XLSTMCache, lengths, cfg, pctx: PartitionCtx = NULL_CTX):
    logits, cache = _forward(params, token[:, None], cfg, pctx, cache, mode="decode")
    return logits[:, 0, :], cache
