"""Lock-discipline lint: annotation-driven checking of shared mutable state.

The serving runtime is genuinely concurrent — the asyncio event loop, the
``engine-step`` executor thread, the ``prefill-pool`` dispatch thread, the
checkpoint writer — and its safety argument lives in docstrings.  This pass
makes the argument machine-checked.  The grammar (all trailing comments):

* ``self.attr = ...  # guarded-by: self._lock`` — every access to ``attr``
  (outside ``__init__``) must sit inside ``with self._lock:``;
* ``self.attr = ...  # owned-by: <role>`` — every access must occur inside
  a function annotated ``def f(...):  # thread: <role>`` (roles are logical
  threads: ``event-loop``, ``engine-step``, ``prefill-pool``, ...);
* ``def f(...):  # thread: <role>`` — declares the function an entry point
  of ``<role>``; nested functions inherit unless they declare their own;
* ``# analysis: bind(var=ClassName)`` (module level) — attribute accesses
  through a variable named ``var`` are checked against ``ClassName``'s
  annotations (cross-object discipline, e.g. the decode pool writing the
  prefill pool's chunk-prefix mirror);
* ``# analysis: shared-global(NAME)`` (module level) — ``NAME`` is a
  process-wide singleton: rebinding it from function scope (or storing to
  ``<module>.NAME``) is flagged.

``__init__`` bodies are exempt (the object is not yet shared during
construction).  Waive individual accesses — or a whole function, with the
pragma on its ``def`` line — via ``# analysis: allow(lock:...) — reason``.

Known limitation, by design: the pass checks *attribute accesses*, not
call graphs.  A ``# thread:`` annotation asserts where the function runs;
callers are trusted to honor it (the assertion is the documentation the
next reader needs, and the accesses inside are then verified against it).
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.common import AnalyzedFile, Finding, iter_python_files

PASS = "lock"

GUARD_RE = re.compile(r"#\s*guarded-by:\s*([^\s#]+)")
OWNER_RE = re.compile(r"#\s*owned-by:\s*([^\s#]+)")
THREAD_RE = re.compile(r"#\s*thread:\s*([^\s#]+)")
BIND_RE = re.compile(r"#\s*analysis:\s*bind\(([^)]*)\)")
SHARED_RE = re.compile(r"#\s*analysis:\s*shared-global\((\w+)\)")

# Files the lint is applied to on the real tree (annotation coverage is
# opt-in per attribute, so running wider is safe — this is the documented
# concurrency surface).
DEFAULT_SUBSET = (
    "serving/async_engine.py",
    "serving/disagg/prefill_pool.py",
    "serving/disagg/handoff.py",
    "serving/disagg/decode_pool.py",
    "obs/trace.py",
    "obs/metrics.py",
    "checkpoint/manager.py",
)

# attr -> ("guard", lock_expr) | ("owner", role)
ClassAnnotations = Dict[str, Tuple[str, str]]


def _def_header_lines(af: AnalyzedFile, node: ast.AST) -> range:
    """Line range of a def's header (``def`` line through the line before
    the first body statement) — where a ``# thread:`` comment may sit."""
    body_start = node.body[0].lineno if getattr(node, "body", None) else node.lineno + 1
    return range(node.lineno, body_start)


def _thread_of(af: AnalyzedFile, node: ast.AST) -> Optional[str]:
    for ln in _def_header_lines(af, node):
        m = THREAD_RE.search(af.line(ln))
        if m:
            return m.group(1)
    return None


def collect_annotations(files: Sequence[AnalyzedFile]) -> Dict[str, ClassAnnotations]:
    """Phase 1: per-class attribute annotations, merged across files."""
    registry: Dict[str, ClassAnnotations] = {}
    for af in files:
        for cls in [n for n in ast.walk(af.tree) if isinstance(n, ast.ClassDef)]:
            anns: ClassAnnotations = registry.setdefault(cls.name, {})
            for node in ast.walk(cls):
                targets: List[ast.expr] = []
                if isinstance(node, ast.Assign):
                    targets = node.targets
                elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                    targets = [node.target]
                else:
                    continue
                for t in targets:
                    if not (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        continue
                    line = af.line(node.lineno)
                    g = GUARD_RE.search(line)
                    o = OWNER_RE.search(line)
                    if g:
                        anns[t.attr] = ("guard", g.group(1))
                    elif o:
                        anns[t.attr] = ("owner", o.group(1))
    return registry


def _binds(af: AnalyzedFile) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for line in af.lines:
        m = BIND_RE.search(line)
        if not m:
            continue
        for part in m.group(1).split(","):
            if "=" in part:
                var, cls = part.split("=", 1)
                out[var.strip()] = cls.strip()
    return out


def _shared_globals(af: AnalyzedFile) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for i, line in enumerate(af.lines, start=1):
        m = SHARED_RE.search(line)
        if m:
            out[m.group(1)] = i
    return out


def _required_lock(guard: str, receiver_src: str) -> str:
    """Rewrite a guard expression declared against ``self`` for the actual
    receiver: guard ``self._lock`` accessed through ``pool`` must hold
    ``pool._lock``."""
    if guard.startswith("self.") and receiver_src != "self":
        return receiver_src + guard[len("self"):]
    return guard


class _Checker:
    def __init__(self, af: AnalyzedFile, registry: Dict[str, ClassAnnotations],
                 binds: Dict[str, str], findings: List[Finding]):
        self.af = af
        self.registry = registry
        self.binds = binds
        self.findings = findings
        self.locks: List[str] = []  # unparsed exprs of held `with` contexts
        self.thread: Optional[str] = None
        self.cls: Optional[str] = None
        self.def_lines: List[int] = []
        self.func: str = "<module>"

    # -------------------------------------------------------------- drive --

    def check_module(self) -> None:
        for node in self.af.tree.body:
            self._visit(node)

    def _visit(self, node: ast.AST) -> None:
        if isinstance(node, ast.ClassDef):
            prev_cls, self.cls = self.cls, node.name
            for child in node.body:
                self._visit(child)
            self.cls = prev_cls
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.name == "__init__" and self.cls is not None:
                return  # construction: the object is not shared yet
            prev_thread = self.thread
            declared = _thread_of(self.af, node)
            if declared is not None:
                self.thread = declared
            self.def_lines.append(node.lineno)
            prev_func, self.func = self.func, node.name
            for child in node.body:
                self._visit(child)
            self.func = prev_func
            self.def_lines.pop()
            self.thread = prev_thread
            return
        if isinstance(node, (ast.With, ast.AsyncWith)):
            added = []
            for item in node.items:
                try:
                    added.append(ast.unparse(item.context_expr))
                except Exception:  # pragma: no cover - unparse is total on 3.9+
                    pass
            self.locks.extend(added)
            for child in node.body:
                self._visit(child)
            del self.locks[len(self.locks) - len(added):]
            return
        if isinstance(node, ast.Attribute):
            self._check_attribute(node)
        for child in ast.iter_child_nodes(node):
            self._visit(child)

    # -------------------------------------------------------------- check --

    def _receiver(self, node: ast.Attribute) -> Optional[Tuple[str, str]]:
        """(class name, receiver source) for a checkable attribute access."""
        v = node.value
        if isinstance(v, ast.Name):
            if v.id == "self" and self.cls is not None:
                return self.cls, "self"
            if v.id in self.binds:
                return self.binds[v.id], v.id
        if isinstance(v, ast.Attribute) and v.attr in self.binds:
            try:
                return self.binds[v.attr], ast.unparse(v)
            except Exception:  # pragma: no cover
                return None
        return None

    def _check_attribute(self, node: ast.Attribute) -> None:
        recv = self._receiver(node)
        if recv is None:
            return
        cls_name, recv_src = recv
        anns = self.registry.get(cls_name, {})
        ann = anns.get(node.attr)
        if ann is None:
            return
        kind, arg = ann
        if kind == "guard":
            required = _required_lock(arg, recv_src)
            if required in self.locks:
                return
            rule = "lock:unguarded"
            msg = (f"{cls_name}.{node.attr} is guarded-by {arg} but "
                   f"{self.func} accesses it without holding {required}")
        else:
            if self.thread == arg:
                return
            rule = "lock:thread"
            held = self.thread or "an unannotated context"
            msg = (f"{cls_name}.{node.attr} is owned-by {arg} but "
                   f"{self.func} (running on {held}) accesses it — annotate "
                   f"the entry point '# thread: {arg}' or fix the handoff")
        if self.af.waived(rule, node.lineno, self.def_lines):
            return
        scope = f"{self.cls}.{self.func}" if self.cls else self.func
        self.findings.append(
            Finding(PASS, rule, self.af.rel, node.lineno, msg, scope=scope))


def _check_shared_globals(files: Sequence[AnalyzedFile],
                          findings: List[Finding]) -> None:
    declared: Dict[str, str] = {}  # name -> declaring file
    for af in files:
        for name in _shared_globals(af):
            declared[name] = af.rel
    if not declared:
        return
    rule = "lock:global-rebind"
    for af in files:
        for node in ast.walk(af.tree):
            if not isinstance(node, (ast.Assign, ast.AugAssign)):
                continue
            targets = node.targets if isinstance(node, ast.Assign) else [node.target]
            for t in targets:
                name = None
                if isinstance(t, ast.Attribute) and t.attr in declared:
                    name = t.attr  # e.g. trace.TRACER = ...
                if name is None:
                    continue
                if af.waived(rule, node.lineno):
                    continue
                findings.append(Finding(
                    PASS, rule, af.rel, node.lineno,
                    f"rebinding shared global {name} (declared in "
                    f"{declared[name]}) — instrumentation sites hold direct "
                    f"references; rebinding silently splits the singleton"))
    for af in files:
        shared_here = _shared_globals(af)
        if not shared_here:
            continue
        for fn in [n for n in ast.walk(af.tree)
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
            global_names = {
                n for node in ast.walk(fn)
                if isinstance(node, ast.Global) for n in node.names}
            hot = global_names & set(shared_here)
            if not hot:
                continue
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign):
                    for t in node.targets:
                        if isinstance(t, ast.Name) and t.id in hot:
                            if not af.waived(rule, node.lineno):
                                findings.append(Finding(
                                    PASS, rule, af.rel, node.lineno,
                                    f"function-scope rebind of shared global "
                                    f"{t.id} via 'global'", scope=fn.name))


def run(root: Path, subset: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the lock-discipline pass over ``root`` (``src/repro`` on the real
    tree).  ``subset=None`` uses :data:`DEFAULT_SUBSET` when those paths
    exist, else every ``.py`` file (fixture trees)."""
    if subset is None:
        paths = iter_python_files(root, DEFAULT_SUBSET)
        if not paths:
            paths = iter_python_files(root)
    else:
        paths = iter_python_files(root, subset)
    files = [AnalyzedFile(p, root) for p in paths]
    findings: List[Finding] = []
    for af in files:
        findings.extend(af.pragma_findings)
    registry = collect_annotations(files)
    for af in files:
        _Checker(af, registry, _binds(af), findings).check_module()
    _check_shared_globals(files, findings)
    return findings
