"""In-repo static analysis: lock discipline, kernel invariants,
determinism, and the program-level auditor.

Run as ``python -m repro.analysis [--all | --pass NAME] [--baseline FILE]``.
See ``README.md`` ("Static analysis") for the annotation grammar and the
pragma/baseline workflow.  Programmatic use::

    from repro.analysis import run_passes
    findings = run_passes(["lock", "determinism"], root=Path("src/repro"))
"""
from __future__ import annotations

from pathlib import Path
from typing import Dict, List, Optional, Sequence

from repro.analysis.common import (  # noqa: F401  (public API)
    Finding, load_baseline, split_baselined)

PASSES = ("lock", "kernel", "determinism", "program")


def repo_root() -> Path:
    """The repository root (two levels above ``src/repro/analysis``)."""
    return Path(__file__).resolve().parents[3]


def default_root() -> Path:
    """The analyzed tree: ``src/repro``."""
    return Path(__file__).resolve().parents[1]


def default_baseline() -> Path:
    return repo_root() / "analysis_baseline.txt"


def run_passes(names: Sequence[str],
               root: Optional[Path] = None) -> Dict[str, List[Finding]]:
    """Run the named passes; returns ``{pass_name: [findings...]}`` with
    duplicate findings (same fingerprint + line) collapsed."""
    root = root or default_root()
    out: Dict[str, List[Finding]] = {}
    for name in names:
        if name == "lock":
            from repro.analysis import locklint
            found = locklint.run(root)
        elif name == "determinism":
            from repro.analysis import determinism
            found = determinism.run(root)
        elif name == "kernel":
            from repro.analysis import kernel_check
            found = kernel_check.run(root)
        elif name == "program":
            from repro.analysis import progcheck
            found = progcheck.run(root)
        else:
            raise ValueError(f"unknown pass {name!r}; choose from {PASSES}")
        seen = set()
        deduped = []
        for f in found:
            key = (f.fingerprint, f.line)
            if key not in seen:
                seen.add(key)
                deduped.append(f)
        out[name] = deduped
    return out
