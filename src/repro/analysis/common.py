"""Shared machinery for the in-repo static analysis passes.

Every pass produces :class:`Finding` records.  Three escape hatches exist,
in decreasing order of preference:

* **fix it** — the default;
* **pragma** — a trailing ``# analysis: allow(<rule>[,<rule>]) — <reason>``
  comment waives the named rules on that line (or, when the pragma is the
  whole line, on the next line; on a ``def`` line, for the entire function
  body).  The reason is mandatory: a pragma without one is itself a
  finding (``analysis:pragma-no-reason``);
* **baseline** — a checked-in file of fingerprints that grandfathers
  pre-existing findings.  Each line must carry a justification; baselines
  are for debt, pragmas are for audited intent.

Fingerprints hash (pass, rule, relative path, enclosing-def scope,
message) — not the line number — so unrelated edits above a finding do not
churn the baseline, while two identical-message findings in different
functions of one file stay distinct.  Baselines written before the scope
field existed still load: the pre-scope formula is kept as
``Finding.legacy_fingerprint`` and matched second, with a rewrite hint
(``legacy_hints``) so the file can be migrated without churning CI.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import re
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

PRAGMA_RE = re.compile(
    r"#\s*analysis:\s*allow\(\s*([^)]*?)\s*\)\s*(?:[—–]|--|-)?\s*(.*)$")


@dataclasses.dataclass(frozen=True)
class Finding:
    pass_name: str  # "lock" | "determinism" | "kernel" | "program" | "analysis"
    rule: str  # e.g. "lock:unguarded", "det:wallclock"
    path: str  # path as reported (relative to the analysis root)
    line: int  # 1-indexed
    message: str
    scope: str = ""  # enclosing def qualname (or program/case label)

    @property
    def fingerprint(self) -> str:
        raw = (f"{self.pass_name}|{self.rule}|{self.path}|{self.scope}|"
               f"{self.message}")
        return hashlib.sha1(raw.encode()).hexdigest()[:12]

    @property
    def legacy_fingerprint(self) -> str:
        """The pre-scope formula (pass, rule, path, message) — accepted on
        baseline load so existing files do not churn, but collision-prone:
        identical messages in two functions of one file hashed the same."""
        raw = f"{self.pass_name}|{self.rule}|{self.path}|{self.message}"
        return hashlib.sha1(raw.encode()).hexdigest()[:12]

    def render(self) -> str:
        where = f" ({self.scope})" if self.scope else ""
        return (f"{self.path}:{self.line}:{where} [{self.rule}] "
                f"{self.message}  [{self.fingerprint}]")


def parse_pragmas(
    text: str, rel: str,
) -> Tuple[Dict[int, Set[str]], Dict[int, Set[str]], List[Finding]]:
    """Extract ``# analysis: allow(...)`` pragmas from source text.

    Returns ``(line_waivers, def_waivers, findings)`` where
    ``line_waivers[lineno]`` is the set of waived rules effective on that
    line, ``def_waivers`` maps a ``def`` line's number to rules waived for
    the whole function body, and ``findings`` reports pragmas missing
    their mandatory reason.
    """
    line_waivers: Dict[int, Set[str]] = {}
    def_waivers: Dict[int, Set[str]] = {}
    findings: List[Finding] = []
    lines = text.splitlines()
    for i, line in enumerate(lines, start=1):
        m = PRAGMA_RE.search(line)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        reason = m.group(2).strip()
        if not reason:
            findings.append(Finding(
                "analysis", "analysis:pragma-no-reason", rel, i,
                "allow() pragma without a reason — every waiver must say why"))
            continue
        code = line[: m.start()].rstrip()
        if not code:
            # comment-only pragma: applies to the statement it precedes —
            # skip over the rest of the comment block to the first code line
            j = i
            while j < len(lines) and (not lines[j].strip()
                                      or lines[j].lstrip().startswith("#")):
                j += 1
            target = j + 1
            line_waivers.setdefault(target, set()).update(rules)
            if j < len(lines) and re.match(r"\s*(async\s+)?def\b", lines[j]):
                def_waivers.setdefault(target, set()).update(rules)
        elif re.match(r"\s*(async\s+)?def\b", code):
            def_waivers.setdefault(i, set()).update(rules)
        line_waivers.setdefault(i, set()).update(rules)
    return line_waivers, def_waivers, findings


class AnalyzedFile:
    """One parsed source file plus its pragma maps."""

    def __init__(self, path: Path, root: Path):
        self.path = path
        self.rel = path.relative_to(root).as_posix()
        self.text = path.read_text()
        self.tree = ast.parse(self.text, filename=str(path))
        self.lines = self.text.splitlines()
        (self.line_waivers, self.def_waivers,
         self.pragma_findings) = parse_pragmas(self.text, self.rel)

    def line(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def waived(self, rule: str, lineno: int,
               def_lines: Sequence[int] = ()) -> bool:
        """Is ``rule`` waived at ``lineno``?  ``def_lines`` are the ``def``
        line numbers of the enclosing function(s), checked for body-wide
        waivers."""
        rules = self.line_waivers.get(lineno, set())
        if rule in rules or "*" in rules:
            return True
        for dl in def_lines:
            drules = self.def_waivers.get(dl, set())
            if rule in drules or "*" in drules:
                return True
        return False


def iter_python_files(root: Path,
                      subset: Optional[Sequence[str]] = None) -> List[Path]:
    """Python files under ``root``; ``subset`` restricts to the given
    root-relative paths (silently skipping ones that do not exist, so a
    fixture tree need not mirror the whole layout)."""
    if subset is not None:
        return [root / s for s in subset if (root / s).exists()]
    return sorted(p for p in root.rglob("*.py")
                  if "__pycache__" not in p.parts)


# ------------------------------------------------------------------ baseline --

def load_baseline(path: Optional[Path]) -> Tuple[Set[str], List[str]]:
    """Read a baseline file: one ``<fingerprint> <pass:rule> <path> — reason``
    per line.  Returns ``(fingerprints, errors)``; a line without a reason
    is an error (the baseline must justify every entry)."""
    fps: Set[str] = set()
    errors: List[str] = []
    if path is None or not path.exists():
        return fps, errors
    for i, raw in enumerate(path.read_text().splitlines(), start=1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        parts = line.split(None, 1)
        fp = parts[0]
        rest = parts[1] if len(parts) > 1 else ""
        if not re.fullmatch(r"[0-9a-f]{12}", fp):
            errors.append(f"{path}:{i}: malformed fingerprint {fp!r}")
            continue
        if not re.search(r"(?:[—–]|--|-)\s*\S", rest):
            errors.append(
                f"{path}:{i}: baseline entry {fp} has no reason — every "
                f"grandfathered finding must say why it is not fixed")
            continue
        fps.add(fp)
    return fps, errors


def split_baselined(
    findings: Sequence[Finding], baseline: Set[str],
) -> Tuple[List[Finding], List[Finding]]:
    """Partition into (active, suppressed).  A finding is suppressed by its
    current fingerprint or — compatibility with baselines written before the
    scope field — by its :attr:`Finding.legacy_fingerprint`."""
    active, suppressed = [], []
    for f in findings:
        if f.fingerprint in baseline or f.legacy_fingerprint in baseline:
            suppressed.append(f)
        else:
            active.append(f)
    return active, suppressed


def legacy_hints(findings: Sequence[Finding], baseline: Set[str]) -> List[str]:
    """Rewrite hints for baseline entries that only matched via the
    pre-scope fingerprint formula — update them so collisions (identical
    messages in different functions) stop being silently co-waived."""
    hints = []
    for f in findings:
        if f.fingerprint not in baseline and f.legacy_fingerprint in baseline:
            hints.append(
                f"baseline entry {f.legacy_fingerprint} uses the pre-scope "
                f"fingerprint of {f.rule} at {f.path} — rewrite it to "
                f"{f.fingerprint} (scoped to {f.scope or '<module>'})")
    return hints
