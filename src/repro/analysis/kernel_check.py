"""Pallas kernel invariant checker: BlockSpecs, index maps, no fp KV in HBM.

The four kernel packages (decode / paged / prefill attention + tlmm) keep
three hand-maintained invariants that, until now, only review enforced:

1. **block divisibility** — every ``BlockSpec`` block shape divides the
   (already padded) operand dim it tiles: a non-dividing block silently
   reads OOB rows in interpret mode and corrupts tiles on hardware;
2. **index maps in bounds** — evaluated at every grid point (with the
   *concrete* scalar-prefetch operands — block tables included), each
   index map must produce block offsets inside the operand.  This is what
   actually pins the block-table walk: a table entry past the page pool,
   or a ``ti``-indexed map missing its clamp, fails here at the grid
   extremes;
3. **fp cache never exists in HBM** (PR 3) — the quantized variants'
   jaxprs must not allocate an fp32 intermediate as large as the
   dequantized KV cache: dequant happens per-tile in VMEM inside the
   kernel, never as a whole-cache materialization feeding it.

Mechanism: ``pl.pallas_call`` is monkeypatched to a recorder that captures
(grid, specs, operands) and returns zeros of ``out_shape``; each op entry
point is then invoked **unjitted** (``fn.__wrapped__``) across a
serving-bucket-style case grid, so the ops' own padding/clamping runs for
real while no kernel body ever executes.  Invariant 3 traces the entry
point with ``jax.make_jaxpr`` (recorder still active) and scans every
equation's output avals.

Kernel findings are waivable by baseline only — there is no meaningful
source line to hang a pragma on for a (case x grid-point) violation.
"""
from __future__ import annotations

import dataclasses
import itertools
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis.common import Finding

PASS = "kernel"

# Serving-bucket-style lengths (ModelRunner.bucket: quantum-aligned then
# geometric), deliberately including non-bucket raw lengths so the ops'
# partial-final-block padding paths (clamp bk, right-pad) are exercised.
BUCKET_LENGTHS = (8, 16, 48, 100, 128)

MAX_GRID_POINTS = 8192  # full enumeration bound; larger grids use corners


@dataclasses.dataclass
class KernelCase:
    """One concrete invocation of an op entry point."""
    label: str
    args: tuple
    kwargs: Dict[str, Any]
    # fp32-materialization threshold in ELEMENTS: the dequantized size of
    # one KV operand (K or V).  None disables invariant 3 for the case.
    fp_elems: Optional[int] = None


@dataclasses.dataclass
class _Captured:
    grid: Tuple[int, ...]
    in_specs: List[Any]
    out_specs: List[Any]
    nsp: int
    operand_shapes: List[Tuple[int, ...]]
    scalars: List[Any]  # concrete np arrays (or None when traced)


def _as_list(x) -> list:
    if x is None:
        return []
    return list(x) if isinstance(x, (list, tuple)) else [x]


def _recorder(captured: List[_Captured]):
    """A stand-in for ``pl.pallas_call`` that records and returns zeros."""
    import jax.numpy as jnp
    import numpy as np

    def fake_pallas_call(kernel, *, grid_spec=None, grid=None, in_specs=None,
                         out_specs=None, out_shape=None, **kw):
        if grid_spec is not None:
            nsp = int(getattr(grid_spec, "num_scalar_prefetch", 0) or 0)
            g = tuple(getattr(grid_spec, "grid", ()) or ())
            ins = _as_list(getattr(grid_spec, "in_specs", None))
            outs = _as_list(getattr(grid_spec, "out_specs", None))
        else:
            nsp = 0
            g = tuple(grid) if grid else ()
            ins = _as_list(in_specs)
            outs = _as_list(out_specs)
        shapes = _as_list(out_shape)

        def runner(*operands):
            scalars: List[Any] = []
            for x in operands[:nsp]:
                try:
                    scalars.append(np.asarray(x))
                except Exception:  # traced under make_jaxpr: no concrete value
                    scalars.append(None)
            captured.append(_Captured(
                grid=g, in_specs=ins, out_specs=outs, nsp=nsp,
                operand_shapes=[tuple(x.shape) for x in operands],
                scalars=scalars))
            res = [jnp.zeros(s.shape, s.dtype) for s in shapes]
            return res if isinstance(out_shape, (list, tuple)) else res[0]

        return runner

    return fake_pallas_call


def _grid_points(grid: Tuple[int, ...]):
    total = 1
    for g in grid:
        total *= max(int(g), 1)
    if total <= MAX_GRID_POINTS:
        return itertools.product(*(range(int(g)) for g in grid))
    # corners only: every combination of first/last per dimension
    return itertools.product(*(sorted({0, int(g) - 1}) for g in grid))


def _block_shape(spec) -> Optional[Tuple]:
    return getattr(spec, "block_shape", None)


def _index_map(spec) -> Optional[Callable]:
    return getattr(spec, "index_map", None)


def _check_captured(cap: _Captured, where: Tuple[str, int], label: str,
                    findings: List[Finding]) -> None:
    rel, line = where
    specs = list(cap.in_specs) + list(cap.out_specs)
    # operand order at call time: [scalar-prefetch...] + block operands;
    # in_specs describe the block operands only
    shapes = list(cap.operand_shapes[cap.nsp:])
    # out shapes are not operands; reconstruct bounds from the specs'
    # index maps against the in-shapes we do have, and from block shapes
    # for outs we only check divisibility against themselves at map time.
    n_in = len(cap.in_specs)
    for si, spec in enumerate(specs):
        block = _block_shape(spec)
        if block is None:
            continue
        operand_shape = shapes[si] if si < len(shapes) else None
        if si < n_in and operand_shape is not None:
            if len(block) != len(operand_shape):
                findings.append(Finding(
                    PASS, "kernel:block-rank", rel, line,
                    f"{label}: in_spec[{si}] block rank {len(block)} != "
                    f"operand rank {len(operand_shape)}"))
                continue
            for d, b in enumerate(block):
                if b is None:
                    continue
                if operand_shape[d] % int(b) != 0:
                    findings.append(Finding(
                        PASS, "kernel:block-divisibility", rel, line,
                        f"{label}: in_spec[{si}] block dim {d} = {b} does "
                        f"not divide operand dim {operand_shape[d]} — the "
                        f"op must pad before tiling"))
    # index-map bounds (needs concrete scalars; skipped under tracing)
    if any(s is None for s in cap.scalars):
        return
    for si, spec in enumerate(specs):
        block = _block_shape(spec)
        imap = _index_map(spec)
        if block is None or imap is None:
            continue
        operand_shape = shapes[si] if si < n_in and si < len(shapes) else None
        if operand_shape is None or len(block) != len(operand_shape):
            continue
        bad = 0
        for pt in _grid_points(cap.grid):
            try:
                idx = imap(*pt, *cap.scalars)
            except Exception as e:
                findings.append(Finding(
                    PASS, "kernel:index-map-error", rel, line,
                    f"{label}: in_spec[{si}] index map raised {e!r} at grid "
                    f"point {pt}"))
                break
            idx = tuple(int(v) for v in idx)
            for d, (b, i) in enumerate(zip(block, idx)):
                bsz = 1 if b is None else int(b)
                if i < 0 or (i + 1) * bsz > operand_shape[d]:
                    findings.append(Finding(
                        PASS, "kernel:index-oob", rel, line,
                        f"{label}: in_spec[{si}] index map at grid point "
                        f"{pt} selects block {idx} (dim {d}: block {i} x "
                        f"{bsz} exceeds operand dim {operand_shape[d]})"))
                    bad += 1
                    break
            if bad >= 3:  # one shape of failure is enough signal per spec
                break


def _scan_fp_alloc(jaxpr, threshold: int, where: Tuple[str, int], label: str,
                   findings: List[Finding]) -> None:
    import numpy as np

    rel, line = where

    def walk(jx) -> None:
        for eqn in jx.eqns:
            for var in eqn.outvars:
                aval = getattr(var, "aval", None)
                shape = getattr(aval, "shape", None)
                dtype = getattr(aval, "dtype", None)
                if shape is None or dtype is None:
                    continue
                if str(dtype) == "float32" and \
                        int(np.prod(shape, dtype=np.int64)) >= threshold:
                    findings.append(Finding(
                        PASS, "kernel:fp-cache-alloc", rel, line,
                        f"{label}: {eqn.primitive.name} allocates fp32 "
                        f"{tuple(shape)} (>= dequantized KV size "
                        f"{threshold}) — the fp cache must never exist in "
                        f"HBM; dequant belongs in-kernel, per tile"))
            for v in eqn.params.values():
                for sub in (v if isinstance(v, (list, tuple)) else [v]):
                    inner = getattr(sub, "jaxpr", None)
                    if inner is not None:
                        walk(inner)

    walk(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)


def _where(fn, root: Optional[Path]) -> Tuple[str, int]:
    code = getattr(fn, "__wrapped__", fn).__code__
    path = Path(code.co_filename)
    rel = path.as_posix()
    if root is not None:
        try:
            rel = path.relative_to(root).as_posix()
        except ValueError:
            pass
    return rel, code.co_firstlineno


def check_op(fn, cases: Sequence[KernelCase], *,
             root: Optional[Path] = None) -> List[Finding]:
    """Run every case against one op entry point, checking all three
    invariants.  ``fn`` may be jitted (its ``__wrapped__`` is used)."""
    import functools
    from unittest import mock

    import jax
    from jax.experimental import pallas as pl_mod

    findings: List[Finding] = []
    where = _where(fn, root)
    raw = getattr(fn, "__wrapped__", fn)
    for case in cases:
        captured: List[_Captured] = []
        fake = _recorder(captured)
        with mock.patch.object(pl_mod, "pallas_call", fake):
            try:
                raw(*case.args, **case.kwargs)
            except Exception as e:
                findings.append(Finding(
                    PASS, "kernel:eval-error", where[0], where[1],
                    f"{case.label}: entry point raised {e!r} during "
                    f"abstract evaluation"))
                continue
            for cap in captured:
                _check_captured(cap, where, case.label, findings)
            if case.fp_elems is not None:
                try:
                    jaxpr = jax.make_jaxpr(
                        functools.partial(raw, **case.kwargs))(*case.args)
                except Exception as e:
                    findings.append(Finding(
                        PASS, "kernel:eval-error", where[0], where[1],
                        f"{case.label}: make_jaxpr raised {e!r}"))
                    continue
                _scan_fp_alloc(jaxpr, case.fp_elems, where,
                               case.label, findings)
    return findings


# --------------------------------------------------------------- case grid --

def _attention_cases():
    """Cases for the four attention entry points over the bucket grid."""
    import jax.numpy as jnp
    import numpy as np

    hkv, g, d = 2, 2, 16
    rng = np.random.default_rng(0)

    def lens(b, s):
        # grid extremes: empty, single token, partial block, full cache
        base = [1, s, max(1, s // 2), max(1, s - 1)]
        return jnp.asarray((base * b)[:b], jnp.int32)

    decode, decode_q, paged, paged_q = [], [], [], []
    for s in BUCKET_LENGTHS:
        for b in (1, 3):
            q = jnp.asarray(rng.standard_normal((b, hkv, g, d)), jnp.float32)
            k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
            v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
            ln = lens(b, s)
            bk = 32  # forces multi-step KV walks and the partial-final pad
            decode.append(KernelCase(
                f"decode b={b} s={s} bk={bk}", (q, k, v, ln), {"bk": bk}))
            for kv_dtype in ("int8", "int4"):
                dp = d if kv_dtype == "int8" else d // 2
                kq = jnp.zeros((b, hkv, s, dp), jnp.int8)
                ks = jnp.ones((b, hkv, s), jnp.float32)
                # invariant 3 only engages once the cache dwarfs the
                # per-query intermediates (the l/m stats are (g, 128) f32
                # per head — legitimate, and bigger than a toy cache)
                fp = b * hkv * s * d if s * d >= 2 * g * 128 else None
                decode_q.append(KernelCase(
                    f"decode-quant {kv_dtype} b={b} s={s} bk={bk}",
                    (q, kq, ks, kq, ks, ln),
                    {"kv_dtype": kv_dtype, "bk": bk},
                    fp_elems=fp))

    # paged: pool of n pages; tables exercise id 0, id n-1 and repeats
    bs, n = 16, 8
    for n_pages in (1, 3):
        for b in (1, 3):
            q = jnp.asarray(rng.standard_normal((b, hkv, g, d)), jnp.float32)
            kp = jnp.asarray(
                rng.standard_normal((n, hkv, bs, d)), jnp.float32)
            tbl = jnp.asarray(
                rng.integers(0, n, (b, n_pages)), jnp.int32)
            tbl = tbl.at[0, 0].set(0)
            tbl = tbl.at[-1, -1].set(n - 1)
            ln = lens(b, n_pages * bs)
            paged.append(KernelCase(
                f"paged b={b} pages={n_pages}", (q, kp, kp, tbl, ln), {}))
            for kv_dtype in ("int8", "int4"):
                dp = d if kv_dtype == "int8" else d // 2
                kpq = jnp.zeros((n, hkv, bs, dp), jnp.int8)
                kps = jnp.ones((n, hkv, bs), jnp.float32)
                paged_q.append(KernelCase(
                    f"paged-quant {kv_dtype} b={b} pages={n_pages}",
                    (q, kpq, kps, kpq, kps, tbl, ln),
                    {"kv_dtype": kv_dtype},
                    fp_elems=n * hkv * bs * d))
    return decode, decode_q, paged, paged_q


def _prefill_cases():
    import jax.numpy as jnp
    import numpy as np

    h, hkv, d = 4, 2, 16
    rng = np.random.default_rng(1)
    cases = []
    for s, blk in ((64, 32), (128, 32), (128, 64)):
        for schedule in ("reverse", "forward"):
            q = jnp.asarray(rng.standard_normal((1, h, s, d)), jnp.float32)
            k = jnp.asarray(rng.standard_normal((1, hkv, s, d)), jnp.float32)
            cases.append(KernelCase(
                f"prefill s={s} blk={blk} {schedule}", (q, k, k),
                {"blk": blk, "schedule": schedule}))
    return cases


def _tlmm_cases():
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.default_rng(2)
    cases = []
    for (m, n, k, bm, bn, bk) in ((128, 128, 512, 128, 128, 512),
                                  (256, 256, 1024, 128, 128, 256)):
        xq = jnp.asarray(rng.integers(-8, 8, (m, k)), jnp.int8)
        wp = jnp.asarray(rng.integers(0, 255, (k // 4, n)), jnp.uint8)
        sc = jnp.ones((m, 1), jnp.float32)
        cases.append(KernelCase(
            f"tlmm m={m} n={n} k={k} bm={bm} bn={bn} bk={bk}",
            (xq, wp, sc), {"bm": bm, "bn": bn, "bk": bk}))
    return cases


def run(root: Path, subset: Optional[Sequence[str]] = None) -> List[Finding]:
    """Check all kernel packages.  ``root`` is used only to relativize
    reported paths (the ops under test are the imported ones)."""
    from repro.kernels.decode_attention.kernel import (
        decode_attention_pallas, decode_attention_quant_pallas)
    from repro.kernels.paged_attention.kernel import (
        paged_decode_attention_pallas, paged_decode_attention_quant_pallas)
    from repro.kernels.prefill_attention.kernel import prefill_attention_pallas
    from repro.kernels.tlmm.kernel import tlmm_pallas

    decode, decode_q, paged, paged_q = _attention_cases()
    findings: List[Finding] = []
    findings += check_op(decode_attention_pallas, decode, root=root)
    findings += check_op(decode_attention_quant_pallas, decode_q, root=root)
    findings += check_op(paged_decode_attention_pallas, paged, root=root)
    findings += check_op(
        paged_decode_attention_quant_pallas, paged_q, root=root)
    findings += check_op(prefill_attention_pallas, _prefill_cases(), root=root)
    findings += check_op(tlmm_pallas, _tlmm_cases(), root=root)
    return findings
