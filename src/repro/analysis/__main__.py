"""CLI driver: ``python -m repro.analysis [--all|--pass NAME] [...]``.

Exit codes: 0 clean (baselined findings allowed), 1 non-baselined findings
or a malformed baseline.  CI runs ``--all`` as a required step.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis import (
    PASSES, default_baseline, default_root, load_baseline, run_passes,
    split_baselined)
from repro.analysis.common import legacy_hints


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="lock-discipline, kernel-invariant, determinism and "
                    "program-level analysis over src/repro")
    ap.add_argument("--all", action="store_true",
                    help="run every pass (default when no --pass is given)")
    ap.add_argument("--pass", dest="passes", action="append",
                    choices=list(PASSES), metavar="NAME",
                    help=f"run one pass (repeatable): {', '.join(PASSES)}")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="baseline file of grandfathered fingerprints "
                         "(default: analysis_baseline.txt at the repo root)")
    ap.add_argument("--root", type=Path, default=None,
                    help="tree to analyze (default: the installed src/repro)")
    args = ap.parse_args(argv)

    names = list(PASSES) if (args.all or not args.passes) else args.passes
    baseline_path = args.baseline or default_baseline()
    baseline, errors = load_baseline(baseline_path)
    root = (args.root or default_root()).resolve()

    results = run_passes(names, root=root)
    exit_code = 0
    total_active = 0
    for name in names:
        active, suppressed = split_baselined(results[name], baseline)
        extra = f"  ({len(suppressed)} baselined)" if suppressed else ""
        print(f"[{name}] {len(active)} finding(s){extra}")
        for f in sorted(active, key=lambda f: (f.path, f.line, f.rule)):
            print(f"  {f.render()}")
        total_active += len(active)
    for hint in legacy_hints(
            [f for name in names for f in results[name]], baseline):
        print(f"[baseline] NOTE: {hint}")
    for e in errors:
        print(f"[baseline] ERROR: {e}")
    if errors or total_active:
        exit_code = 1
        print(f"\nFAIL: {total_active} non-baselined finding(s)"
              + (f", {len(errors)} baseline error(s)" if errors else ""))
        print("Fix the code, add an '# analysis: allow(<rule>) — <reason>' "
              "pragma, or baseline with a reason (see README: Static "
              "analysis).")
    else:
        print("\nOK: no non-baselined findings")
    return exit_code


if __name__ == "__main__":
    sys.exit(main())
