"""Program-level auditor: trace every serving phase program and check the
contracts the file-level lints cannot see.

The other three passes read SOURCE.  This pass traces the PROGRAMS — it
builds the serving grid ({contiguous, paged} x {fp, int8, int4}) on a tiny
reduced model, pulls every registered phase program's jaxpr at the exact
abstract signatures serving dispatches (``ModelRunner.program_signatures``),
and checks four invariant families:

* **dtype flow** — ``prog:f64`` (no float64 anywhere: an accidental Python
  float promotion doubles every buffer); ``prog:fp-cache-alloc`` (in a
  quantized-KV program, no fp32 intermediate the size of the dequantized
  cache OUTSIDE tile scope — ``pallas_call`` interiors are exempt, the jnp
  fallback paths are not: per-layer dequant views are fine, a whole-cache
  materialization defeats the quantization);
* **donation** — ``prog:cache-not-donated`` (a cache-sized buffer threaded
  through a step program — same leaf aval in and out — must be covered by
  the program's declared ``donate_argnums``, else that program silently
  doubles the KV footprint per step);
* **static cost vs roofline** — ``prog:cost-drift`` (FLOPs / HBM bytes
  counted from the jaxpr must sit within tolerance of the analytic bound
  from ``core.roofline.predict_phase`` — the same predictions
  ``obs.drift.roofline_drift`` reports at runtime, so the gate and the
  metric cannot diverge);
* **bucket / recompile coverage** — ``prog:shape-leak`` (the shape sets
  ``bucket()`` / ``chunk_bucket()`` promise are finite, aligned, and
  CLOSED: re-requesting programs for every reachable prompt length after
  ``build_serving_grid()`` must not register anything new — a leak here is
  an unbounded recompile surface in production).

It also validates the kernel entry-point aliasing contract: each
``kernels/*/ops.py`` declares ``CACHE_OPERANDS`` (which operands alias the
persistent KV cache / page pool / packed weights, and that the op never
writes them).  ``prog:op-annotation`` flags a malformed or missing
declaration; ``prog:op-alias`` flags a declared read-only entry whose
traced jaxpr passes a cache operand through to its outputs (cache writes
belong to donated program-level buffers, never to kernel ops).

Waivers: the standard ``# analysis: allow(prog:<rule>) — reason`` pragma on
the PROGRAM BUILDER's ``def`` line (findings anchor to the builder that
registered the program, or to the op entry point), plus the shared
fingerprint baseline.  The pass audits the IMPORTED package — when run
against a ``--root`` other than the installed ``src/repro`` it has nothing
to trace and reports clean.
"""
from __future__ import annotations

import math
from pathlib import Path
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

from repro.analysis.common import AnalyzedFile, Finding

PASS = "program"

LAYOUTS = ("contiguous", "paged")
KV_DTYPES = ("fp", "int8", "int4")

# --- audit grid model: tiny but structurally faithful (GQA-capable dims,
# real bucketing, speculation on, chunked prefill on).  Tracing only —
# nothing compiles beyond the runner's own cache-init kernels.
GRID_ARCH = "smollm-135m"
# Dims chosen so the fp-cache-alloc threshold (one full dequantized cache
# direction: n_slots*L*Hkv*max_len*D = 18432 elems) strictly dominates every
# legitimate f32 buffer: the lm-head weight upcast (d*padded_vocab = 16384),
# the full-bucket logits (max_len*padded_vocab = 12288), the chunk prefix
# mirror leaf (L*Hkv*max_len*D = 9216) and the per-layer dequant views
# (<= n_slots*Hkv*max_len*D = 6144) — a whole-cache materialization is the
# only thing that can cross it.
GRID_MODEL = dict(num_layers=3, d_model=64, vocab_size=128,
                  num_heads=2, num_kv_heads=2, head_dim=32)
GRID_RUNNER = dict(n_slots=2, max_len=48, prompt_len=8, block_size=8,
                   prefill_chunk=8, spec_decode=2)

# --- tolerances -----------------------------------------------------------
# Decode/verify stream the KV cache once per step: counted cache bytes must
# match kv_bytes_per_ctx_token() * capacity almost exactly (the slack covers
# index/length vectors the bound ignores).
KV_BYTES_TOL = 1.15
# Prefill: counted dot_general FLOPs per token vs the 2N bound.  The band
# accounts for the structural slack in 2N accounting: embedding gathers
# contribute params but no dot FLOPs (ratio < 1), attention-score dots on
# the jnp paths contribute FLOPs but no params (ratio > 1).  On the audit
# grid the observed ratios sit in [0.94, 1.04]; the band leaves ~30%
# headroom while still catching a duplicated layer trace (2x) or a program
# that stopped doing the matmuls the bound charges for.
PREFILL_FLOPS_BAND = (0.7, 1.35)

# Pallas tile interiors are exempt from the fp-intermediate rule (that is
# tile scope — kernel_check audits it) and excluded from FLOP counts (the
# 2N prefill bound charges parameter matmuls, not attention scores).
_TILE_PRIMS = ("pallas_call",)

_MEMO: Dict[Tuple, Tuple[List[Finding], List[Dict[str, Any]]]] = {}


# ------------------------------------------------------------- jaxpr walk --

def _sub_jaxprs(eqn) -> Iterator[Any]:
    for v in eqn.params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for x in vs:
            if hasattr(x, "eqns") or hasattr(x, "jaxpr"):
                yield x


def iter_eqns(jaxpr, mult: float = 1.0) -> Iterator[Tuple[Any, float]]:
    """Yield ``(eqn, multiplicity)`` over a (Closed)Jaxpr, recursing into
    sub-jaxprs.  ``scan`` bodies count ``length`` times; ``pallas_call``
    interiors (tile scope) are NOT entered — the call's own outputs still
    are program-scope values and are yielded with the eqn."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in inner.eqns:
        yield eqn, mult
        name = eqn.primitive.name
        if name in _TILE_PRIMS:
            continue
        m = mult * eqn.params["length"] if name == "scan" else mult
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, m)


def _np_dtype(x):
    """numpy dtype of an aval/SDS, or None for extended dtypes (PRNG
    keys)."""
    import numpy as np

    try:
        return np.dtype(x.dtype)
    except TypeError:
        return None


def _aval_key(x) -> Tuple[Tuple[int, ...], str]:
    return tuple(int(d) for d in x.shape), str(x.dtype)


def _nbytes(x) -> int:
    dt = _np_dtype(x)
    return int(math.prod(x.shape)) * (dt.itemsize if dt is not None else 4)


def dot_flops(eqn) -> float:
    """FLOPs of one ``dot_general``: 2 x batch x lhs-free x rhs-free x
    contraction, read off the operand avals."""
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = math.prod(lhs.shape[i] for i in lb)
    contract = math.prod(lhs.shape[i] for i in lc)
    lfree = math.prod(lhs.shape[i] for i in range(len(lhs.shape))
                      if i not in lc and i not in lb)
    rfree = math.prod(rhs.shape[i] for i in range(len(rhs.shape))
                      if i not in rc and i not in rb)
    return 2.0 * batch * contract * lfree * rfree


def counted_flops(jaxpr) -> float:
    """Total dot_general FLOPs of a jaxpr (scan-trip-aware, tile interiors
    excluded)."""
    return sum(dot_flops(eqn) * m for eqn, m in iter_eqns(jaxpr)
               if eqn.primitive.name == "dot_general")


# ------------------------------------------------------- invariant checks --

def check_dtype_flow(jaxpr, *, quantized: bool, fp_threshold_elems: int,
                     emit) -> None:
    """Family 1: no f64 anywhere; in quantized programs no program-scope
    f32 value >= ``fp_threshold_elems`` (a dequantized-cache-sized
    materialization)."""
    import numpy as np

    seen_f64 = False
    for v in list(getattr(jaxpr, "jaxpr", jaxpr).invars):
        if _np_dtype(v.aval) == np.float64:
            seen_f64 = True
            emit("prog:f64", f"float64 program input {v.aval.str_short()}")
    for eqn, _m in iter_eqns(jaxpr):
        for ov in eqn.outvars:
            aval = getattr(ov, "aval", None)
            if aval is None or not hasattr(aval, "dtype"):
                continue
            dt = _np_dtype(aval)
            if dt is None:
                continue
            if dt == np.float64 and not seen_f64:
                seen_f64 = True
                emit("prog:f64",
                     f"float64 intermediate {aval.str_short()} out of "
                     f"{eqn.primitive.name} — Python-float promotion "
                     f"doubles every downstream buffer")
            if quantized and dt == np.float32 \
                    and math.prod(aval.shape) >= fp_threshold_elems:
                emit("prog:fp-cache-alloc",
                     f"fp32 intermediate {aval.str_short()} out of "
                     f"{eqn.primitive.name} is >= the dequantized cache "
                     f"({fp_threshold_elems} elems) outside tile scope — "
                     f"this materializes the fp cache quantization exists "
                     f"to avoid")
                return  # one finding per program is enough signal


def check_donation(jaxpr, abstract_inputs: Sequence[Any],
                   donate_argnums: Sequence[int], threshold_bytes: int,
                   emit) -> None:
    """Family 2: any input leaf >= ``threshold_bytes`` whose aval also
    appears among the outputs (a threaded-through persistent buffer) must
    belong to a donated argument."""
    import jax

    out_keys = {_aval_key(a) for a in jaxpr.out_avals}
    for i, arg in enumerate(abstract_inputs):
        if i in donate_argnums:
            continue
        for leaf in jax.tree.leaves(arg):
            if _nbytes(leaf) < threshold_bytes:
                continue
            if _aval_key(leaf) in out_keys:
                emit("prog:cache-not-donated",
                     f"arg {i} threads a {_nbytes(leaf)}-byte "
                     f"{jax.ShapeDtypeStruct(leaf.shape, leaf.dtype)} leaf "
                     f"through to the outputs without donating it — the "
                     f"step doubles the cache footprint; add arg {i} to "
                     f"donate_argnums")
                break


def cost_findings(rows: Sequence[Dict[str, Any]], emit_for) -> None:
    """Family 3: each cost row's counted/bound ratio must sit inside its
    tolerance band."""
    for row in rows:
        if row["tol_lo"] <= row["ratio"] <= row["tol_hi"]:
            continue
        emit = emit_for(row)
        emit("prog:cost-drift",
             f"{row['kind']} counted from the jaxpr is {row['counted']:.3g} "
             f"vs roofline bound {row['bound']:.3g} "
             f"(ratio {row['ratio']:.3f} outside "
             f"[{row['tol_lo']}, {row['tol_hi']}]) — the traced program and "
             f"core.roofline.predict_phase disagree about what this phase "
             f"does")


def check_bucket_coverage(runner, emit) -> None:
    """Family 4: the bucket functions' promised shape sets are covering,
    aligned, logarithmically bounded, and CLOSED over the built grid."""
    q = runner.block_size if runner.cache_layout == "paged" \
        else runner.prompt_len
    max_len = runner.max_len
    buckets = runner.reachable_buckets()
    bound = 4 + max(0, math.ceil(math.log2(max(1, max_len / q)))) + 2
    if len(buckets) > bound:
        emit("prog:shape-leak",
             f"{len(buckets)} reachable prefill buckets exceeds the "
             f"O(log(max_len/quantum)) promise (<= {bound}) — bucket() is "
             f"leaking per-prompt shapes into the compile cache")
    for n in range(1, max_len + 1):
        b = runner.bucket(n)
        if b < min(n, max_len) or b > max_len:
            emit("prog:shape-leak",
                 f"bucket({n}) = {b} does not cover the prompt within "
                 f"max_len={max_len} — padded prefill would truncate")
            return
        if b % q and b != max_len:
            emit("prog:shape-leak",
                 f"bucket({n}) = {b} is not quantum-aligned (q={q}) and is "
                 f"not the max_len fallback — an unplanned compile shape")
            return
    # closure: after build_serving_grid(), re-requesting the programs for
    # every reachable prompt must be a pure cache hit
    before = set(runner.engine.programs)
    for n in range(1, max_len + 1):
        runner.progs(runner.bucket(n))
    if runner.prefill_chunk is not None:
        for n in range(1, max_len + 1):
            start = 0
            for size in runner.chunk_sizes(n):
                runner.chunk_prog(runner.chunk_bucket(size, start),
                                  runner.prefix_width(start))
                start += size
    leaked = set(runner.engine.programs) - before
    if leaked:
        emit("prog:shape-leak",
             f"serving reached program(s) the built grid did not contain: "
             f"{sorted(leaked)} — build_serving_grid()/bucket() and "
             f"dispatch diverged (a recompile per request in production)")
    missing = [k for k, p in runner.program_signatures().items()
               if not p.abstract_inputs]
    if missing:
        emit("prog:shape-leak",
             f"registered program(s) with no abstract signature: "
             f"{sorted(missing)} — the registry and "
             f"ModelRunner.abstract_signature() diverged; the auditor "
             f"cannot see what serving dispatches")


# ------------------------------------------------------------- op contract --

OPS_MODULES = (
    "repro.kernels.decode_attention.ops",
    "repro.kernels.paged_attention.ops",
    "repro.kernels.prefill_attention.ops",
    "repro.kernels.tlmm.ops",
)


def _op_probe(name: str):
    """Small representative abstract arguments for a kernel entry point —
    enough to trace its jnp path."""
    import jax
    import jax.numpy as jnp

    s = jax.ShapeDtypeStruct
    f32, i32, bf16 = jnp.float32, jnp.int32, jnp.bfloat16
    if name == "decode_attention":
        return (s((2, 2, 32), f32), s((2, 2, 16, 32), bf16),
                s((2, 2, 16, 32), bf16), s((2,), i32)), {}
    if name == "paged_decode_attention":
        return (s((2, 2, 32), f32), s((4, 2, 8, 32), bf16),
                s((4, 2, 8, 32), bf16), s((2, 2), i32), s((2,), i32)), {}
    if name == "gather_scales":
        return (s((4, 2, 8), f32), s((2, 2), i32)), {}
    if name == "prefill_attention":
        return (s((1, 2, 16, 32), f32), s((1, 2, 16, 32), f32),
                s((1, 2, 16, 32), f32)), {}
    if name == "tlmm_matmul":
        from repro.quant.ternary import TernaryWeight

        w = TernaryWeight(packed=s((16, 32), jnp.uint8), scale=s((), f32))
        return (s((4, 64), f32), w), {}
    return None


def check_op_contracts(emit_at, modules: Sequence[Any] = OPS_MODULES) -> None:
    """Validate each ops module's ``CACHE_OPERANDS`` declaration and trace
    the declared read-only entries: a cache operand must never pass through
    to the outputs.  ``modules`` takes import names or module objects; a
    module may carry ``_ANALYSIS_PROBES = {entry: (args, kwargs)}`` to
    override the built-in probe signatures."""
    import importlib
    import inspect

    import jax

    for modname in modules:
        mod = importlib.import_module(modname) \
            if isinstance(modname, str) else modname
        emit = emit_at(Path(mod.__file__), 1)
        decl = getattr(mod, "CACHE_OPERANDS", None)
        if not isinstance(decl, dict) or not decl:
            emit("prog:op-annotation",
                 f"{modname} declares no CACHE_OPERANDS — every kernel ops "
                 f"module must state which operands alias persistent "
                 f"buffers (and that it never writes them)")
            continue
        for entry, spec in decl.items():
            fn = getattr(mod, entry, None)
            if fn is None or not callable(fn):
                emit("prog:op-annotation",
                     f"CACHE_OPERANDS names {entry!r} but {modname} has no "
                     f"such callable")
                continue
            emit = emit_at(Path(mod.__file__), fn.__code__.co_firstlineno)
            params = list(inspect.signature(fn).parameters)
            args = spec.get("args", ())
            bad = [a for a in args if a not in params]
            if bad or not args or "writes" not in spec:
                emit("prog:op-annotation",
                     f"CACHE_OPERANDS[{entry!r}] is malformed: args={args} "
                     f"(unknown: {bad}) writes={spec.get('writes')!r} — "
                     f"declare the cache-aliasing parameter names and "
                     f"writes: False")
                continue
            if spec["writes"]:
                emit("prog:op-annotation",
                     f"CACHE_OPERANDS[{entry!r}] declares writes=True — "
                     f"kernel ops are read-only over caches in this repo; "
                     f"cache mutation belongs to donated program-level "
                     f"buffers")
                continue
            probe = getattr(mod, "_ANALYSIS_PROBES", {}).get(entry) \
                or _op_probe(entry)
            if probe is None:
                continue
            pargs, pkw = probe
            try:
                closed = jax.make_jaxpr(fn)(*pargs, **pkw)
            except Exception as e:  # pragma: no cover - probe drift
                emit("prog:op-annotation",
                     f"could not trace {entry} with its probe signature: "
                     f"{type(e).__name__}: {e}")
                continue
            cache_idx = {params.index(a) for a in args}
            flat_ranges: List[int] = []
            pos = 0
            for i, a in enumerate(pargs):
                n = len(jax.tree.leaves(a))
                if i in cache_idx:
                    flat_ranges.extend(range(pos, pos + n))
                pos += n
            invars = list(closed.jaxpr.invars)
            cache_vars = {id(invars[i]) for i in flat_ranges
                          if i < len(invars)}
            for ov in closed.jaxpr.outvars:
                if id(ov) in cache_vars:
                    emit("prog:op-alias",
                         f"{entry} returns a declared cache operand "
                         f"unchanged — a read-only kernel op must not pass "
                         f"the cache through its outputs (the program level "
                         f"owns cache buffers via donation)")
                    break


# ---------------------------------------------------------------- the pass --

class _Emitter:
    """Findings anchored to real source locations, honoring def-line
    ``allow()`` pragmas in the anchor file."""

    def __init__(self, root: Path):
        self.root = root
        self.findings: List[Finding] = []
        self._afs: Dict[Path, Optional[AnalyzedFile]] = {}

    def _af(self, path: Path) -> Optional[AnalyzedFile]:
        if path not in self._afs:
            try:
                path.relative_to(self.root)
                self._afs[path] = AnalyzedFile(path, self.root)
            except (ValueError, OSError):
                self._afs[path] = None
        return self._afs[path]

    def at(self, path: Path, line: int, scope: str = ""):
        af = self._af(path)
        rel = str(path.relative_to(self.root)) if af else path.name

        def emit(rule: str, msg: str) -> None:
            if af is not None and af.waived(rule, line, (line,)):
                return
            self.findings.append(
                Finding(PASS, rule, rel, line, msg, scope=scope))

        return emit

    def for_program(self, prog, scope: str):
        fn = getattr(prog.fn, "__wrapped__", prog.fn)
        code = getattr(fn, "__code__", None)
        if code is None:  # pragma: no cover - non-Python callable
            return self.at(self.root / "core" / "phase_engine.py", 1, scope)
        return self.at(Path(code.co_filename), code.co_firstlineno, scope)


def _grid_runner(layout: str, kv_dtype: str):
    import jax

    from repro.configs import reduced_config
    from repro.models import get_model
    from repro.serving.core import ModelRunner

    cfg = reduced_config(GRID_ARCH, **GRID_MODEL)
    params = get_model(cfg).init(cfg, jax.random.PRNGKey(0))
    return ModelRunner(cfg, params, cache_layout=layout, kv_dtype=kv_dtype,
                       **GRID_RUNNER)


def _trace(prog):
    import jax

    fn = getattr(prog.fn, "__wrapped__", prog.fn)
    return jax.make_jaxpr(fn)(*prog.abstract_inputs)


def _audit_point(layout: str, kv_dtype: str, em: _Emitter,
                 rows: List[Dict[str, Any]]) -> None:
    import jax

    from repro.core.roofline import predict_phase

    runner = _grid_runner(layout, kv_dtype)
    cfg = runner.cfg
    scope = f"{layout}/{kv_dtype}"
    runner.build_serving_grid()
    check_bucket_coverage(runner, em.at(
        Path(type(runner).bucket.__code__.co_filename),
        type(runner).bucket.__code__.co_firstlineno, scope))

    cache_tree = runner.paged.kv if layout == "paged" else runner.cache
    cache_leaves = jax.tree.leaves(cache_tree)
    donation_threshold = max(_nbytes(x) for x in cache_leaves)
    # one full direction (K or V) of the dequantized fp cache, in elements:
    # 2x the largest per-layer/per-chunk dequant view any legit path makes
    n_slots = runner.slots.n_slots
    fp_threshold = (n_slots * cfg.num_layers * cfg.num_kv_heads
                    * runner.max_len * cfg.head_dim)
    capacity = (runner.paged.max_pages * runner.block_size
                if layout == "paged" else runner.max_len)
    kv_bound = predict_phase("decode", cfg, context=capacity,
                             kv_dtype=kv_dtype, batch=n_slots).hbm_bytes

    sigs = runner.program_signatures()
    split_flops: Dict[str, Dict[str, float]] = {}
    for key in sorted(sigs):
        prog = sigs[key]
        if not prog.abstract_inputs:
            continue  # reported by check_bucket_coverage
        emit = em.for_program(prog, f"{scope}:{key}")
        try:
            closed = _trace(prog)
        except Exception as e:
            emit("prog:shape-leak",
                 f"program {key} does not trace at its registered abstract "
                 f"signature ({type(e).__name__}: {e}) — the signature and "
                 f"the program diverged")
            continue
        check_dtype_flow(closed, quantized=kv_dtype != "fp",
                         fp_threshold_elems=fp_threshold, emit=emit)
        check_donation(closed, prog.abstract_inputs, prog.donate_argnums,
                       donation_threshold, emit)

        if prog.phase == "decode":
            counted = sum(
                _nbytes(leaf) for i in prog.donate_argnums
                for leaf in jax.tree.leaves(prog.abstract_inputs[i]))
            rows.append(dict(
                layout=layout, kv_dtype=kv_dtype, program=key,
                kind="kv_stream_bytes", counted=float(counted),
                bound=float(kv_bound),
                ratio=counted / kv_bound if kv_bound else float("inf"),
                tol_lo=round(1.0 / KV_BYTES_TOL, 4), tol_hi=KV_BYTES_TOL,
                prog=prog))
        elif prog.phase == "prefill":
            flops = counted_flops(closed)
            m = key.split(":")
            if key.startswith("prefill_split_varlen:"):
                base = f"{m[0]}:{m[1]}"
                d = split_flops.setdefault(
                    base, dict(flops=0.0, prog=prog))
                d["flops"] += flops
                if len(m) == 2:  # the body carries the token count
                    b, s = map(int, m[1].split("x"))
                    d.update(tokens=b * s, prog=prog, key=base)
            else:  # chunk programs: tokens = padded chunk length
                c = int(key.split(":")[1].split("+")[0])
                split_flops[key] = dict(flops=flops, tokens=c, prog=prog,
                                        key=key)

    n_params = sum(int(math.prod(x.shape))
                   for x in jax.tree.leaves(runner._pa))
    flops_bound = predict_phase("prefill", n_params=n_params).flops
    for d in split_flops.values():
        per_tok = d["flops"] / d["tokens"]
        rows.append(dict(
            layout=layout, kv_dtype=kv_dtype, program=d["key"],
            kind="flops_per_token", counted=per_tok,
            bound=float(flops_bound), ratio=per_tok / flops_bound,
            tol_lo=PREFILL_FLOPS_BAND[0], tol_hi=PREFILL_FLOPS_BAND[1],
            prog=d["prog"]))


def audit(root: Optional[Path] = None,
          layouts: Sequence[str] = LAYOUTS,
          kv_dtypes: Sequence[str] = KV_DTYPES,
          ) -> Tuple[List[Finding], List[Dict[str, Any]]]:
    """Run the full audit over the serving grid.  Returns ``(findings,
    cost_rows)``; memoized per (root, grid) — the gate, the report and the
    tests share one trace of the grid per process."""
    from repro.analysis import default_root

    root = (root or default_root()).resolve()
    memo_key = (root, tuple(layouts), tuple(kv_dtypes))
    if memo_key in _MEMO:
        return _MEMO[memo_key]
    em = _Emitter(root)
    rows: List[Dict[str, Any]] = []
    for layout in layouts:
        for kv_dtype in kv_dtypes:
            _audit_point(layout, kv_dtype, em, rows)
    check_op_contracts(em.at)

    def emit_for(row):
        return em.for_program(row["prog"],
                              f"{row['layout']}/{row['kv_dtype']}"
                              f":{row['program']}")

    cost_findings(rows, emit_for)
    for row in rows:
        row.pop("prog", None)
    _MEMO[memo_key] = (em.findings, rows)
    return _MEMO[memo_key]


def cost_table(root: Optional[Path] = None) -> List[Dict[str, Any]]:
    """The static-cost-vs-roofline residual table (one row per audited
    (grid point, program, metric)) — consumed by
    ``scripts/analysis_report.py --json`` and the CI step summary."""
    return audit(root)[1]


def run(root: Path, subset: Optional[Sequence[str]] = None) -> List[Finding]:
    """Pass protocol entry point.  The program pass audits the IMPORTED
    package; a foreign ``root`` (fixture trees, ``--root``) has no programs
    to trace and reports clean."""
    from repro.analysis import default_root

    if Path(root).resolve() != default_root().resolve():
        return []
    return audit(root)[0]
