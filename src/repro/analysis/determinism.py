"""Determinism lint: replay-critical modules must not consult nondeterminism.

The repo's headline guarantee — greedy streams bit-identical across
{contiguous, paged} x {fp, int8, int4} x {chunked, spec, disagg,
preempt-replay} — requires that everything deciding *token values* is a
pure function of (prompt, seed, schedule-independent engine state).  This
pass walks the replay-critical modules and flags:

* ``det:wallclock`` — calls into ``time.*`` / ``random.*`` /
  ``os.urandom`` / ``np.random.*`` / ``datetime.*.now``.  Timestamps that
  only feed stats (TTFT/ITL metering, trace spans) are fine — but each one
  must say so with ``# analysis: allow(det:wallclock) — <reason>``, which
  turns "probably just a stat" into an audited claim;
* ``det:bare-set-iter`` — ``for``/comprehension iteration over a bare
  ``set`` (literal, ``set(...)`` call, or a local inferred to be one).
  Set iteration order is salted per-process; feeding it into scheduling or
  sampling silently breaks replay.  ``sorted(...)`` the set first;
* ``det:unkeyed-prng`` — ``jax.random`` draws whose key is not derived via
  ``fold_in`` / ``split`` (directly or through a local).  ``fold_in(key,
  token_index)`` is the repo's replay contract (PR 2): a preempted stream
  re-deriving keys by counter position resamples identically.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import List, Optional, Sequence, Set

from repro.analysis.common import AnalyzedFile, Finding, iter_python_files

PASS = "determinism"

DEFAULT_SUBSET = (
    "serving/core.py",
    "serving/paging.py",
    "serving/spec_decode.py",
    "core/sampling.py",
)

WALLCLOCK_RE = re.compile(
    r"^(time\.\w+|random\.\w+|os\.urandom|(np|numpy)\.random\.\w+"
    r"|datetime\.(datetime|date)\.(now|today|utcnow))$")

# jax.random draws that consume a key (derivation ops are not draws)
DRAWS = {
    "categorical", "uniform", "normal", "bernoulli", "gumbel", "randint",
    "permutation", "shuffle", "choice", "exponential", "laplace", "bits",
}
KEY_DERIVERS = (".fold_in", ".split")


def _call_name(node: ast.Call) -> str:
    try:
        return ast.unparse(node.func)
    except Exception:  # pragma: no cover
        return ""


def _is_set_expr(node: ast.expr) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitAnd, ast.BitOr, ast.Sub, ast.BitXor)):
        # set algebra propagates set-ness from either side
        return _is_set_expr(node.left) or _is_set_expr(node.right)
    return False


def _is_keyed(node: ast.expr, keyed_names: Set[str]) -> bool:
    """Is this expression a replay-safe PRNG key (fold_in/split-derived)?"""
    if isinstance(node, ast.Call):
        return _call_name(node).endswith(KEY_DERIVERS)
    if isinstance(node, ast.Name):
        return node.id in keyed_names
    if isinstance(node, ast.Subscript):  # split(...)[i]
        return _is_keyed(node.value, keyed_names)
    return False


class _Checker:
    def __init__(self, af: AnalyzedFile, findings: List[Finding]):
        self.af = af
        self.findings = findings
        self.def_lines: List[int] = []
        self.func = "<module>"
        # names bound (anywhere in the enclosing function) to set exprs /
        # derived keys — a flow-insensitive but effective local inference
        self.set_names: List[Set[str]] = [set()]
        self.keyed_names: List[Set[str]] = [set()]

    def _emit(self, rule: str, lineno: int, msg: str) -> None:
        if self.af.waived(rule, lineno, self.def_lines):
            return
        self.findings.append(
            Finding(PASS, rule, self.af.rel, lineno, msg, scope=self.func))

    def check_module(self) -> None:
        for node in self.af.tree.body:
            self._visit(node)

    def _scan_assignments(self, fn: ast.AST) -> None:
        """Pre-scan a function body for set-typed / keyed locals so uses
        before the textual assignment (loops) still resolve."""
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                if _is_set_expr(node.value):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            self.set_names[-1].add(t.id)
                if isinstance(node.value, ast.Call) and \
                        _call_name(node.value).endswith(KEY_DERIVERS):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            self.keyed_names[-1].add(t.id)

    def _visit(self, node: ast.AST) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self.def_lines.append(node.lineno)
            prev_func, self.func = self.func, node.name
            self.set_names.append(set(self.set_names[-1]))
            self.keyed_names.append(set(self.keyed_names[-1]))
            self._scan_assignments(node)
            for child in node.body:
                self._visit(child)
            self.keyed_names.pop()
            self.set_names.pop()
            self.func = prev_func
            self.def_lines.pop()
            return

        if isinstance(node, ast.Call):
            name = _call_name(node)
            if WALLCLOCK_RE.match(name):
                self._emit(
                    "det:wallclock", node.lineno,
                    f"{self.func} calls {name}() — wall-clock/entropy in a "
                    f"replay-critical module; if this only feeds stats, say "
                    f"so with an allow() pragma")
            m = re.match(r"(?:jax\.)?random\.(\w+)$", name)
            if m and m.group(1) in DRAWS and "jax" in name:
                key = node.args[0] if node.args else None
                for kw in node.keywords:
                    if kw.arg == "key":
                        key = kw.value
                if key is None or not _is_keyed(key, self.keyed_names[-1]):
                    self._emit(
                        "det:unkeyed-prng", node.lineno,
                        f"{self.func} draws jax.random.{m.group(1)} with a "
                        f"key not derived via fold_in/split — replay "
                        f"requires position-keyed derivation")

        iters: List[ast.expr] = []
        if isinstance(node, (ast.For, ast.AsyncFor)):
            iters.append(node.iter)
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            iters.extend(g.iter for g in node.generators)
        for it in iters:
            bare_set = _is_set_expr(it) or (
                isinstance(it, ast.Name) and it.id in self.set_names[-1])
            if bare_set:
                try:
                    src = ast.unparse(it)
                except Exception:  # pragma: no cover
                    src = "<set>"
                self._emit(
                    "det:bare-set-iter", it.lineno,
                    f"{self.func} iterates bare set {src!r} — per-process "
                    f"hash salt makes the order nondeterministic; sorted() "
                    f"it before it can feed scheduling or sampling")

        for child in ast.iter_child_nodes(node):
            self._visit(child)


def run(root: Path, subset: Optional[Sequence[str]] = None) -> List[Finding]:
    if subset is None:
        paths = iter_python_files(root, DEFAULT_SUBSET)
        if not paths:
            paths = iter_python_files(root)
    else:
        paths = iter_python_files(root, subset)
    findings: List[Finding] = []
    for p in paths:
        af = AnalyzedFile(p, root)
        findings.extend(af.pragma_findings)
        _Checker(af, findings).check_module()
    return findings
