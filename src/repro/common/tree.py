"""Small pytree helpers used across the framework (no flax/optax installed)."""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def tree_param_count(tree: Any) -> int:
    """Total number of *logical* parameters.

    Ternary-packed uint8 leaves hold 4 weights per byte; we count logical
    weights so 6*N*D model-FLOP math stays correct regardless of packing.
    """
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        if hasattr(leaf, "dtype") and leaf.dtype == jnp.uint8:
            n *= 4
        total += n
    return total


def tree_bytes(tree: Any) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize if leaf.shape else jnp.dtype(leaf.dtype).itemsize
    return total


def tree_map_with_path_names(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """tree_map where fn receives a '/'-joined string path (for sharding rules)."""

    def _name(path) -> str:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            elif isinstance(p, jax.tree_util.GetAttrKey):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        return "/".join(parts)

    return jax.tree_util.tree_map_with_path(lambda p, x: fn(_name(p), x), tree)
