"""Hardware model of the target platform (TPU v5e) and of the paper's platform.

All roofline math in :mod:`repro.core.roofline` and the DSE in
:mod:`repro.core.dse` reads these constants.  The container we develop in is
CPU-only; v5e is the *target*, exactly like the paper's Vitis flow targets the
KV260 from an x86 host.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Per-chip hardware constants."""

    name: str
    # Peak compute (FLOP/s).  int8 ops count as 2x bf16 on the v5e MXU.
    peak_flops_bf16: float
    peak_flops_int8: float
    # HBM
    hbm_bytes: int
    hbm_bw: float  # bytes/s
    # Inter-chip interconnect, per link.
    ici_bw_per_link: float  # bytes/s (one direction)
    ici_links: int  # usable links per chip in a 2D torus
    # On-chip memory (the analogue of the paper's LUT/URAM budget).
    vmem_bytes: int
    # Host <-> device (DCN for the pod axis)
    dcn_bw: float


TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    peak_flops_int8=394e12,
    hbm_bytes=16 * 1024**3,
    hbm_bw=819e9,
    ici_bw_per_link=50e9,
    ici_links=4,
    vmem_bytes=128 * 1024**2,
    dcn_bw=25e9,
)

# The paper's platform, used by benchmarks/table1_comparison.py to reproduce
# the paper's own arithmetic (KV260: Zynq UltraScale+ XCK26, LPDDR4-2400 x32).
KV260_DDR_BW = 19.2e9  # bytes/s, theoretical LPDDR4 peak used in the paper's refs
KV260_POWER_W = 4.9  # PD-Swap's measured power (Table 1)

DEFAULT_CHIP = TPU_V5E


def mesh_chips(mesh_shape: tuple[int, ...]) -> int:
    n = 1
    for s in mesh_shape:
        n *= s
    return n
