from repro.common.hardware import TPU_V5E, DEFAULT_CHIP, ChipSpec, mesh_chips
from repro.common.tree import tree_bytes, tree_param_count, tree_map_with_path_names
