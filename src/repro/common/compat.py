"""Version-compat shims for jax API renames (single home — no copies)."""
from __future__ import annotations

import jax


def shard_map(*args, **kwargs):
    """jax.shard_map (check_vma) appeared in newer jax; fall back to
    jax.experimental.shard_map.shard_map (check_rep) on older releases."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(*args, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm

    if "check_vma" in kwargs:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _sm(*args, **kwargs)


def tpu_compiler_params(**kwargs):
    """pltpu.CompilerParams in newer jax, TPUCompilerParams in <=0.4.x."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
