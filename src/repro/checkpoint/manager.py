"""Checkpointing: atomic, async, resharding-on-restore (elastic restart).

Format: one directory per step containing ``arrays.npz`` (flattened pytree
leaves keyed by '/'-joined paths) + ``meta.json`` (step, treedef token,
config fingerprint).  Writes go to ``<dir>.tmp`` then ``os.rename`` —
a checkpoint is either complete or absent (crash-safe).  ``save_async``
snapshots device arrays to host, then writes on a background thread so the
training loop keeps stepping (fault-tolerance requirement: checkpoint
cadence must not stall the step).

Restore takes *target shardings*: leaves are ``device_put`` against whatever
mesh the restarted job has — a job can come back on a different device count
(elastic shrink/grow) and the optimizer state reshards with the params.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.common.tree import tree_map_with_path_names


def _flatten_named(tree: Any) -> dict:
    out = {}
    tree_map_with_path_names(lambda p, x: out.__setitem__(p, np.asarray(x)), tree)
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        # one outstanding background write; handle owned by the training
        # loop ("ckpt-caller"), error slot written by the writer thread and
        # only read back across the join() in wait()
        self._thread: Optional[threading.Thread] = None  # owned-by: ckpt-caller
        self._last_error: Optional[BaseException] = None  # owned-by: ckpt-writer

    # ------------------------------------------------------------- saving --

    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> Path:
        arrays = _flatten_named(jax.device_get(tree))
        return self._write(step, arrays, extra or {})

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None) -> None:  # thread: ckpt-caller
        """Snapshot to host synchronously, write in the background."""
        self.wait()  # one outstanding write max
        arrays = _flatten_named(jax.device_get(tree))

        def work():  # thread: ckpt-writer
            try:
                self._write(step, arrays, extra or {})
            except BaseException as e:  # surfaced on next wait()
                self._last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:  # thread: ckpt-caller
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        # _last_error crosses back to the caller strictly after join() —
        # the join is the happens-before edge, so these reads are safe:
        if self._last_error is not None:  # analysis: allow(lock:thread) — read after join()
            err, self._last_error = self._last_error, None  # analysis: allow(lock:thread) — read after join()
            raise err

    def _write(self, step: int, arrays: dict, extra: dict) -> Path:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **arrays)
        (tmp / "meta.json").write_text(json.dumps({"step": step, "time": time.time(), **extra}))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------ restore --

    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "meta.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None, shardings: Any = None) -> tuple[Any, int]:
        """Restore into the structure of ``template``; device_put against
        ``shardings`` (same pytree structure) when given — this is the
        elastic-resharding path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = self.dir / f"step_{step:08d}"
        with np.load(path / "arrays.npz") as data:
            arrays = {k: data[k] for k in data.files}

        flat_sh = None
        if shardings is not None:
            flat_sh = {}
            tree_map_with_path_names(lambda p, s: flat_sh.__setitem__(p, s), shardings)

        def load(p, t):
            a = arrays[p]
            assert a.shape == tuple(t.shape), (p, a.shape, t.shape)
            a = a.astype(t.dtype)
            if flat_sh is not None and p in flat_sh and flat_sh[p] is not None:
                return jax.device_put(a, flat_sh[p])
            return jax.device_put(a)

        return tree_map_with_path_names(load, template), step
