"""Distributed train step: FSDP x TP, microbatch accumulation, remat,
optional compressed cross-pod DP (beyond-paper).

Global-view pjit: batch sharded over the dp axes, params/optimizer FSDP+TP
sharded via launch.sharding_rules; scan-over-layers keeps the HLO one layer
deep; microbatch accumulation is a ``lax.scan`` over batch slices so weight
all-gathers (FSDP) pipeline against compute.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.phase_engine import make_pctx
from repro.layers.sharding import PartitionCtx, TRAIN_RULES
from repro.models import get_model
from repro.optim.adamw import AdamWConfig, AdamWState, adamw_init, adamw_update
from repro.optim.schedules import SCHEDULES
from repro.launch.sharding_rules import params_shardings


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    schedule: str = "cosine"  # cosine | wsd (minicpm)
    warmup: int = 100
    total_steps: int = 1000
    microbatches: int = 1
    adamw: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    aux_weight: float = 0.01


def train_pctx(mesh: Optional[Mesh]) -> PartitionCtx:
    from repro.core.phase_engine import _mesh_axes

    return PartitionCtx(mesh=mesh, axes=_mesh_axes(mesh), rules=dict(TRAIN_RULES))


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh: Optional[Mesh] = None) -> Callable:
    """Returns train_step(params, opt_state, batch, step) -> (params, opt, metrics)."""
    api = get_model(cfg)
    pctx = train_pctx(mesh)
    sched = SCHEDULES[tcfg.schedule]

    def loss_of(params, batch):
        loss, metrics = api.loss_fn(params, batch, cfg, pctx, aux_weight=tcfg.aux_weight)
        return loss, metrics

    def grads_of(params, batch):
        if tcfg.microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(params, batch)
            return loss, metrics, grads

        def mb_slice(b, i):
            n = tcfg.microbatches
            return jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(x, i * (x.shape[0] // n), x.shape[0] // n, 0),
                b,
            )

        def body(carry, i):
            loss_acc, grads_acc = carry
            (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, mb_slice(batch, i)
            )
            grads_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), grads_acc, grads)
            return (loss_acc + loss, grads_acc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), _ = jax.lax.scan(
            body, (jnp.float32(0), zeros), jnp.arange(tcfg.microbatches)
        )
        n = tcfg.microbatches
        grads = jax.tree.map(lambda g: (g / n), grads)
        return loss_sum / n, {"nll": loss_sum / n}, grads

    def train_step(params, opt_state: AdamWState, batch, step):
        loss, metrics, grads = grads_of(params, batch)
        lr = sched(step, peak_lr=tcfg.lr, warmup=tcfg.warmup, total=tcfg.total_steps)
        params, opt_state, om = adamw_update(grads, opt_state, params, lr, tcfg.adamw)
        out_metrics = {"loss": loss, "lr": lr, **{k: v for k, v in metrics.items() if v.ndim == 0}, **om}
        return params, opt_state, out_metrics

    return train_step


def jit_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    mesh: Optional[Mesh],
    params_abstract: Any,
    *,
    donate: bool = True,
):
    """AOT-ready jitted step with full in/out shardings (dry-run entry)."""
    step_fn = make_train_step(cfg, tcfg, mesh)
    if mesh is None:
        return jax.jit(step_fn, donate_argnums=(0, 1) if donate else ())

    psh = params_shardings(params_abstract, cfg, mesh, train=True)
    opt_abstract = jax.eval_shape(adamw_init, params_abstract)
    osh = AdamWState(
        step=NamedSharding(mesh, P()),
        mu=psh,
        nu=psh,
    )
    pctx = train_pctx(mesh)
    batch_sh = {
        "tokens": pctx.named_sharding("batch", "seq"),
        "targets": pctx.named_sharding("batch", "seq"),
        "mask": pctx.named_sharding("batch", "seq"),
    }
    if cfg.family == "encdec":
        batch_sh["frames"] = pctx.named_sharding("batch", "seq", "embed")
    return jax.jit(
        step_fn,
        in_shardings=(psh, osh, batch_sh, NamedSharding(mesh, P())),
        out_shardings=(psh, osh, None),
        donate_argnums=(0, 1) if donate else (),
    )


def init_train_state(cfg: ModelConfig, key, mesh: Optional[Mesh] = None, dtype=jnp.float32):
    api = get_model(cfg)
    params = api.init(cfg, key, dtype=dtype)
    opt = adamw_init(params)
    if mesh is not None:
        psh = params_shardings(params, cfg, mesh, train=True)
        params = jax.tree.map(jax.device_put, params, psh)
        opt = AdamWState(
            step=jax.device_put(opt.step, NamedSharding(mesh, P())),
            mu=jax.tree.map(jax.device_put, opt.mu, psh),
            nu=jax.tree.map(jax.device_put, opt.nu, psh),
        )
    return params, opt
