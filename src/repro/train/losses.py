"""Cross-entropy without materializing (B, S, V) logits.

At assigned-architecture scale the full logits tensor is the classic OOM:
qwen2.5-14b train_4k would need 256 x 4096 x 152064 x 4 B ≈ 638 TB.  The loss
scans over sequence chunks; each chunk's logits live only inside the scan
body (recomputed in backward), so the live set is (B, chunk, V_shard).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.sharding import NULL_CTX, PartitionCtx


def chunked_ce_loss(
    x: jax.Array,  # (B, S, d) final hidden states
    head: jax.Array,  # (d, Vp)
    targets: jax.Array,  # (B, S)
    mask: jax.Array,  # (B, S)
    pctx: PartitionCtx = NULL_CTX,
    chunk: int = 256,
) -> jax.Array:
    b, s, d = x.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = (s + pad) // chunk
    xs = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
    ts = jnp.moveaxis(targets.reshape(b, nc, chunk), 1, 0)
    ms = jnp.moveaxis(mask.reshape(b, nc, chunk), 1, 0)

    def body(acc, inp):
        xc, tc, mc = inp
        logits = xc.astype(jnp.float32) @ head.astype(jnp.float32)  # (B, chunk, Vp)
        logits = pctx.shard(logits, "batch", "seq", "vocab")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((lse - tgt) * mc), None

    body = jax.checkpoint(body)
    total, _ = jax.lax.scan(body, jnp.float32(0), (xs, ts, ms))
    return total / jnp.maximum(jnp.sum(mask), 1)
