from repro.train.trainer import TrainConfig, make_train_step, jit_train_step, init_train_state, train_pctx
