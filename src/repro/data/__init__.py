from repro.data.pipeline import DataConfig, data_iterator, make_source
