"""Deterministic, restart-safe data pipeline.

Two sources behind one interface:

* ``SyntheticSource`` — stateless PRNG stream: batch(step) is a pure function
  of (seed, step), so restart-at-step-N is exact with zero bookkeeping and
  every host materializes only its own shard.
* ``TextFileSource``  — byte-level tokens from a local corpus, packed into
  fixed-length sequences; position is derived from step (deterministic skip).

Batches are (tokens, targets, mask) int32 arrays of shape (B, S); the loader
yields numpy so the caller controls device placement/sharding.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    source: str = "synthetic"  # synthetic | textfile
    path: Optional[str] = None
    # host sharding: this host materializes rows [host_id::num_hosts]
    host_id: int = 0
    num_hosts: int = 1


class SyntheticSource:
    """Zipf-ish token stream with local n-gram structure (so loss can drop)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step, cfg.host_id))
        b_loc = cfg.batch // cfg.num_hosts
        # zipf-distributed unigrams with a deterministic bigram successor rule
        z = rng.zipf(1.3, size=(b_loc, cfg.seq_len + 1)).astype(np.int64)
        base = (z - 1) % cfg.vocab_size
        succ = (base[:, :-1] * 31 + 7) % cfg.vocab_size
        mix = rng.random((b_loc, cfg.seq_len)) < 0.5
        stream = base.copy()
        stream[:, 1:][mix] = succ[mix]
        tokens = stream[:, :-1].astype(np.int32)
        targets = stream[:, 1:].astype(np.int32)
        mask = np.ones_like(tokens, np.float32)
        return {"tokens": tokens, "targets": targets, "mask": mask}


class TextFileSource:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        data = Path(cfg.path).read_bytes()
        self._tokens = np.frombuffer(data, dtype=np.uint8).astype(np.int32) % cfg.vocab_size
        assert len(self._tokens) > cfg.seq_len + 1, "corpus too small"

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        b_loc = cfg.batch // cfg.num_hosts
        n = len(self._tokens) - cfg.seq_len - 1
        rng = np.random.default_rng((cfg.seed, step, cfg.host_id))
        starts = rng.integers(0, n, size=b_loc)
        rows = np.stack([self._tokens[s : s + cfg.seq_len + 1] for s in starts])
        return {
            "tokens": rows[:, :-1],
            "targets": rows[:, 1:],
            "mask": np.ones((b_loc, cfg.seq_len), np.float32),
        }


def make_source(cfg: DataConfig):
    return TextFileSource(cfg) if cfg.source == "textfile" else SyntheticSource(cfg)


def data_iterator(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    """Deterministic iterator; restart by passing the checkpointed step."""
    src = make_source(cfg)
    step = start_step
    while True:
        yield src.batch(step)
        step += 1
