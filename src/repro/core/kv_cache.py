"""KV-cache slot manager for continuous batching.

The decode buffer is a fixed (L, B_slots, Hkv, max_len, D) allocation in the
*decode* layout (sequence-sharded, §DECODE_RULES).  Prefilled requests are
inserted into free slots by the relayout program; per-slot ``lengths`` drive
the masking inside the decode attention kernel (scalar-prefetched), so slots
of different ages batch together — exactly the paper's "decode attention
scales with the accumulated sequence length" regime, with per-slot lengths.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class SlotState:
    request_id: Optional[str] = None
    length: int = 0
    max_new: int = 0
    generated: int = 0


class KVSlotManager:
    def __init__(self, n_slots: int):
        self.slots: List[SlotState] = [SlotState() for _ in range(n_slots)]

    @property
    def n_slots(self) -> int:
        return len(self.slots)

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.request_id is None]

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.request_id is not None]

    def assign(self, request_id: str, length: int, max_new: int) -> int:
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free KV slots")
        i = free[0]
        self.slots[i] = SlotState(request_id, length, max_new, 0)
        return i

    def release(self, slot: int) -> None:
        """Free one slot (finished or preempted request)."""
        self.slots[slot] = SlotState()

    def step(self, finished_cb=None) -> None:
        """Advance all active slots by one generated token; free finished."""
        for i, s in enumerate(self.slots):
            if s.request_id is None:
                continue
            s.length += 1
            s.generated += 1
            if s.generated >= s.max_new:
                if finished_cb:
                    finished_cb(i, s)
                self.release(i)

    def lengths_array(self) -> jnp.ndarray:
        return jnp.asarray([s.length for s in self.slots], jnp.int32)

    def active_mask(self) -> jnp.ndarray:
        return jnp.asarray([s.request_id is not None for s in self.slots], bool)


def insert_prefill_kv(cache, prefill_kv, slot: int, seq_len: int):
    """Write a prefilled request's relayouted KV into cache slot ``slot``.

    cache leaves: (B_slots, L, ...) — decode layout, batch-leading;
    prefill_kv leaves: (1, L, ...) already padded to max_len and transposed
    by the relayout program.  Under ``kv_dtype`` quantization both trees
    hold ``QuantKV`` (payload + scale plane) leaves with matching structure
    — the relayout program quantized on write — so the same slot-leading
    dynamic_update_slice installs payload and scales together.
    """

    def ins(buf, new):
        return jax.lax.dynamic_update_slice_in_dim(buf, new.astype(buf.dtype), slot, axis=0)

    return jax.tree.map(ins, cache, prefill_kv)
