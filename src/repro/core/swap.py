"""Latency-overlapped logic swap (paper §3.4, Fig. 5 — contribution C5).

The paper's observation: prefill attention hardware is dead the moment the
*last layer's* attention finishes, while the remaining prefill work (last
O-projection + FFN + logits) still takes ~31 ms; starting the ~45 ms
reconfiguration at that point hides ~75 % of it.

TPU mapping: the swap cost is the ``kv_relayout`` program (reshard prefill
KV into the decode cache layout).  JAX dispatch is asynchronous — and
``kv_relayout`` depends only on ``prefill_body`` outputs, so dispatching it
*before* ``prefill_tail`` lets the runtime overlap the two (on TPU they run
back-to-back on independent buffers; the relayout's collectives overlap the
tail's compute).  Decode starts only after both complete — the paper's
conservative correctness rule.

``SwapTiming`` records both the measured wall-clock on this host and the
modeled v5e latencies from the roofline reports, so benchmarks can report
the overlap win on target hardware (Fig. 5 analogue).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional, Tuple

import jax


@dataclasses.dataclass
class SwapTiming:
    t_body: float = 0.0
    t_tail: float = 0.0
    t_relayout: float = 0.0
    t_total_overlapped: float = 0.0
    t_total_serialized: float = 0.0

    @property
    def hidden_fraction(self) -> float:
        """Fraction of the swap latency hidden by the tail (paper: ~75 %)."""
        exposed = max(self.t_total_overlapped - self.t_body - self.t_tail, 0.0)
        if self.t_relayout <= 0:
            return 0.0
        return max(0.0, 1.0 - exposed / self.t_relayout)


@dataclasses.dataclass
class SwapAggregates:
    """Running aggregates over every ``SwapTiming`` ever recorded.

    ``EngineStats`` keeps only a rolling window of raw timings (unbounded
    growth over a long serving run was a leak); these sums survive the
    window and are what the swap-cost-aware scheduling policy consults —
    the measured-history analogue of the paper's 45 ms PCAP bitstream-load
    budget (a modeled roofline figure can override them, see
    ``SwapCostAwarePolicy``).
    """

    count: int = 0
    sum_cost: float = 0.0  # exposed (decode-visible) swap latency
    sum_hidden_fraction: float = 0.0

    @staticmethod
    def exposed_cost(t: SwapTiming) -> float:
        """Decode-visible latency of one swap: the part of the relayout the
        prefill tail failed to hide (overlapped runs), or the full measured
        relayout (serialized runs)."""
        if t.t_total_overlapped:
            return max(t.t_total_overlapped - t.t_body - t.t_tail, 0.0)
        return t.t_relayout

    def update(self, t: SwapTiming) -> None:
        self.count += 1
        self.sum_cost += self.exposed_cost(t)
        self.sum_hidden_fraction += t.hidden_fraction

    @property
    def mean_cost(self) -> float:
        return self.sum_cost / self.count if self.count else 0.0

    @property
    def mean_hidden_fraction(self) -> float:
        return self.sum_hidden_fraction / self.count if self.count else 0.0


class SwapController:
    """Temporal PD swap for one engine (the paper's single-RP mode)."""

    def __init__(
        self,
        prefill_body: Callable,
        prefill_tail: Callable,
        kv_relayout: Callable,
        *,
        conservative: bool = True,
    ):
        self.prefill_body = prefill_body
        self.prefill_tail = prefill_tail
        self.kv_relayout = kv_relayout
        self.conservative = conservative

    def prefill_and_swap(
        self, params, tokens, *, overlap: bool = True
    ) -> Tuple[Any, Any, SwapTiming]:
        """Returns (last_logits, decode_cache, timing).

        overlap=False serializes relayout after the tail (the ablation the
        Fig. 5 benchmark measures against).
        """
        timing = SwapTiming()
        t0 = time.perf_counter()
        x_mid, kv = self.prefill_body(params, tokens)
        jax.block_until_ready(x_mid)
        timing.t_body = time.perf_counter() - t0

        if overlap:
            # Dispatch the swap FIRST: it depends only on `kv`, so it can run
            # concurrently with the tail (async dispatch; on TPU the relayout
            # collectives overlap the tail's FFN compute).
            t1 = time.perf_counter()
            cache = self.kv_relayout(kv)
            logits = self.prefill_tail(params, x_mid)
            jax.block_until_ready(logits)
            timing.t_tail = time.perf_counter() - t1
            jax.block_until_ready(cache)  # conservative: decode waits for swap
            timing.t_total_overlapped = time.perf_counter() - t0
        else:
            t1 = time.perf_counter()
            logits = self.prefill_tail(params, x_mid)
            jax.block_until_ready(logits)
            timing.t_tail = time.perf_counter() - t1
            t2 = time.perf_counter()
            cache = self.kv_relayout(kv)
            jax.block_until_ready(cache)
            timing.t_relayout = time.perf_counter() - t2
            timing.t_total_serialized = time.perf_counter() - t0
        return logits, cache, timing

    def measure_both(self, params, tokens) -> SwapTiming:
        """One serialized + one overlapped run, merged into a single record."""
        _, _, ser = self.prefill_and_swap(params, tokens, overlap=False)
        _, _, ovl = self.prefill_and_swap(params, tokens, overlap=True)
        ser.t_total_overlapped = ovl.t_total_overlapped
        ser.t_body, ser.t_tail = ovl.t_body, ovl.t_tail
        return ser
