"""Three-term roofline analysis from compiled XLA artifacts (§Roofline).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

``cost_analysis()`` of an SPMD-partitioned executable reports the *per-device*
program, so we take flops/bytes per device and divide by per-chip peaks
(equivalent to the global/(chips x peak) formulation).  collective_bytes is
not in cost_analysis — we parse the optimized HLO and sum *operand* sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

from repro.common.hardware import DEFAULT_CHIP, ChipSpec

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# ------------------------------------------------ KV-dtype decode bound --
#
# The paper's Eq. (5) decode bound is KV bytes streamed per token; the
# quantized KV-cache subsystem (repro.quant.kv_quant) changes the bytes-per-
# cached-token coefficient, so the analytic bound is parameterized by
# kv_dtype here and every consumer (DSE coefficients, benchmarks, the
# roofline report note) shifts together.  The bit widths come from the
# storage implementation itself — one source of truth for the format.

from repro.quant.kv_quant import KV_DTYPE_BITS, SCALE_BITS as KV_SCALE_BITS  # noqa: E402


def kv_bytes_per_ctx_token(cfg, kv_dtype: str = "fp", *, include_scales: bool = True) -> float:
    """Bytes of ONE cached token (K + V, all layers) streamed per decode
    step — the Eq. (5) bandwidth coefficient.  Quantized dtypes add the
    fp32 scale row per (layer, head, token) unless ``include_scales=False``
    (the payload-only figure the 2x/4x headline ratios quote)."""
    if kv_dtype not in KV_DTYPE_BITS:
        raise ValueError(f"kv_dtype must be one of {sorted(KV_DTYPE_BITS)}, got {kv_dtype!r}")
    kv_heads = 0 if getattr(cfg, "attention_free", False) else cfg.num_kv_heads
    payload = 2 * cfg.num_layers * kv_heads * cfg.head_dim * KV_DTYPE_BITS[kv_dtype] / 8
    scales = 0.0
    if kv_dtype != "fp" and include_scales:
        scales = 2 * cfg.num_layers * kv_heads * KV_SCALE_BITS / 8
    return payload + scales


def decode_kv_stream_time(cfg, context: int, kv_dtype: str = "fp",
                          chip: ChipSpec = DEFAULT_CHIP) -> float:
    """Eq. (5) KV-bandwidth term: seconds per decoded token spent streaming
    the accumulated cache at ``context`` tokens, at the given precision."""
    return predict_phase("decode", cfg, context=context, kv_dtype=kv_dtype,
                         chip=chip).t_per_token


def expected_accept_length(k: int, accept_rate: float) -> float:
    """Expected tokens emitted per speculative verify round with draft
    depth ``k`` and per-token acceptance probability ``accept_rate``
    (i.i.d. geometric model): ``1 + p + ... + p^k = (1 - p^{k+1})/(1 - p)``
    — the confirmed draft prefix plus the correction/bonus token.  Ranges
    from 1 (p = 0: every round degenerates to plain decode) to ``k + 1``
    (p = 1).  The measured analogue is ``EngineStats.tokens_per_round()``."""
    if k <= 0:
        return 1.0
    p = min(max(float(accept_rate), 0.0), 1.0)
    if p >= 1.0:
        return float(k + 1)
    return (1.0 - p ** (k + 1)) / (1.0 - p)


# ------------------------------------------------ static phase prediction --
#
# The per-phase analytic bounds as COUNTABLE quantities (flops, bytes), not
# just seconds: ``repro.analysis.progcheck`` audits traced phase programs
# against exactly these numbers, and ``repro.obs.drift`` converts the same
# numbers into the residency ratios it exports — one prediction consumed by
# both the static gate and the runtime drift metric, so the bound can never
# drift from the code that enforces it.

@dataclasses.dataclass(frozen=True)
class PhasePrediction:
    """Static roofline prediction for one serving phase.

    ``flops`` is useful FLOPs per prefill token (2N) or 0 for the
    KV-bound phases; ``hbm_bytes`` is KV bytes streamed per round (batch x
    context x the Eq. (5) coefficient) or 0 for prefill; ``t_per_token``
    is the roofline bound in seconds per EMITTED token (speculation
    divides by the expected acceptance length)."""
    phase: str  # "prefill" | "decode" | "spec_verify"
    flops: float
    hbm_bytes: float
    t_per_token: float
    kv_dtype: str = "fp"


def predict_phase(
    phase: str,
    cfg=None,
    *,
    n_params: float = 0.0,
    context: float = 0.0,
    kv_dtype: str = "fp",
    batch: int = 1,
    k: int = 0,
    accept_rate: float = 0.0,
    chip: ChipSpec = DEFAULT_CHIP,
) -> PhasePrediction:
    """The static-prediction API behind ``prefill_compute_time`` /
    ``decode_kv_stream_time[_speculative]``:

    * ``prefill`` — compute-bound: ``flops = 2 * n_params`` per token,
      ``t = flops / peak`` (``cfg`` unused);
    * ``decode`` — KV-stream-bound: ``hbm_bytes = batch * context *
      kv_bytes_per_ctx_token(cfg, kv_dtype)`` per round, ``t`` = one slot's
      stream over HBM bandwidth (slots overlap on the same stream);
    * ``spec_verify`` — decode's bytes, ``t`` divided by
      ``expected_accept_length(k, accept_rate)`` (one stream, k+1 scored
      positions)."""
    if phase == "prefill":
        flops = 2.0 * float(n_params)
        return PhasePrediction(phase, flops, 0.0, flops / chip.peak_flops_bf16,
                               kv_dtype)
    if phase not in ("decode", "spec_verify"):
        raise ValueError(
            f"phase must be prefill | decode | spec_verify, got {phase!r}")
    per_token = kv_bytes_per_ctx_token(cfg, kv_dtype)
    stream = per_token * float(context)
    t = stream / chip.hbm_bw
    if phase == "spec_verify":
        t /= expected_accept_length(k, accept_rate)
    return PhasePrediction(phase, 0.0, batch * stream, t, kv_dtype)


def decode_kv_stream_time_speculative(
    cfg, context: int, k: int, accept_rate: float, kv_dtype: str = "fp",
    chip: ChipSpec = DEFAULT_CHIP,
) -> float:
    """Eq. (5) amortized by speculative decoding: one verify round streams
    the KV cache ONCE and emits ``expected_accept_length(k, accept_rate)``
    tokens, so the per-token KV-bandwidth bound divides by the expected
    acceptance length.  This is the bound the DSE coefficients consume
    (``repro.core.dse.run_dse(spec_k=..., spec_accept_rate=...)``) and the
    roofline report's verify-bound note prints per kv_dtype — the verify
    pass reads the same packed bytes decode does, so the quantized-KV and
    speculative levers multiply."""
    return predict_phase("spec_verify", cfg, context=context, k=k,
                         accept_rate=accept_rate, kv_dtype=kv_dtype,
                         chip=chip).t_per_token


def prefill_compute_time(n_params: float, chip: ChipSpec = DEFAULT_CHIP) -> float:
    """Compute-roofline seconds per PREFILL token: the forward pass does
    ~2 FLOPs per parameter per token (6N counts the backward pass too), so
    a compute-bound prefill streams tokens no faster than
    ``2 N_params / peak``.  The measured analogue is
    ``EngineStats.t_prefill / prefill_tokens``."""
    return predict_phase("prefill", n_params=n_params, chip=chip).t_per_token


def roofline_residency(bound_s: float, measured_s: float) -> float:
    """bound / measured — the fraction of the phase's roofline the engine
    actually achieves (1.0 = at the bound; small = drifted far above it).
    0.0 when nothing was measured, so exporters can emit it unconditionally."""
    if measured_s <= 0.0:
        return 0.0
    return float(bound_s) / float(measured_s)


def decode_arithmetic_intensity(cfg, kv_dtype: str = "fp") -> float:
    """Attention FLOPs per KV byte streamed in decode (flops/byte).

    Per context token the decode RM does 2 flops (QK^T) + 2 flops (PV) per
    query head per head_dim element; shrinking the KV bytes raises the
    intensity, moving the kernel up the bandwidth roofline.
    """
    kv_heads = 0 if getattr(cfg, "attention_free", False) else cfg.num_kv_heads
    if kv_heads == 0:
        return 0.0
    flops = 4 * cfg.num_layers * cfg.num_heads * cfg.head_dim
    return flops / kv_bytes_per_ctx_token(cfg, kv_dtype)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s+)?[a-z0-9\[\],{}() ]*?\b(" + "|".join(COLLECTIVE_OPS) + r")(?:-start|-done)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from optimized HLO text."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # async pair: count the -start only
        kind = m.group(1)
        shapes = _SHAPE_RE.findall(line)
        if not shapes:
            continue
        # first shape(s) describe the result; the operands are the shapes that
        # appear inside the parens.  Conservative + simple: the operands of a
        # collective are the shapes after the op name.
        paren = line[m.end() - 1 :]
        operand_shapes = _SHAPE_RE.findall(paren)
        if not operand_shapes:  # fallback: use result shape
            operand_shapes = shapes[:1]
        out[kind] += sum(_shape_bytes(dt, dims) for dt, dims in operand_shapes)
    return out


@dataclasses.dataclass
class RooflineReport:
    name: str
    chips: int
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, int]
    peak_memory_per_device: Optional[float]
    # the three terms, in seconds
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    model_flops: float = 0.0  # 6*N*D (or 6*N_active*D) global
    chip: str = DEFAULT_CHIP.name
    extras: dict = dataclasses.field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory, "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline lower bound on step time (terms overlap perfectly)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS_global — catches remat/redundancy waste."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-resource roofline achieved by *useful*
        work: MODEL_FLOPS/(chips*peak) over the step's roofline bound."""
        if self.t_bound == 0:
            return 0.0
        chip = DEFAULT_CHIP
        t_ideal = self.model_flops / (self.chips * chip.peak_flops_bf16)
        return t_ideal / self.t_bound

    def row(self) -> dict:
        return {
            "name": self.name,
            "chips": self.chips,
            "flops/dev": self.flops_per_device,
            "hbm_bytes/dev": self.hbm_bytes_per_device,
            "coll_bytes/dev": self.collective_bytes_per_device,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_frac": self.useful_flops_fraction,
            "peak_mem/dev": self.peak_memory_per_device,
        }


def roofline_from_artifacts(
    name: str,
    cost: Dict[str, Any],
    hlo_text: str,
    chips: int,
    *,
    model_flops: float = 0.0,
    peak_memory: Optional[float] = None,
    chip: ChipSpec = DEFAULT_CHIP,
    dtype_peak: str = "bf16",
    loop_aware: bool = True,
    kernel_cost=None,
) -> RooflineReport:
    """Three-term roofline.  ``loop_aware=True`` (default) folds while-loop
    trip counts via :mod:`repro.core.hlo_cost` — XLA's ``cost_analysis()``
    counts scan bodies ONCE, under-reporting scan-over-layers programs by
    ~num_layers x (verified in tests/test_hlo_cost.py).  The raw
    cost_analysis numbers are kept in ``extras['xla_cost_analysis']``.

    ``kernel_cost`` (a kernels.costs.KernelCost) adds the analytic
    BlockSpec-derived cost of the Pallas kernels to the (stub-lowered) HLO
    totals — the kernel-substituted roofline of the phase-specialized
    program."""
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes_from_hlo(hlo_text)
    coll_total = float(sum(coll.values()))
    extras: dict = {"xla_cost_analysis": {"flops": flops, "bytes": hbm,
                                          "collective_bytes": coll_total}}
    if loop_aware:
        from repro.core.hlo_cost import total_costs

        lc = total_costs(hlo_text)
        # flops: take the max — cost_analysis() adds elementwise flops the
        # dot-based analyzer skips, but counts loop bodies once.  bytes: the
        # loop-aware analyzer only — it folds trip counts AND projects out
        # CPU-lowering artifacts (bf16-dot upcast converts, in-place DUS/
        # scatter at update size) that cost_analysis charges at face value.
        flops = max(flops, lc["flops"])
        hbm = lc["bytes"]
        coll = {k: lc.get(f"coll_{k}", 0.0) for k in COLLECTIVE_OPS}
        coll_total = float(lc.get("collective_bytes", 0.0))
    if kernel_cost is not None:
        flops += kernel_cost.flops
        hbm += kernel_cost.hbm_bytes
        extras["kernel_flops"] = kernel_cost.flops
        extras["kernel_hbm_bytes"] = kernel_cost.hbm_bytes
        extras["kernel_vmem_bytes"] = kernel_cost.vmem_bytes
    peak_flops = chip.peak_flops_int8 if dtype_peak == "int8" else chip.peak_flops_bf16
    # ICI: a 2D-torus v5e chip drives ici_links links; a balanced collective
    # schedule streams on all of them.
    ici_bw = chip.ici_bw_per_link * chip.ici_links
    rep = RooflineReport(
        name=name,
        chips=chips,
        flops_per_device=flops,
        hbm_bytes_per_device=hbm,
        collective_bytes_per_device=coll_total,
        collective_breakdown=coll,
        peak_memory_per_device=peak_memory,
        t_compute=flops / peak_flops,
        t_memory=hbm / chip.hbm_bw,
        t_collective=coll_total / ici_bw,
        model_flops=model_flops,
        extras=extras,
    )
    return rep


def memory_analysis_bytes(compiled) -> Optional[float]:
    """Best-effort peak per-device memory from compiled.memory_analysis()."""
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    for attrs in (
        ("temp_size_in_bytes", "argument_size_in_bytes", "output_size_in_bytes"),
    ):
        try:
            total = sum(float(getattr(ma, a)) for a in attrs if hasattr(ma, a))
            if total:
                # arguments counted once (outputs usually alias/donate)
                return total
        except Exception:
            pass
    return None


def cost_analysis_dict(compiled) -> Dict[str, Any]:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}
