"""Roofline-guided design space exploration (paper §3.3, Eq. (2)-(6) — C4).

The paper allocates FPGA resources between the static projection engine and
the two attention RMs subject to

    r_proj + max(r_atten_pre, r_atten_dec) <= R_total            (Eq. 2)

and picks the configuration minimizing

    T_pre + alpha*T_dec(L_long) + (1-alpha)*T_dec(L_short)       (Eq. 6)
    s.t. T_pre <= T_pre_max,   alpha = 0.7

On TPU the shared resource is VMEM (the LUT/URAM analogue): the TLMM tiles of
the static region and the attention working set of whichever RM is loaded
time-share it.  The tunables are the kernel block shapes — prefill attention
block ``blk`` and decode KV block ``bk`` plus the TLMM tile — and the latency
models are rooflines with block-dependent *efficiency ramps*:

  * MXU efficiency grows with tile size (pipeline fill, layout overheads):
    eff_c(b) = b / (b + 64).
  * HBM streaming efficiency grows with DMA transfer size:
    eff_m(bytes) = bytes / (bytes + 96 KiB)  (~latency-bandwidth product).

T_pre(L) = P_proj*L / f_pre + P_attn*L^2 / g_pre(blk) + T_weights   (Eq. 3)
T_dec(L) = D_proj / f_dec + D_attn*L / g_dec(bk) + T_weights        (Eq. 5)

with the P/D coefficients derived from the architecture's per-token FLOPs
and bytes (and optionally re-calibrated from dry-run cost_analysis).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional

from repro.common.hardware import DEFAULT_CHIP, ChipSpec
from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DseConfig:
    prefill_blk: int
    decode_bk: int
    tlmm_bm: int
    tlmm_bk: int
    tlmm_bn: int

    def vmem_prefill(self, cfg: ModelConfig) -> int:
        d = cfg.head_dim
        b = self.prefill_blk
        # q, k, v tiles (bf16, double-buffered k/v) + m/l/acc scratch (f32)
        return 2 * (b * d) + 2 * 2 * (2 * b * d) + 4 * (2 * b * 128 + b * d)

    def vmem_decode(self, cfg: ModelConfig) -> int:
        d = cfg.head_dim
        g = max(cfg.q_group, 1)
        # q pinned + double-buffered K and V streams + scratch
        return 2 * (g * d) + 2 * 2 * (2 * self.decode_bk * d) + 4 * (2 * g * 128 + g * d)

    def vmem_static(self) -> int:
        # TLMM tiles: x (int8) + packed w (uint8/4) + acc (int32), dbl-buffered
        return 2 * (self.tlmm_bm * self.tlmm_bk) + 2 * (self.tlmm_bk // 4 * self.tlmm_bn) + 4 * self.tlmm_bm * self.tlmm_bn


@dataclasses.dataclass
class DsePoint:
    config: DseConfig
    t_pre: float
    t_dec_short: float
    t_dec_long: float
    objective: float
    vmem_bytes: int
    feasible: bool
    note: str = ""


def _eff_compute(block: int) -> float:
    return block / (block + 64.0)


def _eff_mem(bytes_per_transfer: float) -> float:
    return bytes_per_transfer / (bytes_per_transfer + 96 * 1024.0)


@dataclasses.dataclass
class ArchCoefficients:
    """P_proj/P_attn/D_proj/D_attn of Eq. (3)/(5), per token (per chip)."""

    proj_flops_per_tok: float  # dense projection+FFN flops per token
    attn_flops_per_tok_per_ctx: float  # attention flops per token per context token
    proj_bytes_per_tok_dec: float  # weight bytes streamed per decode token
    kv_bytes_per_tok_per_ctx: float  # KV bytes per decode token per context token
    weights_bytes: float  # resident weights (T_weights analogue: one full stream)

    @staticmethod
    def from_config(cfg: ModelConfig, chips: int = 1, kv_dtype: str = "fp") -> "ArchCoefficients":
        from repro.core.roofline import kv_bytes_per_ctx_token

        n_active = cfg.active_param_count()
        wbytes = 0.25 if cfg.quant.ternary else 2.0
        # Eq. (5) KV coefficient, parameterized by cache precision: int8/int4
        # payload + fp32 scale rows (repro.core.roofline owns the arithmetic)
        kv_per_tok = kv_bytes_per_ctx_token(cfg, kv_dtype)
        attn_flops = 0 if cfg.attention_free else 4 * cfg.num_layers * cfg.num_heads * cfg.head_dim
        return ArchCoefficients(
            proj_flops_per_tok=2 * n_active / chips,
            attn_flops_per_tok_per_ctx=attn_flops / chips,
            proj_bytes_per_tok_dec=n_active * wbytes / chips,
            kv_bytes_per_tok_per_ctx=kv_per_tok / chips,
            weights_bytes=n_active * wbytes / chips,
        )


def t_prefill(co: ArchCoefficients, cfg_p: DseConfig, length: int, chip: ChipSpec = DEFAULT_CHIP) -> float:
    d = 128
    f_pre = chip.peak_flops_int8 * _eff_compute(cfg_p.tlmm_bm)  # int8 TLMM
    g_pre = chip.peak_flops_bf16 * _eff_compute(cfg_p.prefill_blk)
    t_w = co.weights_bytes / chip.hbm_bw  # one pass over resident weights
    return co.proj_flops_per_tok * length / f_pre + co.attn_flops_per_tok_per_ctx * length**2 / g_pre + t_w


def t_decode(co: ArchCoefficients, cfg_p: DseConfig, context: int, chip: ChipSpec = DEFAULT_CHIP,
             tokens_per_round: float = 1.0) -> float:
    """Per-token decode latency at a given context (Eq. 5).

    ``tokens_per_round`` amortizes the weight + KV stream over a
    speculative verify round's expected emitted tokens
    (``repro.core.roofline.expected_accept_length``): the round streams
    weights and cache ONCE whatever the draft depth — the verify FLOPs for
    the extra k positions ride in the bandwidth shadow on a memory-bound
    fabric — so the per-token bound divides by the expected acceptance
    length.  1.0 (the default) is plain decode."""
    d = 128
    f_dec = chip.hbm_bw * _eff_mem(256 * 1024)  # weight streaming, big transfers
    kv_transfer = cfg_p.decode_bk * d * 2
    g_dec = chip.hbm_bw * _eff_mem(kv_transfer)
    per_round = co.proj_bytes_per_tok_dec / f_dec + co.kv_bytes_per_tok_per_ctx * context / g_dec
    return per_round / max(tokens_per_round, 1.0)


def run_dse(
    cfg: ModelConfig,
    *,
    chips: int = 1,
    alpha: float = 0.7,
    l_short: int = 128,
    l_long: int = 2048,
    prefill_len: int = 512,
    t_pre_max: Optional[float] = None,
    chip: ChipSpec = DEFAULT_CHIP,
    static_baseline: bool = False,
    kv_dtype: str = "fp",
    spec_k: int = 0,
    spec_accept_rate: float = 0.0,
) -> List[DsePoint]:
    """Enumerate the space; returns points sorted by Eq. (6) objective.

    static_baseline=True models the paper's static-accelerator comparison:
    ONE attention configuration serves both phases, so the constraint
    becomes r_proj + r_pre + r_dec <= R (both RMs resident) and blk == bk.
    ``kv_dtype`` shifts the Eq. (5) KV coefficient (quantized cache);
    ``spec_k``/``spec_accept_rate`` amortize the decode terms over the
    expected speculative acceptance length (prompt-lookup verify rounds) —
    the two levers compose multiplicatively.
    """
    co = ArchCoefficients.from_config(cfg, chips, kv_dtype)
    from repro.core.roofline import expected_accept_length

    tokens_per_round = expected_accept_length(spec_k, spec_accept_rate)
    points: List[DsePoint] = []
    blks = [128, 256, 512]
    bks = [128, 256, 512, 1024, 2048]
    tlmms = [(128, 512, 128), (256, 512, 256), (128, 1024, 256)]
    for blk, bk, (tm, tk, tn) in itertools.product(blks, bks, tlmms):
        if static_baseline and blk != bk:
            continue
        p = DseConfig(blk, bk, tm, tk, tn)
        if static_baseline:
            vmem = p.vmem_static() + p.vmem_prefill(cfg) + p.vmem_decode(cfg)  # both resident
        else:
            vmem = p.vmem_static() + max(p.vmem_prefill(cfg), p.vmem_decode(cfg))  # Eq. (2)
        feasible = vmem <= chip.vmem_bytes
        tp = t_prefill(co, p, prefill_len, chip)
        td_s = t_decode(co, p, l_short, chip, tokens_per_round)
        td_l = t_decode(co, p, l_long, chip, tokens_per_round)
        if t_pre_max is not None and tp > t_pre_max:
            feasible = False
        obj = tp + alpha * td_l + (1 - alpha) * td_s  # Eq. (6)
        points.append(DsePoint(p, tp, td_s, td_l, obj, vmem, feasible))
    points.sort(key=lambda x: (not x.feasible, x.objective))
    return points


def best_config(cfg: ModelConfig, **kw) -> DseConfig:
    pts = run_dse(cfg, **kw)
    for p in pts:
        if p.feasible:
            return p.config
    return pts[0].config
