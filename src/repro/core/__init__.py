"""PD-Swap core: phase-specialized engines, logic-swap controller, DSE."""
from repro.core.phase_engine import PhaseEngine, PhaseProgram, make_pctx
from repro.core.swap import SwapController, SwapTiming
from repro.core.kv_cache import KVSlotManager, insert_prefill_kv
from repro.core.dse import run_dse, best_config, DseConfig, DsePoint
from repro.core.roofline import (
    RooflineReport,
    roofline_from_artifacts,
    collective_bytes_from_hlo,
    cost_analysis_dict,
    memory_analysis_bytes,
)
from repro.core.disagg import split_pod_meshes, DisaggCostModel
