"""Trip-count-aware cost accounting over optimized HLO text.

XLA's ``HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports) counts
while-loop bodies ONCE — under scan-over-layers that under-reports FLOPs,
bytes and collective traffic by the trip count (verified experimentally in
tests/test_hlo_cost.py).  Fortunately the optimized HLO annotates every scan
loop with ``backend_config={"known_trip_count":{"n":...}}``.

This analyzer parses the module into computations with a per-computation
symbol table (op name -> result shape), accounts per-op costs:

  * FLOPs: dot ops — 2 x elems(result) x prod(lhs contracting dims)
  * bytes: result + operand bytes of memory-relevant ops (fusion call sites,
    dots, copies, gathers, slices, collectives, ...)
  * collective bytes: operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (per kind)

and folds the call graph bottom-up, multiplying while bodies by their known
trip counts (nested scans multiply).  Fusion-computation internals count for
FLOPs only — their memory traffic is the fusion call site's operands.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_MEM_OPS = set(
    (
        "fusion", "dot", "convolution", "copy", "gather", "scatter",
        "dynamic-slice", "dynamic-update-slice", "reduce", "sort", "transpose",
        "reshape", "broadcast", "iota", "concatenate", "pad", "slice",
        "select-and-scatter", "reduce-window", "custom-call", "cholesky",
        "triangular-solve", "rng", "convert", "bitcast-convert",
    )
) | set(COLLECTIVES)

_SKIP_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "opt-barrier",
}


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(type_text: str) -> int:
    """Total bytes of a (possibly tuple) type string."""
    return sum(
        _shape_elems(dims) * _DTYPE_BYTES.get(dt, 0)
        for dt, dims in _SHAPE_RE.findall(type_text)
    )


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    result_type: str
    operands: List[str]
    attrs: str
    operand_text: str = ""


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    calls: List[Tuple[str, float]] = dataclasses.field(default_factory=list)  # (callee, mult)
    flops_only_calls: List[str] = dataclasses.field(default_factory=list)


def _parse_ops(block: List[str]) -> List[_Op]:
    ops = []
    for line in block:
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, body = m.group(1), m.group(2)
        oc = _OPCODE_RE.search(body)
        if not oc:
            continue
        opcode = oc.group(1)
        # result type = text before the opcode occurrence
        result_type = body[: oc.start()].strip()
        paren_start = body.index("(", oc.start())
        # operand refs inside the first balanced paren group
        depth, i = 0, paren_start
        end = len(body)
        for i in range(paren_start, len(body)):
            if body[i] == "(":
                depth += 1
            elif body[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_text = body[paren_start:end]
        attrs = body[end:]
        ops.append(_Op(name, opcode, result_type, _OPERANDS_RE.findall(operand_text), attrs, operand_text))
    return ops


def parse_hlo(hlo: str) -> Tuple[Dict[str, List[_Op]], Optional[str], set]:
    comps: Dict[str, List[str]] = {}
    entry = None
    current = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        st = line.strip()
        if current is None:
            if st.endswith("{") and "->" in st and (st.startswith("%") or st.startswith("ENTRY")):
                name_m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", st)
                if name_m:
                    current = name_m.group(1)
                    comps[current] = []
                    if st.startswith("ENTRY"):
                        entry = current
            continue
        if st == "}":
            current = None
            continue
        comps[current].append(line)

    parsed = {name: _parse_ops(block) for name, block in comps.items()}
    fusion_callees = set()
    for ops in parsed.values():
        for op in ops:
            if op.opcode in ("fusion", "reduce", "sort", "scatter", "map", "reduce-window", "select-and-scatter", "custom-call", "all-reduce", "reduce-scatter"):
                fusion_callees.update(_CALLS_RE.findall(op.attrs))
    return parsed, entry, fusion_callees


def _pure_convert_callees(parsed: Dict[str, List[_Op]]) -> set:
    """Fusion computations that are pure dtype converts (convert/bitcast/
    copy-free elementwise casts).  On TPU these never materialize — the MXU
    consumes bf16 directly and the VPU converts in-register — but XLA:CPU
    rewrites every bf16 dot as convert-to-f32 + f32 dot and LICM hoists the
    converts (for a KV cache that is a whole-buffer f32 copy).  Counting
    them would charge the TPU roofline for a CPU-lowering artifact."""
    out = set()
    for name, ops in parsed.items():
        body = [o for o in ops if o.opcode not in _SKIP_OPS]
        if body and all(o.opcode in ("convert", "bitcast-convert", "broadcast") for o in body):
            out.add(name)
    return out


def _fusion_param_reads(callee_ops: List[_Op], n_params: int) -> Optional[Dict[int, float]]:
    """Per-parameter effective read bytes for a fusion computation.

    A fusion whose parameter is consumed ONLY by dynamic-slice/slice ops
    reads just the sliced window from HBM, not the whole operand (the
    classic scan-over-layers pattern: slice layer l from the stacked cache).
    Returns {param_index: bytes} for parameters where the cap applies."""
    # map param name -> index: the N of "parameter(N)" sits in operand_text
    idx_of = {}
    for op in callee_ops:
        if op.opcode == "parameter":
            digits = op.operand_text.strip("() ")
            if digits.isdigit():
                idx_of[op.name] = int(digits)
    if not idx_of:
        return None
    reads: Dict[int, float] = {}
    for pname, pidx in idx_of.items():
        uses = [o for o in callee_ops if pname in o.operands and o.opcode != "parameter"]
        if uses and all(u.opcode in ("dynamic-slice", "slice") for u in uses):
            reads[pidx] = sum(_type_bytes(u.result_type) for u in uses)
    return reads or None


def _comp_cost(
    name: str,
    ops: List[_Op],
    is_fusion: bool,
    parsed: Optional[Dict[str, List[_Op]]] = None,
    convert_callees: Optional[set] = None,
) -> CompCost:
    shapes = {op.name: op.result_type for op in ops}
    parsed = parsed or {}
    convert_callees = convert_callees or set()
    # ops that are free dtype casts: resolve operands through them so
    # consumers charge the ORIGINAL width
    alias: Dict[str, str] = {}
    for op in ops:
        if op.opcode == "convert" and op.operands:
            alias[op.name] = op.operands[0]
        elif op.opcode in ("fusion", "call") and op.operands:
            # newer XLA:CPU emits hoisted converts as call(%parallel_convert)
            # instead of convert-only fusions — same projection applies
            callees = _CALLS_RE.findall(op.attrs)
            if callees and all(cn in convert_callees for cn in callees):
                alias[op.name] = op.operands[0]

    def resolve(o: str) -> str:
        seen = set()
        while o in alias and o not in seen:
            seen.add(o)
            o = alias[o]
        return o

    c = CompCost()
    for op in ops:
        if op.opcode in _SKIP_OPS:
            continue
        if op.opcode == "while":
            trip = 1.0
            tm = _TRIP_RE.search(op.attrs)
            if tm:
                trip = float(tm.group(1))
            bm = _BODY_RE.search(op.attrs)
            cm = _COND_RE.search(op.attrs)
            if bm:
                c.calls.append((bm.group(1), trip))
            if cm:
                c.calls.append((cm.group(1), trip))
            continue
        if op.opcode in ("call", "conditional", "async-start"):
            if op.name in alias:
                continue  # pure dtype-cast call: free on TPU
            for callee in _CALLS_RE.findall(op.attrs):
                c.calls.append((callee, 1.0))
            for callee in re.findall(r"(?:true_computation|false_computation)=%?([\w.\-]+)", op.attrs):
                c.calls.append((callee, 1.0))
            continue
        if op.opcode in ("dot", "convolution"):
            cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
            k = 1
            if cm and op.operands:
                lhs_type = shapes.get(op.operands[0], "")
                sm = _SHAPE_RE.search(lhs_type)
                if sm:
                    lhs_dims = [int(x) for x in sm.group(2).split(",") if x]
                    for idx in (int(x) for x in cm.group(1).split(",") if x):
                        if idx < len(lhs_dims):
                            k *= lhs_dims[idx]
            c.flops += 2.0 * (_type_bytes(op.result_type) / max(_DTYPE_BYTES.get(_SHAPE_RE.search(op.result_type).group(1), 1), 1)) * k if _SHAPE_RE.search(op.result_type) else 0.0
        for kind in COLLECTIVES:
            if op.opcode.startswith(kind) and not op.opcode.endswith("-done"):
                b = sum(_type_bytes(shapes.get(o, "")) for o in op.operands)
                if b == 0:
                    b = _type_bytes(op.result_type)
                c.coll_bytes += b
                c.coll_by_kind[kind] = c.coll_by_kind.get(kind, 0.0) + b
                break
        if op.opcode == "fusion":
            for callee in _CALLS_RE.findall(op.attrs):
                c.flops_only_calls.append(callee)
        if not is_fusion and op.opcode in _MEM_OPS:
            if op.opcode in ("fusion", "convert") and op.name in alias:
                b = 0.0  # pure dtype cast: free on TPU (fuses into consumer)
            elif op.opcode in ("fusion", "scatter") and (
                "dynamic-update-slice" in op.name or "scatter" in op.name
            ):
                # In-place-update fusions (DUS / scatter roots): XLA aliases
                # the big buffer operand; real traffic is the update slice
                # (read-modify-write), not the whole KV cache.
                ob = [_type_bytes(shapes.get(resolve(o), "")) for o in op.operands]
                b = 2 * (sum(ob) - max(ob)) if ob else _type_bytes(op.result_type)
            elif op.opcode == "dynamic-update-slice":
                # In-place DUS touches only the updated slice (read-modify-
                # write), not the whole buffer — critical for KV caches.
                upd = _type_bytes(shapes.get(op.operands[1], "")) if len(op.operands) > 1 else 0
                b = 2 * upd
            elif op.opcode == "dynamic-slice":
                # Reads only the sliced window.
                b = 2 * _type_bytes(op.result_type)
            else:
                b = _type_bytes(op.result_type)
                # per-parameter effective reads: a fusion that only
                # dynamic-slices a parameter reads the window, not the buffer
                reads = None
                if op.opcode == "fusion" and parsed:
                    callees = _CALLS_RE.findall(op.attrs)
                    if len(callees) == 1 and callees[0] in parsed:
                        reads = _fusion_param_reads(parsed[callees[0]], len(op.operands))
                for i, o in enumerate(op.operands):
                    if reads and i in reads:
                        b += reads[i]
                    else:
                        b += _type_bytes(shapes.get(resolve(o), ""))
            c.bytes += b
    return c


def total_costs(hlo: str) -> Dict[str, float]:
    """Trip-count-folded totals for the entry computation, projected to TPU
    execution semantics (pure-convert fusions free, slice-only fusion reads
    window-sized, in-place DUS/scatter at update size)."""
    parsed, entry, fusion_callees = parse_hlo(hlo)
    convert_callees = _pure_convert_callees(parsed)
    costs = {
        name: _comp_cost(name, ops, name in fusion_callees, parsed, convert_callees)
        for name, ops in parsed.items()
    }
    memo: Dict[str, Tuple[float, float, float, Dict[str, float]]] = {}

    def fold(name: str, depth=0):
        if name in memo:
            return memo[name]
        c = costs.get(name)
        if c is None or depth > 64:
            return (0.0, 0.0, 0.0, {})
        memo[name] = (0.0, 0.0, 0.0, {})
        fl, by, co = c.flops, c.bytes, c.coll_bytes
        kinds = dict(c.coll_by_kind)
        for callee in c.flops_only_calls:
            cf, _, _, _ = fold(callee, depth + 1)
            fl += cf
        for callee, mult in c.calls:
            cf, cb, cc, ck = fold(callee, depth + 1)
            fl += mult * cf
            by += mult * cb
            co += mult * cc
            for k, v in ck.items():
                kinds[k] = kinds.get(k, 0.0) + mult * v
        memo[name] = (fl, by, co, kinds)
        return memo[name]

    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0}
    fl, by, co, kinds = fold(entry)
    out = {"flops": fl, "bytes": by, "collective_bytes": co}
    for k, v in kinds.items():
        out[f"coll_{k}"] = v
    return out
